"""Merge-path kernel: fused linear merge + absorb of two sorted tiles.

This is the Pallas twin of :mod:`repro.core.ordered_index`'s rank-scatter
merge, and the replacement for the bitonic-merge kernel in
:mod:`repro.kernels.merge_aggregate` on the engine's hot path.

Merge Path (Green, McColl & Bader): output lane ``k`` of the merged
sequence lies on the ``k``-th anti-diagonal of the |A|×|B| merge grid;
the crossing point ``(i, k-i)`` — "``i`` rows of A and ``k-i`` rows of B
precede output ``k``" — is found by a per-lane binary search over the
diagonal.  All ``|A|+|B|`` lanes search independently, so the whole merge
is ⌈log₂N⌉ data-parallel probe rounds followed by ONE gather, instead of
the bitonic merge's log₂(2N) full-width compare-exchange sweeps over keys
*and every payload column*.  The duplicate absorb (flag-based segmented
scan, shared with :mod:`repro.kernels.segmented_reduce`) runs fused in
the same VMEM residency, so one page absorb costs one HBM round trip.

Inputs need only be **sorted** — duplicates within either input are fine
(they stay adjacent through the merge and the scan combines them).
Keys arrive as one or two uint32 **lanes**: 32-bit keys are one lane,
64-bit keys a (hi, lo) pair compared lexicographically per lane — the
TPU path needs no native 64-bit ops.  EMPTY (= all lanes 0xFFFF_FFFF)
padding ranks to the tail like any other key.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.segmented_reduce import _lanes_eq, _lanes_empty, _lex_leq, _segmented_scan


def _merge_path_split(ka_lanes, kb_lanes):
    """Per-lane diagonal binary search.

    ka_lanes / kb_lanes: tuples of (1, N) / (1, M) uint32 key lanes (hi
    lane first), each lexicographically sorted ascending.  Returns
    ``(ia, ib, take_a)`` of shape (1, N+M): lane ``k`` of the merged
    output reads ``A[ia[k]]`` when ``take_a[k]`` else ``B[ib[k]]``
    (stable: A wins ties).
    """
    n, m = ka_lanes[0].shape[-1], kb_lanes[0].shape[-1]
    a_lanes = [k[0] for k in ka_lanes]
    b_lanes = [k[0] for k in kb_lanes]
    k = jax.lax.broadcasted_iota(jnp.int32, (1, n + m), 1)
    lo = jnp.maximum(0, k - m)  # feasible: all of B already consumed
    hi = jnp.minimum(k, n)
    # predicate g(i) = "taking i rows of A before lane k is feasible",
    # i.e. A[i-1] <= B[k-i]; monotone decreasing in i, so binary search
    # for the largest feasible i.  Boundary clauses make the comparison
    # vacuous when either side is exhausted.
    for _ in range(int(math.ceil(math.log2(max(n, m) + 1))) + 1):
        mid = (lo + hi + 1) >> 1
        a_prev = [jnp.take(a, jnp.clip(mid - 1, 0, n - 1)) for a in a_lanes]
        b_next = [jnp.take(b, jnp.clip(k - mid, 0, m - 1)) for b in b_lanes]
        ok = (mid <= 0) | (k - mid >= m) | _lex_leq(a_prev, b_next)
        lo = jnp.where(ok, mid, lo)
        hi = jnp.where(ok, hi, mid - 1)
    ia = lo
    ib = k - lo
    a_key = [jnp.take(a, jnp.clip(ia, 0, n - 1)) for a in a_lanes]
    b_key = [jnp.take(b, jnp.clip(ib, 0, m - 1)) for b in b_lanes]
    take_a = (ia < n) & ((ib >= m) | _lex_leq(a_key, b_key))
    return jnp.clip(ia, 0, n - 1), jnp.clip(ib, 0, m - 1), take_a


def _make_kernel(nlanes: int):
    def _kernel(*refs):
        ka_refs = refs[:nlanes]
        ca_ref, sa_ref, mna_ref, mxa_ref = refs[nlanes : nlanes + 4]
        kb_refs = refs[nlanes + 4 : 2 * nlanes + 4]
        cb_ref, sb_ref, mnb_ref, mxb_ref = refs[2 * nlanes + 4 : 2 * nlanes + 8]
        outs = refs[2 * nlanes + 8 :]
        ok_refs = outs[:nlanes]
        oc_ref, os_ref, omn_ref, omx_ref, ot_ref = outs[nlanes:]

        ka = tuple(k[...] for k in ka_refs)
        kb = tuple(k[...] for k in kb_refs)
        ia, ib, take_a = _merge_path_split(ka, kb)

        def sel1(xa, xb):  # (1,N)/(1,M) → (1,N+M)
            return jnp.where(take_a, jnp.take(xa[0], ia), jnp.take(xb[0], ib))

        def selv(xa, xb):  # (V,N)/(V,M) → (V,N+M); take_a broadcasts over V
            ga = jnp.take(xa, ia[0], axis=-1)
            gb = jnp.take(xb, ib[0], axis=-1)
            return jnp.where(take_a, ga, gb)

        keys = tuple(sel1(a, b) for a, b in zip(ka, kb))
        cnt = sel1(ca_ref[...], cb_ref[...])
        ssum = selv(sa_ref[0], sb_ref[0])
        smin = selv(mna_ref[0], mnb_ref[0])
        smax = selv(mxa_ref[0], mxb_ref[0])
        # absorb duplicates (segmented scan) while everything is VMEM-resident
        cnt, ssum, smin, smax, tails = _segmented_scan(keys, cnt, ssum, smin, smax)
        for o, kk in zip(ok_refs, keys):
            o[...] = kk
        oc_ref[...] = cnt
        os_ref[...] = ssum[None]
        omn_ref[...] = smin[None]
        omx_ref[...] = smax[None]
        ot_ref[...] = tails

    return _kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_path_tiles(ka, ca, sa, mna, mxa, kb, cb, sb, mnb, mxb, *,
                     interpret: bool = True):
    """Merge two sorted tile sets — (T,N)+(T,M) key lane(s), (T,V?,·)
    payloads — into (T,N+M) merged + scanned aggregates + tail mask.
    ``ka``/``kb`` are (T,N) arrays (one lane) or tuples of (T,N) uint32
    lanes (hi first) for 64-bit keys.  Unlike the bitonic kernel, N and M
    need not match (compaction by the caller, see ops.py), and the sum /
    min / max planes may have different widths."""
    ka_lanes = tuple(ka) if isinstance(ka, (tuple, list)) else (ka,)
    kb_lanes = tuple(kb) if isinstance(kb, (tuple, list)) else (kb,)
    assert len(ka_lanes) == len(kb_lanes)
    nlanes = len(ka_lanes)
    t, n = ka_lanes[0].shape
    m = kb_lanes[0].shape[-1]
    k_out = n + m
    sa_spec = pl.BlockSpec((1, n), lambda i: (i, 0))
    sb_spec = pl.BlockSpec((1, m), lambda i: (i, 0))

    def vspec(x):
        v = x.shape[1]
        w = x.shape[-1]
        return pl.BlockSpec((1, v, w), lambda i: (i, 0, 0))

    o1 = pl.BlockSpec((1, k_out), lambda i: (i, 0))

    def ovspec(v):
        return pl.BlockSpec((1, v, k_out), lambda i: (i, 0, 0))

    return pl.pallas_call(
        _make_kernel(nlanes),
        out_shape=tuple(
            jax.ShapeDtypeStruct((t, k_out), k.dtype) for k in ka_lanes
        ) + (
            jax.ShapeDtypeStruct((t, k_out), ca.dtype),
            jax.ShapeDtypeStruct((t, sa.shape[1], k_out), sa.dtype),
            jax.ShapeDtypeStruct((t, mna.shape[1], k_out), mna.dtype),
            jax.ShapeDtypeStruct((t, mxa.shape[1], k_out), mxa.dtype),
            jax.ShapeDtypeStruct((t, k_out), jnp.bool_),
        ),
        grid=(t,),
        in_specs=[sa_spec] * nlanes
        + [sa_spec, vspec(sa), vspec(mna), vspec(mxa)]
        + [sb_spec] * nlanes
        + [sb_spec, vspec(sb), vspec(mnb), vspec(mxb)],
        out_specs=tuple([o1] * nlanes) + (
            o1, ovspec(sa.shape[1]), ovspec(mna.shape[1]), ovspec(mxa.shape[1]), o1,
        ),
        interpret=interpret,
    )(*ka_lanes, ca, sa, mna, mxa, *kb_lanes, cb, sb, mnb, mxb)


def _make_probe_kernel(nlanes: int, m: int):
    def _kernel(*refs):
        ka_refs = refs[:nlanes]
        kb_refs = refs[nlanes : 2 * nlanes]
        pos_ref, hit_ref = refs[2 * nlanes :]

        a_lanes = [k[...][0] for k in ka_refs]
        b_lanes = [k[...][0] for k in kb_refs]
        n = a_lanes[0].shape[-1]
        # lower_bound per output lane: smallest j with A[i] <= B[j]
        # (monotone in j since B is sorted), by the same fixed-round
        # binary search the merge split uses — all n lanes in parallel.
        lo = jnp.zeros((n,), jnp.int32)
        hi = jnp.full((n,), m, jnp.int32)
        for _ in range(int(math.ceil(math.log2(m + 1))) + 1):
            mid = (lo + hi) >> 1
            b_mid = [jnp.take(b, jnp.clip(mid, 0, m - 1)) for b in b_lanes]
            leq = _lex_leq(a_lanes, b_mid)
            hi = jnp.where(leq, mid, hi)
            lo = jnp.where(leq, lo, mid + 1)
        pos = jnp.clip(lo, 0, m - 1)
        probed = [jnp.take(b, pos) for b in b_lanes]
        hit = _lanes_eq(a_lanes, probed) & ~_lanes_empty(a_lanes)
        pos_ref[...] = pos[None]
        hit_ref[...] = hit[None]

    return _kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_path_probe_tiles(ka, kb, *, interpret: bool = True):
    """Two-sided merge-join probe: rank-align each key of sorted tile set
    ``ka`` — (T,N) uint32 array or tuple of lanes (hi first) — against
    sorted tile set ``kb`` (T,M).  Returns ``(pos, hit)`` of shape (T,N):
    ``kb[pos[i]] == ka[i]`` where ``hit`` (EMPTY keys never hit).  The
    per-lane binary search is the probe half of the merge-path diagonal
    split; no sort and no scatter, O(log M) rounds in one VMEM residency.
    """
    ka_lanes = tuple(ka) if isinstance(ka, (tuple, list)) else (ka,)
    kb_lanes = tuple(kb) if isinstance(kb, (tuple, list)) else (kb,)
    assert len(ka_lanes) == len(kb_lanes)
    nlanes = len(ka_lanes)
    t, n = ka_lanes[0].shape
    m = kb_lanes[0].shape[-1]
    a_spec = pl.BlockSpec((1, n), lambda i: (i, 0))
    b_spec = pl.BlockSpec((1, m), lambda i: (i, 0))
    return pl.pallas_call(
        _make_probe_kernel(nlanes, m),
        out_shape=(
            jax.ShapeDtypeStruct((t, n), jnp.int32),
            jax.ShapeDtypeStruct((t, n), jnp.bool_),
        ),
        grid=(t,),
        in_specs=[a_spec] * nlanes + [b_spec] * nlanes,
        out_specs=(a_spec, a_spec),
        interpret=interpret,
    )(*ka_lanes, *kb_lanes)
