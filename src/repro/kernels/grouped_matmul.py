"""Expert-blocked (grouped) matmul — the MoE compute hot spot fed by
sort-based dispatch.

After tokens are sorted by expert id (the paper's grouping, applied to
routing) and padded to a per-expert capacity C, the activations form a
(E·C, D) matrix whose row-blocks each belong to exactly one expert.  The
kernel computes  out[e·C+i, :] = x[e·C+i, :] @ w[e, :, :]  with MXU-aligned
(bm × bk)·(bk × bn) tiles and a VMEM accumulator, walking k as the
innermost grid dimension.  Aligning the capacity C to the row-block bm
means a block never straddles experts — the index map picks w's expert
block directly from the row-block id, no scatter/gather anywhere.

Cost: 2·E·C·D·F flops; arithmetic intensity rises with bm/bn like an
ordinary matmul, so MXU utilization matches dense matmul on the padded
shape — the price of padding is the capacity factor, which the sorted
dispatch keeps near 1 by construction (tokens are contiguous per expert).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("capacity", "block_m", "block_n", "block_k", "interpret"),
)
def grouped_matmul(
    x: jax.Array,  # (E*C, D) rows sorted/padded by expert
    w: jax.Array,  # (E, D, F)
    *,
    capacity: int,  # C — rows per expert, multiple of block_m
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    ec, d = x.shape
    e, dw, f = w.shape
    assert dw == d and ec == e * capacity
    assert capacity % block_m == 0, "capacity must align to the row block"
    assert d % block_k == 0 and f % block_n == 0
    nk = d // block_k
    blocks_per_expert = capacity // block_m

    grid = (ec // block_m, f // block_n, nk)

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        out_shape=jax.ShapeDtypeStruct((ec, f), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda m, n, k: (m, k)),
            pl.BlockSpec(
                (1, block_k, block_n),
                lambda m, n, k, bpe=blocks_per_expert: (m // bpe, k, n),
            ),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda m, n, k: (m, n)),
        scratch_shapes=[  # fp32 accumulator tile in VMEM
            pltpu.VMEM((block_m, block_n), jnp.float32)
        ],
        interpret=interpret,
    )(x, w)
