"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel test sweeps shapes/dtypes and asserts allclose against these.
They are also the XLA fallback path used on CPU and inside dry-runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import EMPTY


def ref_sort(keys: jax.Array) -> jax.Array:
    """(T, N) → keys sorted along the last axis."""
    return jnp.sort(keys, axis=-1)


def ref_argsort(keys: jax.Array) -> jax.Array:
    return jnp.argsort(keys, axis=-1)


def ref_segmented_scan(keys, cnt, ssum, smin, smax):
    """Per-tile segmented inclusive scan over sorted keys.

    keys/cnt (T, N); ssum/smin/smax (T, V, N).  Returns scanned columns and
    the tail mask, like repro.kernels.segmented_reduce.segmented_scan_tiles.
    """
    t, n = keys.shape
    idx = jnp.arange(n)[None, :]
    valid = keys != EMPTY
    heads = jnp.concatenate(
        [jnp.ones((t, 1), bool), keys[:, 1:] != keys[:, :-1]], axis=1
    )
    seg = jnp.cumsum(heads, axis=1) - 1  # (T, N) segment ids

    def scan_tile(seg_t, col_t, op, init):
        # column (V, N) — segment_scan via associative ops per segment
        def f(carry, x):
            s, v = x
            new = jnp.where(s == carry[0], op(carry[1], v), v)
            return (s, new), new

        (_, _), out = jax.lax.scan(
            f, (jnp.int32(-1), jnp.full(col_t.shape[:-1], init, col_t.dtype)),
            (seg_t, jnp.moveaxis(col_t, -1, 0)),
        )
        return jnp.moveaxis(out, 0, -1)

    cnt_s = jnp.stack(
        [scan_tile(seg[i], cnt[i][None], jnp.add, 0)[0] for i in range(t)]
    )
    sum_s = jnp.stack([scan_tile(seg[i], ssum[i], jnp.add, 0.0) for i in range(t)])
    min_s = jnp.stack(
        [scan_tile(seg[i], smin[i], jnp.minimum, jnp.inf) for i in range(t)]
    )
    max_s = jnp.stack(
        [scan_tile(seg[i], smax[i], jnp.maximum, -jnp.inf) for i in range(t)]
    )
    tails = (
        jnp.concatenate([keys[:, :-1] != keys[:, 1:], jnp.ones((t, 1), bool)], axis=1)
        & valid
    )
    return cnt_s, sum_s, min_s, max_s, tails


def ref_merge_absorb(ka, ca, sa, mna, mxa, kb, cb, sb, mnb, mxb):
    """Oracle for merge_aggregate: concat → sort → segmented scan."""
    keys = jnp.concatenate([ka, kb], axis=-1)
    perm = jnp.argsort(keys, axis=-1)
    g1 = lambda x, y: jnp.take_along_axis(jnp.concatenate([x, y], -1), perm, axis=-1)
    gv = lambda x, y: jnp.take_along_axis(
        jnp.concatenate([x, y], -1), perm[:, None, :], axis=-1
    )
    keys = jnp.take_along_axis(keys, perm, axis=-1)
    return (keys,) + ref_segmented_scan(
        keys, g1(ca, cb), gv(sa, sb), gv(mna, mnb), gv(mxa, mxb)
    )


def ref_grouped_matmul(x, w, *, capacity: int):
    e = w.shape[0]
    xs = x.reshape(e, capacity, x.shape[-1])
    return jnp.einsum("ecd,edf->ecf", xs, w).reshape(e * capacity, w.shape[-1]).astype(x.dtype)
