"""Fused merge-and-absorb of two sorted tiles — the wide-merge inner loop.

One wide-merge step (§4, Fig 9) takes the resident sorted index tile and
one incoming run page, and must produce the merged, duplicate-combined
index.  Unfused, that is: concat → full sort → segmented reduce.  Fused,
we exploit that **both inputs are already sorted**: concatenating A with
reverse(B) yields a bitonic sequence, so a *single* bitonic-merge sweep
(log₂(2N) compare-exchange stages instead of the full sort's
log²-stage network) orders the union; the segmented-scan absorb then runs
in the same kernel while everything is VMEM-resident — one HBM round trip
per page instead of three.

Payload columns (count/sum/min/max) ride along through both phases.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.types import EMPTY
from repro.kernels.segmented_reduce import _segmented_scan


def _bitonic_merge(keys, cols):
    """keys (1,2N) forming a bitonic sequence; cols: list of (C,2N) arrays.
    One descending-stride sweep yields ascending order."""
    n2 = keys.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, keys.shape, 1)
    j = n2 // 2
    while j >= 1:
        upper = (idx & j) != 0
        part_hi = jnp.roll(keys, j, axis=-1)
        part_lo = jnp.roll(keys, -j, axis=-1)
        partner = jnp.where(upper, part_hi, part_lo)
        take_self = jnp.where(~upper, keys <= partner, keys >= partner)
        new_cols = []
        for c in cols:
            c_hi = jnp.roll(c, j, axis=-1)
            c_lo = jnp.roll(c, -j, axis=-1)
            c_part = jnp.where(upper, c_hi, c_lo)
            new_cols.append(jnp.where(take_self, c, c_part))
        keys = jnp.where(take_self, keys, partner)
        cols = new_cols
        j //= 2
    return keys, cols


def _kernel(ka_ref, ca_ref, sa_ref, mna_ref, mxa_ref,
            kb_ref, cb_ref, sb_ref, mnb_ref, mxb_ref,
            ok_ref, oc_ref, os_ref, omn_ref, omx_ref, ot_ref):
    # phase 1: bitonic merge of (A, reverse(B))
    keys = jnp.concatenate([ka_ref[...], kb_ref[...][:, ::-1]], axis=-1)
    cols = [
        jnp.concatenate([ca_ref[...], cb_ref[...][:, ::-1]], axis=-1),
        jnp.concatenate([sa_ref[0], sb_ref[0][:, ::-1]], axis=-1),
        jnp.concatenate([mna_ref[0], mnb_ref[0][:, ::-1]], axis=-1),
        jnp.concatenate([mxa_ref[0], mxb_ref[0][:, ::-1]], axis=-1),
    ]
    keys, cols = _bitonic_merge(keys, cols)
    cnt, ssum, smin, smax = cols
    # phase 2: absorb duplicates (segmented scan) while still in VMEM
    cnt, ssum, smin, smax, tails = _segmented_scan(keys, cnt, ssum, smin, smax)
    ok_ref[...] = keys
    oc_ref[...] = cnt
    os_ref[...] = ssum[None]
    omn_ref[...] = smin[None]
    omx_ref[...] = smax[None]
    ot_ref[...] = tails


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_absorb_tiles(ka, ca, sa, mna, mxa, kb, cb, sb, mnb, mxb, *,
                       interpret: bool = True):
    """Merge two sorted (T,N)/(T,V,N) tile sets → (T,2N) merged + scanned
    aggregates + tail mask (compaction done by the caller, see ops.py)."""
    t, n = ka.shape
    v = sa.shape[1]
    s1 = pl.BlockSpec((1, n), lambda i: (i, 0))
    sv = pl.BlockSpec((1, v, n), lambda i: (i, 0, 0))
    o1 = pl.BlockSpec((1, 2 * n), lambda i: (i, 0))
    ov = pl.BlockSpec((1, v, 2 * n), lambda i: (i, 0, 0))
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((t, 2 * n), ka.dtype),
            jax.ShapeDtypeStruct((t, 2 * n), ca.dtype),
            jax.ShapeDtypeStruct((t, v, 2 * n), sa.dtype),
            jax.ShapeDtypeStruct((t, v, 2 * n), mna.dtype),
            jax.ShapeDtypeStruct((t, v, 2 * n), mxa.dtype),
            jax.ShapeDtypeStruct((t, 2 * n), jnp.bool_),
        ),
        grid=(t,),
        in_specs=[s1, s1, sv, sv, sv, s1, s1, sv, sv, sv],
        out_specs=(o1, o1, ov, ov, ov, o1),
        interpret=interpret,
    )(ka, ca, sa, mna, mxa, kb, cb, sb, mnb, mxb)
