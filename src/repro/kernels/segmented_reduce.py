"""Segmented reduce-by-key over sorted tiles — the "absorb" hot spot.

Given key-sorted rows, equal keys form segments; the paper's b-tree absorb
(aggregate a row into its group) becomes a **flag-based segmented scan**:

    for d in 1, 2, 4, … N/2:
        v[i] ← v[i] ⊕ v[i−d]   unless a segment boundary lies in (i−d, i]
        f[i] ← f[i] ∨ f[i−d]

log₂N data-parallel steps, each a lane roll + masked combine — exactly the
structure the bitonic kernel uses, so both map onto the same VPU idiom.
Segment *tails* then hold complete group aggregates (count/sum/min/max);
compaction of tails to the front is a cheap memory-bound scatter done by
the XLA caller (see ops.py) — the O(N log N) compute lives here.

The kernel carries all aggregate columns in one fused pass: count and sum
scan with ⊕ = add, min/max columns with ⊕ = min/max, sharing the boundary
flags and the rolls' mask logic.  The value planes may have different
widths (an AggSpec that skips e.g. min/max passes a 1-wide dummy plane).

Keys arrive as one or two uint32 **lanes**: 32-bit keys are a single
lane; 64-bit keys are a (hi, lo) pair compared/equality-tested per lane,
so the kernel never needs native 64-bit integer ops on the VPU.  A key is
EMPTY iff *every* lane is the uint32 EMPTY (the 64-bit sentinel's halves
are both 0xFFFF_FFFF).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.types import EMPTY


def _lex_leq(a_lanes, b_lanes):
    """a <= b on multi-lane keys (hi lane first)."""
    leq = a_lanes[-1] <= b_lanes[-1]
    for a, b in zip(reversed(a_lanes[:-1]), reversed(b_lanes[:-1])):
        leq = (a < b) | ((a == b) & leq)
    return leq


def _lanes_eq(a_lanes, b_lanes):
    """Elementwise equality of two multi-lane key vectors."""
    eq = a_lanes[0] == b_lanes[0]
    for a, b in zip(a_lanes[1:], b_lanes[1:]):
        eq = eq & (a == b)
    return eq


def _lanes_empty(lanes):
    """True where the (possibly multi-lane) key is the EMPTY sentinel."""
    e = lanes[0] == EMPTY
    for k in lanes[1:]:
        e = e & (k == EMPTY)
    return e


def _segmented_scan(keys_lanes, cnt, ssum, smin, smax):
    """keys_lanes: tuple of (1,N) uint32 lanes (hi→lo); cnt (1,N);
    ssum/smin/smax (V?,N).  Returns scanned values and the tail mask (last
    row of each segment)."""
    if not isinstance(keys_lanes, (tuple, list)):
        keys_lanes = (keys_lanes,)
    k0 = keys_lanes[0]
    n = k0.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, k0.shape, 1)
    valid = ~_lanes_empty(keys_lanes)
    prev = [jnp.roll(k, 1, axis=-1) for k in keys_lanes]
    heads = ~_lanes_eq(keys_lanes, prev) | (idx == 0)
    f = heads | ~valid
    d = 1
    while d < n:
        fd = jnp.roll(f, d, axis=-1)
        edge = idx < d
        can_add = (~f) & (~edge)
        cd = jnp.roll(cnt, d, axis=-1)
        cnt = jnp.where(can_add, cnt + cd, cnt)
        # value columns broadcast the (1,N) mask over their V rows
        sd = jnp.roll(ssum, d, axis=-1)
        ssum = jnp.where(can_add, ssum + sd, ssum)
        mnd = jnp.roll(smin, d, axis=-1)
        smin = jnp.where(can_add, jnp.minimum(smin, mnd), smin)
        mxd = jnp.roll(smax, d, axis=-1)
        smax = jnp.where(can_add, jnp.maximum(smax, mxd), smax)
        f = f | (fd & ~edge) | edge
        d *= 2
    nxt = [jnp.roll(k, -1, axis=-1) for k in keys_lanes]
    tails = (~_lanes_eq(keys_lanes, nxt) | (idx == n - 1)) & valid
    return cnt, ssum, smin, smax, tails


def _make_kernel(nlanes: int):
    def _kernel(*refs):
        k_refs = refs[:nlanes]
        c_ref, s_ref, mn_ref, mx_ref = refs[nlanes : nlanes + 4]
        oc_ref, os_ref, omn_ref, omx_ref, ot_ref = refs[nlanes + 4 :]
        cnt, ssum, smin, smax, tails = _segmented_scan(
            tuple(k[...] for k in k_refs),
            c_ref[...], s_ref[...], mn_ref[...], mx_ref[...],
        )
        oc_ref[...] = cnt
        os_ref[...] = ssum
        omn_ref[...] = smin
        omx_ref[...] = smax
        ot_ref[...] = tails

    return _kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def segmented_scan_tiles(keys, cnt, ssum, smin, smax, *, interpret: bool = True):
    """(T,N) key lane(s) / cnt and (T,V?,N) value tiles → scanned values +
    tail mask.  ``keys`` is a (T,N) array (one lane) or a tuple of (T,N)
    uint32 lanes, hi lane first, for 64-bit keys."""
    keys_lanes = keys if isinstance(keys, (tuple, list)) else (keys,)
    keys_lanes = tuple(keys_lanes)
    t, n = keys_lanes[0].shape
    spec1 = pl.BlockSpec((1, n), lambda i: (i, 0))

    def specv(x):
        v = x.shape[1]
        return pl.BlockSpec((1, v, n), lambda i: (i, 0, 0))

    out = pl.pallas_call(
        _make_kernel(len(keys_lanes)),
        out_shape=(
            jax.ShapeDtypeStruct((t, n), cnt.dtype),
            jax.ShapeDtypeStruct(ssum.shape, ssum.dtype),
            jax.ShapeDtypeStruct(smin.shape, smin.dtype),
            jax.ShapeDtypeStruct(smax.shape, smax.dtype),
            jax.ShapeDtypeStruct((t, n), jnp.bool_),
        ),
        grid=(t,),
        in_specs=[spec1] * len(keys_lanes)
        + [spec1, specv(ssum), specv(smin), specv(smax)],
        out_specs=(spec1, specv(ssum), specv(smin), specv(smax), spec1),
        interpret=interpret,
    )(*keys_lanes, cnt, ssum, smin, smax)
    return out
