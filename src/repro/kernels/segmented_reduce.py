"""Segmented reduce-by-key over sorted tiles — the "absorb" hot spot.

Given key-sorted rows, equal keys form segments; the paper's b-tree absorb
(aggregate a row into its group) becomes a **flag-based segmented scan**:

    for d in 1, 2, 4, … N/2:
        v[i] ← v[i] ⊕ v[i−d]   unless a segment boundary lies in (i−d, i]
        f[i] ← f[i] ∨ f[i−d]

log₂N data-parallel steps, each a lane roll + masked combine — exactly the
structure the bitonic kernel uses, so both map onto the same VPU idiom.
Segment *tails* then hold complete group aggregates (count/sum/min/max);
compaction of tails to the front is a cheap memory-bound scatter done by
the XLA caller (see ops.py) — the O(N log N) compute lives here.

The kernel carries all aggregate columns in one fused pass: count and sum
scan with ⊕ = add, min/max columns with ⊕ = min/max, sharing the boundary
flags and the rolls' mask logic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.types import EMPTY


def _segmented_scan(keys, cnt, ssum, smin, smax):
    """keys (1,N); cnt (1,N); ssum/smin/smax (V,N). Returns scanned values
    and the tail mask (last row of each segment)."""
    n = keys.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, keys.shape, 1)
    valid = keys != EMPTY
    prev_keys = jnp.roll(keys, 1, axis=-1)
    heads = (keys != prev_keys) | (idx == 0)
    f = heads | ~valid
    d = 1
    while d < n:
        fd = jnp.roll(f, d, axis=-1)
        edge = idx < d
        can_add = (~f) & (~edge)
        cd = jnp.roll(cnt, d, axis=-1)
        cnt = jnp.where(can_add, cnt + cd, cnt)
        # value columns broadcast the (1,N) mask over their V rows
        sd = jnp.roll(ssum, d, axis=-1)
        ssum = jnp.where(can_add, ssum + sd, ssum)
        mnd = jnp.roll(smin, d, axis=-1)
        smin = jnp.where(can_add, jnp.minimum(smin, mnd), smin)
        mxd = jnp.roll(smax, d, axis=-1)
        smax = jnp.where(can_add, jnp.maximum(smax, mxd), smax)
        f = f | (fd & ~edge) | edge
        d *= 2
    next_keys = jnp.roll(keys, -1, axis=-1)
    tails = ((keys != next_keys) | (idx == n - 1)) & valid
    return cnt, ssum, smin, smax, tails


def _kernel(k_ref, c_ref, s_ref, mn_ref, mx_ref,
            oc_ref, os_ref, omn_ref, omx_ref, ot_ref):
    cnt, ssum, smin, smax, tails = _segmented_scan(
        k_ref[...], c_ref[...], s_ref[...], mn_ref[...], mx_ref[...]
    )
    oc_ref[...] = cnt
    os_ref[...] = ssum
    omn_ref[...] = smin
    omx_ref[...] = smax
    ot_ref[...] = tails


@functools.partial(jax.jit, static_argnames=("interpret",))
def segmented_scan_tiles(keys, cnt, ssum, smin, smax, *, interpret: bool = True):
    """(T,N) keys/cnt and (T,V,N) value tiles → scanned values + tail mask."""
    t, n = keys.shape
    v = ssum.shape[1]
    spec1 = pl.BlockSpec((1, n), lambda i: (i, 0))
    specv = pl.BlockSpec((1, v, n), lambda i: (i, 0, 0))
    # kernel refs drop the leading block dim of size 1 via index maps below
    def k1(ref):
        return ref

    out = pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((t, n), cnt.dtype),
            jax.ShapeDtypeStruct((t, v, n), ssum.dtype),
            jax.ShapeDtypeStruct((t, v, n), smin.dtype),
            jax.ShapeDtypeStruct((t, v, n), smax.dtype),
            jax.ShapeDtypeStruct((t, n), jnp.bool_),
        ),
        grid=(t,),
        in_specs=[spec1, spec1, specv, specv, specv],
        out_specs=(spec1, specv, specv, specv, spec1),
        interpret=interpret,
    )(keys, cnt, ssum, smin, smax)
    return out
