"""jit'd wrappers exposing the Pallas kernels with framework-level shapes.

These handle the impedance between user shapes and kernel tiles: padding
to powers of two / MXU multiples, EMPTY-key padding, AggState struct ↔
(T,N)/(T,V,N) tile layout, and the XLA-side compaction scatter that
follows the in-kernel segmented scans.  ``interpret=True`` everywhere on
CPU (Mosaic is TPU-only); the flag flips off on real hardware.

Key-width handling: kernels only ever see uint32 lanes.  A uint64 key
vector is split here into a (hi, lo) pair of uint32 lanes — compared
lexicographically inside the kernels — and recombined on the way out, so
the TPU path needs no native 64-bit integer ops.  Callers must hold
:func:`repro.core.types.key_dtype_context` for uint64 inputs (the
engine's sorted_ops entry points do).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as _dispatch
from repro.core.types import (
    EMPTY,
    AggState,
    concat_states,
    empty_key,
    empty_like,
)
from repro.kernels import bitonic_sort as _bs
from repro.kernels import grouped_matmul as _gm
from repro.kernels import merge_aggregate as _ma
from repro.kernels import merge_path as _mp
from repro.kernels import segmented_reduce as _sr

# Centralized in repro.core.dispatch: interpret everywhere except on real
# TPU (override with REPRO_PALLAS_INTERPRET=0/1).
INTERPRET = _dispatch.should_interpret()

_LO32 = 0xFFFFFFFF


def _next_pow2(n: int) -> int:
    return 1 << max(1, (n - 1).bit_length())


def _key_lanes(keys: jax.Array) -> tuple[jax.Array, ...]:
    """Split a 1-D key vector into uint32 lanes (hi lane first)."""
    if keys.dtype == jnp.uint64:
        hi = (keys >> np.uint64(32)).astype(jnp.uint32)
        lo = (keys & np.uint64(_LO32)).astype(jnp.uint32)
        return (hi, lo)
    return (keys.astype(jnp.uint32),)


def _lanes_to_keys(lanes: tuple[jax.Array, ...], dtype) -> jax.Array:
    """Recombine uint32 lanes into a key vector of ``dtype``."""
    if len(lanes) == 1:
        return lanes[0].astype(dtype)
    hi, lo = lanes
    return (hi.astype(jnp.uint64) << np.uint64(32)) | lo.astype(jnp.uint64)


def sort_keys(keys: jax.Array) -> jax.Array:
    """Sort a 1-D uint32/uint64 key vector (EMPTY-padded to a power of 2)."""
    n = keys.shape[0]
    m = _next_pow2(n)
    lanes = tuple(
        jnp.full((1, m), EMPTY, jnp.uint32).at[0, :n].set(lane)
        for lane in _key_lanes(keys)
    )
    sorted_lanes, _ = _bs.bitonic_sort_multi(lanes, (), interpret=INTERPRET)
    return _lanes_to_keys(tuple(l[0, :n] for l in sorted_lanes), keys.dtype)


def argsort_keys(keys: jax.Array) -> jax.Array:
    """Key-argsort via the multi-lane kernel, with the row index as an
    extra LEAST-significant key lane.

    The index lane makes the bitonic network stable: all EMPTY keys tie,
    and without it the (unstable) network could emit a pow2-pad slot
    (index ≥ n) ahead of one of the state's own EMPTY rows — the first n
    outputs would then reference a pad row and any clamp would duplicate
    a real row into the tail.  With the index tie-break, in-state rows
    (indices < n) always precede pad rows, so the first n outputs are
    exactly a permutation of range(n)."""
    n = keys.shape[0]
    m = _next_pow2(n)
    lanes = tuple(
        jnp.full((1, m), EMPTY, jnp.uint32).at[0, :n].set(lane)
        for lane in _key_lanes(keys)
    )
    idx_lane = jnp.arange(m, dtype=jnp.uint32)[None, :]
    sorted_lanes, _ = _bs.bitonic_sort_multi(
        lanes + (idx_lane,), (), interpret=INTERPRET
    )
    perm = sorted_lanes[-1][0]
    return perm[:n].astype(jnp.int32)


# Back-compat aliases (the registry and older callers use the u32 names).
sort_u32 = sort_keys
argsort_u32 = argsort_keys


def _plane_to_tile(plane: jax.Array, n: int, fill: float) -> jax.Array:
    """(N, V) value plane → (1, V, N) kernel tile; width-0 planes become a
    1-wide neutral dummy the kernel scans and the caller drops."""
    if plane.shape[1] == 0:
        return jnp.full((1, 1, n), fill, jnp.float32)
    return jnp.moveaxis(plane, 0, -1)[None]


def _state_to_tiles(state: AggState, n: int):
    """AggState (N rows) → key lanes (1,N), cnt (1,N), value tiles
    (1,V?,N) with per-plane widths (dummy 1-wide plane when absent)."""
    key_lanes = tuple(lane[None] for lane in _key_lanes(state.keys))
    cnt = state.count[None]
    ssum = _plane_to_tile(state.sum, n, 0.0)
    smin = _plane_to_tile(state.min, n, jnp.inf)
    smax = _plane_to_tile(state.max, n, -jnp.inf)
    return key_lanes, cnt, ssum, smin, smax


def _compact(keys, cnt, ssum, smin, smax, tails, widths) -> AggState:
    """Scatter segment tails to the front (XLA side; memory-bound).

    ``keys`` is the merged/sorted key *vector* (n,) in its native dtype;
    the value tiles are (1,V?,n); ``widths`` the output per-plane widths.
    """
    n = keys.shape[-1]
    cnt, tails = cnt[0], tails[0]
    ssum, smin, smax = ssum[0], smin[0], smax[0]
    pos = jnp.cumsum(tails.astype(jnp.int32)) - 1
    idx = jnp.where(tails, pos, n)  # out-of-range → dropped
    kd = keys.dtype
    out_keys = jnp.full((n,), empty_key(kd), kd).at[idx].set(keys, mode="drop")
    out_cnt = jnp.zeros((n,), cnt.dtype).at[idx].set(cnt, mode="drop")

    def sc(col, fill):
        return jnp.full((n,), fill, col.dtype).at[idx].set(col, mode="drop")

    def plane(tile, width, fill):
        if width == 0:
            return jnp.zeros((n, 0), jnp.float32)
        return jnp.stack([sc(tile[v], fill) for v in range(width)], axis=-1)

    ws, wm, wx = widths
    return AggState(
        out_keys,
        out_cnt,
        plane(ssum, ws, 0.0),
        plane(smin, wm, jnp.inf),
        plane(smax, wx, -jnp.inf),
    )


def segmented_combine(state: AggState) -> AggState:
    """Pallas-backed equivalent of sorted_ops.segmented_combine (input must
    be key-sorted; output compacted to the front, EMPTY-padded)."""
    n0 = state.capacity
    n = _next_pow2(n0)
    state = _pad_state(state, n)
    key_lanes, cnt, ssum, smin, smax = _state_to_tiles(state, n)
    c2, s2, mn2, mx2, tails = _sr.segmented_scan_tiles(
        key_lanes, cnt, ssum, smin, smax, interpret=INTERPRET
    )
    out = _compact(state.keys, c2, s2, mn2, mx2, tails, state.widths)
    return jax.tree.map(lambda x: x[:n0], out)


def merge_absorb_sorted(a: AggState, b: AggState, *, assume_unique: bool = False) -> AggState:
    """Fused merge-absorb of two key-sorted states via the merge-path
    kernel: linear merge (per-lane diagonal binary search, no sort/
    compare-exchange network), absorb fused in-kernel.  Returns the
    combined state of capacity exactly |a|+|b| (sorted, duplicate-
    combined, EMPTY-padded) so jitted callers see the same shapes as the
    XLA engine.  ``assume_unique`` is accepted for interface parity; the
    in-VMEM segmented scan handles both cases in the same pass."""
    del assume_unique
    cap_out = a.capacity + b.capacity
    na = _next_pow2(a.capacity)
    nb = _next_pow2(b.capacity)
    a = _pad_state(a, na)
    b = _pad_state(b, nb)
    ka, ca, sa, mna, mxa = _state_to_tiles(a, na)
    kb, cb, sb, mnb, mxb = _state_to_tiles(b, nb)
    out_tiles = _mp.merge_path_tiles(
        ka, ca, sa, mna, mxa, kb, cb, sb, mnb, mxb, interpret=INTERPRET
    )
    nlanes = len(ka)
    merged_lanes = tuple(t[0] for t in out_tiles[:nlanes])
    c2, s2, mn2, mx2, tails = out_tiles[nlanes:]
    merged_keys = _lanes_to_keys(merged_lanes, a.keys.dtype)
    out = _compact(merged_keys, c2, s2, mn2, mx2, tails, a.widths)
    # compacted rows ≤ |a|+|b| ≤ na+nb: trimming the EMPTY tail is lossless
    return jax.tree.map(lambda x: x[:cap_out], out)


def merge_absorb_sorted_bitonic(a: AggState, b: AggState) -> AggState:
    """Previous-generation fused step (bitonic merge network); kept for
    benchmarking against the merge-path kernel.  uint32 keys only."""
    assert a.keys.dtype == jnp.uint32, "bitonic merge benchmark path is u32-only"
    cap_out = a.capacity + b.capacity
    n = _next_pow2(max(a.capacity, b.capacity))
    a = _pad_state(a, n)
    b = _pad_state(b, n)
    (ka,), ca, sa, mna, mxa = _state_to_tiles(a, n)
    (kb,), cb, sb, mnb, mxb = _state_to_tiles(b, n)
    k2, c2, s2, mn2, mx2, tails = _ma.merge_absorb_tiles(
        ka, ca, sa, mna, mxa, kb, cb, sb, mnb, mxb, interpret=INTERPRET
    )
    out = _compact(k2[0], c2, s2, mn2, mx2, tails, a.widths)
    return jax.tree.map(lambda x: x[: min(cap_out, 2 * n)], out)


def join_probe(a_keys: jax.Array, b_keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Merge-join probe via the merge-path kernel's lane-parallel binary
    search: rank-align each (sorted) a-key against the (sorted) b-keys.
    Returns ``(pos, hit)`` shaped like ``a_keys`` with ``pos`` clipped
    into b's row range (see :func:`repro.core.merge_join.join_probe`).
    EMPTY pow2 padding on either side is benign: EMPTY ranks to the tail
    and never equals a valid key, so padded rows cannot hit."""
    n0, m0 = a_keys.shape[0], b_keys.shape[0]
    n, m = _next_pow2(n0), _next_pow2(m0)
    ka = tuple(
        jnp.full((1, n), EMPTY, jnp.uint32).at[0, :n0].set(lane)
        for lane in _key_lanes(a_keys)
    )
    kb = tuple(
        jnp.full((1, m), EMPTY, jnp.uint32).at[0, :m0].set(lane)
        for lane in _key_lanes(b_keys)
    )
    pos, hit = _mp.merge_path_probe_tiles(ka, kb, interpret=INTERPRET)
    return jnp.clip(pos[0, :n0], 0, max(m0 - 1, 0)), hit[0, :n0]


def _pad_state(state: AggState, n: int) -> AggState:
    if state.capacity == n:
        return state
    return concat_states(state, empty_like(state, n - state.capacity))


def moe_grouped_matmul(x, w, *, capacity, block_m=128, block_n=128, block_k=128):
    return _gm.grouped_matmul(
        x, w, capacity=capacity, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=INTERPRET,
    )
