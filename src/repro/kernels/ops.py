"""jit'd wrappers exposing the Pallas kernels with framework-level shapes.

These handle the impedance between user shapes and kernel tiles: padding
to powers of two / MXU multiples, EMPTY-key padding, AggState struct ↔
(T,N)/(T,V,N) tile layout, and the XLA-side compaction scatter that
follows the in-kernel segmented scans.  ``interpret=True`` everywhere on
CPU (Mosaic is TPU-only); the flag flips off on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch as _dispatch
from repro.core.types import EMPTY, AggState
from repro.kernels import bitonic_sort as _bs
from repro.kernels import grouped_matmul as _gm
from repro.kernels import merge_aggregate as _ma
from repro.kernels import merge_path as _mp
from repro.kernels import segmented_reduce as _sr

# Centralized in repro.core.dispatch: interpret everywhere except on real
# TPU (override with REPRO_PALLAS_INTERPRET=0/1).
INTERPRET = _dispatch.should_interpret()


def _next_pow2(n: int) -> int:
    return 1 << max(1, (n - 1).bit_length())


def sort_u32(keys: jax.Array) -> jax.Array:
    """Sort a 1-D uint32 vector (EMPTY-padded to a power of two)."""
    n = keys.shape[0]
    m = _next_pow2(n)
    padded = jnp.full((1, m), EMPTY, jnp.uint32).at[0, :n].set(keys)
    return _bs.bitonic_sort(padded, interpret=INTERPRET)[0, :n]


def argsort_u32(keys: jax.Array) -> jax.Array:
    """Key-argsort via the kv kernel with the row index as payload."""
    n = keys.shape[0]
    m = _next_pow2(n)
    padded = jnp.full((1, m), EMPTY, jnp.uint32).at[0, :n].set(keys)
    pay = jnp.arange(m, dtype=jnp.uint32)[None, :]
    _, perm = _bs.bitonic_sort_kv(padded, pay, interpret=INTERPRET)
    perm = perm[0]
    # padded slots carry EMPTY keys which sort to the tail; any index ≥ n
    # in the first n outputs would be a bug (covered by tests)
    return jnp.minimum(perm[:n], n - 1).astype(jnp.int32)


def _state_to_tiles(state: AggState, n: int):
    """AggState (N rows) → (1,N) / (1,V,N) tiles, V≥1 (dummy col if V=0)."""
    keys = state.keys[None]
    cnt = state.count[None]
    v = max(1, state.width)
    if state.width == 0:
        z = jnp.zeros((1, 1, n), jnp.float32)
        return keys, cnt, z, z, z
    ssum = jnp.moveaxis(state.sum, 0, -1)[None]
    smin = jnp.moveaxis(state.min, 0, -1)[None]
    smax = jnp.moveaxis(state.max, 0, -1)[None]
    return keys, cnt, ssum, smin, smax


def _compact(keys, cnt, ssum, smin, smax, tails, width: int) -> AggState:
    """Scatter segment tails to the front (XLA side; memory-bound)."""
    n = keys.shape[-1]
    keys, cnt, tails = keys[0], cnt[0], tails[0]
    ssum, smin, smax = ssum[0], smin[0], smax[0]
    pos = jnp.cumsum(tails.astype(jnp.int32)) - 1
    idx = jnp.where(tails, pos, n)  # out-of-range → dropped
    out_keys = jnp.full((n,), EMPTY, jnp.uint32).at[idx].set(keys, mode="drop")
    out_cnt = jnp.zeros((n,), cnt.dtype).at[idx].set(cnt, mode="drop")

    def sc(col, fill):
        return jnp.full((n,), fill, col.dtype).at[idx].set(col, mode="drop")

    if width == 0:
        z = jnp.zeros((n, 0), jnp.float32)
        return AggState(out_keys, out_cnt, z, z, z)
    out_sum = jnp.stack([sc(ssum[v], 0.0) for v in range(width)], axis=-1)
    out_min = jnp.stack([sc(smin[v], jnp.inf) for v in range(width)], axis=-1)
    out_max = jnp.stack([sc(smax[v], -jnp.inf) for v in range(width)], axis=-1)
    return AggState(out_keys, out_cnt, out_sum, out_min, out_max)


def segmented_combine(state: AggState) -> AggState:
    """Pallas-backed equivalent of sorted_ops.segmented_combine (input must
    be key-sorted; output compacted to the front, EMPTY-padded)."""
    n0 = state.capacity
    n = _next_pow2(n0)
    if n != n0:
        pad = n - n0
        state = jax.tree.map(
            lambda x: jnp.concatenate(
                [x, jnp.full((pad,) + x.shape[1:], _pad_val(x), x.dtype)], 0
            ),
            state,
        )
    keys, cnt, ssum, smin, smax = _state_to_tiles(state, n)
    c2, s2, mn2, mx2, tails = _sr.segmented_scan_tiles(
        keys, cnt, ssum, smin, smax, interpret=INTERPRET
    )
    out = _compact(keys, c2, s2, mn2, mx2, tails, state.width)
    return jax.tree.map(lambda x: x[:n0], out)


def merge_absorb_sorted(a: AggState, b: AggState, *, assume_unique: bool = False) -> AggState:
    """Fused merge-absorb of two key-sorted states via the merge-path
    kernel: linear merge (per-lane diagonal binary search, no sort/
    compare-exchange network), absorb fused in-kernel.  Returns the
    combined state of capacity exactly |a|+|b| (sorted, duplicate-
    combined, EMPTY-padded) so jitted callers see the same shapes as the
    XLA engine.  ``assume_unique`` is accepted for interface parity; the
    in-VMEM segmented scan handles both cases in the same pass."""
    del assume_unique
    cap_out = a.capacity + b.capacity
    na = _next_pow2(a.capacity)
    nb = _next_pow2(b.capacity)
    a = _pad_state(a, na)
    b = _pad_state(b, nb)
    ka, ca, sa, mna, mxa = _state_to_tiles(a, na)
    kb, cb, sb, mnb, mxb = _state_to_tiles(b, nb)
    k2, c2, s2, mn2, mx2, tails = _mp.merge_path_tiles(
        ka, ca, sa, mna, mxa, kb, cb, sb, mnb, mxb, interpret=INTERPRET
    )
    out = _compact(k2, c2, s2, mn2, mx2, tails, a.width)
    # compacted rows ≤ |a|+|b| ≤ na+nb: trimming the EMPTY tail is lossless
    return jax.tree.map(lambda x: x[:cap_out], out)


def merge_absorb_sorted_bitonic(a: AggState, b: AggState) -> AggState:
    """Previous-generation fused step (bitonic merge network); kept for
    benchmarking against the merge-path kernel."""
    cap_out = a.capacity + b.capacity
    n = _next_pow2(max(a.capacity, b.capacity))
    a = _pad_state(a, n)
    b = _pad_state(b, n)
    ka, ca, sa, mna, mxa = _state_to_tiles(a, n)
    kb, cb, sb, mnb, mxb = _state_to_tiles(b, n)
    k2, c2, s2, mn2, mx2, tails = _ma.merge_absorb_tiles(
        ka, ca, sa, mna, mxa, kb, cb, sb, mnb, mxb, interpret=INTERPRET
    )
    out = _compact(k2, c2, s2, mn2, mx2, tails, a.width)
    return jax.tree.map(lambda x: x[: min(cap_out, 2 * n)], out)


def _pad_val(x):
    if x.dtype == jnp.uint32:
        return EMPTY
    if jnp.issubdtype(x.dtype, jnp.floating):
        return 0.0
    return 0


def _pad_state(state: AggState, n: int) -> AggState:
    if state.capacity == n:
        return state
    pad = n - state.capacity
    return AggState(
        keys=jnp.concatenate([state.keys, jnp.full((pad,), EMPTY, jnp.uint32)]),
        count=jnp.concatenate([state.count, jnp.zeros((pad,), state.count.dtype)]),
        sum=jnp.concatenate([state.sum, jnp.zeros((pad, state.width), jnp.float32)]),
        min=jnp.concatenate(
            [state.min, jnp.full((pad, state.width), jnp.inf, jnp.float32)]
        ),
        max=jnp.concatenate(
            [state.max, jnp.full((pad, state.width), -jnp.inf, jnp.float32)]
        ),
    )


def moe_grouped_matmul(x, w, *, capacity, block_m=128, block_n=128, block_k=128):
    return _gm.grouped_matmul(
        x, w, capacity=capacity, block_m=block_m, block_n=block_n,
        block_k=block_k, interpret=INTERPRET,
    )
