"""Bitonic key(+payload) sort of VMEM tiles — the run-generation hot spot.

The paper replaces quicksort/priority queues with an ordered in-memory
index; on TPU the index's "insert a sorted batch" operation needs the
batch sorted first (§3.4).  This kernel sorts one power-of-two tile of
uint32 keys (with an optional uint32 payload moved alongside, e.g. the
original row position for argsort) entirely in VMEM.

TPU adaptation: the classic compare-exchange `partner = i XOR j` is
expressed with **lane/sublane rolls + masked min/max**, never gathers:
for stride j,  partner values = where(bit_j(i), roll(x, +j), roll(x, -j)).
All rolls are power-of-two strides of the trailing (lane) axis of a
(1, N) tile, which Mosaic supports natively; masks come from broadcasted
iota.  Work/depth: N·log²N compares, fully VPU-vectorized, zero control
flow (the stage loops unroll at trace time).

Grid: one program per tile; ``ops.py`` shards larger inputs into tiles
and merges with :mod:`repro.kernels.merge_aggregate`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cex(keys, payload, j: int, direction):
    """One compare-exchange stage at stride j.

    keys/payload: (1, N); direction: (1, N) bool, True = ascending block.
    """
    n = keys.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, keys.shape, 1)
    upper = (idx & j) != 0  # bit_j set → partner is i - j
    # roll(+j) brings x[i-j] to lane i; roll(-j) brings x[i+j]
    part_hi = jnp.roll(keys, j, axis=-1)
    part_lo = jnp.roll(keys, -j, axis=-1)
    partner = jnp.where(upper, part_hi, part_lo)
    # ascending: lane with bit clear keeps min, bit set keeps max
    keep_min = jnp.where(direction, ~upper, upper)
    take_self = jnp.where(keep_min, keys <= partner, keys >= partner)
    new_keys = jnp.where(take_self, keys, partner)
    if payload is None:
        return new_keys, None
    pay_hi = jnp.roll(payload, j, axis=-1)
    pay_lo = jnp.roll(payload, -j, axis=-1)
    pay_partner = jnp.where(upper, pay_hi, pay_lo)
    new_pay = jnp.where(take_self, payload, pay_partner)
    return new_keys, new_pay


def _bitonic_body(keys, payload):
    n = keys.shape[-1]
    assert n & (n - 1) == 0, "tile length must be a power of two"
    idx = jax.lax.broadcasted_iota(jnp.int32, keys.shape, 1)
    k = 2
    while k <= n:
        # block of size k sorts ascending iff bit_k(i) clear (global ascending)
        direction = (idx & k) == 0 if k < n else jnp.ones_like(idx, dtype=bool)
        j = k // 2
        while j >= 1:
            keys, payload = _cex(keys, payload, j, direction)
            j //= 2
        k *= 2
    return keys, payload


def _sort_kernel(k_ref, o_ref):
    keys, _ = _bitonic_body(k_ref[...], None)
    o_ref[...] = keys


def _sort_kv_kernel(k_ref, v_ref, ok_ref, ov_ref):
    keys, vals = _bitonic_body(k_ref[...], v_ref[...])
    ok_ref[...] = keys
    ov_ref[...] = vals


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort(keys: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Sort a (T, N) batch of tiles along the last axis (N a power of 2)."""
    t, n = keys.shape
    return pl.pallas_call(
        _sort_kernel,
        out_shape=jax.ShapeDtypeStruct((t, n), keys.dtype),
        grid=(t,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        interpret=interpret,
    )(keys)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_kv(keys: jax.Array, vals: jax.Array, *, interpret: bool = True):
    """Key-sort with a payload column moved alongside (stable w.r.t. the
    payload when the payload encodes the original position in low bits)."""
    t, n = keys.shape
    out = pl.pallas_call(
        _sort_kv_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((t, n), keys.dtype),
            jax.ShapeDtypeStruct((t, n), vals.dtype),
        ),
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ),
        interpret=interpret,
    )(keys, vals)
    return out
