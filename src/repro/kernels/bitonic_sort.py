"""Bitonic key(+payload) sort of VMEM tiles — the run-generation hot spot.

The paper replaces quicksort/priority queues with an ordered in-memory
index; on TPU the index's "insert a sorted batch" operation needs the
batch sorted first (§3.4).  This kernel sorts one power-of-two tile of
uint32 keys (with optional uint32 payload lanes moved alongside, e.g. the
original row position for argsort) entirely in VMEM.

TPU adaptation: the classic compare-exchange `partner = i XOR j` is
expressed with **lane/sublane rolls + masked min/max**, never gathers:
for stride j,  partner values = where(bit_j(i), roll(x, +j), roll(x, -j)).
All rolls are power-of-two strides of the trailing (lane) axis of a
(1, N) tile, which Mosaic supports natively; masks come from broadcasted
iota.  Work/depth: N·log²N compares, fully VPU-vectorized, zero control
flow (the stage loops unroll at trace time).

Keys may span multiple uint32 **lanes** compared lexicographically (hi
lane first): 64-bit composite keys sort as a (hi, lo) pair without any
native 64-bit ops — each compare-exchange stage rolls every lane and
selects with one shared lexicographic predicate.

Grid: one program per tile; ``ops.py`` shards larger inputs into tiles
and merges with :mod:`repro.kernels.merge_path`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.segmented_reduce import _lex_leq


def _cex(key_lanes, move_lanes, j: int, direction):
    """One compare-exchange stage at stride j.

    key_lanes / move_lanes: tuples of (1, N) arrays; direction: (1, N)
    bool, True = ascending block.  Keys compare lexicographically across
    lanes; move lanes travel with their row.
    """
    idx = jax.lax.broadcasted_iota(jnp.int32, key_lanes[0].shape, 1)
    upper = (idx & j) != 0  # bit_j set → partner is i - j

    def partner(x):
        # roll(+j) brings x[i-j] to lane i; roll(-j) brings x[i+j]
        return jnp.where(upper, jnp.roll(x, j, axis=-1), jnp.roll(x, -j, axis=-1))

    part_keys = tuple(partner(k) for k in key_lanes)
    # ascending: lane with bit clear keeps min, bit set keeps max
    keep_min = jnp.where(direction, ~upper, upper)
    take_self = jnp.where(
        keep_min, _lex_leq(key_lanes, part_keys), _lex_leq(part_keys, key_lanes)
    )
    new_keys = tuple(jnp.where(take_self, k, p) for k, p in zip(key_lanes, part_keys))
    new_move = tuple(jnp.where(take_self, m, partner(m)) for m in move_lanes)
    return new_keys, new_move


def _bitonic_body(key_lanes, move_lanes):
    n = key_lanes[0].shape[-1]
    assert n & (n - 1) == 0, "tile length must be a power of two"
    idx = jax.lax.broadcasted_iota(jnp.int32, key_lanes[0].shape, 1)
    k = 2
    while k <= n:
        # block of size k sorts ascending iff bit_k(i) clear (global ascending)
        direction = (idx & k) == 0 if k < n else jnp.ones_like(idx, dtype=bool)
        j = k // 2
        while j >= 1:
            key_lanes, move_lanes = _cex(key_lanes, move_lanes, j, direction)
            j //= 2
        k *= 2
    return key_lanes, move_lanes


def _make_kernel(nk: int, nm: int):
    def _kernel(*refs):
        keys = tuple(r[...] for r in refs[:nk])
        move = tuple(r[...] for r in refs[nk : nk + nm])
        keys, move = _bitonic_body(keys, move)
        for r, v in zip(refs[nk + nm : 2 * nk + nm], keys):
            r[...] = v
        for r, v in zip(refs[2 * nk + nm :], move):
            r[...] = v

    return _kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_multi(key_lanes, move_lanes=(), *, interpret: bool = True):
    """Sort (T, N) tile batches along the last axis (N a power of 2).

    ``key_lanes``: tuple of (T, N) arrays compared lexicographically (hi
    lane first).  ``move_lanes``: tuple of (T, N) arrays carried alongside.
    Returns (sorted_key_lanes, moved_lanes) as tuples.
    """
    key_lanes = tuple(key_lanes)
    move_lanes = tuple(move_lanes)
    t, n = key_lanes[0].shape
    spec = pl.BlockSpec((1, n), lambda i: (i, 0))
    all_in = key_lanes + move_lanes
    out = pl.pallas_call(
        _make_kernel(len(key_lanes), len(move_lanes)),
        out_shape=tuple(jax.ShapeDtypeStruct((t, n), x.dtype) for x in all_in),
        grid=(t,),
        in_specs=[spec] * len(all_in),
        out_specs=tuple([spec] * len(all_in)),
        interpret=interpret,
    )(*all_in)
    return out[: len(key_lanes)], out[len(key_lanes) :]


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort(keys: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Sort a (T, N) batch of tiles along the last axis (N a power of 2)."""
    (sorted_keys,), _ = bitonic_sort_multi((keys,), (), interpret=interpret)
    return sorted_keys


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_kv(keys: jax.Array, vals: jax.Array, *, interpret: bool = True):
    """Key-sort with a payload column moved alongside (stable w.r.t. the
    payload when the payload encodes the original position in low bits)."""
    (sorted_keys,), (moved,) = bitonic_sort_multi((keys,), (vals,), interpret=interpret)
    return sorted_keys, moved
