"""Aggregation as a service: long-lived ingest sessions over the
device-resident streaming engine.

The paper's deployment story (F1 Query) is an *operator inside a
serving system*, not a batch job: rows arrive continuously and queries
observe the running aggregate mid-flight.  This package is that layer —
a persistent :class:`~repro.core.pipeline.StreamingAggregator` wrapped
in a service protocol:

* :class:`AggregationService` — engine-level: packed keys in, double-
  buffered ingest, **merge-on-read snapshots** (non-destructive drain +
  pre-merge + wide merge into a fresh buffer; the live engine state is
  byte-untouched and ingest continues), watermark eviction, and a host
  metrics facade.
* :class:`AggregationSession` / :func:`serve_aggregate` — schema-level:
  composite :class:`~repro.core.schema.KeySpec` keys, declarative
  :class:`~repro.core.schema.AggSpec` aggregates, snapshots as
  :class:`~repro.core.schema.AggResult`, and TTL expiry keyed on the
  major (watermark) key column.
* :class:`ServiceMetrics` — rows ingested, snapshot latency quantiles,
  occupancy and duplicate rate, all maintained host-side from counters
  the engine already produces (no per-chunk readbacks).
"""
from repro.service.metrics import ServiceMetrics
from repro.service.service import AggregationService
from repro.service.session import AggregationSession, serve_aggregate

__all__ = [
    "AggregationService",
    "AggregationSession",
    "ServiceMetrics",
    "serve_aggregate",
]
