"""Schema-level sessions: composite keys, declarative aggregates, TTL.

:class:`AggregationSession` is the :func:`repro.aggregate` rendering of
the service — batches are column mappings packed through a
:class:`~repro.core.schema.KeySpec`, snapshots come back as
:class:`~repro.core.schema.AggResult`, and sessionization-style expiry
is keyed on the **watermark column**: the major (most significant)
column of the composite key.  Because the KeySpec packs major-first,
"watermark below the cutoff" is ONE contiguous packed-key range
``[0, cutoff << shift)`` — TTL expiry reduces to the engine's sorted
prefix retirement, no per-row predicate anywhere.
"""
from __future__ import annotations

import numpy as np

from repro.core import dispatch
from repro.core import schema as schema_mod
from repro.core.schema import AggResult, AggSpec, KeySpec
from repro.core.types import ExecConfig, SpillStats, empty_state, key_dtype_context
from repro.service.metrics import ServiceMetrics
from repro.service.service import AggregationService


class AggregationSession:
    """A long-lived grouped-aggregation session over column batches.

    ::

        sess = repro.serve_aggregate(
            by=KeySpec.of(minute=22, user=10), values="amount",
            aggs=("count", "sum"), watermark="minute")
        for batch in source:
            sess.ingest(batch)           # zero-readback ingest
            if query_due:
                res = sess.snapshot()    # merge-on-read AggResult
        sess.expire_below(minute=now - ttl)   # retire closed sessions
        final = sess.close()

    ``watermark`` names the major key column used by
    :meth:`expire_below`; it must be the FIRST KeySpec column so expiry
    is a single packed-key range.  The payload width is fixed by the
    first ingested batch (the engine's plane widths are static).
    """

    def __init__(
        self,
        *,
        by: KeySpec,
        values: str | None = None,
        aggs=("count",),
        watermark: str | None = None,
        cfg: ExecConfig | None = None,
        policy: str = "rs",
        backend: str = "auto",
        index_rows: int | None = None,
        output_estimate: int | None = None,
        output_rows: int | None = None,
        mesh=None,
        mesh_axis: str | None = None,
        overlap: bool = True,
        governor=None,
    ):
        if not isinstance(aggs, AggSpec):
            aggs = AggSpec(aggs) if isinstance(aggs, str) else AggSpec(*aggs)
        if values is not None and not isinstance(values, str):
            raise TypeError(
                "session batches are column mappings: values must name a "
                f"column (a str), got {type(values).__name__}"
            )
        if values is None and aggs.needs_payload():
            raise ValueError(
                f"aggregates {aggs.names} need a payload; pass "
                "values=<column name>"
            )
        if watermark is not None and watermark != by.names[0]:
            raise ValueError(
                f"watermark column {watermark!r} must be the major (first) "
                f"key column {by.names[0]!r}: the KeySpec packs major-first, "
                "so only the major column maps TTL expiry onto one "
                "contiguous packed-key range"
            )
        self.by = by
        self.aggs = aggs
        self.values = values
        self.watermark = watermark
        self.cfg = cfg or ExecConfig()
        self._engine_kw = dict(
            policy=policy, backend=backend, index_rows=index_rows,
            output_estimate=output_estimate, output_rows=output_rows,
            mesh=mesh, mesh_axis=mesh_axis, overlap=overlap,
            governor=governor,
        )
        self._svc: AggregationService | None = None
        self._closed = False

    # -- plumbing --------------------------------------------------------

    def _prep(self, batch) -> tuple[np.ndarray, np.ndarray | None]:
        packed = self.by.pack(batch)
        if self.values is None:
            return packed, None
        if self.values not in batch:
            raise KeyError(
                f"values column {self.values!r} missing from batch")
        vals = np.asarray(batch[self.values], dtype=np.float32)
        if vals.ndim == 1:
            vals = vals[:, None]
        if len(vals) != len(packed):
            raise ValueError(
                f"values column {self.values!r} has {len(vals)} rows, key "
                f"columns have {len(packed)}"
            )
        return packed, vals

    def _ensure_service(self, payload_width: int) -> AggregationService:
        if self._svc is None:
            self._svc = AggregationService(
                self.cfg, key_dtype=self.by.key_dtype, width=payload_width,
                widths=self.aggs.plane_widths(payload_width),
                **self._engine_kw,
            )
        return self._svc

    def _result(self, state, stats: SpillStats) -> AggResult:
        plan = schema_mod._plan(
            self.metrics.rows_ingested, self.cfg,
            self._engine_kw["output_estimate"])
        plan.update(
            algorithm="insort", pipeline="device", streamed=True,
            service=True,
            backend=(self._svc._agg.backend if self._svc is not None
                     else dispatch.resolve_backend_name(
                         self._engine_kw["backend"])),
            snapshots=self.metrics.snapshots_taken,
        )
        return AggResult(state=state, stats=stats, by=self.by,
                         aggs=self.aggs, plan=plan)

    @property
    def metrics(self) -> ServiceMetrics:
        return (self._svc.metrics if self._svc is not None
                else ServiceMetrics())

    @property
    def closed(self) -> bool:
        return self._closed

    def _check_open(self):
        if self._closed:
            raise RuntimeError("AggregationSession is closed")

    # -- the session protocol --------------------------------------------

    def ingest(self, batch) -> None:
        """Absorb one column-batch mapping (key columns named by the
        KeySpec, plus the values column when requested)."""
        self._check_open()
        packed, vals = self._prep(batch)
        if not len(packed):
            return
        svc = self._ensure_service(0 if vals is None else vals.shape[1])
        svc.ingest(packed, vals)

    def snapshot(self) -> AggResult:
        """Merge-on-read snapshot as a sorted :class:`AggResult`.

        Non-destructive — ingest continues afterwards.  A session that
        never ingested (or whose rows were all retired) answers a valid
        EMPTY relation, not an error: the result keeps the declared
        key columns and aggregate planes at width 0 rows."""
        self._check_open()
        if self._svc is None:  # nothing ever ingested
            with key_dtype_context(self.by.key_dtype):
                state = empty_state(
                    0, 0, key_dtype=self.by.key_dtype,
                    widths=self.aggs.plane_widths(0))
            return self._result(state, SpillStats())
        state, stats = self._svc.snapshot()
        return self._result(state, stats)

    def expire_below(self, cutoff=None, **by_name) -> int:
        """Retire every group whose watermark column is ``< cutoff``
        (TTL expiry).  Accepts the cutoff positionally or by column name
        (``sess.expire_below(minute=120)``).  Returns the cumulative
        retired-row count; later snapshots report it as
        ``stats.rows_retired``."""
        self._check_open()
        if self.watermark is None:
            raise RuntimeError(
                "session has no watermark column; construct with "
                "watermark=<major key column> to enable TTL expiry"
            )
        if by_name:
            if cutoff is not None or set(by_name) != {self.watermark}:
                raise ValueError(
                    f"pass ONE cutoff for the watermark column "
                    f"{self.watermark!r}, got cutoff={cutoff!r}, {by_name}"
                )
            cutoff = by_name[self.watermark]
        if cutoff is None:
            raise ValueError("expire_below needs a cutoff")
        col = self.by.columns[0]
        cutoff = int(cutoff)
        if not 0 <= cutoff <= col.max_value + 1:
            raise ValueError(
                f"cutoff {cutoff} out of range for {col.bits}-bit column "
                f"{col.name!r}"
            )
        if self._svc is None:
            return 0
        threshold = cutoff << self.by.shift_of(self.watermark)
        return self._svc.retire_below(threshold)

    def close(self) -> AggResult:
        """Destructive final drain; the session accepts no further
        ingest.  An empty session closes to the same valid empty
        relation a snapshot would report."""
        self._check_open()
        self._closed = True
        if self._svc is None:
            with key_dtype_context(self.by.key_dtype):
                state = empty_state(
                    0, 0, key_dtype=self.by.key_dtype,
                    widths=self.aggs.plane_widths(0))
            return self._result(state, SpillStats())
        state, stats = self._svc.close()
        return self._result(state, stats)


def serve_aggregate(**kwargs) -> AggregationSession:
    """Open a long-lived aggregation session — the serving twin of
    :func:`repro.aggregate` (same ``by=``/``values=``/``aggs=`` schema
    arguments, plus ``watermark=`` for TTL expiry and the streaming
    engine's knobs).  See :class:`AggregationSession`."""
    return AggregationSession(**kwargs)
