"""Engine-level aggregation service: persistent ingest + merge-on-read.

:class:`AggregationService` owns one long-lived
:class:`~repro.core.pipeline.StreamingAggregator` and turns its staged
absorb protocol into a serving loop:

* :meth:`ingest` — double-buffered by default: the chunk is staged
  (async host→device transfer) and the *previous* chunk's absorb is
  dispatched, so transfer overlaps compute exactly as in
  :func:`~repro.core.pipeline.aggregate_device_stream`.
* :meth:`snapshot` — merge-on-read: the engine's statically planned
  drain + pre-merge + wide merge runs as a NON-donating program into a
  fresh output buffer.  The live engine state is byte-for-byte
  untouched, so ingest continues afterwards; repeated snapshots hit a
  pow2-bucketed set of compiled programs.
* :meth:`retire_below` — watermark eviction: resident rows with keys
  below a threshold are retired from the run store and tables, counted
  into ``SpillStats.rows_retired`` (surfaced by every later snapshot).
* :meth:`close` — the destructive finalize of the plain streaming
  protocol, ending the session.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.pipeline import StreamingAggregator
from repro.core.types import (
    AggState,
    DeviceSpillStats,
    ExecConfig,
    SpillStats,
)
from repro.service.metrics import ServiceMetrics


class AggregationService:
    """A persistent device-resident aggregation engine behind a serving
    protocol: ingest packed-key micro-batches, answer snapshot queries
    mid-flight, retire expired key ranges, finalize on close.

    Constructor arguments mirror
    :class:`~repro.core.pipeline.StreamingAggregator` (``mesh=`` keeps a
    per-shard engine under ``shard_map``); ``overlap=False`` disables
    the ingest double buffer (each chunk is absorbed synchronously with
    its staging — useful for latency-vs-throughput comparisons, see
    ``benchmarks/bench_service.py``).
    """

    def __init__(
        self,
        cfg: ExecConfig | None = None,
        *,
        policy: str = "rs",
        key_dtype=np.uint32,
        width: int = 0,
        widths: tuple[int, int, int] | None = None,
        backend: str = "auto",
        index_rows: int | None = None,
        output_estimate: int | None = None,
        output_rows: int | None = None,
        mesh=None,
        mesh_axis: str | None = None,
        overlap: bool = True,
        governor=None,
    ):
        self._agg = StreamingAggregator(
            cfg, policy=policy, key_dtype=key_dtype, width=width,
            widths=widths, backend=backend, index_rows=index_rows,
            output_estimate=output_estimate, output_rows=output_rows,
            mesh=mesh, mesh_axis=mesh_axis, governor=governor,
        )
        self.overlap = bool(overlap)
        self.metrics = ServiceMetrics()
        self._pending = None  # staged-but-not-absorbed chunk (overlap)
        self._closed = False

    # -- introspection ---------------------------------------------------

    @property
    def cfg(self) -> ExecConfig:
        return self._agg.cfg

    @property
    def policy(self) -> str:
        return self._agg.policy

    @property
    def current_policy(self) -> str:
        """The run-generation policy the next ingest will use — under
        ``policy="adaptive"`` this is the governor's current arm."""
        return self._agg.arm

    @property
    def key_dtype(self) -> np.dtype:
        return self._agg.key_dtype

    @property
    def rows_ingested(self) -> int:
        return self.metrics.rows_ingested

    @property
    def closed(self) -> bool:
        return self._closed

    def _check_open(self):
        if self._closed:
            raise RuntimeError("AggregationService is closed")

    # -- ingest ----------------------------------------------------------

    def ingest(self, keys, payload=None) -> None:
        """Absorb one micro-batch (host NumPy keys + optional payload).

        Zero host syncs: the chunk is staged with an explicit async
        ``device_put`` and (with ``overlap``) the previous chunk's
        absorb is dispatched behind it, hiding the transfer."""
        self._check_open()
        staged = self._agg.stage(keys, payload)
        if staged is None:
            return
        if self.overlap:
            pending, self._pending = self._pending, staged
            if pending is not None:
                self._agg.absorb_staged(pending)
        else:
            self._agg.absorb_staged(staged)
        self.metrics.observe_ingest(staged.rows)

    def flush(self) -> None:
        """Dispatch the absorb of any chunk still held by the double
        buffer (query/evict/close boundaries call this implicitly so
        answers always cover every ingested row)."""
        if self._pending is not None:
            pending, self._pending = self._pending, None
            self._agg.absorb_staged(pending)

    # -- merge-on-read ---------------------------------------------------

    def snapshot_device(self) -> tuple[AggState, DeviceSpillStats]:
        """:meth:`snapshot` without the host sync: device values only,
        no latency metric (compose with other device programs)."""
        self._check_open()
        self.flush()
        return self._agg.snapshot_device()

    def snapshot(self) -> tuple[AggState, SpillStats]:
        """Answer the current aggregate without consuming the engine.

        Returns ``(state, stats)`` like a finalize — keys sorted,
        EMPTY-padded tail, ``stats.rows_retired`` carrying the eviction
        account — but the live engine state is untouched and ingest
        continues.  The blocking readback is timed into the service's
        snapshot latency quantiles."""
        self._check_open()
        self.flush()
        t0 = time.perf_counter()
        # the aggregator-level snapshot retries ONCE at the next pow2
        # out_capacity if the wide merge overflows (loud log), so a
        # slightly-low output_estimate degrades to a slow snapshot
        # instead of a dead session
        state, stats = self._agg.snapshot()
        jax.block_until_ready(state.keys)
        seconds = time.perf_counter() - t0
        self.metrics.observe_snapshot(
            stats, groups=int(state.occupancy()), seconds=seconds)
        self.metrics.observe_policy(
            self._agg.policy_events, readbacks=self._agg.readbacks_paid,
            current=self._agg.arm)
        return state, stats

    # -- eviction --------------------------------------------------------

    def retire_below(self, threshold) -> int:
        """Retire every resident row with key ``< threshold`` (watermark
        TTL).  One scalar host sync; returns the cumulative retired-row
        count, which every later snapshot also reports as
        ``stats.rows_retired``."""
        self._check_open()
        self.flush()
        total = self._agg.evict_below(threshold)
        self.metrics.rows_retired = total
        return total

    # -- teardown --------------------------------------------------------

    def close(self) -> tuple[AggState, SpillStats]:
        """Final destructive drain (the plain streaming ``finalize``);
        the service accepts no further ingest."""
        self._check_open()
        self.flush()
        self._closed = True
        out = self._agg.finalize()
        self.metrics.observe_policy(
            self._agg.policy_events, readbacks=self._agg.readbacks_paid,
            current=self._agg.arm)
        return out
