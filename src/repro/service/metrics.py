"""Host-side metrics facade for the aggregation service.

Everything here is a plain Python counter updated from numbers the host
already knows (chunk row counts) or reads back anyway at snapshot
boundaries (the one :meth:`~repro.core.types.DeviceSpillStats.finalize`
readback).  Crucially, NOTHING in this module touches the device on the
ingest path — the engine's zero-readback contract is what the service's
sustained throughput rests on, and the metrics must not tax it.
"""
from __future__ import annotations

import dataclasses

from repro.core.types import SpillStats


def _quantile(sorted_values: list[float], q: float) -> float:
    """Nearest-rank quantile over an already sorted list (0 when empty)."""
    if not sorted_values:
        return 0.0
    idx = min(len(sorted_values) - 1, int(q * len(sorted_values)))
    return sorted_values[idx]


@dataclasses.dataclass
class ServiceMetrics:
    """Running counters of one service/session lifetime.

    ``duplicate_rate`` is the observed fraction of ingested rows that
    collapsed into an existing group as of the last snapshot
    (``1 - groups/rows``) — the signal the hash-vs-sort literature uses
    to pick a policy, surfaced here so an operator can re-provision a
    long-lived session.  With eviction active it is computed over the
    cumulative ingest and is therefore an upper bound (retired groups
    no longer count toward ``groups``).
    """

    rows_ingested: int = 0
    chunks_ingested: int = 0
    snapshots_taken: int = 0
    rows_retired: int = 0
    groups_last_snapshot: int = 0
    duplicate_rate: float = 0.0
    max_index_occupancy: int = 0
    runs_generated: int = 0
    rows_spilled: int = 0
    # adaptive-policy telemetry (zero / empty for fixed-policy sessions):
    # the governor's switch events, the O(stream/k) scalar readbacks it
    # paid, and the arm the next ingest will run under
    policy_switches: int = 0
    readbacks_paid: int = 0
    current_policy: str = ""
    policy_events: list[dict] = dataclasses.field(default_factory=list)
    snapshot_latencies_s: list[float] = dataclasses.field(
        default_factory=list)

    # -- update hooks ----------------------------------------------------

    def observe_ingest(self, rows: int) -> None:
        """Record one ingested chunk (host-known row count, no sync)."""
        self.rows_ingested += int(rows)
        self.chunks_ingested += 1

    def observe_snapshot(self, stats: SpillStats, *, groups: int,
                         seconds: float) -> None:
        """Fold one snapshot's (already read back) stats in."""
        self.snapshots_taken += 1
        self.groups_last_snapshot = int(groups)
        self.rows_retired = int(stats.rows_retired)
        self.max_index_occupancy = max(
            self.max_index_occupancy, int(stats.max_index_occupancy))
        self.runs_generated = int(stats.runs_generated)
        self.rows_spilled = int(stats.rows_spilled_run_generation)
        if self.rows_ingested:
            self.duplicate_rate = max(
                0.0, 1.0 - groups / self.rows_ingested)
        self.snapshot_latencies_s.append(float(seconds))

    def observe_policy(self, events: list[dict], *, readbacks: int,
                       current: str) -> None:
        """Fold in the engine's policy-governor telemetry (host-known —
        the events were recorded when the governor's readbacks already
        happened, so this adds no device traffic)."""
        self.policy_events = list(events)
        self.policy_switches = len(self.policy_events)
        self.readbacks_paid = int(readbacks)
        self.current_policy = str(current)

    # -- derived views ---------------------------------------------------

    def snapshot_latency_s(self, q: float) -> float:
        """Latency quantile (e.g. ``q=0.5`` / ``q=0.99``) over every
        snapshot taken so far."""
        return _quantile(sorted(self.snapshot_latencies_s), q)

    def summary(self) -> dict:
        """One flat dict for logs / JSON reports."""
        return {
            "rows_ingested": self.rows_ingested,
            "chunks_ingested": self.chunks_ingested,
            "snapshots_taken": self.snapshots_taken,
            "rows_retired": self.rows_retired,
            "groups_last_snapshot": self.groups_last_snapshot,
            "duplicate_rate": round(self.duplicate_rate, 4),
            "max_index_occupancy": self.max_index_occupancy,
            "runs_generated": self.runs_generated,
            "rows_spilled": self.rows_spilled,
            "policy_switches": self.policy_switches,
            "readbacks_paid": self.readbacks_paid,
            "current_policy": self.current_policy,
            "snapshot_p50_s": self.snapshot_latency_s(0.5),
            "snapshot_p99_s": self.snapshot_latency_s(0.99),
        }
