"""The ordered in-memory index engine (paper §3.4), with *merge*-based
absorption instead of sort-the-world.

The paper's central data structure is an ordered in-memory index whose
batched insert "turns the per-row search into a merge".  The previous
implementation absorbed a batch by concatenating it with the table and
re-sorting the union — O((M+B)·log(M+B)) comparisons per batch.  This
module implements the batched insert as an actual **linear two-pointer
merge**, vectorized for XLA:

* :func:`merge_ranks` — the output position of every row of two sorted
  key vectors in their merged order, via two ``searchsorted`` rank
  computations (each row binary-searches the *other* side once; no sort
  of the union ever happens).
* :func:`interleave_sorted` — gather both states through those ranks:
  the ranks are a permutation of ``range(|a|+|b|)``, inverted by one more
  binary search, so one gather per column produces the merged,
  still-sorted union.
* :func:`merge_absorb_xla` — interleave + segmented combine: equal keys
  are adjacent after the merge, so the b-tree "absorb" is the same
  segmented combine used everywhere else.  The combine itself is a
  segmented associative scan + compaction gather, so the whole XLA
  merge-absorb path emits **no sort and no scatter**.

The :class:`OrderedIndex` wrapper carries the engine invariant **in the
type**:

    keys ascending · valid keys duplicate-free · EMPTY-padded suffix

Every constructor either establishes the invariant (``from_unsorted`` —
the only remaining full-argsort path) or preserves it (``merge_absorb``,
``trim``, ``empty``), so a function receiving an ``OrderedIndex`` never
needs to re-sort defensively.  The Pallas twin of this engine is the
merge-path kernel in :mod:`repro.kernels.merge_path`; backend selection
goes through :mod:`repro.core.dispatch`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.types import (
    AggState,
    concat_states,
    empty_key,
    empty_state,
    take,
)

_INF = jnp.float32(jnp.inf)


# ---------------------------------------------------------------------------
# linear merge of two sorted key vectors (rank computation)
# ---------------------------------------------------------------------------


def merge_ranks(a_keys: jax.Array, b_keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Output positions of two *sorted* key vectors in merged order.

    ``pos_a[i] = i + |{j : b[j] <  a[i]}|`` and
    ``pos_b[j] = j + |{i : a[i] <= b[j]}|`` — together a permutation of
    ``range(|a|+|b|)`` (stable: ``a`` precedes ``b`` on ties).  EMPTY is
    the key dtype's maximum, so padding naturally ranks to the tail.  No
    sort primitive is used (see the jaxpr test in tests/test_ordered_index.py).
    """
    na, nb = a_keys.shape[0], b_keys.shape[0]
    pos_a = jnp.arange(na, dtype=jnp.int32) + jnp.searchsorted(
        b_keys, a_keys, side="left", method="scan_unrolled"
    ).astype(jnp.int32)
    pos_b = jnp.arange(nb, dtype=jnp.int32) + jnp.searchsorted(
        a_keys, b_keys, side="right", method="scan_unrolled"
    ).astype(jnp.int32)
    return pos_a, pos_b


def merge_gather_indices(a_keys: jax.Array, b_keys: jax.Array) -> jax.Array:
    """Gather indices realizing the linear merge: ``src[k]`` is the row of
    ``concat(a, b)`` that lands at merged position ``k``.

    Built from :func:`merge_ranks` by *inverting* the (sorted) ``pos_a``
    rank vector with one more binary search instead of scattering through
    it — scatters are the expensive primitive on every backend, gathers
    are nearly free.
    """
    na, nb = a_keys.shape[0], b_keys.shape[0]
    pos_a, _ = merge_ranks(a_keys, b_keys)
    k = jnp.arange(na + nb, dtype=jnp.int32)
    # ca[k] = #rows of `a` among the first k merged rows; where position k
    # holds an `a` row, pos_a[ca[k]] == k.
    ca = jnp.searchsorted(pos_a, k, side="left", method="scan_unrolled").astype(
        jnp.int32
    )
    ca_c = jnp.minimum(ca, max(na - 1, 0))
    take_a = jnp.take(pos_a, ca_c, mode="clip") == k
    ib = jnp.minimum(k - ca, max(nb - 1, 0))
    return jnp.where(take_a, ca_c, na + ib)


def interleave_sorted(a: AggState, b: AggState) -> AggState:
    """Merge two key-sorted states into one sorted state of capacity
    ``|a|+|b|`` (duplicates kept adjacent, not yet combined)."""
    src = merge_gather_indices(a.keys, b.keys)

    def pick(xa, xb):
        return jnp.take(jnp.concatenate([xa, xb], axis=0), src, axis=0, mode="clip")

    return jax.tree.map(pick, a, b)


# ---------------------------------------------------------------------------
# segmented combine (the b-tree absorb) — XLA reference implementation
# ---------------------------------------------------------------------------


def _segmented_scan_xla(state: AggState) -> tuple[AggState, jax.Array]:
    """Inclusive segmented scan over a key-sorted state: row i holds the
    aggregate of its segment's prefix, so segment *tails* hold complete
    group aggregates.  Returns (scanned state, tail mask).

    This is the XLA rendering of the flag-based segmented scan the Pallas
    kernel uses (:mod:`repro.kernels.segmented_reduce`): a single
    ``lax.associative_scan`` over (restart-flag, count, sum, min, max)
    tuples — log-depth slices and elementwise combines, **no scatter**.
    """
    k = state.keys
    valid = k != empty_key(k.dtype)
    same_prev = jnp.concatenate([jnp.zeros((1,), bool), k[1:] == k[:-1]]) & valid
    starts = ~same_prev  # EMPTY rows restart too: they never join a group

    def comb(a, b):
        fa, ca, sa, mna, mxa = a
        fb, cb, sb, mnb, mxb = b
        keep = fb  # b starts a new segment ⇒ discard a's running aggregate
        kcol = keep[..., None]
        return (
            fa | fb,
            jnp.where(keep, cb, ca + cb),
            jnp.where(kcol, sb, sa + sb),
            jnp.where(kcol, mnb, jnp.minimum(mna, mnb)),
            jnp.where(kcol, mxb, jnp.maximum(mxa, mxb)),
        )

    _, cnt, ssum, smin, smax = jax.lax.associative_scan(
        comb, (starts, state.count, state.sum, state.min, state.max)
    )
    tails = jnp.concatenate([k[1:] != k[:-1], jnp.ones((1,), bool)]) & valid
    return AggState(k, cnt, ssum, smin, smax), tails


def segmented_combine_xla(state: AggState) -> AggState:
    """Combine adjacent equal-key rows of a key-sorted state.

    Output keeps the input capacity: unique groups are compacted to the
    front (still sorted), the tail is EMPTY.  Implemented scatter-free: a
    segmented associative scan leaves each group's aggregate at its tail
    row, and the tails are compacted to the front with the same
    cumsum-invert *gather* used everywhere else (:func:`_compact_rows`) —
    scatters are the expensive primitive on every backend.
    """
    if state.capacity == 0:
        return state
    scanned, tails = _segmented_scan_xla(state)
    return _compact_rows(scanned, tails)


def compact_indices(keep: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Gather indices compacting the ``keep``-flagged rows to the front
    without a scatter: ``src[j]`` is the row index of the j-th kept row
    (found by a binary search over the running count of kept rows) and
    ``live[j]`` flags whether output row j holds a kept row at all.
    Shared by the segmented-combine compaction and the merge join's
    match compaction."""
    n = keep.shape[0]
    csum = jnp.cumsum(keep.astype(jnp.int32))
    n_keep = csum[-1]
    j = jnp.arange(n, dtype=jnp.int32)
    src = jnp.searchsorted(csum, j + 1, side="left", method="scan_unrolled").astype(
        jnp.int32
    )
    return jnp.minimum(src, n - 1), j < n_keep


def _compact_rows(state: AggState, keep: jax.Array) -> AggState:
    """Gather the ``keep``-flagged rows to the front (EMPTY/neutral tail)
    via :func:`compact_indices` — no scatter."""
    pos, live = compact_indices(keep)

    def take_live(col, fill):
        v = jnp.take(col, pos, axis=0, mode="clip")
        mask = live.reshape((-1,) + (1,) * (v.ndim - 1))
        return jnp.where(mask, v, fill)

    return AggState(
        keys=take_live(state.keys, empty_key(state.keys.dtype)),
        count=take_live(state.count, 0),
        sum=take_live(state.sum, 0.0),
        min=take_live(state.min, _INF),
        max=take_live(state.max, -_INF),
    )


def pair_combine_xla(merged: AggState) -> AggState:
    """Absorb duplicates in a sorted state where every key appears at most
    twice — the case after merging two *duplicate-free* sorted states
    (the OrderedIndex invariant).  One shifted compare + one compaction
    gather; no segmented scan, no scatter.
    """
    k = merged.keys
    n = merged.capacity
    if n == 0:
        return merged
    valid = k != empty_key(k.dtype)
    same_next = jnp.concatenate([k[1:] == k[:-1], jnp.zeros((1,), bool)]) & valid
    same_prev = jnp.concatenate([jnp.zeros((1,), bool), k[1:] == k[:-1]]) & valid
    heads = valid & ~same_prev

    def shift_up(x, fill):
        return jnp.concatenate(
            [x[1:], jnp.full((1,) + x.shape[1:], fill, x.dtype)], axis=0
        )

    m = same_next
    mcol = m[:, None]
    cnt = merged.count + jnp.where(m, shift_up(merged.count, 0), 0)
    ssum = merged.sum + jnp.where(mcol, shift_up(merged.sum, 0.0), 0.0)
    smin = jnp.where(mcol, jnp.minimum(merged.min, shift_up(merged.min, _INF)), merged.min)
    smax = jnp.where(mcol, jnp.maximum(merged.max, shift_up(merged.max, -_INF)), merged.max)
    return _compact_rows(AggState(k, cnt, ssum, smin, smax), heads)


def merge_absorb_xla(
    a: AggState, b: AggState, *, assume_unique: bool = False
) -> AggState:
    """Linear merge-absorb of two key-sorted states: interleave by rank,
    then combine the now-adjacent equal keys.  Capacity ``|a|+|b|``.

    ``assume_unique=True`` asserts each input is duplicate-free (the
    OrderedIndex invariant): merged groups then hold at most two rows and
    the combine collapses to :func:`pair_combine_xla`.
    """
    if a.capacity == 0 or b.capacity == 0:  # degenerate: nothing to merge
        merged = concat_states(a, b)
        return merged if assume_unique else segmented_combine_xla(merged)
    merged = interleave_sorted(a, b)
    if assume_unique:
        return pair_combine_xla(merged)
    return segmented_combine_xla(merged)


# ---------------------------------------------------------------------------
# the typed engine layer
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OrderedIndex:
    """A fixed-capacity AggState carrying the engine invariant in the type:
    keys ascending, valid keys duplicate-free, EMPTY-padded suffix.

    Constructors either establish the invariant (``from_unsorted`` — the
    only full-argsort path) or preserve it (``empty``, ``merge_absorb``,
    ``trim``).  ``wrap`` asserts nothing and exists for callers that
    maintain the invariant themselves (e.g. shift/mask steps that keep
    prefixes of sorted states).
    """

    state: AggState

    # -- plain accessors -------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.state.capacity

    @property
    def width(self) -> int:
        return self.state.width

    @property
    def keys(self) -> jax.Array:
        return self.state.keys

    def occupancy(self) -> jax.Array:
        return self.state.occupancy()

    # -- constructors ----------------------------------------------------
    @classmethod
    def empty(
        cls, capacity: int, width: int, *, key_dtype=jnp.uint32
    ) -> "OrderedIndex":
        return cls(empty_state(capacity, width, key_dtype=key_dtype))

    @classmethod
    def wrap(cls, state: AggState) -> "OrderedIndex":
        """Trust the caller that ``state`` already satisfies the invariant."""
        return cls(state)

    @classmethod
    def from_unsorted(cls, state: AggState, *, backend: str = "xla") -> "OrderedIndex":
        """Canonicalize arbitrary rows: full argsort + combine.  This is
        the only entry point that sorts; everything else merges."""
        be = dispatch.get_backend(backend)
        return cls(be.segmented_combine(take(state, be.argsort(state.keys))))

    # -- invariant-preserving ops ---------------------------------------
    def merge_absorb(self, other: "OrderedIndex", *, backend: str = "xla") -> "OrderedIndex":
        """Batched insert (§3.4): linear merge, never a full sort.
        Result capacity is ``self.capacity + other.capacity``.  Both
        sides carry the duplicate-free invariant, so the absorb is a
        single pair-combine."""
        be = dispatch.get_backend(backend)
        return OrderedIndex(be.merge_sorted(self.state, other.state, assume_unique=True))

    def trim(self, capacity: int) -> "OrderedIndex":
        """Keep the first ``capacity`` rows (the smallest keys).  Safe
        whenever occupancy ≤ capacity; callers check occupancy first."""
        return OrderedIndex(jax.tree.map(lambda x: x[:capacity], self.state))
