"""Analytic spill-volume / merge-level models (paper §3.5, §4.3, Examples
3–5, Figures 7, 23, 24).

All quantities are in rows (the paper's unit).  These models drive the
optimizer-style planning in :mod:`repro.core.insort`, reproduce the
paper's worked examples exactly (tested in tests/test_cost_model.py), and
generate the Fig 23/24 curves.  The same arithmetic validates the *exact*
accounting measured from the executable implementation — the
property-based tests assert the two agree.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class CostBreakdown:
    run_generation_spill: float = 0.0
    merge_spill: float = 0.0
    merge_steps: list[float] = dataclasses.field(default_factory=list)
    initial_runs: float = 0.0
    initial_run_size: float = 0.0
    merge_levels: int = 0

    @property
    def total_spill(self) -> float:
        return self.run_generation_spill + self.merge_spill

    @property
    def io_volume(self) -> float:  # write + read, the unit of Fig 23/24
        return 2.0 * self.total_spill


def ceil_log(x: float, F: int) -> int:
    """ceil(log_F(x)) robust to x being an exact power of F in floats."""
    if x <= 1:
        return 0
    return max(1, math.ceil(round(math.log(x, F), 9)))


def expected_unique(n: float, o: float) -> float:
    """E[#distinct keys among n draws from o equally-likely keys]."""
    if o <= 0:
        return 0.0
    return o * (1.0 - (1.0 - 1.0 / o) ** n)


def early_agg_run_gen(I: float, O: float, M: float, *, replacement_selection=False):
    """§3.5: with memory full of unique keys, each input row is absorbed
    with probability M/O.  Predicted spill: M + (1 − M/O)·I  (Fig 7)."""
    if O <= M:
        return 0.0, 0.0, 0.0  # spill, runs, run size
    spill = M + (1.0 - M / O) * I
    run_size = 2.0 * M if replacement_selection else M
    return spill, max(1.0, spill / run_size), run_size


def _partial_phase_steps(n: float, F: int) -> list[int]:
    """Fan-ins of the minimal merge steps reducing n runs to F (paper Ex 4:
    500 → 100 with F=100 takes one fan-in-5 step then four fan-in-100)."""
    n = int(math.ceil(n))
    if n <= F:
        return []
    red = n - F
    k = math.ceil(red / (F - 1))
    first = red - (k - 1) * (F - 1) + 1  # fan-in of the first (smallest) step
    return [first] + [F] * (k - 1)


def simulate_insort(
    I: float,
    O: float,
    M: float,
    F: int,
    *,
    early_aggregation: bool = True,
    wide_merge: bool = True,
    in_run_dedup: bool = True,
    replacement_selection: bool = False,
) -> CostBreakdown:
    """Level-by-level spill accounting for sort-based aggregation.

    Switch matrix (matching the executable variants):
      early_aggregation=False, in_run_dedup=False, wide_merge=False
          → traditional sort + in-stream aggregation (Fig 2 top)
      early_aggregation=False, in_run_dedup=True, wide_merge=False
          → duplicate removal within runs [3] (Fig 2 bottom)
      early_aggregation=True,  wide_merge=True
          → the paper's operator (§3 + §4)
    """
    cb = CostBreakdown()
    if early_aggregation:
        spill, n_runs, run_size = early_agg_run_gen(
            I, O, M, replacement_selection=replacement_selection
        )
        if spill == 0.0:
            return cb  # in-memory (Fig 6)
    elif in_run_dedup:
        run_size = expected_unique(M, O)
        n_runs = math.ceil(I / M)
        spill = n_runs * run_size
    else:
        run_size = M
        n_runs = math.ceil(I / M)
        spill = I
    cb.run_generation_spill = spill
    cb.initial_runs = n_runs
    cb.initial_run_size = run_size

    dedup = early_aggregation or in_run_dedup
    n, s = n_runs, run_size

    if wide_merge:
        # §4.3: traditional levels only while runs are smaller than O/F,
        # then one wide merge (its output streams out; no spill).
        pre = 0
        if O > M:
            pre = max(0, ceil_log(O / s, F) - 1)
        for _ in range(pre):
            if n <= 1:
                break
            n_new = math.ceil(n / F)
            s = min(s * F, O)
            if n_new >= 1 and n > 1:
                cb.merge_spill += n_new * s
                cb.merge_steps.append(n_new * s)
                cb.merge_levels += 1
            n = n_new
        if n > 1:
            cb.merge_levels += 1  # the wide merge itself (no spill)
        return cb

    # traditional merging: full levels while far from F, then minimal steps
    while n > F:
        if math.ceil(n / F) >= F:
            n_new = math.ceil(n / F)
            s_new = min(s * F, O) if dedup else s * F
            cb.merge_spill += n_new * s_new
            cb.merge_steps.append(n_new * s_new)
            cb.merge_levels += 1
            n, s = n_new, s_new
        else:
            for fan in _partial_phase_steps(n, F):
                out = min(fan * s, O) if dedup else fan * s
                cb.merge_spill += out
                cb.merge_steps.append(out)
            cb.merge_levels += 1
            n = F
            break
    cb.merge_levels += 1  # final merge (streams out, no spill)
    return cb


def simulate_hash(
    I: float, O: float, M: float, F: int, *, hybrid: bool = True
) -> CostBreakdown:
    """Hash aggregation with recursive partitioning (Examples 3/4, Fig 24).

    L = ceil(log_F(O/M)) partitioning levels; each level rewrites the
    then-remaining rows once; hybrid hashing absorbs M/O of the input
    before the first write.  Output buffers during partitioning are too
    small for meaningful early aggregation (§4.1), so no other reduction.
    """
    cb = CostBreakdown()
    if O <= M:
        return cb
    levels = ceil_log(O / M, F)
    cb.merge_levels = levels
    remaining = I * (1.0 - M / O) if hybrid else I
    for _ in range(levels):
        cb.merge_spill += remaining
        cb.merge_steps.append(remaining)
        # partitions only shrink once their output fits memory (final level)
    cb.run_generation_spill = 0.0
    return cb


def merge_levels_insort(O: float, M: float, F: int) -> int:
    """§4.3: output-driven merge depth ceil(log_F(O/M)) (0 if O ≤ M)."""
    if O <= M:
        return 0
    return ceil_log(O / M, F)


def merge_levels_traditional(I: float, M: float, F: int) -> int:
    """Input-driven merge depth of a traditional external sort."""
    runs = math.ceil(I / M)
    if runs <= 1:
        return 0
    return ceil_log(runs, F)


# ---------------------------------------------------------------------------
# calibrated layer: measured per-row constants + fitted crossover surface
# ---------------------------------------------------------------------------
#
# Everything above is the paper's *volume* arithmetic (rows spilled,
# merge levels) — machine-independent by construction.  The layer below
# attaches measured per-row times from ``core/_cost_constants.py``
# (regenerated by ``make calibrate``) so the planner and the runtime
# policy governor (:mod:`repro.core.adaptive`) can compare policies in
# seconds on *this* machine, which is exactly what the hash-vs-sort
# empirical study says cannot be hand-set.

COST_SCHEMA_VERSION = 1
COST_FIELDS = (
    "absorb_row_ns",
    "absorb_dup_row_ns",
    "sort_row_ns",
    "merge_row_ns",
    "hash_probe_row_ns",
    "spill_write_row_ns",
)
#: per-policy absorb fields are measured at two duplicate-rate anchors
#: (unique input ≈ d=0, heavy-duplicate input ≈ d=1) and interpolated.
ABSORB_FIELDS = ("absorb_row_ns", "absorb_dup_row_ns")
ABSORB_POLICIES = ("traditional", "inrun_dedup", "early_agg", "rs")

#: policies whose absorb step sorts each incoming batch from scratch —
#: these get the zero-sort-term credit when the input is already ordered.
SORTING_POLICIES = ("traditional", "inrun_dedup")


class StaleConstantsError(ValueError):
    """``core/_cost_constants.py`` does not match the generator schema —
    re-run ``make calibrate`` (the file is autogenerated)."""


def validate_constants(table: dict, *, source: str = "core/_cost_constants.py"):
    """Check a ``COST_CONSTANTS``-shaped table against the generator
    schema; raises :class:`StaleConstantsError` naming every problem.
    CI runs this (tests/test_adaptive.py) so a schema drift between the
    generator and the checked-in file fails loudly."""
    problems = []
    if not isinstance(table, dict) or not table:
        problems.append("top level must be a non-empty dict of backend entries")
        table = {}
    for backend, entry in table.items():
        where = f"{source}[{backend!r}]"
        if not isinstance(entry, dict):
            problems.append(f"{where}: entry must be a dict")
            continue
        ver = entry.get("schema_version")
        if ver != COST_SCHEMA_VERSION:
            problems.append(
                f"{where}: schema_version={ver!r}, generator writes "
                f"{COST_SCHEMA_VERSION}"
            )
        for field in COST_FIELDS:
            if field not in entry:
                problems.append(f"{where}: missing field {field!r}")
            elif field in ABSORB_FIELDS:
                sub = entry[field]
                missing = [p for p in ABSORB_POLICIES if p not in sub] \
                    if isinstance(sub, dict) else list(ABSORB_POLICIES)
                if missing:
                    problems.append(
                        f"{where}: {field} missing policies {missing}"
                    )
                else:
                    bad = [p for p in ABSORB_POLICIES
                           if not (float(sub[p]) > 0.0)]
                    if bad:
                        problems.append(
                            f"{where}: {field} non-positive for {bad}"
                        )
            elif not (float(entry[field]) >= 0.0):
                problems.append(f"{where}: {field} must be >= 0")
    if problems:
        raise StaleConstantsError(
            "stale/invalid cost constants — re-run `make calibrate`:\n  "
            + "\n  ".join(problems)
        )


def load_cost_constants(backend: str | None = None) -> dict:
    """The calibrated constants entry for ``backend`` (falling back to
    the committed ``cpu`` defaults for uncalibrated backends)."""
    from repro.core import _cost_constants as cc

    validate_constants(cc.COST_CONSTANTS)
    table = cc.COST_CONSTANTS
    if backend in table:
        return table[backend]
    return table["cpu"]


def _spill_fraction(policy: str, dup_rate: float) -> float:
    """Fraction of absorbed rows the run-generation phase spills.  The
    traditional sort spills every row; the deduplicating policies spill
    only the rows their window fails to absorb (§3.5 first-order: the
    duplicate fraction is absorbed)."""
    d = min(1.0, max(0.0, dup_rate))
    if policy == "traditional":
        return 1.0
    return 1.0 - d


def policy_cost_per_row(
    policy: str,
    dup_rate: float,
    *,
    constants: dict | None = None,
    backend: str | None = None,
    merge_levels: int = 1,
    input_sorted: bool = False,
) -> float:
    """Calibrated per-input-row cost (ns) of running the streamed
    pipeline under ``policy`` at the given duplicate rate.

    ``cost(d) = absorb + spill_frac(d) · (spill_write + merge · levels)``

    ``input_sorted=True`` credits an upstream-established key order with
    a zero sort term: the batch-sorting policies' absorb cost drops by
    the measured ``sort_row_ns`` (an upstream :func:`repro.aggregate`
    emits key-sorted relations, so re-sorting them is pure waste).
    """
    c = constants if constants is not None else load_cost_constants(backend)
    d = min(1.0, max(0.0, dup_rate))
    a0 = float(c["absorb_row_ns"][policy])
    a1 = float(c["absorb_dup_row_ns"][policy])
    absorb = a0 + d * (a1 - a0)
    if input_sorted and policy in SORTING_POLICIES:
        absorb = max(0.0, absorb - float(c["sort_row_ns"]))
    per_spilled = float(c["spill_write_row_ns"]) + float(c["merge_row_ns"]) * max(
        0, merge_levels
    )
    return absorb + _spill_fraction(policy, dup_rate) * per_spilled


def choose_policy(
    dup_rate: float,
    *,
    arms=("traditional", "early_agg", "rs"),
    constants: dict | None = None,
    backend: str | None = None,
    merge_levels: int = 1,
    input_sorted: bool = False,
) -> str:
    """argmin over ``arms`` of :func:`policy_cost_per_row` — the
    decision the runtime governor re-evaluates mid-flight."""
    c = constants if constants is not None else load_cost_constants(backend)
    return min(
        arms,
        key=lambda p: policy_cost_per_row(
            p, dup_rate, constants=c, merge_levels=merge_levels,
            input_sorted=input_sorted,
        ),
    )


def crossover_dup_rate(
    a: str = "traditional",
    b: str = "early_agg",
    *,
    constants: dict | None = None,
    backend: str | None = None,
    merge_levels: int = 1,
    input_sorted: bool = False,
) -> float:
    """The duplicate rate at which policy ``b`` starts beating policy
    ``a`` (clamped to [0, 1]).  With the default pair this is the fitted
    machine-specific hash-vs-sort-style crossover surface: below it the
    cheap-absorb policy wins, above it the deduplicating window pays for
    itself."""
    c = constants if constants is not None else load_cost_constants(backend)

    def cost(p, d):
        return policy_cost_per_row(
            p, d, constants=c, merge_levels=merge_levels,
            input_sorted=input_sorted,
        )

    # cost_p(d) is linear in d, so solve cost_a(d) == cost_b(d) exactly.
    a0, a1 = cost(a, 0.0), cost(a, 1.0)
    b0, b1 = cost(b, 0.0), cost(b, 1.0)
    denom = (a1 - a0) - (b1 - b0)
    if denom == 0.0:
        return 0.0 if b0 <= a0 else 1.0
    d = (b0 - a0) / denom
    return min(1.0, max(0.0, d))


def estimate_seconds(
    policy: str,
    n_rows: float,
    dup_rate: float,
    *,
    constants: dict | None = None,
    backend: str | None = None,
    merge_levels: int = 1,
    input_sorted: bool = False,
) -> float:
    """End-to-end predicted wall time for ``n_rows`` under ``policy``."""
    return (
        policy_cost_per_row(
            policy, dup_rate, constants=constants, backend=backend,
            merge_levels=merge_levels, input_sorted=input_sorted,
        )
        * n_rows
        * 1e-9
    )


def cost_surface(
    n_rows: float,
    output_estimate: float,
    *,
    backend: str | None = None,
    merge_levels: int = 1,
    input_sorted: bool = False,
) -> dict:
    """The fitted decision surface, as surfaced in ``AggResult.plan``."""
    c = load_cost_constants(backend)
    d_est = 0.0
    if n_rows > 0 and output_estimate > 0:
        d_est = min(1.0, max(0.0, 1.0 - output_estimate / n_rows))
    kw = dict(constants=c, merge_levels=merge_levels, input_sorted=input_sorted)
    return {
        "calibrated_backend": c["meta"].get("backend", "cpu")
        if isinstance(c.get("meta"), dict) else "cpu",
        "schema_version": c["schema_version"],
        "input_sorted": input_sorted,
        "estimated_dup_rate": d_est,
        "crossover_dup_rate": crossover_dup_rate(**kw),
        "policy_cost_ns_per_row": {
            p: policy_cost_per_row(p, d_est, **kw)
            for p in ("traditional", "early_agg", "rs")
        },
        "chosen_policy": choose_policy(d_est, **kw),
    }


def join_cost_surface(
    n_left: float,
    n_right: float,
    *,
    inputs_sorted: bool = True,
    backend: str | None = None,
) -> dict:
    """Calibrated cost picture of a two-sided join, as surfaced in
    ``JoinResult.plan["cost_model"]``.

    The merge join itself is one rank-alignment probe over already-sorted
    inputs; what varies is the **order-enforcement** term: a join whose
    inputs arrive sorted (``inputs_sorted=True`` — every upstream
    :func:`repro.aggregate` emits key-sorted relations) pays a ZERO sort
    term (``sort_rows == 0``), while re-sorting both sides first pays
    ``sort_row_ns`` per input row.  ``sort_ns_avoided`` makes the credit
    explicit — it is the order-enforcement cost the composed pipeline
    never pays (the ROADMAP's "Reducing Order Enforcement Cost" item).
    The hash-join baseline (build + probe at ``hash_probe_row_ns``) is
    included for the optimizer-style comparison.
    """
    c = load_cost_constants(backend)
    sort_ns = float(c["sort_row_ns"])
    merge_ns = float(c["merge_row_ns"])
    hash_ns = float(c["hash_probe_row_ns"])
    n = float(n_left) + float(n_right)
    sort_rows = 0.0 if inputs_sorted else n
    probe_ns = merge_ns * float(n_left)
    return {
        "inputs_sorted": inputs_sorted,
        "sort_rows": sort_rows,
        "sort_ns": sort_ns * sort_rows,
        "sort_ns_avoided": sort_ns * n if inputs_sorted else 0.0,
        "probe_ns": probe_ns,
        "merge_join_ns": sort_ns * sort_rows + probe_ns,
        "hash_join_ns": hash_ns * n,
    }


def fig24_curves(
    I: float = 100e6, M: float = 100e3, F: int = 10, points: int = 25
):
    """Revised algorithm comparison (Fig 24): I/O volume vs reduction factor.

    Returns (reduction_factors, io_sort_early3, io_hash_hybrid, io_insort).
    Row ≡ byte here (the paper plots MB with these same parameters).
    """
    out = ([], [], [], [])
    for i in range(points):
        red = 10 ** (3.0 * i / (points - 1))  # 1 … 1000
        O = I / red
        a = simulate_insort(
            I, O, M, F, early_aggregation=False, in_run_dedup=True, wide_merge=False
        ).io_volume
        b = simulate_hash(I, O, M, F, hybrid=True).io_volume
        c = simulate_insort(I, O, M, F, early_aggregation=True, wide_merge=True).io_volume
        out[0].append(red)
        out[1].append(a)
        out[2].append(b)
        out[3].append(c)
    return out
