"""Backend registry for the ordered-index engine.

Every grouping primitive (argsort, segmented combine, sorted merge-absorb)
used to be selected by a ``backend: str`` threaded through each call site
with ad-hoc lazy imports.  This module centralizes that plumbing:

* ``register_backend(name, loader)`` — loaders build a :class:`Backend`
  on first use and may raise :class:`BackendUnavailable` (capability
  probing: e.g. the Pallas backend probes its kernel imports).
* ``get_backend(name)`` — resolves a name (or ``"auto"``) to a cached
  :class:`Backend`.  ``"auto"`` prefers Pallas on TPU and XLA elsewhere.
* ``should_interpret()`` — the single source of truth for Pallas
  ``interpret=`` mode: interpret everywhere except on real TPU, with an
  explicit ``REPRO_PALLAS_INTERPRET`` env override for experiments.

Built-in backends:

* ``"xla"``    — pure-jnp reference engine (:mod:`repro.core.ordered_index`);
  always available, bit-exact oracle for tests and dry-runs.
* ``"pallas"`` — TPU kernels (:mod:`repro.kernels`): bitonic argsort,
  fused segmented scan, and the merge-path merge-absorb kernel.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable

import jax


class BackendUnavailable(RuntimeError):
    """A backend's loader determined it cannot run in this environment."""


@dataclasses.dataclass(frozen=True)
class Backend:
    """The three primitives every engine backend must provide.

    ``argsort(keys) -> perm``
        Key-argsort of a 1-D uint32/uint64 vector (the per-dtype EMPTY
        sentinel sorts to the end).  uint64 callers hold
        :func:`repro.core.types.key_dtype_context`.
    ``segmented_combine(state) -> state``
        Combine adjacent equal-key rows of a *key-sorted* AggState;
        unique groups compacted to the front, EMPTY-padded tail.
    ``merge_sorted(a, b, assume_unique=False) -> state``
        Linear merge-absorb of two *key-sorted* AggStates; returns a
        sorted, duplicate-combined state of capacity ``|a| + |b|``.
        Must not perform a full sort of the union.  ``assume_unique``
        promises both inputs are duplicate-free (the OrderedIndex
        invariant), licensing a cheaper pair-combine.
    ``interleave(a, b) -> state`` (optional)
        Linear merge of two *key-sorted* AggStates WITHOUT combining
        duplicates — the raw sorted multiset union (traditional merge
        levels that defer aggregation need exactly this).  ``None``
        means the engine falls back to the XLA rank-gather interleave.
    ``join_probe(a_keys, b_keys) -> (pos, hit)`` (optional)
        Rank-align each key of a *sorted* vector against a second
        *sorted* vector (the merge join's probe phase; see
        :func:`repro.core.merge_join.join_probe`).  ``None`` means the
        join falls back to the XLA searchsorted probe.
    ``shardable``
        Whether the backend's primitives may be traced inside a
        ``shard_map`` manual-collective region (the mesh-sharded
        pipeline runs the whole engine per shard).  Capability flag, not
        a promise of speed — interpret-mode Pallas is shardable but
        slow off-TPU.
    """

    name: str
    argsort: Callable
    segmented_combine: Callable
    merge_sorted: Callable
    interleave: Callable | None = None
    join_probe: Callable | None = None
    shardable: bool = True


_loaders: dict[str, Callable[[], Backend]] = {}
_cache: dict[str, Backend] = {}


def register_backend(
    name: str, loader: Callable[[], Backend], *, overwrite: bool = False
) -> None:
    """Register a lazy backend loader.  The loader runs on first
    ``get_backend(name)`` and may raise :class:`BackendUnavailable`."""
    if name in _loaders and not overwrite:
        raise ValueError(f"backend {name!r} already registered")
    _loaders[name] = loader
    _cache.pop(name, None)


def registered_backends() -> tuple[str, ...]:
    return tuple(_loaders)


def backend_available(name: str) -> bool:
    """Capability probe: can ``name`` actually be constructed here?"""
    try:
        get_backend(name)
        return True
    except (KeyError, BackendUnavailable):
        return False


def _auto_order() -> tuple[str, ...]:
    # On TPU the Pallas kernels are the fast path; everywhere else they
    # run in interpret mode and the XLA engine wins.
    if jax.default_backend() == "tpu":
        return ("pallas", "xla")
    return ("xla", "pallas")


def get_backend(name: str = "xla") -> Backend:
    """Resolve a backend name (or ``"auto"``) to a Backend instance."""
    if name in ("auto", None):
        last: Exception | None = None
        for cand in _auto_order():
            try:
                return get_backend(cand)
            except (KeyError, BackendUnavailable) as e:  # keep probing
                last = e
        raise BackendUnavailable(f"no usable backend among {_auto_order()}: {last}")
    if name in _cache:
        return _cache[name]
    if name not in _loaders:
        raise KeyError(
            f"unknown backend {name!r}; registered: {registered_backends()}"
        )
    be = _loaders[name]()
    _cache[name] = be
    return be


def resolve_backend_name(name: str) -> str:
    """Normalize ``"auto"`` to a concrete backend name (for static args)."""
    return get_backend(name).name


def check_shardable(name: str) -> None:
    """Raise :class:`BackendUnavailable` if ``name`` cannot run inside a
    ``shard_map`` region (mesh-sharded pipeline front door guard)."""
    be = get_backend(name)
    if not be.shardable:
        raise BackendUnavailable(
            f"backend {be.name!r} does not support shard_map execution; "
            "use backend='xla' (or 'auto') for mesh-sharded aggregation"
        )


def should_interpret() -> bool:
    """Pallas interpret mode: True off-TPU, overridable via env."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.lower() not in ("0", "false", "no")
    return jax.default_backend() != "tpu"


def _load_xla() -> Backend:
    import jax.numpy as jnp

    from repro.core import ordered_index as oi

    return Backend(
        name="xla",
        argsort=jnp.argsort,
        segmented_combine=oi.segmented_combine_xla,
        merge_sorted=oi.merge_absorb_xla,
        interleave=oi.interleave_sorted,
    )


def _load_pallas() -> Backend:
    try:
        from repro.kernels import ops as kops
    except Exception as e:  # missing pallas / mosaic in this build
        raise BackendUnavailable(f"pallas kernels unavailable: {e}") from e
    return Backend(
        name="pallas",
        argsort=kops.argsort_keys,
        segmented_combine=kops.segmented_combine,
        merge_sorted=kops.merge_absorb_sorted,
        # no fused non-combining merge kernel yet: the rank-gather
        # interleave is memory-bound and the XLA fallback serves it
        interleave=None,
        join_probe=kops.join_probe,
    )


register_backend("xla", _load_xla)
register_backend("pallas", _load_pallas)
