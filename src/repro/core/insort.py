"""The paper's full operator: in-sort duplicate removal, grouping, and
aggregation = early aggregation during run generation (§3) + wide merging
in the final merge step (§4).

Merge planning follows §4.3 exactly: traditional (aggregating) merge
levels are worthwhile only while a merge step's total input is smaller
than the final output O; once intermediate runs reach size ≥ O/F, a single
wide merge finishes the job.  With initial runs of ~M unique rows that is

    pre_levels = max(0, ceil(log_F(O / M)) - 1)

traditional levels, then one wide merge — total merge depth
``ceil(log_F(O/M))`` versus the input-driven ``ceil(log_F(I/M))`` of a
traditional sort.  O is taken from an optimizer-style estimate when given
(the paper's point is that the *same* algorithm is optimal regardless, so
a wrong estimate only shifts work between merge styles, never breaks
correctness — we property-test exactly that).
"""
from __future__ import annotations

import math

import jax
import numpy as np

from repro.core import dispatch
from repro.core import merge as merge_mod
from repro.core import run_generation as rg
from repro.core.types import AggState, ExecConfig, SpillStats, key_dtype_context


def plan_pre_merge_levels(
    output_estimate: int, cfg: ExecConfig, num_runs: int
) -> int:
    """§4.3 policy: number of traditional merge levels before the wide merge."""
    from repro.core.cost_model import ceil_log

    M, F = cfg.memory_rows, cfg.fanin
    if output_estimate <= M:
        levels = 0
    else:
        levels = max(0, ceil_log(output_estimate / M, F) - 1)
    # never more levels than needed to reach a single run anyway
    max_useful = ceil_log(num_runs, F) if num_runs > 1 else 0
    return min(levels, max_useful)


def insort_aggregate(
    keys: np.ndarray,
    payload: np.ndarray | None = None,
    cfg: ExecConfig | None = None,
    *,
    output_estimate: int | None = None,
    early_aggregation: bool = True,
    use_wide_merge: bool = True,
    run_policy: str = "rs",
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
    pipeline: str = "host",
    mesh=None,
    mesh_axis: str | None = None,
) -> tuple[AggState, SpillStats]:
    """Group/aggregate an unsorted stream under a memory budget of M rows.

    Returns (sorted aggregate state, exact spill accounting).  Flags switch
    off the paper's two techniques to recover the baselines of Fig 2:

    * ``early_aggregation=False, use_wide_merge=False`` → traditional
      external merge sort + in-stream aggregation (Fig 2 top) when
      combined with ``policy='traditional'`` semantics, or Bitton/DeWitt
      in-run dedup (Fig 2 bottom).

    ``pipeline`` selects the executor: ``"host"`` (default here) is the
    reference loop with exact per-level accounting; ``"device"`` routes
    to the fused scan-based program of :mod:`repro.core.pipeline` (O(1)
    host syncs; the §4.3 pre-wide merge levels are planned statically
    from ``output_estimate`` and run on device too).  Plans the fused
    program cannot express (``use_wide_merge=False``) always run on the
    host loop.

    ``mesh`` shards the device pipeline over a mesh axis (one program,
    per-shard run generation + key-range exchange); it requires
    ``pipeline="device"`` with the wide merge enabled.
    """
    cfg = cfg or ExecConfig()
    backend = dispatch.resolve_backend_name(backend)  # "auto" → concrete
    if pipeline not in ("host", "device"):
        raise ValueError(f"unknown pipeline {pipeline!r}; expected host|device")
    if mesh is not None and not (pipeline == "device" and use_wide_merge):
        raise ValueError(
            "mesh-sharded aggregation requires pipeline='device' with the "
            "wide merge enabled (the host loop is single-device)"
        )
    if pipeline == "device" and use_wide_merge:
        from repro.core import pipeline as pipeline_mod

        if early_aggregation:
            policy = "rs" if run_policy == "rs" else "early_agg"
        else:
            policy = "inrun_dedup"
        return pipeline_mod.insort_aggregate_device(
            keys, payload, cfg, policy=policy, backend=backend, widths=widths,
            output_estimate=output_estimate, mesh=mesh, mesh_axis=mesh_axis,
        )
    keys = rg._np_keys(keys)
    with key_dtype_context(keys):
        if early_aggregation and run_policy == "rs":
            # replacement selection via the ordered index (§3.3): runs up to
            # 2M, absorption continues at ~M/O throughout — the paper's model.
            runs, table, stats = rg.generate_runs_rs(
                keys, payload, cfg, backend=backend, widths=widths
            )
        else:
            policy = "early_agg" if early_aggregation else "inrun_dedup"
            runs, table, stats = rg.generate_runs(
                keys, payload, cfg, policy=policy, backend=backend, widths=widths
            )
        if table is not None:  # in-memory case (paper Fig 6): nothing spilled
            return table, stats

        if output_estimate is None:
            # production default: assume strong reduction (the common case the
            # paper optimizes); correctness never depends on this.
            output_estimate = cfg.memory_rows * cfg.fanin

        if not use_wide_merge:
            out = merge_mod.final_merge_traditional(
                runs, cfg, aggregate=early_aggregation or policy == "inrun_dedup",
                stats=stats, backend=backend,
            )
            return out, stats

        pre = plan_pre_merge_levels(output_estimate, cfg, len(runs))
        for _ in range(pre):
            if len(runs) <= 1:
                break
            runs = merge_mod.traditional_merge(
                runs, cfg, aggregate_during_merge=True, stats=stats, backend=backend,
                stop_at=max(1, math.ceil(len(runs) / cfg.fanin)),
            )
        if len(runs) == 1:
            # everything already in one aggregated run: stream it out
            return runs[0].state, stats
        out = merge_mod.wide_merge(runs, cfg, stats=stats, backend=backend)
        return out, stats


def sort_then_stream_aggregate(
    keys: np.ndarray,
    payload: np.ndarray | None = None,
    cfg: ExecConfig | None = None,
    *,
    backend: str = "auto",
) -> tuple[AggState, SpillStats]:
    """Baseline of Fig 2 (top): full external merge sort of the raw input,
    then in-stream aggregation of the sorted stream.  Spill volume grows
    with the *input* at every merge level — the paper's worst case."""
    cfg = cfg or ExecConfig()
    backend = dispatch.resolve_backend_name(backend)
    keys = rg._np_keys(keys)
    with key_dtype_context(keys):
        if keys.shape[0] <= cfg.memory_rows:  # in-memory quicksort: no spill
            from repro.core.sorted_ops import sorted_groupby

            return sorted_groupby(keys, payload, backend=backend), SpillStats()
        runs, _, stats = rg.generate_runs(
            keys, payload, cfg, policy="traditional", backend=backend
        )
        if not runs:
            raise AssertionError("traditional policy always writes runs")
        out = merge_mod.final_merge_traditional(
            runs, cfg, aggregate=False, stats=stats, backend=backend
        )
        return out, stats
