"""Schema front door: composite keys, declarative aggregates, and the
single ``aggregate()`` entry point.

The paper's thesis is that one sort-based algorithm can serve as a
system's *only* aggregation operator.  This module is the API rendering
of that thesis: instead of per-algorithm functions over a hard-wired
``uint32`` key and a fixed count/sum/min/max accumulator, callers
describe

* **what the key is** — :class:`KeySpec`, an ordered list of named
  integer key columns with bit widths, packed most-significant-first
  into ONE machine sort key (``uint32`` when ≤ 32 total bits, else
  ``uint64``).  Packing most-significant-first makes the single sort
  realize the lexicographic ordering of the column list, which is what
  lets the engine exploit *interesting orderings* generically: any
  prefix of the column list is sorted for free (Guravannavar et al.'s
  order-enforcement payoff), and rollup over any hierarchy needs one
  sort (§2.2).
* **what to compute** — :class:`AggSpec`, the requested aggregates
  (count, sum, min, max, plus finalizers like avg).  The engine's
  :class:`~repro.core.types.AggState` then carries only the value
  planes the request needs: ``AggSpec("count")`` drops all three float
  planes from every kernel and every spilled run.

``aggregate()`` routes through the backend registry
(:mod:`repro.core.dispatch`) and the analytic cost model
(:mod:`repro.core.cost_model`), and returns an :class:`AggResult` whose
relation is sorted by the composite key — `group_by`, `distinct`,
`rollup`, … in :mod:`repro.core.operators` are thin wrappers.

64-bit keys on the host are plain NumPy ``uint64``; device computation
runs inside :func:`repro.core.types.key_dtype_context`, and the Pallas
kernels compare them as a (hi, lo) pair of uint32 lanes — no native
64-bit ops on the TPU path.
"""
from __future__ import annotations

import dataclasses
import itertools
import logging
from collections.abc import Iterator
from typing import Any, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.core import dispatch
from repro.core import hash_agg as hash_mod
from repro.core import insort as insort_mod
from repro.core import merge_join as mj_mod
from repro.core import sorted_ops
from repro.core.types import (
    AggState,
    ExecConfig,
    SpillStats,
    concat_states,
    empty_key,
    empty_like,
    key_dtype_context,
    key_dtype_for_bits,
    max_key,
)


# ---------------------------------------------------------------------------
# KeySpec — composite sort keys
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KeyColumn:
    """One named integer key column occupying ``bits`` bits of the packed
    key.  Values must lie in ``[0, 2**bits)``."""

    name: str
    bits: int

    def __post_init__(self):
        if not self.name:
            raise ValueError("key column needs a name")
        if not 1 <= self.bits <= 64:
            raise ValueError(f"column {self.name!r}: bits must be in [1, 64]")

    @property
    def max_value(self) -> int:
        return (1 << self.bits) - 1


@dataclasses.dataclass(frozen=True)
class KeySpec:
    """An ordered list of key columns, major (most significant) first.

    ``KeySpec.of(year=23, month=4, day=5)`` packs ``(year << 9) |
    (month << 5) | day`` into a uint32; totals over 32 bits widen to
    uint64 (the paper's composite keys stop competing for 32 bits).  The
    packed EMPTY sentinel (all ones) is reserved: the all-max column
    combination is rejected by :meth:`pack`.
    """

    columns: tuple[KeyColumn, ...]

    def __post_init__(self):
        if not self.columns:
            raise ValueError("KeySpec needs at least one column")
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate key column names: {names}")
        if self.total_bits > 64:
            raise ValueError(
                f"composite key needs {self.total_bits} bits; the engine "
                "supports at most 64"
            )

    # -- construction ----------------------------------------------------
    @classmethod
    def of(cls, **bits_by_name: int) -> "KeySpec":
        """``KeySpec.of(year=23, month=4, day=5)`` — order is significance
        order, major first (Python keeps kwargs ordered)."""
        return cls(tuple(KeyColumn(n, b) for n, b in bits_by_name.items()))

    # -- properties ------------------------------------------------------
    @property
    def names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.columns)

    @property
    def total_bits(self) -> int:
        return sum(c.bits for c in self.columns)

    @property
    def key_dtype(self) -> np.dtype:
        return key_dtype_for_bits(self.total_bits)

    @property
    def empty(self) -> np.unsignedinteger:
        return empty_key(self.key_dtype)

    @property
    def max_packed(self) -> np.unsignedinteger:
        return max_key(self.key_dtype)

    def shift_of(self, name: str) -> int:
        """Bit position of a column's least-significant bit in the packed key."""
        shift = 0
        for c in reversed(self.columns):
            if c.name == name:
                return shift
            shift += c.bits
        raise KeyError(f"no key column {name!r} in {self.names}")

    def prefix(self, n: int) -> "KeySpec":
        """The KeySpec of the first (most significant) ``n`` columns."""
        if not 1 <= n <= len(self.columns):
            raise ValueError(f"prefix length {n} not in [1, {len(self.columns)}]")
        return KeySpec(self.columns[:n])

    # -- packing ---------------------------------------------------------
    def _as_columns(self, columns) -> list[np.ndarray]:
        if isinstance(columns, Mapping):
            missing = [n for n in self.names if n not in columns]
            if missing:
                raise KeyError(f"missing key columns: {missing}")
            cols = [columns[n] for n in self.names]
        else:
            cols = list(columns)
            if len(cols) != len(self.columns):
                raise ValueError(
                    f"expected {len(self.columns)} key columns, got {len(cols)}"
                )
        return [np.asarray(c) for c in cols]

    def pack(self, columns, *, validate: bool = True) -> np.ndarray:
        """Pack named columns (mapping or significance-ordered sequence)
        into one sort-key vector of :attr:`key_dtype`.

        Packing happens host-side in NumPy — uint64 needs no JAX x64
        flag here.  ``validate=True`` checks every column against its bit
        budget and rejects the reserved EMPTY bit pattern.
        """
        cols = self._as_columns(columns)
        out = np.zeros(cols[0].shape, dtype=np.uint64)
        for spec, col in zip(self.columns, cols):
            col = col.astype(np.uint64)
            if validate and col.size and int(col.max()) > spec.max_value:
                raise ValueError(
                    f"column {spec.name!r} exceeds its {spec.bits}-bit budget "
                    f"(max value {int(col.max())} > {spec.max_value})"
                )
            out = (out << np.uint64(spec.bits)) | col
        packed = out.astype(self.key_dtype)
        if validate and packed.size and bool((packed == self.empty).any()):
            raise ValueError(
                "the all-ones column combination packs to the reserved EMPTY "
                "sentinel; reduce a column's max value or widen a column"
            )
        return packed

    def unpack(self, keys) -> dict[str, np.ndarray]:
        """Packed keys → named columns (EMPTY rows map to all-max columns)."""
        keys = np.asarray(keys).astype(np.uint64)
        out: dict[str, np.ndarray] = {}
        shift = 0
        for c in reversed(self.columns):
            mask = np.uint64((1 << c.bits) - 1)
            out[c.name] = ((keys >> np.uint64(shift)) & mask).astype(
                np.uint32 if c.bits <= 32 else np.uint64
            )
            shift += c.bits
        return {n: out[n] for n in self.names}


# ---------------------------------------------------------------------------
# AggSpec — declarative aggregates
# ---------------------------------------------------------------------------

_FINALIZERS = {"avg": ("sum", "count")}
_STORED = ("count", "sum", "min", "max")
_KNOWN = set(_STORED) | set(_FINALIZERS)


@dataclasses.dataclass(frozen=True)
class AggSpec:
    """The requested aggregates: any of count/sum/min/max plus finalizers
    (currently ``avg`` = sum/count).  The stored accumulator carries only
    the value planes the request needs — ``AggSpec("count")`` spills no
    float columns at all."""

    names: tuple[str, ...]

    def __init__(self, *names: str):
        if len(names) == 1 and isinstance(names[0], (tuple, list)):
            names = tuple(names[0])
        if not names:
            names = ("count",)
        unknown = [n for n in names if n not in _KNOWN]
        if unknown:
            raise ValueError(f"unknown aggregates {unknown}; known: {sorted(_KNOWN)}")
        object.__setattr__(self, "names", tuple(dict.fromkeys(names)))

    @property
    def stored(self) -> tuple[str, ...]:
        """Accumulator fields needed (requested + finalizer inputs)."""
        need = set()
        for n in self.names:
            need.update(_FINALIZERS.get(n, (n,)))
        return tuple(n for n in _STORED if n in need or n == "count")

    def plane_widths(self, payload_width: int) -> tuple[int, int, int]:
        """(sum, min, max) plane widths for a V-wide payload."""
        stored = self.stored
        return (
            payload_width if "sum" in stored else 0,
            payload_width if "min" in stored else 0,
            payload_width if "max" in stored else 0,
        )

    def needs_payload(self) -> bool:
        return any(w for w in self.plane_widths(1))

    def finalize(self, state: AggState) -> dict[str, Any]:
        """Accumulator → the requested user-facing aggregate columns."""
        valid = state.valid()
        out: dict[str, Any] = {}
        for n in self.names:
            if n == "count":
                out["count"] = state.count
            elif n == "sum":
                out["sum"] = state.sum
            elif n == "min":
                out["min"] = jnp.where(valid[:, None], state.min, 0.0)
            elif n == "max":
                out["max"] = jnp.where(valid[:, None], state.max, 0.0)
            elif n == "avg":
                c = jnp.maximum(state.count, 1).astype(jnp.float32)[:, None]
                out["avg"] = state.sum / c
        return out


# ---------------------------------------------------------------------------
# the front door
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AggResult:
    """Sorted result relation of :func:`aggregate`.

    ``state`` is the raw accumulator (keys sorted ascending, EMPTY-padded
    tail); ``relation()`` unpacks it into named key columns + the
    requested aggregate columns, dropping the padding.
    """

    state: AggState
    stats: SpillStats
    by: KeySpec
    aggs: AggSpec
    plan: dict[str, Any]

    @property
    def keys(self):
        return self.state.keys

    def occupancy(self) -> int:
        return int(self.state.occupancy())

    def relation(self) -> dict[str, np.ndarray]:
        """Named key columns + aggregate columns, padding removed, rows
        sorted by the composite key (major column first)."""
        keys = np.asarray(self.state.keys)
        # mask with the STATE's sentinel: a rollup prefix level may carry a
        # narrower KeySpec (≤32 bits) over a still-uint64 engine state
        mask = keys != empty_key(keys.dtype)
        out = {n: c[mask] for n, c in self.by.unpack(keys).items()}
        for name, col in self.aggs.finalize(self.state).items():
            out[name] = np.asarray(col)[mask]
        return out

    @property
    def sorted_by(self) -> dict[str, Any]:
        """The order property this relation carries: rows ascend by the
        packed composite key, i.e. lexicographically by every ``by``
        column (major first) — established by the ONE sort the aggregation
        paid.  Downstream operators consume it instead of re-sorting:
        :meth:`merge_join` and :meth:`rollup` run with a zero sort term,
        which is what the plan's ``input_sorted`` / ``inputs_sorted``
        cost-model credit records."""
        return {
            "columns": self.by.names,
            "prefix_len": len(self.by.columns),
            "key_dtype": str(np.dtype(self.by.key_dtype)),
        }

    def _ordered_state(self) -> AggState:
        """The state with the single-device OrderedIndex layout (keys
        ascending, ONE EMPTY tail).  Mesh-produced relations are globally
        sorted but EMPTY-padded per shard; one compaction gather — not a
        sort — closes the interior gaps."""
        if self.plan.get("mesh"):
            return mj_mod.compact_state(self.state)
        return self.state

    def merge_join(
        self,
        other: "AggResult",
        *,
        how: str = "inner",
        backend: str = "auto",
        mesh=None,
        mesh_axis: str | None = None,
    ) -> "JoinResult":
        """Merge join with another aggregated relation, consuming BOTH
        sides' established key order — no sort, no scatter (§2.5 +
        the "interesting orderings" payoff).

        ``how``: ``"inner"`` (aligned per-side aggregate packets plus the
        group-join product columns), ``"semi"`` (this side's groups with
        a match), ``"anti"`` (groups without one).  Join keys must agree
        between the two sides — same packed dtype and same column bit
        layout — and a mismatch raises immediately (a silent truncation
        would join garbage).

        ``mesh`` runs the sharded form: both sides are partitioned by ONE
        jointly sampled cut vector through the existing key-range
        ``all_to_all``, each owner merges its fragments and joins
        locally — order survives the shuffle, so there is still no sort
        anywhere.  ``stats.rows_exchanged`` counts both sides' shuffle
        volume on top of whatever the inputs already paid."""
        _check_join_compat(self.by, other.by)
        if how not in mj_mod.JOIN_HOWS:
            raise ValueError(
                f"unknown join how={how!r}; expected one of {mj_mod.JOIN_HOWS}"
            )
        backend = dispatch.resolve_backend_name(backend)
        stats = SpillStats.reduce_shards([self.stats, other.stats])
        plan: dict[str, Any] = {
            "operator": "merge_join",
            "how": how,
            "backend": backend,
            "inputs_sorted": True,
            "sorted_by": [self.sorted_by, other.sorted_by],
            "left_plan": self.plan,
            "right_plan": other.plan,
        }
        with key_dtype_context(self.by.key_dtype):
            if mesh is not None:
                left, right, exchange, axis, world = _mesh_merge_join(
                    self._ordered_state(), other._ordered_state(),
                    mesh, mesh_axis, how=how, backend=backend,
                )
                stats = dataclasses.replace(
                    stats,
                    rows_exchanged=(stats.rows_exchanged
                                    + exchange["rows_exchanged"]),
                    exchange_quota=max(stats.exchange_quota,
                                       exchange["quota"]),
                    exchange_max_fill=max(stats.exchange_max_fill,
                                          exchange["max_fill"]),
                    exchange_retries=(stats.exchange_retries
                                      + exchange["retries"]),
                )
                plan["mesh"] = {"axis": axis, "world": world,
                                "exchange": exchange}
            else:
                left, right = mj_mod.merge_join(
                    self._ordered_state(), other._ordered_state(),
                    how=how, backend=backend,
                )
            products = None
            if how == "inner":
                products = _join_products_state(left, right)
        plan["cost_model"] = cost_model.join_cost_surface(
            self.state.capacity, other.state.capacity, inputs_sorted=True,
        )
        plan["cost_model_resort_baseline"] = cost_model.join_cost_surface(
            self.state.capacity, other.state.capacity, inputs_sorted=False,
        )
        return JoinResult(
            left=left, right=right, products=products, by=self.by,
            left_aggs=self.aggs, right_aggs=other.aggs, stats=stats,
            plan=plan, how=how,
        )

    def rollup(
        self, levels: Sequence[int] | None = None, *, backend: str = "auto"
    ) -> dict[tuple[str, ...], "AggResult"]:
        """Coarser prefix levels peeled from this ALREADY-sorted result —
        §2.2's "rollup from one sort", as an operator over the result
        instead of a fresh aggregation: no input re-read, no sort, no
        spill.  Returns ``{prefix column names: AggResult}`` like the
        module-level :func:`rollup`."""
        backend = dispatch.resolve_backend_name(backend)
        out: dict[tuple[str, ...], AggResult] = {}
        with key_dtype_context(self.by.key_dtype):
            state = self._ordered_state()
            for names, st, spec in _iter_prefix_levels(
                state, self.by, levels, backend
            ):
                plan = dict(self.plan)
                plan.pop("mesh", None)  # compacted above: tail layout again
                plan["rollup"] = {"level": names, "sorts": 0,
                                  "from_order": self.sorted_by}
                out[names] = AggResult(
                    state=st, stats=self.stats, by=spec, aggs=self.aggs,
                    plan=plan,
                )
        return out


def _check_join_compat(left_by: KeySpec, right_by: KeySpec) -> None:
    """Joining two relations requires ONE shared packed key space: same
    key dtype and same column bit layout.  Anything else raises loudly —
    the seed prototype silently truncated to uint32, which joins garbage
    on >32-bit keys."""
    if left_by.key_dtype != right_by.key_dtype:
        raise TypeError(
            f"join key dtype mismatch: left packs to "
            f"{np.dtype(left_by.key_dtype)} ({left_by.total_bits} bits, "
            f"columns {left_by.names}), right to "
            f"{np.dtype(right_by.key_dtype)} ({right_by.total_bits} bits, "
            f"columns {right_by.names}) — repack both sides with one "
            "KeySpec bit layout"
        )
    lb = tuple(c.bits for c in left_by.columns)
    rb = tuple(c.bits for c in right_by.columns)
    if lb != rb:
        raise TypeError(
            f"join key layout mismatch: left columns {left_by.names} pack "
            f"as bits {lb}, right columns {right_by.names} as {rb} — equal "
            "packed keys would not mean equal column values"
        )


def _join_products_state(left: AggState, right: AggState) -> AggState:
    """The group-join product columns (§2.5) materialized as an AggState
    sharing the join's key vector, sum plane = [join_count,
    Σ_L·|R| (V_L cols), |L|·Σ_R (V_R cols)].  Carrying the products as
    sum planes makes rollup exact: SUM over join pairs is additive
    across fine keys, so peeling a prefix level segmented-combines the
    products right along with the per-side packets."""
    prods = mj_mod.group_join_products(left, right)
    plane = jnp.concatenate(
        [
            prods["join_count"][:, None],
            prods["sum_left_x_count_right"],
            prods["count_left_x_sum_right"],
        ],
        axis=1,
    )
    n = left.capacity
    return AggState(
        keys=left.keys,
        count=left.count,
        sum=plane,
        min=jnp.zeros((n, 0), jnp.float32),
        max=jnp.zeros((n, 0), jnp.float32),
    )


@dataclasses.dataclass
class JoinResult:
    """Result of an order-consuming :meth:`AggResult.merge_join`.

    ``left`` and ``right`` are per-side aggregate packets **aligned on
    ONE sorted key vector** (right is None for semi/anti); ``products``
    carries the §2.5 group-join product columns as sum planes (inner
    only).  Because everything shares one key order, the result is
    itself an ordered relation: :meth:`rollup` peels prefix levels from
    it with segmented combines — still zero sorts downstream of the
    sources' original ones."""

    left: AggState
    right: AggState | None
    products: AggState | None
    by: KeySpec
    left_aggs: AggSpec
    right_aggs: AggSpec
    stats: SpillStats
    plan: dict[str, Any]
    how: str = "inner"

    @property
    def state(self) -> AggState:
        return self.left

    @property
    def keys(self):
        return self.left.keys

    def occupancy(self) -> int:
        return int(self.left.occupancy())

    @property
    def sorted_by(self) -> dict[str, Any]:
        """Join output inherits the inputs' key order (see
        :attr:`AggResult.sorted_by`)."""
        return {
            "columns": self.by.names,
            "prefix_len": len(self.by.columns),
            "key_dtype": str(np.dtype(self.by.key_dtype)),
        }

    def _ordered_states(self) -> tuple[AggState, ...]:
        states = tuple(
            s for s in (self.left, self.right, self.products) if s is not None
        )
        if self.plan.get("mesh"):
            # identical key vectors ⇒ identical compaction ⇒ alignment holds
            states = tuple(mj_mod.compact_state(s) for s in states)
        return states + (None,) * (3 - len(states))

    def relation(self) -> dict[str, np.ndarray]:
        """Key columns + per-side aggregate columns (``*_left`` /
        ``*_right``) + the group-join product columns (inner joins),
        padding removed, rows in key order."""
        keys = np.asarray(self.left.keys)
        mask = keys != empty_key(keys.dtype)
        out = {n: c[mask] for n, c in self.by.unpack(keys).items()}
        for name, col in self.left_aggs.finalize(self.left).items():
            out[f"{name}_left"] = np.asarray(col)[mask]
        if self.right is not None:
            for name, col in self.right_aggs.finalize(self.right).items():
                out[f"{name}_right"] = np.asarray(col)[mask]
        if self.products is not None:
            wl = self.left.sum.shape[1]
            plane = np.asarray(self.products.sum)[mask]
            out["join_count"] = plane[:, 0]
            out["sum_left_x_count_right"] = plane[:, 1 : 1 + wl]
            out["count_left_x_sum_right"] = plane[:, 1 + wl :]
        return out

    def rollup(
        self, levels: Sequence[int] | None = None, *, backend: str = "auto"
    ) -> dict[tuple[str, ...], "JoinResult"]:
        """Prefix-level rollup OF THE JOIN — aggregate → merge join →
        rollup from the sources' single sorts.  All constituent states
        share one key vector, so each peel applies the identical
        segmented combine to every side and alignment is preserved; the
        product planes are sums over join pairs, hence roll up exactly
        (the coarse ``join_count`` is Σ over fine matched keys of
        |L|·|R|, i.e. the fine join's cardinality grouped by prefix)."""
        backend = dispatch.resolve_backend_name(backend)
        out: dict[tuple[str, ...], JoinResult] = {}
        with key_dtype_context(self.by.key_dtype):
            left0, right0, prod0 = self._ordered_states()
            peels = [_iter_prefix_levels(left0, self.by, levels, backend)]
            if right0 is not None:
                peels.append(_iter_prefix_levels(right0, self.by, levels, backend))
            if prod0 is not None:
                peels.append(_iter_prefix_levels(prod0, self.by, levels, backend))
            for tier in zip(*peels):
                names, st_l, spec = tier[0]
                st_r = tier[1][1] if right0 is not None else None
                st_p = tier[-1][1] if prod0 is not None else None
                plan = dict(self.plan)
                plan.pop("mesh", None)
                plan["rollup"] = {"level": names, "sorts": 0,
                                  "from_order": self.sorted_by}
                out[names] = JoinResult(
                    left=st_l, right=st_r, products=st_p, by=spec,
                    left_aggs=self.left_aggs, right_aggs=self.right_aggs,
                    stats=self.stats, plan=plan, how=self.how,
                )
        return out


def _mesh_merge_join(a: AggState, b: AggState, mesh, mesh_axis, *,
                     how: str, backend: str):
    """Mesh-sharded merge join: joint sampled cuts → both sides through
    the CAPACITY-BOUNDED key-range exchange → per-owner local merge join
    (see :func:`repro.distributed.groupby.sharded_merge_join_local`).
    A send segment over either side's per-peer quota retries ONCE at the
    next pow2 quotas with a loud log, then raises
    (:class:`~repro.core.types.ExchangeOverflowError`); any other row
    loss (an owner's matches over its output slice) raises immediately.
    Returns ``(left, right_or_None, exchange, axis, world)`` where
    ``exchange`` is a dict of host accounting (``rows_exchanged``,
    ``quota``, ``max_fill``, ``retries``)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.pipeline import resolve_mesh_axis
    from repro.core.types import ExchangeOverflowError
    from repro.distributed import groupby as gb_mod
    from repro.distributed._compat import shard_map

    axis = resolve_mesh_axis(mesh, mesh_axis)
    world = int(mesh.shape[axis])
    dispatch.check_shardable(backend)

    def prep(st: AggState) -> AggState:
        cap = -(-st.capacity // world) * world
        if cap != st.capacity:
            st = concat_states(st, empty_like(st, cap - st.capacity))
        return st

    a, b = prep(a), prep(b)
    spec = AggState(keys=P(axis), count=P(axis), sum=P(axis, None),
                    min=P(axis, None), max=P(axis, None))
    cap_a, cap_b = a.capacity // world, b.capacity // world
    q_a = gb_mod.default_exchange_quota(cap_a, world)
    q_b = gb_mod.default_exchange_quota(cap_b, world)

    def sharded(qa, qb):
        def body(a_, b_):
            return gb_mod.sharded_merge_join_local(
                a_, b_, axis, world, how=how, backend=backend,
                quota_a=qa, quota_b=qb,
            )

        return shard_map(body, mesh=mesh, in_specs=(spec, spec),
                         out_specs=(spec, spec, P(), P(), P(), P()))

    left, right, rows_sent, send_dropped, dropped, max_fill = (
        sharded(q_a, q_b)(a, b))
    retries = 0
    if bool(send_dropped):
        qa2 = min(gb_mod._pow2_ceil(q_a + 1), gb_mod._pow2_ceil(cap_a))
        qb2 = min(gb_mod._pow2_ceil(q_b + 1), gb_mod._pow2_ceil(cap_b))
        if qa2 <= q_a and qb2 <= q_b:
            raise ExchangeOverflowError(
                "mesh-sharded merge join exchange overflowed its per-peer "
                f"quotas at the lossless ceiling (fullest segment "
                f"{int(max_fill)} rows vs quotas {q_a}/{q_b})",
                quota=max(q_a, q_b), max_fill=int(max_fill),
            )
        logging.getLogger(__name__).warning(
            "mesh merge join exchange overflowed its per-peer quotas "
            "%d/%d (fullest segment %d rows); retrying once at %d/%d",
            q_a, q_b, int(max_fill), qa2, qb2,
        )
        retries = 1
        q_a, q_b = qa2, qb2
        left, right, rows_sent, send_dropped, dropped, max_fill = (
            sharded(q_a, q_b)(a, b))
        if bool(send_dropped):
            raise ExchangeOverflowError(
                "mesh-sharded merge join exchange overflowed its per-peer "
                f"quotas even after one retry at {q_a}/{q_b} (fullest "
                f"segment {int(max_fill)} rows) — results would be "
                "missing join keys",
                quota=max(q_a, q_b), max_fill=int(max_fill),
            )
    if bool(dropped):
        raise RuntimeError(
            "mesh-sharded merge join dropped rows: a key-range owner's "
            "matches exceeded its output slice (skewed cuts) — results "
            "would be missing join keys.  Widen the inputs' capacity or "
            "join without mesh="
        )
    exchange = {
        "rows_exchanged": int(rows_sent),
        "quota": max(q_a, q_b),
        "max_fill": int(max_fill),
        "retries": retries,
    }
    return left, (right if how == "inner" else None), exchange, axis, world


def pipeline(steps):
    """Run an order-preserving operator pipeline: ONE sort per source
    relation, ZERO sorts between operators.

    ``steps`` is a list; the FIRST entry is the source — an existing
    :class:`AggResult` or ``("aggregate", kwargs)`` — and each later
    entry is ``("merge_join", {"right": <AggResult | ("aggregate",
    kwargs)>, ...})`` or ``("rollup", {"levels": ...})``::

        out = repro.pipeline([
            ("aggregate", dict(columns=..., by=spec, values=v,
                               aggs=("count", "sum"))),
            ("merge_join", {"right": dim_result}),
            ("rollup", {"levels": [2, 1]}),
        ])

    Operators past the sources consume the established key order
    (:attr:`AggResult.sorted_by`): the merge join is a rank-alignment
    probe and the rollup a chain of segmented combines — neither emits a
    sort or scatter.  The returned result's ``plan["pipeline"]`` records
    the stage list, the number of source sorts paid, and the zero
    re-sort count the composition guarantees."""
    if not steps:
        raise ValueError("pipeline needs at least a source step")
    sources = 0

    def _source(spec):
        nonlocal sources
        if isinstance(spec, AggResult):
            sources += 1
            return spec
        if (isinstance(spec, tuple) and len(spec) == 2
                and spec[0] == "aggregate"):
            sources += 1
            return aggregate(**spec[1])
        raise TypeError(
            "pipeline source must be an AggResult or ('aggregate', "
            f"kwargs), got {spec!r}"
        )

    stages = ["aggregate"]
    cur = _source(steps[0])
    for step in steps[1:]:
        if not (isinstance(step, tuple) and len(step) == 2):
            raise TypeError(f"pipeline step must be (op, kwargs), got {step!r}")
        op, kw = step
        kw = dict(kw)
        if op == "merge_join":
            right = _source(kw.pop("right"))
            cur = cur.merge_join(right, **kw)
            stages.append(f"merge_join[{cur.how}]")
        elif op == "rollup":
            if isinstance(cur, dict):
                raise TypeError("cannot compose past a rollup fan-out")
            cur = cur.rollup(**kw)
            stages.append("rollup")
        else:
            raise ValueError(f"unknown pipeline op {op!r}: merge_join|rollup")
    block = {"stages": stages, "source_sorts": sources, "re_sorts": 0}
    results = cur.values() if isinstance(cur, dict) else (cur,)
    for r in results:
        r.plan = dict(r.plan)
        r.plan["pipeline"] = block
    return cur


def _iter_prefix_levels(state: AggState, by: KeySpec, levels, backend: str):
    """Peel minor key columns off a key-sorted state, yielding
    ``(prefix_names, state, prefix_spec)`` finest level first.  Dropping
    the least-significant column is a right-shift — monotone on the
    packed key — so every coarser level is ONE segmented combine of the
    already-sorted finer level: no sort, no spill (§2.2).  Caller holds
    :func:`key_dtype_context`."""
    n_cols = len(by.columns)
    if levels is None:
        levels = list(range(n_cols, -1, -1))
    requested = sorted(set(int(l) for l in levels), reverse=True)
    if requested[0] > n_cols or requested[-1] < 0:
        raise ValueError(f"rollup levels {requested} out of range [0, {n_cols}]")
    spec = by
    cur = n_cols
    for lvl in requested:
        while cur > lvl:
            # peel the minor column: shift is monotone ⇒ stays sorted
            dropped = spec.columns[-1]
            spec = KeySpec(spec.columns[:-1]) if cur > 1 else spec
            shifted = state.keys >> state.keys.dtype.type(dropped.bits)
            sentinel = empty_key(state.keys.dtype)
            if cur == 1:
                # grand total: a single all-rows group under key 0
                spec = KeySpec((KeyColumn("__all__", 1),))
                shifted = jnp.zeros_like(state.keys)
            keys2 = jnp.where(state.valid(), shifted, sentinel)
            state = sorted_ops.segmented_combine(
                AggState(keys2, state.count, state.sum, state.min, state.max),
                backend=backend,
            )
            cur -= 1
        yield by.names[:lvl], state, spec


def _resolve_order_by(order_by, by: KeySpec) -> bool:
    """order_by must be a prefix of the key columns (satisfiable from the
    one sort); returns whether sorted output is required."""
    if order_by is None or order_by is False:
        return False
    if order_by is True:
        return True
    names = (order_by,) if isinstance(order_by, str) else tuple(order_by)
    if names != by.names[: len(names)]:
        raise ValueError(
            f"order_by {names} is not a prefix of the key columns {by.names}; "
            "one sort cannot satisfy it — reorder the KeySpec"
        )
    return True


def _plan(
    n_rows: int,
    cfg: ExecConfig,
    output_estimate: int | None,
    *,
    input_sorted: bool = False,
) -> dict:
    """Optimizer-style cost comparison (paper Fig 23/24): predicted spill
    volumes for the in-sort operator and the hash baseline, plus the
    machine-calibrated decision surface (``make calibrate``).  The
    paper's point — and this function's — is that in-sort aggregation is
    never worse in *volume*, so ``algorithm="auto"`` is always in-sort;
    WHICH in-sort run-generation policy wins in *seconds* is what the
    calibrated surface (and, streamed, the runtime governor) decides.

    ``input_sorted=True`` credits a key order an upstream
    :func:`aggregate` already established: the sort term of the
    predicted cost is zero (sorting an already-sorted relation is pure
    waste — the ROADMAP's order-enforcement item)."""
    O = output_estimate or cfg.memory_rows * cfg.fanin
    insort_cb = cost_model.simulate_insort(
        n_rows, O, cfg.memory_rows, cfg.fanin,
        early_aggregation=True, wide_merge=True, replacement_selection=True,
    )
    hash_cb = cost_model.simulate_hash(
        n_rows, O, cfg.memory_rows, cfg.fanin, hybrid=True
    )
    levels = max(1, cost_model.merge_levels_insort(O, cfg.memory_rows,
                                                   cfg.fanin))
    import jax  # the constants table is keyed by device backend

    return {
        "input_rows": n_rows,
        "output_estimate": O,
        "in_memory": n_rows <= cfg.memory_rows,
        "input_sorted": input_sorted,
        "predicted_spill_insort": insort_cb.total_spill,
        "predicted_spill_hash": hash_cb.total_spill,
        "cost_model": cost_model.cost_surface(
            n_rows, O, backend=jax.default_backend(), merge_levels=levels,
            input_sorted=input_sorted,
        ),
    }


def aggregate(
    columns,
    *,
    by: KeySpec,
    values=None,
    aggs: AggSpec | Sequence[str] | str = ("count",),
    order_by=None,
    algorithm: str = "auto",
    backend: str = "auto",
    cfg: ExecConfig | None = None,
    output_estimate: int | None = None,
    input_sorted: bool = False,
    pipeline: str = "device",
    mesh=None,
    mesh_axis: str | None = None,
) -> AggResult:
    """Duplicate removal / grouping / aggregation behind one front door.

    ``columns``: mapping of key-column name → integer vector (or a
    significance-ordered sequence), packed per ``by``.  ``values``: the
    optional V-wide float payload the aggregates run over.  ``aggs``
    names the requested aggregates; the accumulator carries only what
    they need.  ``order_by`` (True, or a prefix of ``by``'s column
    names) asserts the result must be key-sorted — free for the
    sort-based algorithms, an extra sort for the hash baselines.

    **Streamed input**: ``columns`` may instead be a generator/iterator
    of column-batch mappings (see
    :func:`repro.data.pipeline.iter_column_batches`); the engine then
    absorbs the input chunk by chunk through the double-buffered
    streamed pipeline (:func:`repro.core.pipeline.
    aggregate_device_stream`) — the input never needs to be resident at
    once, and the device footprint is bounded by the chunk size.  In
    this form ``values`` names a float column carried in each batch
    mapping (a string), the algorithm is in-sort on the device pipeline
    (the only external algorithm here — exactly the paper's point), and
    everything else behaves identically.

    ``algorithm``: ``"auto"`` (the paper's systems-only choice: in-sort),
    ``"insort"``, ``"hash"``, ``"f1_hash"``, ``"sort_then_stream"``, or
    ``"inmemory"``.  Streamed input additionally accepts (and defaults
    to, where the geometry allows) ``"adaptive"``: the in-sort pipeline
    with the run-generation policy re-decided mid-flight by the
    calibrated policy governor (:mod:`repro.core.adaptive`).
    ``backend``: ``"auto" | "xla" | "pallas"`` through the dispatch
    registry.

    ``input_sorted=True`` asserts the input already arrives in key
    order (e.g. the relation came out of an upstream ``aggregate`` —
    its results are key-sorted by construction); the plan's calibrated
    cost surface then credits a zero sort term.

    ``output_estimate`` sizes the result buffers; if the output
    overruns them anyway, finalize retries ONCE at the next power of
    two (with one more pre-merge level) before raising.

    With the default ``pipeline="device"``, the in-sort algorithms
    compile to ONE device program — run generation as a ``lax.scan``
    fused with the wide merge (:mod:`repro.core.pipeline`), with a single
    host readback for the stats.  ``pipeline="host"`` selects the
    host-orchestrated reference loop (exact per-merge-level accounting).

    ``mesh`` (a :class:`jax.sharding.Mesh`) shards that one device
    program over ``mesh_axis`` (default: the mesh's first axis): each
    device runs run generation over its shard, then a key-range
    ``all_to_all`` exchanges the locally aggregated sorted fragments and
    each range owner merges them — the relation stays globally sorted by
    the composite key, and ``stats.rows_exchanged`` records the shuffle
    volume (valid rows on the wire, which local early aggregation keeps
    below the input row count on duplicate-heavy data).  In-sort +
    ``pipeline="device"`` only; ``mesh=None`` is today's single-device
    program, bit for bit.
    """
    cfg = cfg or ExecConfig()
    if mesh is not None and algorithm not in ("auto", "insort"):
        raise ValueError(
            f"mesh-sharded aggregation is in-sort only, got algorithm="
            f"{algorithm!r}"
        )
    if not isinstance(aggs, AggSpec):
        aggs = AggSpec(aggs) if isinstance(aggs, str) else AggSpec(*aggs)
    if isinstance(columns, Iterator):
        return _aggregate_stream(
            columns, by=by, values=values, aggs=aggs, order_by=order_by,
            algorithm=algorithm, backend=backend, cfg=cfg,
            output_estimate=output_estimate, input_sorted=input_sorted,
            pipeline=pipeline, mesh=mesh, mesh_axis=mesh_axis,
        )
    if algorithm == "adaptive":
        raise ValueError(
            "algorithm='adaptive' adapts mid-stream — it needs streamed "
            "input (pass an iterator of column batches); one-shot input "
            "is planned up front with algorithm='auto'"
        )
    packed = by.pack(columns)
    want_sorted = _resolve_order_by(order_by, by)
    if values is not None:
        values = np.asarray(values, dtype=np.float32)
        if values.ndim == 1:
            values = values[:, None]
        widths = aggs.plane_widths(values.shape[1])
        if not any(widths):
            values = None  # nothing requested needs the payload
            widths = (0, 0, 0)
    else:
        widths = (0, 0, 0)
        if aggs.needs_payload():
            raise ValueError(
                f"aggregates {aggs.names} need a payload; pass values=..."
            )
    plan = _plan(len(packed), cfg, output_estimate, input_sorted=input_sorted)
    backend = dispatch.resolve_backend_name(backend)
    plan["backend"] = backend

    sort_based = algorithm in ("auto", "insort", "sort_then_stream", "inmemory")
    plan["algorithm"] = "insort" if algorithm == "auto" else algorithm
    plan["pipeline"] = pipeline if algorithm in ("auto", "insort") else "host"
    if mesh is not None:
        from repro.core.pipeline import resolve_mesh_axis

        axis = resolve_mesh_axis(mesh, mesh_axis)
        plan["mesh"] = {"axis": axis, "world": int(mesh.shape[axis])}
    with key_dtype_context(by.key_dtype):
        if algorithm in ("auto", "insort"):
            state, stats = insort_mod.insort_aggregate(
                packed, values, cfg, output_estimate=output_estimate,
                backend=backend, widths=widths, pipeline=pipeline,
                mesh=mesh, mesh_axis=mesh_axis,
            )
        elif algorithm == "sort_then_stream":
            state, stats = insort_mod.sort_then_stream_aggregate(
                packed, values, cfg, backend=backend
            )
        elif algorithm == "hash":
            state, stats = hash_mod.hash_aggregate(
                packed, values, cfg, output_estimate=output_estimate,
                backend=backend, widths=widths,
            )
        elif algorithm == "f1_hash":
            state, stats = hash_mod.f1_hash_aggregate(
                packed, values, cfg, backend=backend, widths=widths
            )
        elif algorithm == "inmemory":
            state = sorted_ops.sorted_groupby(
                packed, values, backend=backend, widths=widths
            )
            stats = SpillStats()
        else:
            raise ValueError(f"unknown algorithm {algorithm!r}")
        if want_sorted and not sort_based:
            # hash order → key order: the extra sort the paper's operator
            # never pays (Fig 19)
            state = sorted_ops.sort_state(state, backend=backend)
    return AggResult(state=state, stats=stats, by=by, aggs=aggs, plan=plan)


def _aggregate_stream(
    batches,
    *,
    by: KeySpec,
    values,
    aggs: AggSpec,
    order_by,
    algorithm: str,
    backend: str,
    cfg: ExecConfig,
    output_estimate: int | None,
    input_sorted: bool,
    pipeline: str,
    mesh,
    mesh_axis: str | None,
) -> AggResult:
    """:func:`aggregate` over an iterator of column-batch mappings.

    Each batch mapping carries the key columns named by ``by`` plus (when
    ``values`` is a column name) one float value column.  Batches are
    packed host-side one at a time and fed to the double-buffered
    streamed device pipeline — host→device transfer of batch k+1 overlaps
    the device aggregating batch k, and only the finalize syncs.

    ``algorithm="auto"`` runs ``"adaptive"`` where the geometry allows
    (single device, ``memory_rows`` divisible by ``batch_rows``): the
    run-generation policy is re-decided mid-flight by the calibrated
    governor, so a wrong up-front estimate costs one observation window,
    not the stream.  ``"insort"`` keeps the fixed default policy."""
    if algorithm not in ("auto", "insort", "adaptive"):
        raise ValueError(
            f"streamed input runs the in-sort device pipeline only, got "
            f"algorithm={algorithm!r}"
        )
    adaptive_ok = mesh is None and cfg.memory_rows % cfg.batch_rows == 0
    if algorithm == "adaptive" and not adaptive_ok:
        raise ValueError(
            "algorithm='adaptive' needs a single-device stream with "
            "memory_rows divisible by batch_rows, got "
            f"mesh={'set' if mesh is not None else None}, "
            f"memory_rows={cfg.memory_rows}, batch_rows={cfg.batch_rows}"
        )
    adaptive = algorithm == "adaptive" or (algorithm == "auto" and adaptive_ok)
    policy = "adaptive" if adaptive else "rs"
    if pipeline != "device":
        raise ValueError(
            f"streamed input requires pipeline='device', got {pipeline!r}"
        )
    if values is not None and not isinstance(values, str):
        raise TypeError(
            "with streamed input, values must name a column carried in "
            f"each batch mapping (a str), got {type(values).__name__}"
        )
    _resolve_order_by(order_by, by)  # sort-based: always satisfiable

    backend = dispatch.resolve_backend_name(backend)
    rows_seen = 0

    def _prep(batch):
        nonlocal rows_seen
        packed = by.pack(batch)
        rows_seen += len(packed)
        if values is None:
            return packed, None
        if values not in batch:
            raise KeyError(f"values column {values!r} missing from batch")
        vals = np.asarray(batch[values], dtype=np.float32)
        if vals.ndim == 1:
            vals = vals[:, None]
        if len(vals) != len(packed):
            raise ValueError(
                f"values column {values!r} has {len(vals)} rows, key "
                f"columns have {len(packed)}"
            )
        return packed, vals

    from repro.core import pipeline as pipeline_mod

    # Peek one batch to fix the payload width (plane widths are static).
    it = iter(batches)
    first = next(it, None)
    if first is None:
        with key_dtype_context(by.key_dtype):
            state, stats = pipeline_mod.insort_aggregate_device_stream(
                iter(()), cfg, policy=policy, backend=backend,
                widths=(0, 0, 0), width=0, key_dtype=by.key_dtype,
                output_estimate=output_estimate, mesh=mesh,
                mesh_axis=mesh_axis,
            )
        plan = _plan(0, cfg, output_estimate, input_sorted=input_sorted)
        plan.update(algorithm="adaptive" if adaptive else "insort",
                    policy=policy, pipeline="device", backend=backend,
                    streamed=True)
        return AggResult(state=state, stats=stats, by=by, aggs=aggs, plan=plan)

    first_prepped = _prep(first)
    V = 0 if first_prepped[1] is None else first_prepped[1].shape[1]
    widths = aggs.plane_widths(V)
    if values is not None and not any(widths):
        # nothing requested needs the payload — drop the value column
        values = None
        rows_seen = 0
        first_prepped = _prep(first)
        V, widths = 0, (0, 0, 0)
    elif values is None and aggs.needs_payload():
        raise ValueError(
            f"aggregates {aggs.names} need a payload; pass values=<column "
            "name>"
        )

    chunks = itertools.chain([first_prepped], (_prep(b) for b in it))
    with key_dtype_context(by.key_dtype):
        state, stats = pipeline_mod.insort_aggregate_device_stream(
            chunks, cfg, policy=policy, backend=backend, widths=widths,
            width=V, key_dtype=by.key_dtype, output_estimate=output_estimate,
            mesh=mesh, mesh_axis=mesh_axis,
        )
    plan = _plan(rows_seen, cfg, output_estimate, input_sorted=input_sorted)
    plan.update(algorithm="adaptive" if adaptive else "insort",
                policy=policy, pipeline="device", backend=backend,
                streamed=True)
    if adaptive:
        plan["policy_switches"] = stats.policy_switches
        plan["readbacks_paid"] = stats.readbacks_paid
    if mesh is not None:
        axis = pipeline_mod.resolve_mesh_axis(mesh, mesh_axis)
        plan["mesh"] = {"axis": axis, "world": int(mesh.shape[axis])}
    return AggResult(state=state, stats=stats, by=by, aggs=aggs, plan=plan)


def serve_aggregate(**kwargs):
    """Open a long-lived aggregation session — the serving twin of
    :func:`aggregate` for continuously arriving input.

    Same schema arguments (``by=``, ``values=``, ``aggs=``) plus
    ``watermark=<major key column>`` for TTL expiry and the streaming
    engine's knobs (``policy=``, ``cfg=``, ``mesh=``, …).  The session
    ingests column batches with zero host readbacks and answers
    **merge-on-read snapshots**: sorted :class:`AggResult` relations
    computed without consuming the live engine state, so ingest
    continues uninterrupted.  See
    :class:`repro.service.AggregationSession`."""
    from repro.service import AggregationSession  # lazy: optional layer

    return AggregationSession(**kwargs)


# ---------------------------------------------------------------------------
# generic rollup: any prefix hierarchy, all levels from ONE sort
# ---------------------------------------------------------------------------


def rollup(
    columns,
    *,
    by: KeySpec,
    values=None,
    aggs: AggSpec | Sequence[str] | str = ("count", "sum"),
    levels: Sequence[int] | None = None,
    algorithm: str = "auto",
    backend: str = "auto",
    cfg: ExecConfig | None = None,
    output_estimate: int | None = None,
    pipeline: str = "device",
) -> tuple[dict[tuple[str, ...], AggResult], SpillStats]:
    """``GROUP BY ROLLUP(...)`` over any key hierarchy from ONE sort (§2.2).

    Aggregates at the full key, then peels minor columns off the sorted
    output: dropping the least-significant column is a right-shift, which
    is monotone on the packed key, so every coarser level is a
    segmented combine of the (already sorted) finer level — no further
    sort, no extra spill.  ``levels`` selects prefix lengths (default:
    every prefix plus the grand total, which reports as ``()``).

    Returns ({prefix column names: AggResult}, stats of the one sort).
    """
    cfg = cfg or ExecConfig()
    if not isinstance(aggs, AggSpec):
        aggs = AggSpec(aggs) if isinstance(aggs, str) else AggSpec(*aggs)
    fine = aggregate(
        columns, by=by, values=values, aggs=aggs, algorithm=algorithm,
        backend=backend, cfg=cfg, output_estimate=output_estimate,
        pipeline=pipeline,
        order_by=True,  # the peel below requires key-sorted input (hash
        # algorithms pay their post-sort here, Fig 19 style)
    )
    backend = dispatch.resolve_backend_name(backend)
    out: dict[tuple[str, ...], AggResult] = {}
    with key_dtype_context(by.key_dtype):
        for names, state, spec in _iter_prefix_levels(
            fine.state, by, levels, backend
        ):
            out[names] = AggResult(
                state=state, stats=fine.stats, by=spec, aggs=aggs,
                plan=fine.plan,
            )
    return out, fine.stats
