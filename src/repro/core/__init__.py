"""repro.core — sort-based duplicate removal, grouping, and aggregation.

The paper's contribution (Do & Graefe: early aggregation during run
generation + wide merging in the final merge step) as a composable JAX
module, plus the baselines it is measured against.
"""
from repro.core.types import (
    AggState,
    DeviceSpillStats,
    ExecConfig,
    SpillStats,
    EMPTY,
    EMPTY64,
    MAX_KEY,
    MAX_KEY64,
    empty_key,
    key_dtype_context,
    key_dtype_for_bits,
    max_key,
)
from repro.core.dispatch import (
    Backend,
    BackendUnavailable,
    backend_available,
    get_backend,
    register_backend,
    registered_backends,
)
from repro.core.ordered_index import OrderedIndex, merge_ranks
from repro.core.sorted_ops import (
    sorted_groupby,
    finalize,
    sort_state,
    segmented_combine,
    interleave,
    interleave_many,
    merge_absorb,
    merge_absorb_many,
)
from repro.core.insort import insort_aggregate, sort_then_stream_aggregate
from repro.core.hash_agg import hash_aggregate, f1_hash_aggregate
from repro.core.instream import instream_aggregate
from repro.core.operators import (
    group_by,
    distinct,
    group_by_order_by,
    count_and_count_distinct,
    rollup,
    intersect_distinct,
    pack_keys,
    unpack_keys,
)
from repro.core.schema import (
    AggResult,
    AggSpec,
    KeyColumn,
    KeySpec,
    aggregate,
)
from repro.core.pipeline import (
    aggregate_device,
    generate_runs_device,
    insort_aggregate_device,
)
from repro.core import cost_model

__all__ = [
    "AggState",
    "DeviceSpillStats",
    "ExecConfig",
    "SpillStats",
    "EMPTY",
    "EMPTY64",
    "MAX_KEY",
    "MAX_KEY64",
    "empty_key",
    "key_dtype_context",
    "key_dtype_for_bits",
    "max_key",
    "AggResult",
    "AggSpec",
    "KeyColumn",
    "KeySpec",
    "aggregate",
    "Backend",
    "BackendUnavailable",
    "backend_available",
    "get_backend",
    "register_backend",
    "registered_backends",
    "OrderedIndex",
    "merge_ranks",
    "sorted_groupby",
    "finalize",
    "sort_state",
    "segmented_combine",
    "interleave",
    "interleave_many",
    "merge_absorb",
    "merge_absorb_many",
    "insort_aggregate",
    "aggregate_device",
    "generate_runs_device",
    "insort_aggregate_device",
    "sort_then_stream_aggregate",
    "hash_aggregate",
    "f1_hash_aggregate",
    "instream_aggregate",
    "group_by",
    "distinct",
    "group_by_order_by",
    "count_and_count_distinct",
    "rollup",
    "intersect_distinct",
    "pack_keys",
    "unpack_keys",
    "cost_model",
]
