"""Vectorized ordered-index primitives (the TPU adaptation of the paper's
in-memory b-tree).

The paper replaces priority queues with an ordered in-memory index whose
batched usage pattern it spells out in §3.4: *sort the incoming batch, then
turn the per-row search into a merge*.  On a vector machine that whole
recipe collapses into four primitives over fixed-capacity tiles:

* ``sort_state``          — key-sort a tile (EMPTY keys sink to the end);
* ``segmented_combine``   — absorb equal keys by combining aggregate states
                            (the b-tree "absorb" of §3);
* ``absorb``              — sort + combine: canonicalize *unsorted* rows;
* ``merge_absorb``        — batched insert of one **sorted** state into
                            another: a linear merge (searchsorted-rank
                            scatter on XLA, the merge-path kernel on
                            Pallas) — never a full sort of the union.

``merge_absorb`` requires both inputs key-sorted (duplicates within either
input are fine; they combine in the same pass).  Full argsort remains only
in ``sort_state``/``absorb`` for genuinely unsorted input.

This module is the thin user-facing layer: the engine lives in
:mod:`repro.core.ordered_index` (XLA) and :mod:`repro.kernels` (Pallas),
selected per call through the registry in :mod:`repro.core.dispatch`
(``backend="xla" | "pallas" | "auto"``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.ordered_index import OrderedIndex  # noqa: F401  (re-export)
from repro.core.types import (
    AggState,
    key_dtype_context,
    rows_to_state,
    take,
)


# ---------------------------------------------------------------------------
# sorting
# ---------------------------------------------------------------------------


def sort_state(state: AggState, *, backend: str = "auto") -> AggState:
    """Key-sort all rows of a state; EMPTY (=key dtype max) rows sink to
    the end."""
    with key_dtype_context(state):
        perm = dispatch.get_backend(backend).argsort(state.keys)
        return take(state, perm)


# ---------------------------------------------------------------------------
# segmented combine (absorb duplicates)
# ---------------------------------------------------------------------------


def segmented_combine(state: AggState, *, backend: str = "auto") -> AggState:
    """Combine adjacent equal-key rows of a key-sorted state.

    Output keeps the input capacity: unique groups are compacted to the
    front (still sorted), the tail is EMPTY.  This is the vectorized
    equivalent of inserting a sorted batch into the paper's b-tree and
    letting existing keys absorb the new rows.
    """
    with key_dtype_context(state):
        return dispatch.get_backend(backend).segmented_combine(state)


def absorb(state: AggState, *, backend: str = "auto") -> AggState:
    """sort + combine: canonicalize any state to sorted/compacted form."""
    return segmented_combine(sort_state(state, backend=backend), backend=backend)


def merge_absorb(
    table: AggState,
    incoming: AggState,
    *,
    backend: str = "auto",
    assume_unique: bool = False,
) -> AggState:
    """Batched insert of ``incoming`` into the ordered index ``table``.

    Both inputs must be **key-sorted** (EMPTY-padded; duplicates within
    either input are combined too).  Returns a state of capacity
    ``len(table) + len(incoming)`` — sorted, duplicate-free, EMPTY-padded
    — via a linear merge: no full argsort on any backend.  The caller
    decides whether the result still fits "memory" (paper: whether the
    b-tree must spill).

    ``assume_unique=True`` promises both inputs are also duplicate-free
    (the OrderedIndex invariant): merged groups then hold at most two
    rows and the absorb drops to a single pair-combine.
    """
    with key_dtype_context(table):
        return dispatch.get_backend(backend).merge_sorted(
            table, incoming, assume_unique=assume_unique
        )


def interleave(a: AggState, b: AggState, *, backend: str = "auto") -> AggState:
    """Linear merge of two **key-sorted** states WITHOUT combining
    duplicates: the raw sorted multiset union, capacity ``|a| + |b|``,
    EMPTY rows ranked to the tail.  Traditional merge levels that defer
    aggregation (the paper's Fig 2 top baseline) are trees of exactly
    this operation.  Backends without a fused kernel fall back to the
    XLA rank-gather interleave."""
    from repro.core import ordered_index as oi

    with key_dtype_context(a):
        be = dispatch.get_backend(backend)
        fn = be.interleave or oi.interleave_sorted
        return fn(a, b)


def _merge_tree(states: list[AggState], pair_fn) -> AggState:
    """Balanced binary tree reduction over ≥1 states with ``pair_fn``
    (odd element carried to the next round)."""
    assert states, "merge tree needs at least one state"
    states = list(states)
    while len(states) > 1:
        nxt = [
            pair_fn(states[i], states[i + 1])
            for i in range(0, len(states) - 1, 2)
        ]
        if len(states) % 2:
            nxt.append(states[-1])
        states = nxt
    return states[0]


def merge_absorb_many(
    states: list[AggState], *, backend: str = "auto", assume_unique: bool = False
) -> AggState:
    """Balanced tree of linear merges over already-sorted states (the
    multi-fragment absorb used by the distributed group-by, the hash
    splice, and the traditional merge's aggregating groups).  Capacity of
    the result is the summed input capacity."""
    return _merge_tree(
        list(states),
        lambda a, b: merge_absorb(a, b, backend=backend, assume_unique=assume_unique),
    )


def interleave_many(states: list[AggState], *, backend: str = "auto") -> AggState:
    """Balanced tree of non-combining linear merges: the raw sorted
    multiset union of already-sorted states (traditional merge levels
    that defer aggregation).  Capacity is the summed input capacity."""
    return _merge_tree(
        list(states), lambda a, b: interleave(a, b, backend=backend)
    )


# ---------------------------------------------------------------------------
# fused in-memory fast path (what the LM framework calls)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("backend", "widths"))
def _sorted_groupby_jit(keys, payload, *, backend: str, widths):
    return absorb(rows_to_state(keys, payload, widths=widths), backend=backend)


def sorted_groupby(
    keys: jax.Array,
    payload: jax.Array | None = None,
    *,
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
) -> AggState:
    """One-shot device group-by: the `O ≤ M` case of the paper (Fig 6).

    Sorted output comes for free — the "interesting orderings" property the
    paper leans on for group-by + order-by fusion.  ``widths`` restricts
    which value planes the result carries (see
    :class:`repro.core.schema.AggSpec`).
    """
    with key_dtype_context(keys):
        return _sorted_groupby_jit(keys, payload, backend=backend, widths=widths)


def unique_count(state: AggState) -> jax.Array:
    return state.occupancy()


def finalize(state: AggState, aggs: tuple[str, ...] = ("count", "sum", "min", "max", "avg")):
    """Turn accumulator state into user-facing aggregate columns."""
    out = {"key": state.keys}
    valid = state.valid()
    for a in aggs:
        if a == "count":
            out["count"] = state.count
        elif a == "sum":
            out["sum"] = state.sum
        elif a == "min":
            out["min"] = jnp.where(valid[:, None], state.min, 0.0)
        elif a == "max":
            out["max"] = jnp.where(valid[:, None], state.max, 0.0)
        elif a == "avg":
            c = jnp.maximum(state.count, 1).astype(jnp.float32)[:, None]
            out["avg"] = state.sum / c
        else:
            raise ValueError(f"unknown aggregate {a!r}")
    return out
