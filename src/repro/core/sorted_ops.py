"""Vectorized ordered-index primitives (the TPU adaptation of the paper's
in-memory b-tree).

The paper replaces priority queues with an ordered in-memory index whose
batched usage pattern it spells out in §3.4: *sort the incoming batch, then
turn the per-row search into a merge*.  On a vector machine that whole
recipe collapses into three primitives over fixed-capacity tiles:

* ``sort_state``          — key-sort a tile (EMPTY keys sink to the end);
* ``segmented_combine``   — absorb equal keys by combining aggregate states
                            (the b-tree "absorb" of §3);
* ``merge_absorb``        — batched insert = concat + sort + combine.

Everything is fixed-shape and jit-friendly.  ``backend='pallas'`` routes the
sort / segmented reduction through the Pallas TPU kernels in
:mod:`repro.kernels`; the default XLA path is the oracle-equivalent
implementation used on CPU and in dry-runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import EMPTY, AggState, concat_states, rows_to_state, take

_INF = jnp.float32(jnp.inf)


# ---------------------------------------------------------------------------
# sorting
# ---------------------------------------------------------------------------


def sort_state(state: AggState, *, backend: str = "xla") -> AggState:
    """Key-sort all rows of a state; EMPTY (=uint32 max) rows sink to the end."""
    if backend == "pallas":
        from repro.kernels import ops as _ops  # lazy; optional path

        perm = _ops.argsort_u32(state.keys)
    else:
        perm = jnp.argsort(state.keys)
    return take(state, perm)


# ---------------------------------------------------------------------------
# segmented combine (absorb duplicates)
# ---------------------------------------------------------------------------


def _segment_ids(sorted_keys: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(head flags, segment index) for a key-sorted vector; EMPTY rows get
    an out-of-range segment so scatters drop them."""
    n = sorted_keys.shape[0]
    valid = sorted_keys != EMPTY
    neq = jnp.concatenate(
        [jnp.ones((1,), dtype=bool), sorted_keys[1:] != sorted_keys[:-1]]
    )
    heads = neq & valid
    seg = jnp.cumsum(heads.astype(jnp.int32)) - 1
    seg = jnp.where(valid, seg, n)  # out-of-range ⇒ dropped by scatters
    return heads, seg


def segmented_combine(state: AggState, *, backend: str = "xla") -> AggState:
    """Combine adjacent equal-key rows of a key-sorted state.

    Output keeps the input capacity: unique groups are compacted to the
    front (still sorted), the tail is EMPTY.  This is the vectorized
    equivalent of inserting a sorted batch into the paper's b-tree and
    letting existing keys absorb the new rows.
    """
    if backend == "pallas":
        from repro.kernels import ops as _ops

        return _ops.segmented_combine(state)
    n = state.capacity
    heads, seg = _segment_ids(state.keys)
    out_keys = jnp.full((n,), EMPTY, dtype=jnp.uint32).at[seg].set(
        state.keys, mode="drop"
    )
    count = jnp.zeros((n,), jnp.int32).at[seg].add(state.count, mode="drop")
    ssum = jnp.zeros_like(state.sum).at[seg].add(state.sum, mode="drop")
    smin = jnp.full_like(state.min, _INF).at[seg].min(state.min, mode="drop")
    smax = jnp.full_like(state.max, -_INF).at[seg].max(state.max, mode="drop")
    return AggState(keys=out_keys, count=count, sum=ssum, min=smin, max=smax)


def absorb(state: AggState, *, backend: str = "xla") -> AggState:
    """sort + combine: canonicalize any state to sorted/compacted form."""
    return segmented_combine(sort_state(state, backend=backend), backend=backend)


def merge_absorb(table: AggState, incoming: AggState, *, backend: str = "xla") -> AggState:
    """Batched insert of ``incoming`` into the ordered index ``table``.

    Returns a state of capacity ``len(table) + len(incoming)`` — sorted,
    duplicate-free, EMPTY-padded.  The caller decides whether the result
    still fits "memory" (paper: whether the b-tree must spill).
    """
    return absorb(concat_states(table, incoming), backend=backend)


# ---------------------------------------------------------------------------
# fused in-memory fast path (what the LM framework calls)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("backend",))
def sorted_groupby(keys: jax.Array, payload: jax.Array | None = None, *, backend: str = "xla") -> AggState:
    """One-shot device group-by: the `O ≤ M` case of the paper (Fig 6).

    Sorted output comes for free — the "interesting orderings" property the
    paper leans on for group-by + order-by fusion.
    """
    return absorb(rows_to_state(keys, payload), backend=backend)


def unique_count(state: AggState) -> jax.Array:
    return state.occupancy()


def finalize(state: AggState, aggs: tuple[str, ...] = ("count", "sum", "min", "max", "avg")):
    """Turn accumulator state into user-facing aggregate columns."""
    out = {"key": state.keys}
    valid = state.valid()
    for a in aggs:
        if a == "count":
            out["count"] = state.count
        elif a == "sum":
            out["sum"] = state.sum
        elif a == "min":
            out["min"] = jnp.where(valid[:, None], state.min, 0.0)
        elif a == "max":
            out["max"] = jnp.where(valid[:, None], state.max, 0.0)
        elif a == "avg":
            c = jnp.maximum(state.count, 1).astype(jnp.float32)[:, None]
            out["avg"] = state.sum / c
        else:
            raise ValueError(f"unknown aggregate {a!r}")
    return out
