"""User-facing relational operators built on the single in-sort engine.

The paper's thesis: one sort-based algorithm can serve as *the only*
aggregation algorithm for unsorted inputs.  Accordingly ``group_by`` with
``algorithm="auto"`` always picks in-sort aggregation; the hash and
sort-then-stream baselines exist for the paper's comparisons.

Interesting-orderings payoffs (§2.2, §6.3, §6.4) are implemented as
operators that reuse a single sort:

* ``group_by_order_by``      — grouping whose sorted output satisfies an
                               equal ORDER BY for free (Fig 19);
* ``count_and_count_distinct`` — one sort on (g, a) serves both DISTINCT
                               and the subsequent grouping (Fig 20);
* ``rollup``                 — all rollup levels from one sort (§2.2);
* ``intersect_distinct``     — sorted plans spill each row once, not twice
                               (Figs 21/22).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hash_agg as hash_mod
from repro.core import insort as insort_mod
from repro.core import schema as schema_mod
from repro.core import sorted_ops
from repro.core.types import (
    EMPTY,
    AggState,
    ExecConfig,
    SpillStats,
    empty_key,
    key_dtype_context,
)


# ---------------------------------------------------------------------------
# key packing (multi-column grouping keys → one uint32)
# ---------------------------------------------------------------------------


def pack_keys(hi, lo, lo_bits: int):
    """Pack two non-negative integer columns into one uint32 sort key with
    ``hi`` major — the composite-key trick behind rollup/count-distinct."""
    hi = jnp.asarray(hi, dtype=jnp.uint32)
    lo = jnp.asarray(lo, dtype=jnp.uint32)
    return (hi << lo_bits) | lo


def unpack_keys(keys, lo_bits: int):
    keys = jnp.asarray(keys, dtype=jnp.uint32)
    return keys >> lo_bits, keys & ((jnp.uint32(1) << lo_bits) - jnp.uint32(1))


# ---------------------------------------------------------------------------
# group by / distinct
# ---------------------------------------------------------------------------


def group_by(
    keys,
    payload=None,
    cfg: ExecConfig | None = None,
    *,
    algorithm: str = "auto",
    output_estimate: int | None = None,
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
    pipeline: str = "device",
    mesh=None,
    mesh_axis: str | None = None,
) -> tuple[AggState, SpillStats]:
    """Duplicate removal / grouping / aggregation of an unsorted input.

    algorithm: "auto" (≡ "insort" — the paper's systems-only choice),
    "insort", "hash", "sort_then_stream", or "inmemory" (no budget).
    Keys may be uint32 or (for composite keys packed by
    :class:`repro.core.schema.KeySpec`) uint64; ``repro.aggregate`` is
    the schema-level front door over this dispatch.

    The in-sort algorithm runs on the device-resident fused pipeline by
    default (``pipeline="device"``: one compiled program, O(1) host
    syncs); ``pipeline="host"`` selects the reference loop with the
    paper's exact per-merge-level accounting.  ``mesh`` (a
    :class:`jax.sharding.Mesh`) shards the device pipeline over
    ``mesh_axis`` — per-shard run generation, a key-range ``all_to_all``
    of the locally aggregated outputs, and a per-owner merge; output is
    globally sorted by (range owner, key).  In-sort only.

    ``keys`` may instead be an iterator of chunks — bare key arrays, or
    ``(keys, payload)`` pairs — absorbed through the double-buffered
    streamed pipeline (in-sort + device only; pass ``payload=None``).
    """
    cfg = cfg or ExecConfig()
    if isinstance(keys, Iterator):
        if algorithm not in ("auto", "insort") or pipeline != "device":
            raise ValueError(
                "streamed input runs the in-sort device pipeline only "
                f"(got algorithm={algorithm!r}, pipeline={pipeline!r})"
            )
        if payload is not None:
            raise ValueError(
                "with streamed input, pass payload chunks as (keys, "
                "payload) pairs in the iterator, not payload="
            )
        from repro.core import pipeline as pipeline_mod

        return pipeline_mod.insort_aggregate_device_stream(
            keys, cfg, backend=backend, widths=widths,
            output_estimate=output_estimate, mesh=mesh, mesh_axis=mesh_axis,
        )
    if algorithm in ("auto", "insort"):
        return insort_mod.insort_aggregate(
            keys, payload, cfg, output_estimate=output_estimate, backend=backend,
            widths=widths, pipeline=pipeline, mesh=mesh, mesh_axis=mesh_axis,
        )
    if mesh is not None:
        raise ValueError(
            f"mesh-sharded aggregation is in-sort only; algorithm "
            f"{algorithm!r} cannot shard (use algorithm='insort')"
        )
    if algorithm == "hash":
        return hash_mod.hash_aggregate(
            keys, payload, cfg, output_estimate=output_estimate, backend=backend,
            widths=widths,
        )
    if algorithm == "f1_hash":
        return hash_mod.f1_hash_aggregate(
            keys, payload, cfg, backend=backend, widths=widths
        )
    if algorithm == "sort_then_stream":
        return insort_mod.sort_then_stream_aggregate(keys, payload, cfg, backend=backend)
    if algorithm == "inmemory":
        from repro.core.run_generation import _np_keys

        nk = _np_keys(keys)
        with key_dtype_context(nk):
            st = sorted_ops.sorted_groupby(
                nk,
                None if payload is None else jnp.asarray(payload),
                backend=backend,
                widths=widths,
            )
        return st, SpillStats()
    raise ValueError(f"unknown algorithm {algorithm!r}")


def distinct(keys, cfg: ExecConfig | None = None, **kw) -> tuple[AggState, SpillStats]:
    """SELECT DISTINCT — grouping with no payload."""
    return group_by(keys, None, cfg, **kw)


def group_by_order_by(keys, payload=None, cfg=None, *, algorithm="auto", **kw):
    """GROUP BY g ORDER BY g (Fig 19).  In-sort output is already sorted;
    hash output needs an extra full sort of the result (charged here)."""
    state, stats = group_by(keys, payload, cfg, algorithm=algorithm, **kw)
    extra_sort_rows = 0
    if algorithm in ("hash", "f1_hash"):
        state = sorted_ops.sort_state(state)  # hash order → key order
        extra_sort_rows = int(state.occupancy())
    return state, stats, extra_sort_rows


def count_and_count_distinct(g, a, lo_bits: int, cfg=None, *, algorithm="auto", **kw):
    """``select g, count(a), count(distinct a) … group by g`` (Fig 20).

    Sort-based: ONE sort on the composite key (g, a); duplicate removal on
    (g, a) and the subsequent per-g grouping use the same interesting
    ordering.  Hash-based needs two hash tables (both may spill) — modeled
    by running two hash aggregations and summing their spills.
    """
    g = jnp.asarray(g, dtype=jnp.uint32)
    a = jnp.asarray(a, dtype=jnp.uint32)
    packed = pack_keys(g, a, lo_bits)
    if algorithm in ("auto", "insort"):
        # one memory-intensive operation (the sort); both results fall out.
        dedup, stats = group_by(np.asarray(packed), None, cfg, algorithm="insort", **kw)
        gg, _ = unpack_keys(dedup.keys, lo_bits)
        gg = jnp.where(dedup.keys != EMPTY, gg, jnp.uint32(EMPTY))
        # per-g: count(a) = sum of per-(g,a) counts; count(distinct a) = rows
        per_g = sorted_ops.sorted_groupby(
            gg,
            jnp.stack(
                [dedup.count.astype(jnp.float32), dedup.valid().astype(jnp.float32)],
                axis=1,
            ),
        )  # in-stream over sorted keys in production; fused here
        return per_g, stats
    # hash plan: two independent hash aggregations
    dedup, s1 = group_by(np.asarray(packed), None, cfg, algorithm="hash", **kw)
    gg, _ = unpack_keys(dedup.keys, lo_bits)
    gg = jnp.where(dedup.keys != EMPTY, gg, jnp.uint32(EMPTY))
    per_g, s2 = group_by(
        np.asarray(jnp.where(dedup.keys != EMPTY, gg, jnp.uint32(EMPTY))),
        np.asarray(
            jnp.stack(
                [dedup.count.astype(jnp.float32), dedup.valid().astype(jnp.float32)],
                axis=1,
            )
        ),
        cfg,
        algorithm="hash",
        **kw,
    )
    s1.rows_spilled_merge += s2.total_spill_rows
    return per_g, s1


def rollup(day, month, year, payload=None, cfg=None, **kw):
    """``group by rollup(day, month, year)`` from ONE sort (§2.2): sort on
    (year, month, day); every coarser level is a segmented combine of the
    finer level's (already sorted) output.  Hash plans need one hash table
    per level.

    Thin wrapper over the generic :func:`repro.core.schema.rollup` (any
    prefix hierarchy, any key width) with the legacy (year 23 / month 4 /
    day 5 bits) uint32 packing and level names.  All four value planes
    are carried so every level keeps (N, V) sum/min/max shapes; coarse
    levels now aggregate over the original *rows* (count(month-level) is
    the month's row count, min/max are true per-level extrema) instead of
    re-aggregating the finer level's sums.
    """
    spec = schema_mod.KeySpec.of(year=23, month=4, day=5)
    cols = {
        "year": np.asarray(year, np.uint32),
        "month": np.asarray(month, np.uint32),
        "day": np.asarray(day, np.uint32),
    }
    aggs = ("count", "sum", "min", "max") if payload is not None else ("count",)
    out, stats = schema_mod.rollup(
        cols, by=spec, values=payload, aggs=schema_mod.AggSpec(*aggs),
        cfg=cfg, **kw,
    )
    legacy = {
        "day": ("year", "month", "day"),
        "month": ("year", "month"),
        "year": ("year",),
        "all": (),
    }
    return {name: out[lvl].state for name, lvl in legacy.items()}, stats


def intersect_distinct(a, b, cfg=None, *, algorithm="auto", **kw):
    """``select k from T1 intersect select k from T2`` (Figs 21/22).

    Sort-based plan: in-sort DISTINCT on each input (each row spills at
    most once), then a merge join of two sorted, duplicate-free streams —
    no further spill.  Hash-based plan: hash DISTINCT on each input plus a
    hash join that spills both (rows spill twice).
    """
    alg = "insort" if algorithm in ("auto", "insort") else "hash"
    da, sa = distinct(a, cfg, algorithm=alg, **kw)
    db, sb = distinct(b, cfg, algorithm=alg, **kw)
    if alg == "hash":
        da = sorted_ops.sort_state(da)
        db = sorted_ops.sort_state(db)
        # hash join spills both inputs again when larger than memory
        cfgM = (cfg or ExecConfig()).memory_rows
        extra = 0
        na, nb = int(da.occupancy()), int(db.occupancy())
        if na + nb > cfgM:
            extra = na + nb
        sa.rows_spilled_merge += sb.total_spill_rows + extra
    else:
        sa.rows_spilled_merge += sb.total_spill_rows
    with key_dtype_context(da):
        out = _merge_probe_intersect(da.keys, db.keys)
    return out, sa


@jax.jit
def _merge_probe_intersect(ka: jax.Array, kb: jax.Array) -> jax.Array:
    """Merge-join of two sorted, duplicate-free, EMPTY-padded key streams.

    Each ``ka`` row binary-searches ``kb`` once (a searchsorted merge
    probe — O(N·log M) total, versus the O(N·M) ``jnp.isin`` membership
    test this replaces), and hits are compacted to the front with the
    same cumsum-invert gather the engine uses — no sort, no scatter.
    EMPTY never probes equal because ``kb[pos]`` at the clip boundary is
    either EMPTY≠key or the key EMPTY is excluded explicitly.
    """
    sentinel = empty_key(ka.dtype)
    n, m = ka.shape[0], kb.shape[0]
    pos = jnp.searchsorted(kb, ka, side="left", method="scan_unrolled")
    probed = jnp.take(kb, jnp.minimum(pos, m - 1), mode="clip")
    hit = (probed == ka) & (ka != sentinel)
    # compact hits to the front (gather via running-count inversion)
    csum = jnp.cumsum(hit.astype(jnp.int32))
    n_hit = csum[-1]
    j = jnp.arange(n, dtype=jnp.int32)
    src = jnp.searchsorted(csum, j + 1, side="left", method="scan_unrolled")
    src = jnp.minimum(src, n - 1).astype(jnp.int32)
    live = j < n_hit
    return jnp.where(live, jnp.take(ka, src, mode="clip"), sentinel)


def validate_against_oracle(state: AggState, keys, payload=None):
    """NumPy oracle check used across the test suite (uint32 or uint64)."""
    keys = np.asarray(keys)
    if keys.dtype != np.uint64:
        keys = keys.astype(np.uint32)
    mask = keys != empty_key(keys.dtype)
    keys = keys[mask]
    uk, inv = np.unique(keys, return_inverse=True)
    got_k = np.asarray(state.keys)
    got_valid = got_k != empty_key(got_k.dtype)
    got = got_k[got_valid]
    order = np.argsort(got, kind="stable")
    assert np.array_equal(np.sort(got), uk), "key sets differ"
    cnt = np.zeros(len(uk), np.int64)
    np.add.at(cnt, inv, 1)
    got_cnt = np.asarray(state.count)[got_valid][order]
    assert np.array_equal(got_cnt, cnt), "counts differ"
    if payload is not None:
        payload = np.asarray(payload, dtype=np.float32)[mask]
        if payload.ndim == 1:
            payload = payload[:, None]
        sums = np.zeros((len(uk), payload.shape[1]), np.float64)
        np.add.at(sums, inv, payload.astype(np.float64))
        got_sum = np.asarray(state.sum, dtype=np.float64)[got_valid][order]
        np.testing.assert_allclose(got_sum, sums, rtol=2e-4, atol=2e-3)
    return True
