"""Core row/aggregate-state types shared by every grouping algorithm.

The paper's operators consume streams of (key, payload) rows and produce
(key, aggregate) rows.  All algorithms in :mod:`repro.core` share one
fixed-shape representation so that sort-based, hash-based, and in-stream
aggregation are interchangeable and bit-comparable:

* keys are ``uint32``; the sentinel ``EMPTY = 0xFFFF_FFFF`` marks unused
  slots and conveniently sorts to the end, which is how fixed-capacity
  "memory" tiles model the paper's variable-occupancy b-tree.
* the aggregate state is a struct-of-arrays ``AggState`` carrying
  count / sum / min / max over a ``V``-wide float payload (``V = 0`` for
  pure duplicate removal).  ``avg`` etc. are finalizers over this state,
  matching the paper's note (§3.3) that the in-memory row format differs
  from both input and output formats.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

EMPTY = np.uint32(0xFFFFFFFF)
# Largest key a user may supply (EMPTY is reserved).
MAX_KEY = np.uint32(0xFFFFFFFE)

_F32_INF = np.float32(np.inf)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AggState:
    """Struct-of-arrays aggregate accumulator.

    ``keys``   (N,)    uint32, EMPTY marks invalid rows.
    ``count``  (N,)    int64-safe int32 group cardinalities.
    ``sum``    (N, V)  float32 running sums.
    ``min``    (N, V)  float32 running minima (+inf for invalid).
    ``max``    (N, V)  float32 running maxima (-inf for invalid).
    """

    keys: jax.Array
    count: jax.Array
    sum: jax.Array
    min: jax.Array
    max: jax.Array

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def width(self) -> int:
        return self.sum.shape[1]

    def valid(self) -> jax.Array:
        return self.keys != EMPTY

    def occupancy(self) -> jax.Array:
        return jnp.sum(self.valid().astype(jnp.int32))


def empty_state(capacity: int, width: int) -> AggState:
    """A fresh, all-invalid accumulator of fixed capacity."""
    return AggState(
        keys=jnp.full((capacity,), EMPTY, dtype=jnp.uint32),
        count=jnp.zeros((capacity,), dtype=jnp.int32),
        sum=jnp.zeros((capacity, width), dtype=jnp.float32),
        min=jnp.full((capacity, width), _F32_INF, dtype=jnp.float32),
        max=jnp.full((capacity, width), -_F32_INF, dtype=jnp.float32),
    )


def rows_to_state(keys: jax.Array, payload: jax.Array | None) -> AggState:
    """Lift raw input rows into aggregate states (count=1, sum=min=max=v)."""
    keys = keys.astype(jnp.uint32)
    n = keys.shape[0]
    if payload is None:
        payload = jnp.zeros((n, 0), dtype=jnp.float32)
    if payload.ndim == 1:
        payload = payload[:, None]
    payload = payload.astype(jnp.float32)
    valid = keys != EMPTY
    vcol = valid[:, None]
    return AggState(
        keys=keys,
        count=valid.astype(jnp.int32),
        sum=jnp.where(vcol, payload, 0.0),
        min=jnp.where(vcol, payload, _F32_INF),
        max=jnp.where(vcol, payload, -_F32_INF),
    )


def concat_states(a: AggState, b: AggState) -> AggState:
    return jax.tree.map(lambda x, y: jnp.concatenate([x, y], axis=0), a, b)


def take(state: AggState, idx: jax.Array) -> AggState:
    """Row-gather a state (used to apply sort permutations)."""
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), state)


def slice_rows(state: AggState, start, size: int) -> AggState:
    def f(x):
        return jax.lax.dynamic_slice_in_dim(x, start, size, axis=0)

    return jax.tree.map(f, state)


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """External-algorithm knobs, mirroring the paper's experiment parameters.

    memory_rows  M — the fixed "memory allocation" in rows.
    page_rows    P — unit of temporary-storage I/O in rows.
    fanin        F — traditional merge fan-in / hash partitioning fan-out.
    batch_rows     — input consumption granularity (paper §5 sorts small
                     input batches before probing the index).
    """

    memory_rows: int = 1 << 12
    page_rows: int = 1 << 8
    fanin: int = 8
    batch_rows: int = 1 << 10

    def __post_init__(self):
        assert self.page_rows <= self.memory_rows
        assert self.batch_rows <= self.memory_rows
        assert self.fanin >= 2


@dataclasses.dataclass
class SpillStats:
    """Exact temporary-storage accounting (rows, the paper's unit)."""

    rows_spilled_run_generation: int = 0
    rows_spilled_merge: int = 0
    runs_generated: int = 0
    merge_steps: int = 0
    merge_levels: int = 0
    pages_read: int = 0
    rows_emitted: int = 0  # rows streamed out of the wide merge's left edge
    index_overflowed: bool = False
    max_index_occupancy: int = 0

    @property
    def total_spill_rows(self) -> int:
        return self.rows_spilled_run_generation + self.rows_spilled_merge

    def as_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["total_spill_rows"] = self.total_spill_rows
        return d
