"""Core row/aggregate-state types shared by every grouping algorithm.

The paper's operators consume streams of (key, payload) rows and produce
(key, aggregate) rows.  All algorithms in :mod:`repro.core` share one
fixed-shape representation so that sort-based, hash-based, and in-stream
aggregation are interchangeable and bit-comparable:

* keys are ``uint32`` or ``uint64`` (the *key dtype* travels with the
  arrays); the per-dtype sentinel ``EMPTY`` (the dtype's maximum) marks
  unused slots and conveniently sorts to the end, which is how
  fixed-capacity "memory" tiles model the paper's variable-occupancy
  b-tree.  64-bit keys exist so composite grouping keys (see
  :mod:`repro.core.schema`) stop competing for 32 bits; on the host they
  are plain NumPy ``uint64``, and any jnp computation over them must run
  inside :func:`key_dtype_context` (which enables JAX x64 only for that
  scope — the Pallas kernels instead compare 64-bit keys as a (hi, lo)
  pair of uint32 lanes and never need native 64-bit ops).
* the aggregate state is a struct-of-arrays ``AggState`` carrying
  count / sum / min / max over a ``V``-wide float payload (``V = 0`` for
  pure duplicate removal).  Each value plane may independently be absent
  (width 0) so an :class:`repro.core.schema.AggSpec` can request e.g.
  count+sum without paying for min/max.  ``avg`` etc. are finalizers over
  this state, matching the paper's note (§3.3) that the in-memory row
  format differs from both input and output formats.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

EMPTY = np.uint32(0xFFFFFFFF)
# Largest key a user may supply (EMPTY is reserved).
MAX_KEY = np.uint32(0xFFFFFFFE)

# 64-bit twins of the sentinels (composite keys wider than 32 bits).
EMPTY64 = np.uint64(0xFFFFFFFFFFFFFFFF)
MAX_KEY64 = np.uint64(0xFFFFFFFFFFFFFFFE)

KEY_DTYPES = (np.dtype(np.uint32), np.dtype(np.uint64))

_F32_INF = np.float32(np.inf)


def empty_key(dtype) -> np.unsignedinteger:
    """The EMPTY sentinel for a key dtype (its maximum value)."""
    dtype = np.dtype(dtype)
    if dtype == np.uint32:
        return EMPTY
    if dtype == np.uint64:
        return EMPTY64
    raise TypeError(f"unsupported key dtype {dtype}; expected one of {KEY_DTYPES}")


def max_key(dtype) -> np.unsignedinteger:
    """Largest user-suppliable key for a key dtype (EMPTY is reserved)."""
    dtype = np.dtype(dtype)
    if dtype == np.uint32:
        return MAX_KEY
    if dtype == np.uint64:
        return MAX_KEY64
    raise TypeError(f"unsupported key dtype {dtype}; expected one of {KEY_DTYPES}")


def key_dtype_for_bits(bits: int):
    """Smallest supported key dtype holding ``bits`` key bits."""
    if bits <= 32:
        return np.dtype(np.uint32)
    if bits <= 64:
        return np.dtype(np.uint64)
    raise ValueError(f"composite keys are limited to 64 bits, got {bits}")


def _dtype_of(x) -> np.dtype:
    if hasattr(x, "keys"):  # AggState / OrderedIndex
        x = x.keys
    try:
        return np.dtype(x)  # dtype objects, scalar types, dtype names
    except TypeError:
        return np.dtype(x.dtype)  # arrays / scalars


def key_dtype_context(x):
    """Context manager required around jnp computation on 64-bit keys.

    JAX canonicalizes 64-bit types away unless x64 is enabled; enabling it
    globally would change dtype semantics for the whole process (models,
    optimizers, …).  This scopes ``jax.experimental.enable_x64`` to the
    engine call operating on uint64 keys and is a no-op for uint32.
    Accepts an array, an AggState, or a dtype.
    """
    if _dtype_of(x) == np.uint64:
        from jax.experimental import enable_x64

        return enable_x64()
    return contextlib.nullcontext()


def as_key_array(keys) -> jax.Array:
    """Lift user keys to a jnp key vector, preserving uint64, casting
    everything else to the legacy uint32."""
    dtype = _dtype_of(keys)
    if dtype == np.uint64:
        return jnp.asarray(keys, dtype=jnp.uint64)  # caller holds the context
    return jnp.asarray(keys).astype(jnp.uint32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AggState:
    """Struct-of-arrays aggregate accumulator.

    ``keys``   (N,)    uint32 or uint64, EMPTY (dtype max) marks invalid rows.
    ``count``  (N,)    int64-safe int32 group cardinalities.
    ``sum``    (N, Vs) float32 running sums.
    ``min``    (N, Vm) float32 running minima (+inf for invalid).
    ``max``    (N, Vx) float32 running maxima (-inf for invalid).

    The value planes usually share one width V, but any of them may be
    width 0 when the requested aggregates don't need it (see
    :class:`repro.core.schema.AggSpec`).
    """

    keys: jax.Array
    count: jax.Array
    sum: jax.Array
    min: jax.Array
    max: jax.Array

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    @property
    def width(self) -> int:
        """The payload width V (max over the carried value planes)."""
        return max(self.widths)

    @property
    def widths(self) -> tuple[int, int, int]:
        """Per-plane widths (sum, min, max)."""
        return (self.sum.shape[1], self.min.shape[1], self.max.shape[1])

    @property
    def key_dtype(self) -> np.dtype:
        return np.dtype(self.keys.dtype)

    def valid(self) -> jax.Array:
        return self.keys != empty_key(self.keys.dtype)

    def occupancy(self) -> jax.Array:
        # dtype pinned: x64 mode would promote a plain sum to int64 and
        # break scan/while_loop carries built around occupancy counters
        return jnp.sum(self.valid(), dtype=jnp.int32)


def empty_state(
    capacity: int,
    width: int,
    *,
    key_dtype=np.uint32,
    widths: tuple[int, int, int] | None = None,
) -> AggState:
    """A fresh, all-invalid accumulator of fixed capacity.

    ``widths`` overrides the per-plane (sum, min, max) widths; by default
    all three carry ``width`` columns.
    """
    ws, wm, wx = widths if widths is not None else (width, width, width)
    key_dtype = np.dtype(key_dtype)
    return AggState(
        keys=jnp.full((capacity,), empty_key(key_dtype), dtype=key_dtype),
        count=jnp.zeros((capacity,), dtype=jnp.int32),
        sum=jnp.zeros((capacity, ws), dtype=jnp.float32),
        min=jnp.full((capacity, wm), _F32_INF, dtype=jnp.float32),
        max=jnp.full((capacity, wx), -_F32_INF, dtype=jnp.float32),
    )


def empty_like(state: AggState, capacity: int) -> AggState:
    """An all-invalid state matching ``state``'s key dtype and plane widths."""
    return empty_state(
        capacity, state.width, key_dtype=state.key_dtype, widths=state.widths
    )


def rows_to_state(
    keys: jax.Array,
    payload: jax.Array | None,
    *,
    widths: tuple[int, int, int] | None = None,
) -> AggState:
    """Lift raw input rows into aggregate states (count=1, sum=min=max=v).

    ``widths`` selects which value planes to materialize: each entry is
    either the payload width V or 0 (plane not requested).
    """
    keys = as_key_array(keys)
    n = keys.shape[0]
    if payload is None:
        payload = jnp.zeros((n, 0), dtype=jnp.float32)
    if payload.ndim == 1:
        payload = payload[:, None]
    payload = payload.astype(jnp.float32)
    v = payload.shape[1]
    ws, wm, wx = widths if widths is not None else (v, v, v)
    for w in (ws, wm, wx):
        assert w in (0, v), f"plane width {w} must be 0 or the payload width {v}"
    valid = keys != empty_key(keys.dtype)
    vcol = valid[:, None]
    return AggState(
        keys=keys,
        count=valid.astype(jnp.int32),
        sum=jnp.where(vcol, payload, 0.0) if ws else jnp.zeros((n, 0), jnp.float32),
        min=jnp.where(vcol, payload, _F32_INF) if wm else jnp.zeros((n, 0), jnp.float32),
        max=jnp.where(vcol, payload, -_F32_INF) if wx else jnp.zeros((n, 0), jnp.float32),
    )


def concat_states(a: AggState, b: AggState) -> AggState:
    return jax.tree.map(lambda x, y: jnp.concatenate([x, y], axis=0), a, b)


def take(state: AggState, idx: jax.Array) -> AggState:
    """Row-gather a state (used to apply sort permutations)."""
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), state)


def slice_rows(state: AggState, start, size: int) -> AggState:
    def f(x):
        return jax.lax.dynamic_slice_in_dim(x, start, size, axis=0)

    return jax.tree.map(f, state)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamEngineState:
    """The device-resident carry of the external-aggregation scan, as an
    explicit, reusable pytree.

    The fused pipeline (:mod:`repro.core.pipeline`) advances this state
    one input batch at a time; making the carry a first-class value is
    what lets a host loop feed the engine **super-batches** (chunks of
    the input stream) through a jitted ``absorb_chunk`` step, double-
    buffering host→device transfer behind compute, instead of requiring
    the whole input resident as one ``(T, B)`` stack.

    Field usage varies by run-generation policy (unused tables carry
    capacity 0 so the pytree structure stays uniform per policy):

    ``table``     early-agg ordered in-memory index (capacity M), or the
                  replacement-selection run partition (capacity M + 2B).
    ``table2``    replacement selection's next-run partition.
    ``frontier``  replacement selection's eviction frontier key (scalar).
    ``store``     the stacked run buffer — leading dims ``(R, C)``:
                  R page-aligned run slots of C rows each.
    ``lens``      ``(R,)`` int32 per-slot run lengths.
    ``cursor``    replacement selection's write cursor within the open
                  run slot.
    ``ridx``      the next free run slot.
    ``spilled``   rows spilled by run generation so far.
    ``absorbed``  valid input rows the engine has consumed so far (the
                  observation block's denominator).
    ``dups``      duplicate-key encounters observed while absorbing:
                  rows that combined into an existing group (absorbing
                  policies) or adjacent equal-key pairs within a sorted
                  batch (non-deduping ``traditional``).  ``dups /
                  absorbed`` is the running duplicate-rate estimate the
                  adaptive policy governor steers on.

    All counters are device scalars: absorbing a chunk performs **zero**
    host synchronizations, and the spill accounting becomes a
    :class:`DeviceSpillStats` only at the single finalize readback.  The
    observation block (``absorbed``, ``dups``, plus occupancy/``ridx``)
    is read back *explicitly* — and only every k-th chunk — by the
    adaptive streaming mode (:mod:`repro.core.adaptive`).
    """

    table: AggState
    table2: AggState
    frontier: jax.Array
    store: AggState
    lens: jax.Array
    cursor: jax.Array
    ridx: jax.Array
    spilled: jax.Array
    absorbed: jax.Array
    dups: jax.Array

    @property
    def run_slots(self) -> int:
        """R — preallocated run slots in the stacked store."""
        return self.lens.shape[-1]

    @property
    def slot_rows(self) -> int:
        """C — page-aligned capacity of one run slot."""
        return self.store.keys.shape[-1]

    @property
    def key_dtype(self) -> np.dtype:
        return np.dtype(self.store.keys.dtype)


# scalar leaves of StreamEngineState (everything else has a leading row or
# slot dim).  The mesh-sharded stream keeps these as (1,)-shaped per-shard
# arrays so every leaf can carry a sharded leading axis; these helpers
# convert at the shard_map boundary.
_SES_SCALARS = ("frontier", "cursor", "ridx", "spilled", "absorbed", "dups")


def expand_engine_scalars(es: StreamEngineState) -> StreamEngineState:
    """() scalar leaves → (1,) so each leaf has a shardable leading dim."""
    return dataclasses.replace(
        es, **{f: getattr(es, f)[None] for f in _SES_SCALARS}
    )


def squeeze_engine_scalars(es: StreamEngineState) -> StreamEngineState:
    """(1,) scalar leaves → () (inverse of :func:`expand_engine_scalars`)."""
    return dataclasses.replace(
        es, **{f: getattr(es, f)[0] for f in _SES_SCALARS}
    )


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """External-algorithm knobs, mirroring the paper's experiment parameters.

    memory_rows  M — the fixed "memory allocation" in rows.
    page_rows    P — unit of temporary-storage I/O in rows.
    fanin        F — traditional merge fan-in / hash partitioning fan-out.
    batch_rows     — input consumption granularity (paper §5 sorts small
                     input batches before probing the index).
    """

    memory_rows: int = 1 << 12
    page_rows: int = 1 << 8
    fanin: int = 8
    batch_rows: int = 1 << 10

    def __post_init__(self):
        assert self.page_rows <= self.memory_rows
        assert self.batch_rows <= self.memory_rows
        assert self.fanin >= 2


@dataclasses.dataclass
class SpillStats:
    """Exact temporary-storage accounting (rows, the paper's unit)."""

    rows_spilled_run_generation: int = 0
    rows_spilled_merge: int = 0
    runs_generated: int = 0
    merge_steps: int = 0
    merge_levels: int = 0
    pages_read: int = 0
    rows_emitted: int = 0  # rows streamed out of the wide merge's left edge
    index_overflowed: bool = False
    max_index_occupancy: int = 0
    # shuffle-volume accounting (mesh-sharded pipeline): valid rows that
    # entered the cross-shard all_to_all exchange, summed over shards.
    # 0 for every single-device plan.
    rows_exchanged: int = 0
    # eviction accounting (streaming service TTL/key retirement): state
    # rows retired from the live engine — nothing leaves the engine
    # without being counted here or emitted.  0 for every one-shot plan.
    rows_retired: int = 0
    # adaptive-streaming observation block (defaults for every fixed-policy
    # or one-shot plan, so device-vs-host stats parity is unaffected):
    # the engine's final duplicate-rate estimate, how often the governor
    # switched run-generation policy mid-stream, and how many decision
    # scalar readbacks the host paid for them (the O(stream/k) budget).
    duplicate_rate: float = 0.0
    policy_switches: int = 0
    readbacks_paid: int = 0
    # capacity-bounded exchange accounting (mesh-sharded pipeline; all 0
    # for single-device plans): the per-peer send quota the exchange ran
    # at (rows per shard->owner fragment), the fullest send segment
    # actually observed (max over peers and shards — `max_fill / quota`
    # is the sampled cuts' balance signal), and how many times a host
    # entry point had to retry the exchange at a wider quota.
    exchange_quota: int = 0
    exchange_max_fill: int = 0
    exchange_retries: int = 0

    @property
    def total_spill_rows(self) -> int:
        return self.rows_spilled_run_generation + self.rows_spilled_merge

    def as_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["total_spill_rows"] = self.total_spill_rows
        return d

    @classmethod
    def reduce_shards(cls, shards: "list[SpillStats]") -> "SpillStats":
        """Host twin of :meth:`DeviceSpillStats.cross_shard`: combine
        per-shard accounting into the global view — counters add, depth
        and peak-occupancy take the max, flags OR.  Used by tests to
        predict the sharded pipeline's stats from per-shard references."""
        assert shards, "reduce_shards needs at least one shard"
        return cls(
            rows_spilled_run_generation=sum(
                s.rows_spilled_run_generation for s in shards
            ),
            rows_spilled_merge=sum(s.rows_spilled_merge for s in shards),
            runs_generated=sum(s.runs_generated for s in shards),
            merge_steps=sum(s.merge_steps for s in shards),
            merge_levels=max(s.merge_levels for s in shards),
            pages_read=sum(s.pages_read for s in shards),
            rows_emitted=sum(s.rows_emitted for s in shards),
            index_overflowed=any(s.index_overflowed for s in shards),
            max_index_occupancy=max(s.max_index_occupancy for s in shards),
            rows_exchanged=sum(s.rows_exchanged for s in shards),
            rows_retired=sum(s.rows_retired for s in shards),
            duplicate_rate=max(s.duplicate_rate for s in shards),
            policy_switches=sum(s.policy_switches for s in shards),
            readbacks_paid=sum(s.readbacks_paid for s in shards),
            exchange_quota=max(s.exchange_quota for s in shards),
            exchange_max_fill=max(s.exchange_max_fill for s in shards),
            exchange_retries=sum(s.exchange_retries for s in shards),
        )


class MergeOverflowError(RuntimeError):
    """The wide merge dropped rows (``merge_dropped_rows`` tripped):
    either its index outgrew its capacity or the output overran its
    buffer.  Subclasses :class:`RuntimeError` so existing callers that
    catch broadly keep working; the streaming finalize/snapshot path
    catches *this* type specifically to auto-retry once at the next
    pow2 output capacity."""


class ExchangeOverflowError(RuntimeError):
    """The cross-shard exchange's per-peer send quota was too small for
    at least one send segment (``exchange_dropped`` tripped): rows would
    have been silently left behind on the sending shard.  The host entry
    points (one-shot mesh aggregate, streaming finalize/snapshot, the
    mesh merge join, and the distributed group-by) catch *this* type
    specifically to retry ONCE at the next pow2 quota — a second
    overflow propagates.  Carries the static ``quota`` the exchange ran
    at and the observed ``max_fill`` so the retry can size itself."""

    def __init__(self, message: str, *, quota: int, max_fill: int):
        super().__init__(message)
        self.quota = quota
        self.max_fill = max_fill


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceSpillStats:
    """:class:`SpillStats` as a device pytree of int32/bool scalars.

    The device-resident pipeline (:mod:`repro.core.pipeline`) accumulates
    spill accounting in scan/while carries instead of host counters, so an
    entire run-generation + wide-merge program needs **zero** host syncs
    until the caller asks for numbers.  :meth:`finalize` performs that one
    readback and returns the plain host :class:`SpillStats`.

    Three device-side safety flags have no host twin — each means rows
    were (or would have been) silently lost, so ``finalize`` raises
    instead of returning corrupt accounting: ``run_buffer_overflowed``
    trips if run generation needed more run slots than the preallocated
    stacked buffer holds; ``merge_dropped_rows`` trips if the wide-merge
    index exceeded its hard capacity (resident > index_rows + page_rows)
    and live rows were trimmed; ``exchange_dropped`` trips if a
    cross-shard send segment exceeded the per-peer exchange quota
    (raised as the retryable :class:`ExchangeOverflowError`).
    """

    rows_spilled_run_generation: jax.Array
    rows_spilled_merge: jax.Array
    runs_generated: jax.Array
    merge_steps: jax.Array
    merge_levels: jax.Array
    pages_read: jax.Array
    rows_emitted: jax.Array
    index_overflowed: jax.Array
    max_index_occupancy: jax.Array
    run_buffer_overflowed: jax.Array
    merge_dropped_rows: jax.Array
    rows_exchanged: jax.Array
    rows_retired: jax.Array
    # capacity-bounded exchange block: exchange_dropped is the third
    # loud-failure flag (a send segment exceeded the per-peer quota);
    # finalize raises ExchangeOverflowError on it so host entry points
    # can retry once at a wider quota.
    exchange_dropped: jax.Array
    exchange_quota: jax.Array
    exchange_max_fill: jax.Array

    @classmethod
    def zeros(cls) -> "DeviceSpillStats":
        z = jnp.int32(0)
        f = jnp.bool_(False)
        return cls(z, z, z, z, z, z, z, f, z, f, f, z, z, f, z, z)

    def cross_shard(self, axis_name: str) -> "DeviceSpillStats":
        """Reduce per-shard accounting to the global view inside a
        ``shard_map`` region: row/step counters ``psum``, merge depth and
        peak index occupancy ``pmax``, and the loud-failure flags OR
        (``pmax`` over their int casts) — so a single shard's overflow
        trips :meth:`finalize` globally.  The result is replicated; the
        sharded pipeline's stats output therefore still needs only ONE
        host readback."""
        ps = lambda x: jax.lax.psum(x, axis_name)
        pm = lambda x: jax.lax.pmax(x, axis_name)
        por = lambda x: pm(x.astype(jnp.int32)) > 0
        return DeviceSpillStats(
            rows_spilled_run_generation=ps(self.rows_spilled_run_generation),
            rows_spilled_merge=ps(self.rows_spilled_merge),
            runs_generated=ps(self.runs_generated),
            merge_steps=ps(self.merge_steps),
            merge_levels=pm(self.merge_levels),
            pages_read=ps(self.pages_read),
            rows_emitted=ps(self.rows_emitted),
            index_overflowed=por(self.index_overflowed),
            max_index_occupancy=pm(self.max_index_occupancy),
            run_buffer_overflowed=por(self.run_buffer_overflowed),
            merge_dropped_rows=por(self.merge_dropped_rows),
            rows_exchanged=ps(self.rows_exchanged),
            rows_retired=ps(self.rows_retired),
            exchange_dropped=por(self.exchange_dropped),
            exchange_quota=pm(self.exchange_quota),
            exchange_max_fill=pm(self.exchange_max_fill),
        )

    def finalize(self, *, entry_point: str = "finalize") -> SpillStats:
        """One host readback → plain :class:`SpillStats` (the pipeline's
        only device→host synchronization point).

        ``entry_point`` names the merge program that produced these stats
        ("finalize" for the destructive drain, "snapshot" for the
        merge-on-read service query) so an overflow raised here tells the
        caller which knob to turn.
        """
        if bool(self.run_buffer_overflowed):
            raise RuntimeError(
                f"device run buffer overflowed its preallocated run slots "
                f"during {entry_point}; results would be missing rows "
                "(this is a bug in the slot bound — please report input "
                "sizes and ExecConfig)"
            )
        if bool(self.exchange_dropped):
            raise ExchangeOverflowError(
                f"the cross-shard exchange during {entry_point} overflowed "
                f"its per-peer send quota ({int(self.exchange_max_fill)} "
                f"rows in the fullest segment vs quota "
                f"{int(self.exchange_quota)}); rows would have been left "
                "behind — pass a larger exchange_quota (host entry points "
                "retry once at the next pow2 automatically)",
                quota=int(self.exchange_quota),
                max_fill=int(self.exchange_max_fill),
            )
        if bool(self.merge_dropped_rows):
            if entry_point == "snapshot":
                hint = (
                    "raise output_rows (the snapshot output capacity) or "
                    "pass a larger output_estimate (more pre-merge levels)"
                )
            else:
                hint = (
                    "pass a larger output_estimate (more pre-merge levels) "
                    "or raise index_rows"
                )
            raise MergeOverflowError(
                f"the wide merge during {entry_point} dropped rows: either "
                "its index overflowed its capacity (max resident "
                f"{int(self.max_index_occupancy)} rows) or the output "
                f"overran its buffer — {hint}"
            )
        return SpillStats(
            rows_spilled_run_generation=int(self.rows_spilled_run_generation),
            rows_spilled_merge=int(self.rows_spilled_merge),
            runs_generated=int(self.runs_generated),
            merge_steps=int(self.merge_steps),
            merge_levels=int(self.merge_levels),
            pages_read=int(self.pages_read),
            rows_emitted=int(self.rows_emitted),
            index_overflowed=bool(self.index_overflowed),
            max_index_occupancy=int(self.max_index_occupancy),
            rows_exchanged=int(self.rows_exchanged),
            rows_retired=int(self.rows_retired),
            exchange_quota=int(self.exchange_quota),
            exchange_max_fill=int(self.exchange_max_fill),
        )
