"""In-stream aggregation for pre-sorted input (baseline #1 of the paper).

"Each tuple read will have either the same by-list as the previous tuple,
or it will be an entirely new by-list" [10] — a single pass, O(1) groups of
state.  Implemented as a jitted scan over fixed-size chunks with a one-row
carry so the streaming property (bounded memory independent of input size)
is structural, not an accident of jnp fusion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import sorted_ops
from repro.core.types import (
    AggState,
    empty_like,
    key_dtype_context,
    rows_to_state,
)


@functools.partial(jax.jit, static_argnames=("chunk", "out_capacity", "widths"))
def _instream_jit(
    sorted_keys: jax.Array,
    payload: jax.Array | None = None,
    *,
    chunk: int = 1024,
    out_capacity: int | None = None,
    widths: tuple[int, int, int] | None = None,
) -> tuple[AggState, jax.Array]:
    n = sorted_keys.shape[0]
    if out_capacity is None:
        out_capacity = n
    pad = (-n) % chunk
    state = rows_to_state(sorted_keys, payload, widths=widths)
    if pad:
        state = jax.tree.map(
            lambda x, e: jnp.concatenate([x, e], axis=0),
            state,
            empty_like(state, pad),
        )
    nchunks = (n + pad) // chunk
    chunked = jax.tree.map(lambda x: x.reshape((nchunks, chunk) + x.shape[1:]), state)

    out0 = empty_like(state, out_capacity)
    carry0 = (empty_like(state, 1), out0, jnp.int32(0))

    def step(carry, ch):
        open_grp, out, cur = carry
        # combine the open group with this chunk; chunk is already sorted
        merged = sorted_ops.segmented_combine(
            jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0), open_grp, ch)
        )  # capacity chunk+1, sorted, compacted
        occ = merged.occupancy()
        # all groups except the last are closed: emit them
        e = jnp.maximum(occ - 1, 0)
        idx = jnp.where(jnp.arange(chunk + 1) < e, cur + jnp.arange(chunk + 1), out_capacity)
        out = jax.tree.map(lambda d, s: d.at[idx].set(s, mode="drop"), out, merged)
        # carry the last (still-open) group
        last = jnp.maximum(occ - 1, 0)
        open_grp = jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, last, 1, axis=0), merged
        )
        open_grp = jax.tree.map(
            lambda x, z: jnp.where(
                (occ > 0).reshape((1,) * x.ndim), x, z
            ),
            open_grp,
            empty_like(state, 1),
        )
        return (open_grp, out, cur + e), None

    (open_grp, out, cur), _ = jax.lax.scan(step, carry0, chunked)
    # flush the final open group
    occ = open_grp.occupancy()
    idx = jnp.where(jnp.arange(1) < occ, cur + jnp.arange(1), out_capacity)
    out = jax.tree.map(lambda d, s: d.at[idx].set(s, mode="drop"), out, open_grp)
    return out, cur + occ


def instream_aggregate(
    sorted_keys: jax.Array,
    payload: jax.Array | None = None,
    *,
    chunk: int = 1024,
    out_capacity: int | None = None,
    widths: tuple[int, int, int] | None = None,
) -> tuple[AggState, jax.Array]:
    """Aggregate a key-sorted stream. Returns (output state, #groups)."""
    with key_dtype_context(sorted_keys):
        return _instream_jit(
            sorted_keys, payload, chunk=chunk, out_capacity=out_capacity,
            widths=widths,
        )
