"""Order-consuming merge join over sorted, duplicate-free states.

The paper's second claim is that sort-based aggregation pays for itself
*downstream*: its output relation arrives key-sorted, so a subsequent
join can be a **merge join** that never sorts.  Every ``AggResult`` in
this repo (one-shot, streamed, sharded, service snapshot) satisfies the
OrderedIndex invariant — keys ascending, valid keys duplicate-free,
EMPTY-padded suffix — which is exactly a merge join's precondition.

This module is the device-resident join layer over that invariant:

* :func:`join_probe` — the two-sided probe: each left row binary-searches
  the right key vector once (``searchsorted`` rank alignment, the same
  primitive the linear merge-absorb is built from) producing a match
  rank + hit mask.  No sort of either input ever happens; the jaxpr
  contains **no sort and no scatter** (tested, u32 and u64).  The Pallas
  backend routes the probe through the merge-path kernel's lane-parallel
  binary search (:func:`repro.kernels.merge_path.merge_path_probe_tiles`)
  so 64-bit keys compare as (hi, lo) uint32 lanes on TPU.
* :func:`merge_join` — inner / left-semi / left-anti join of two sorted
  duplicate-free ``AggState``s: probe + cumsum-invert compaction gather
  (shared with the segmented combine).  Inner joins return BOTH sides'
  aggregate rows aligned on one sorted key vector, which is what lets a
  downstream rollup peel prefix levels from the join output without any
  further sort (see :meth:`repro.core.schema.JoinResult.rollup`).
* :func:`group_join_products` — the aggregation-fused group-join of
  §2.5/Fig 4 over two *already aggregated* sides: per key,
  ``|L|·|R|`` (the join cardinality contribution) and the
  ``Σ_L payload·|R|`` / ``|L|·Σ_R payload`` cross sums — COUNT/SUM/AVG
  group-joins straight from the two sides' aggregate states, no row
  enumeration.

Both inputs must share one key dtype (uint32 or uint64, caller holds
:func:`repro.core.types.key_dtype_context` for uint64 — the schema layer
does).  Capacities are static: the joined state has the LEFT capacity
(each left key matches at most one right key since both sides are
duplicate-free), so jitted callers see fixed shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.ordered_index import compact_indices
from repro.core import types as types_mod
from repro.core.types import AggState, empty_key

JOIN_HOWS = ("inner", "semi", "anti")

_INF = jnp.float32(jnp.inf)


def join_probe(
    a_keys: jax.Array, b_keys: jax.Array, *, backend: str = "auto"
) -> tuple[jax.Array, jax.Array]:
    """Rank-align each (sorted) left key against the (sorted) right keys.

    Returns ``(pos, hit)``: ``pos[i]`` is the right row holding
    ``a_keys[i]`` when ``hit[i]`` (clipped to a valid row index
    otherwise), via one ``searchsorted`` per left row — the merge join's
    entire "merge" phase, no sort, no scatter.  EMPTY left rows never
    hit (EMPTY is the key dtype's maximum and is excluded explicitly);
    the EMPTY tail of ``b_keys`` ranks after every valid key and cannot
    produce a false hit because EMPTY ≠ any valid key.
    """
    be = dispatch.get_backend(backend)
    if be.join_probe is not None:
        return be.join_probe(a_keys, b_keys)
    return join_probe_xla(a_keys, b_keys)


def join_probe_xla(a_keys: jax.Array, b_keys: jax.Array):
    """XLA reference probe (see :func:`join_probe`)."""
    sentinel = empty_key(a_keys.dtype)
    m = b_keys.shape[0]
    if m == 0:
        pos = jnp.zeros(a_keys.shape, jnp.int32)
        return pos, jnp.zeros(a_keys.shape, bool)
    pos = jnp.searchsorted(
        b_keys, a_keys, side="left", method="scan_unrolled"
    ).astype(jnp.int32)
    pos = jnp.minimum(pos, m - 1)
    probed = jnp.take(b_keys, pos, mode="clip")
    hit = (probed == a_keys) & (a_keys != sentinel)
    return pos, hit


def _gather_rows(state: AggState, idx: jax.Array, live: jax.Array) -> AggState:
    """Row-gather ``state`` through ``idx``, neutral-filling dead rows."""

    def pick(col, fill):
        v = jnp.take(col, idx, axis=0, mode="clip")
        mask = live.reshape((-1,) + (1,) * (v.ndim - 1))
        return jnp.where(mask, v, fill)

    return AggState(
        keys=pick(state.keys, empty_key(state.keys.dtype)),
        count=pick(state.count, 0),
        sum=pick(state.sum, 0.0),
        min=pick(state.min, _INF),
        max=pick(state.max, -_INF),
    )


@jax.jit
def compact_state(state: AggState) -> AggState:
    """Close interior EMPTY gaps with ONE compaction gather.

    A mesh-sharded relation is globally sorted by (owner, key) but
    EMPTY-padded *per shard*, so its key vector has interior sentinel
    runs.  Valid keys are still ascending, so compacting them to the
    front restores the single-device OrderedIndex layout without a sort
    (and without emitting one) — the order the upstream sort established
    survives the shuffle.
    """
    src, live = compact_indices(state.keys != empty_key(state.keys.dtype))
    return _gather_rows(state, src, live)


@functools.partial(jax.jit, static_argnames=("how", "backend"))
def merge_join(
    a: AggState, b: AggState, *, how: str = "inner", backend: str = "xla"
) -> tuple[AggState, AggState | None]:
    """Merge join of two sorted, duplicate-free, EMPTY-padded states.

    ``how``:

    * ``"inner"`` — keys present on both sides.  Returns ``(left,
      right)``: two states of capacity ``|a|`` sharing ONE sorted key
      vector (matches compacted to the front, EMPTY tail), ``left``
      carrying the left side's aggregate planes and ``right`` the
      right side's.
    * ``"semi"`` — left rows with a right match (``right`` is None).
    * ``"anti"`` — left rows with NO right match — the paper notes these
      "cannot be produced early"; here they are simply the probe's
      misses (``right`` is None).

    The program is probe (rank alignment) + compaction gather: no sort
    and no scatter primitive on the XLA backend (jaxpr-tested for u32
    and u64 keys), because the inputs' established order does all the
    work — this is the "interesting orderings" payoff the cost model
    credits via the zero sort term.
    """
    if how not in JOIN_HOWS:
        raise ValueError(f"unknown join how={how!r}; expected one of {JOIN_HOWS}")
    if a.capacity == 0:
        return a, (b if how == "inner" else None)
    pos, hit = join_probe(a.keys, b.keys, backend=backend)
    if how == "anti":
        keep = (a.keys != empty_key(a.keys.dtype)) & ~hit
    else:
        keep = hit
    src, live = compact_indices(keep)
    left = _gather_rows(a, src, live)
    if how != "inner":
        return left, None
    if b.capacity == 0:
        return left, types_mod.empty_like(b, a.capacity)
    right = _gather_rows(b, jnp.take(pos, src, mode="clip"), live)
    return left, right


def group_join_products(left: AggState, right: AggState) -> dict[str, jax.Array]:
    """The aggregation-fused group-join (§2.5, Fig 4) over an inner merge
    join's aligned sides.

    Per joined key ``k`` with left packet ``(|L|, Σ_L v)`` and right
    packet ``(|R|, Σ_R w)``:

    * ``join_count``      = |L|·|R| — this key's contribution to the
      join cardinality (float32: counts are per-side group sizes and
      their product overflows int32 on hot keys);
    * ``sum_left_x_count_right`` = Σ_L v · |R| — the sum of the left
      payload over all (l, r) join pairs;
    * ``count_left_x_sum_right`` = |L| · Σ_R w — symmetric.

    Enough for COUNT(*)/SUM/AVG group-joins without enumerating a single
    join pair; full row enumeration would expand the same packets.
    """
    n_l = left.count.astype(jnp.float32)
    n_r = right.count.astype(jnp.float32)
    return {
        "join_count": n_l * n_r,
        "sum_left_x_count_right": left.sum * n_r[:, None],
        "count_left_x_sum_right": right.sum * n_l[:, None],
    }
