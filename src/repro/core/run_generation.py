"""Run generation for external in-sort aggregation (paper §3).

Read-sort-write cycles (the paper's production choice, §5) with three
spill policies that reproduce the paper's comparison space:

* ``traditional``  — fill memory with raw rows, sort, write a run of
  exactly M rows (Fig 2 top: no data reduction before the final merge).
* ``inrun_dedup``  — fill memory with raw rows, sort, aggregate duplicates
  *within the run* before writing (Bitton/DeWitt [3], Fig 2 bottom).
* ``early_agg``    — the paper's §3: every input batch is sorted, deduped,
  and absorbed into the ordered in-memory index; memory holds only
  *unique* keys, so a run is written only once M distinct keys
  accumulated.  If the output fits memory, nothing spills (Fig 6).

The drivers here are host-orchestrated (like the paper's I/O loop) around
jitted fixed-shape steps, blocking on an occupancy readback after every
batch: they are the **reference path** — exact, per-batch spill
accounting in the paper's unit (rows), used by the cost-model study and
as the oracle-parity baseline.  The production path is
:mod:`repro.core.pipeline`, which runs the same policies as a single
jitted ``lax.scan`` with device-resident run buffers and O(1) host syncs
per input; the step primitives (:func:`rs_split_absorb`,
:func:`rs_evict_step`) are shared so both paths execute the same
per-batch state machine.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sorted_ops
from repro.core.types import (
    EMPTY,
    AggState,
    ExecConfig,
    SpillStats,
    as_key_array,
    concat_states,
    empty_key,
    empty_like,
    empty_state,
    key_dtype_context,
    rows_to_state,
)


@dataclasses.dataclass
class Run:
    """One sorted, EMPTY-padded run on "temporary storage" (HBM/host)."""

    state: AggState
    length: int  # occupied rows


@functools.partial(jax.jit, static_argnames=("backend",))
def _absorb_batch(table: AggState, batch_keys, batch_payload, *, backend="xla"):
    """One read-sort-write step: sort/dedupe the batch (paper §5), merge it
    into the ordered index, and report the new occupancy."""
    batch = sorted_ops.absorb(
        rows_to_state(batch_keys, batch_payload, widths=table.widths),
        backend=backend,
    )
    # table and batch are both duplicate-free ordered indexes: the insert
    # is a linear merge + pair-combine, never a sort.
    merged = sorted_ops.merge_absorb(table, batch, backend=backend, assume_unique=True)
    return merged, merged.occupancy()


@functools.partial(jax.jit, static_argnames=("capacity", "dedup", "backend", "widths"))
def _sort_chunk(keys, payload, capacity: int, *, dedup: bool, backend="xla",
                widths=None):
    """Sort (and optionally dedup) one chunk, padded to the fixed run
    capacity.  Chunks are produced at ≤ capacity rows, so only padding is
    ever needed; trimming would silently drop rows."""
    state = rows_to_state(keys, payload, widths=widths)
    assert state.capacity <= capacity, (
        f"chunk of {state.capacity} rows exceeds run capacity {capacity}"
    )
    if dedup:
        state = sorted_ops.absorb(state, backend=backend)
    else:
        state = sorted_ops.sort_state(state, backend=backend)
    pad = capacity - state.capacity
    if pad > 0:
        state = concat_states(state, empty_like(state, pad))
    return state, state.occupancy()


def _np_chunks(keys: np.ndarray, payload: np.ndarray | None, size: int):
    n = keys.shape[0]
    for s in range(0, n, size):
        e = min(n, s + size)
        k = keys[s:e]
        p = None if payload is None else payload[s:e]
        if k.shape[0] < size:  # fixed shapes: pad the final batch with EMPTY
            padn = size - k.shape[0]
            k = np.concatenate([k, np.full((padn,), empty_key(k.dtype), dtype=k.dtype)])
            if p is not None:
                p = np.concatenate([p, np.zeros((padn,) + p.shape[1:], p.dtype)])
        yield k, p


def _np_keys(keys: np.ndarray) -> np.ndarray:
    """Host-side key canonicalization: uint64 is preserved, everything
    else becomes the legacy uint32."""
    keys = np.asarray(keys)
    if keys.dtype != np.uint64:
        keys = keys.astype(np.uint32)
    return keys


def generate_runs(
    keys: np.ndarray,
    payload: np.ndarray | None,
    cfg: ExecConfig,
    *,
    policy: str = "early_agg",
    backend: str = "xla",
    widths: tuple[int, int, int] | None = None,
) -> tuple[list[Run], AggState | None, SpillStats]:
    """Consume an unsorted input stream; return (runs, resident_table, stats).

    ``resident_table`` is non-None only for ``early_agg`` — the in-memory
    index content at end-of-input.  If no runs were written the operation
    completed entirely in memory (paper Fig 6) and the table *is* the
    result.
    """
    keys = _np_keys(keys)
    if payload is not None:
        payload = np.asarray(payload, dtype=np.float32)
        if payload.ndim == 1:
            payload = payload[:, None]
    width = 0 if payload is None else payload.shape[1]
    M, B = cfg.memory_rows, cfg.batch_rows
    stats = SpillStats()
    runs: list[Run] = []

    with key_dtype_context(keys):
        if policy in ("traditional", "inrun_dedup"):
            # memory buffers M raw rows; sort(+dedup) on write.
            for ck, cp in _np_chunks(keys, payload, M):
                state, occ = _sort_chunk(
                    as_key_array(ck), None if cp is None else jnp.asarray(cp),
                    M, dedup=(policy == "inrun_dedup"), backend=backend,
                    widths=widths,
                )
                length = int(occ)
                runs.append(Run(state=state, length=length))
                stats.rows_spilled_run_generation += length
                stats.runs_generated += 1
            return runs, None, stats

        if policy != "early_agg":
            raise ValueError(f"unknown run-generation policy {policy!r}")

        # --- early aggregation: ordered in-memory index absorbs duplicates ---
        table = empty_state(M, width, key_dtype=keys.dtype, widths=widths)
        for ck, cp in _np_chunks(keys, payload, B):
            merged, occ = _absorb_batch(
                table, as_key_array(ck), None if cp is None else jnp.asarray(cp),
                backend=backend,
            )  # capacity M + B
            n = int(occ)
            if n > M:
                # memory full: write the entire index content as one sorted run
                # (read-sort-write cycle; runs ≈ M *unique* rows, paper §5).
                runs.append(Run(state=merged, length=n))
                stats.rows_spilled_run_generation += n
                stats.runs_generated += 1
                table = empty_state(M, width, key_dtype=keys.dtype, widths=widths)
            else:
                table = jax.tree.map(lambda x: x[: M], merged)  # trim back to M

        if not runs:
            return [], table, stats
        # flush the final partial run
        occ = int(table.occupancy())
        if occ > 0:
            pad = empty_like(table, B)
            runs.append(Run(state=concat_states(table, pad), length=occ))
            stats.rows_spilled_run_generation += occ
            stats.runs_generated += 1
        return runs, None, stats


# ---------------------------------------------------------------------------
# replacement selection with an ordered index (§3.3)
# ---------------------------------------------------------------------------
#
# "Run generation using an in-memory index can produce runs twice the size
#  of memory without an additional comparison and without a flag in each
#  row in memory.  Eviction … repeatedly scans the in-memory index; …
#  the current key value of the eviction scan governs assignment of new
#  input rows to partitions and runs."
#
# Two tables model the partitioned b-tree: `run_table` holds keys ≥ the
# eviction frontier (they may still join the open run), `next_table` holds
# keys below it (they must wait for the next run).  Absorption therefore
# continues at rate ~M/O for the whole input — matching hybrid hashing in
# the O ∈ (M, 2M] band (paper §4.4, Example 5), where read-sort-write
# cycles give up their resident table on every flush.


def _mask_state(state: AggState, keep) -> AggState:
    return AggState(
        keys=jnp.where(keep, state.keys, empty_key(state.keys.dtype)),
        count=jnp.where(keep, state.count, 0),
        sum=jnp.where(keep[:, None], state.sum, 0.0),
        min=jnp.where(keep[:, None], state.min, jnp.float32(jnp.inf)),
        max=jnp.where(keep[:, None], state.max, jnp.float32(-jnp.inf)),
    )


def rs_split_absorb(run_table, next_table, frontier, batch, *, backend="xla"):
    """Partition one **sorted, deduped** batch at the eviction frontier and
    absorb each half into its table (traceable; shared by the host
    reference loop and the device-resident scan body)."""
    valid = batch.keys != empty_key(batch.keys.dtype)
    # the sorted batch splits at the frontier into a `lo` prefix and a
    # `hi` suffix; masking keeps `lo` sorted as-is, while `hi` must be
    # rolled left past the masked prefix to restore the sorted/EMPTY-
    # padded invariant merge_absorb requires.
    n_lo = jnp.sum(valid & (batch.keys < frontier), dtype=jnp.int32)
    hi = _mask_state(batch, valid & (batch.keys >= frontier))
    hi = jax.tree.map(lambda x: jnp.roll(x, -n_lo, axis=0), hi)
    lo = _mask_state(batch, valid & (batch.keys < frontier))
    cap_r, cap_n = run_table.capacity, next_table.capacity
    run_table = jax.tree.map(
        lambda x: x[:cap_r],
        sorted_ops.merge_absorb(run_table, hi, backend=backend, assume_unique=True),
    )
    next_table = jax.tree.map(
        lambda x: x[:cap_n],
        sorted_ops.merge_absorb(next_table, lo, backend=backend, assume_unique=True),
    )
    return run_table, next_table


@functools.partial(jax.jit, static_argnames=("backend",))
def _rs_absorb(run_table, next_table, frontier, bkeys, bpay, *, backend="xla"):
    batch = sorted_ops.absorb(
        rows_to_state(bkeys, bpay, widths=run_table.widths), backend=backend
    )
    run_table, next_table = rs_split_absorb(
        run_table, next_table, frontier, batch, backend=backend
    )
    return run_table, next_table, run_table.occupancy(), next_table.occupancy()


def rs_evict_step(run_table, quantum: int):
    """Advance the eviction scan: pop the lowest ``quantum`` rows
    (traceable; shared by the host loop and the device scan)."""
    cap = run_table.capacity
    evicted = jax.tree.map(lambda x: x[:quantum], run_table)
    src = jnp.minimum(jnp.arange(cap) + quantum, cap - 1)
    rest = jax.tree.map(lambda x: jnp.take(x, src, axis=0), run_table)
    live = jnp.arange(cap) < jnp.maximum(run_table.occupancy() - quantum, 0)
    rest = _mask_state(rest, live)
    kd = evicted.keys.dtype
    valid = evicted.keys != empty_key(kd)
    frontier = jnp.max(jnp.where(valid, evicted.keys, jnp.zeros((), kd)))
    # dtype pinned: x64 mode would promote the sum to int64 and break
    # scan/while carries built around int32 cursors
    n_evicted = jnp.sum(valid, dtype=jnp.int32)
    return evicted, rest, frontier, n_evicted


@functools.partial(jax.jit, static_argnames=("quantum", "backend"))
def _rs_evict(run_table, quantum: int, *, backend="xla"):
    del backend  # pure jnp; kept for call-site symmetry
    return rs_evict_step(run_table, quantum)


def generate_runs_rs(
    keys: np.ndarray,
    payload: np.ndarray | None,
    cfg: ExecConfig,
    *,
    backend: str = "xla",
    widths: tuple[int, int, int] | None = None,
) -> tuple[list[Run], AggState | None, SpillStats]:
    """Replacement-selection run generation with early aggregation (§3.3).

    Returns (runs, resident_table_if_no_spill, stats).  Runs approach 2M
    rows for random input; absorption continues at ~M/O throughout.
    """
    keys = _np_keys(keys)
    if payload is not None:
        payload = np.asarray(payload, dtype=np.float32)
        if payload.ndim == 1:
            payload = payload[:, None]
    width = 0 if payload is None else payload.shape[1]
    M, B = cfg.memory_rows, cfg.batch_rows
    cap = M + 2 * B
    stats = SpillStats()
    runs: list[Run] = []
    with key_dtype_context(keys):
        return _generate_runs_rs_body(
            keys, payload, cfg, backend=backend, widths=widths,
            width=width, cap=cap, stats=stats, runs=runs,
        )


def _generate_runs_rs_body(keys, payload, cfg, *, backend, widths, width, cap,
                           stats, runs):
    M, B = cfg.memory_rows, cfg.batch_rows
    run_table = empty_state(cap, width, key_dtype=keys.dtype, widths=widths)
    next_table = empty_state(cap, width, key_dtype=keys.dtype, widths=widths)
    frontier = jnp.zeros((), keys.dtype)
    open_chunks: list[AggState] = []  # evicted pieces of the open run
    open_len = 0

    def close_run():
        nonlocal open_chunks, open_len
        if open_len == 0:
            return
        state = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *open_chunks)
        runs.append(Run(state=state, length=open_len))
        stats.runs_generated += 1
        open_chunks, open_len = [], 0

    for ck, cp in _np_chunks(keys, payload, B):
        run_table, next_table, occ_r, occ_n = _rs_absorb(
            run_table, next_table, frontier, as_key_array(ck),
            None if cp is None else jnp.asarray(cp), backend=backend,
        )
        occ_r, occ_n = int(occ_r), int(occ_n)
        while occ_r + occ_n > M:
            if occ_r == 0:
                # open run exhausted: close it, promote the next partition
                close_run()
                run_table, next_table = next_table, empty_like(next_table, cap)
                frontier = jnp.zeros((), keys.dtype)
                occ_r, occ_n = occ_n, 0
                continue
            evicted, run_table, frontier, n_ev = _rs_evict(run_table, B, backend=backend)
            n_ev = int(n_ev)
            trimmed = jax.tree.map(lambda x: x[:n_ev], evicted)
            open_chunks.append(trimmed)
            open_len += n_ev
            stats.rows_spilled_run_generation += n_ev
            occ_r -= n_ev

    if not runs and open_len == 0:
        # everything absorbed in memory (run_table ∪ next_table, but with
        # no eviction ever, next_table is empty and frontier 0)
        return [], run_table, stats
    # drain: finish the open run with run_table's remainder, then the rest.
    # Both tables satisfy the OrderedIndex invariant throughout (merge,
    # trim, and evict-shift all preserve it), so no re-sort is needed.
    occ_r = int(run_table.occupancy())
    if occ_r > 0:
        open_chunks.append(jax.tree.map(lambda x: x[:occ_r], run_table))
        open_len += occ_r
        stats.rows_spilled_run_generation += occ_r
    close_run()
    occ_n = int(next_table.occupancy())
    if occ_n > 0:
        runs.append(Run(
            state=jax.tree.map(lambda x: x[: occ_n + B], next_table),
            length=occ_n,
        ))
        stats.rows_spilled_run_generation += occ_n
        stats.runs_generated += 1
    return runs, None, stats
