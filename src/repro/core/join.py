"""Join-by-grouping (paper §2.5, Fig 4): the fused join over RAW rows.

An inner join computed *inside* the sort: both inputs' rows are tagged
with their side and sorted together on the join key; equal keys form
mixed **value packets** [24].  Whenever run generation or a merge step
combines value packets, the cross product of the newly-met left×right
rows is emitted as an incremental join result — "early aggregation in
this context means early and incremental join results".  Once two rows
have met in one value packet they never meet again (they stay in the same
packet), so no duplicate outputs arise (the paper's Fig 4 invariant).

Vectorized form: the "value packet" of key k is summarized per side by
the fixed-width aggregate state (count/sum/min/max over that side's
payload).  Combining packets A=(l₁,r₁), B=(l₂,r₂) emits the cross terms
l₁×r₂ and l₂×r₁ — computable from the summaries when the join's output
is itself an aggregate (COUNT(*), SUM(expr)), which is the
aggregation-fused join this engine targets (the paper's group-join and
set operations in §2.2/§2.5).  Full row enumeration joins would enumerate
packet members instead; the packet algebra is identical.

This module joins **unaggregated inputs** with ONE mixed sort.  Its
sibling :mod:`repro.core.merge_join` is the other half of the paper's
story: once each side has been aggregated separately (each paying its
own sort), the join consumes the two established orders with NO sort at
all — that is the operator behind :meth:`repro.AggResult.merge_join`.

Join keys route through :class:`repro.core.schema.KeySpec` packing:
multi-column and >32-bit keys work (the packed dtype — uint32 or
uint64 — is whatever the spec needs), and a dtype mismatch between the
two sides raises immediately instead of silently truncating, which is
what the seed prototype did (`.astype(np.uint32)` on both sides joins
garbage the moment a key exceeds 32 bits).

``join_aggregate`` returns, per join key: |L|·|R| (the join cardinality
contribution) and Σ_L payload·|R| + |L|·Σ_R payload style sums — enough
for COUNT/SUM/AVG group-joins — plus exact spill accounting showing the
paper's claim that the mixed sort spills each input row once.
"""
from __future__ import annotations

from typing import Mapping

import numpy as np
import jax.numpy as jnp

from repro.core import insort as insort_mod
from repro.core.types import ExecConfig, empty_key, key_dtype_context


def _pack_side(side, by) -> np.ndarray:
    """One side's join keys → a packed key vector of ``by.key_dtype``."""
    if isinstance(side, Mapping):
        return by.pack(side)
    arr = np.asarray(side)
    if arr.ndim == 1 and len(by.columns) == 1:
        return by.pack([arr])
    return by.pack(side)  # significance-ordered sequence of columns


def resolve_join_keys(left_keys, right_keys, by=None):
    """Pack/validate both sides' join keys into ONE shared key dtype.

    With ``by`` (a :class:`~repro.core.schema.KeySpec`), both sides pack
    through the same column layout — multi-column and >32-bit keys work,
    and per-column bit budgets are validated.  Without it, both sides
    must already be integer vectors of the SAME dtype (the common uint32
    or uint64 key space is then inferred); differing dtypes raise — the
    caller must say which packing they mean via a KeySpec rather than
    have one side silently truncated or reinterpreted.
    """
    if by is not None:
        return _pack_side(left_keys, by), _pack_side(right_keys, by), \
            by.key_dtype
    lk = np.asarray(left_keys)
    rk = np.asarray(right_keys)
    if lk.dtype != rk.dtype:
        raise TypeError(
            f"join key dtype mismatch: left is {lk.dtype}, right is "
            f"{rk.dtype} — equal bit patterns would not mean equal keys. "
            "Pack both sides through one KeySpec (by=...) instead"
        )
    if lk.dtype.kind not in "ui":
        raise TypeError(f"join keys must be integers, got {lk.dtype}")
    if lk.dtype.kind == "i" and (
        (lk.size and int(lk.min()) < 0) or (rk.size and int(rk.min()) < 0)
    ):
        raise ValueError("join keys must be non-negative")
    hi = max(int(lk.max()) if lk.size else 0, int(rk.max()) if rk.size else 0)
    kd = np.dtype(np.uint64) if (lk.dtype.itemsize > 4 or hi >= 2**32 - 1) \
        else np.dtype(np.uint32)
    if hi >= int(empty_key(kd)):
        raise ValueError(
            f"join key {hi} collides with the {kd} EMPTY sentinel; pack "
            "through a wider KeySpec"
        )
    return lk.astype(kd), rk.astype(kd), kd


def join_aggregate(
    left_keys,
    right_keys,
    left_payload: np.ndarray | None = None,
    right_payload: np.ndarray | None = None,
    cfg: ExecConfig | None = None,
    *,
    by=None,
    output_estimate: int | None = None,
):
    """Aggregation-fused inner join via one mixed sort (§2.5, Fig 4).

    ``left_keys`` / ``right_keys``: integer key vectors of one shared
    dtype, or — with ``by=KeySpec(...)`` — named column mappings packed
    through the spec (multi-column and >32-bit join keys).  Returns
    (result dict, stats): per sorted join key, |L|, |R|, |L|·|R|, and the
    Σ payload·count cross sums.  keys are sorted (interesting ordering
    for downstream merge joins); stats shows each input row spilled ≤
    once.
    """
    cfg = cfg or ExecConfig()
    lk, rk, key_dtype = resolve_join_keys(left_keys, right_keys, by)
    lp = (np.zeros((len(lk), 0), np.float32) if left_payload is None
          else np.asarray(left_payload, np.float32).reshape(len(lk), -1))
    rp = (np.zeros((len(rk), 0), np.float32) if right_payload is None
          else np.asarray(right_payload, np.float32).reshape(len(rk), -1))
    # mixed stream: tag the side in the payload, not the key — both sides
    # share value packets keyed by the join key alone (Fig 4)
    keys = np.concatenate([lk, rk])
    width = max(lp.shape[1], rp.shape[1], 1)

    def pad(p):
        if p.shape[1] < width:
            p = np.concatenate(
                [p, np.zeros((p.shape[0], width - p.shape[1]), np.float32)], 1)
        return p

    # per-row features: [is_left, is_right, left_val…, right_val…]
    feats = np.zeros((len(keys), 2 + 2 * width), np.float32)
    feats[: len(lk), 0] = 1.0
    feats[len(lk):, 1] = 1.0
    feats[: len(lk), 2 : 2 + width] = pad(lp)
    feats[len(lk):, 2 + width :] = pad(rp)

    with key_dtype_context(key_dtype):
        state, stats = insort_mod.insort_aggregate(
            keys, feats, cfg, output_estimate=output_estimate
        )
        valid = state.valid()
    n_l = state.sum[:, 0]          # |L| per packet
    n_r = state.sum[:, 1]          # |R| per packet
    sum_l = state.sum[:, 2 : 2 + width]
    sum_r = state.sum[:, 2 + width :]
    join_count = jnp.where(valid, n_l * n_r, 0.0)
    # Σ_{(l,r) pairs} l.payload  =  Σ_L payload · |R|   (and symmetric)
    sum_lpay = sum_l * n_r[:, None]
    sum_rpay = sum_r * n_l[:, None]
    return {
        "keys": state.keys,
        "n_left": n_l,
        "n_right": n_r,
        "join_count": join_count,
        "sum_left_pay": sum_lpay,
        "sum_right_pay": sum_rpay,
    }, stats


def semi_join(left_keys, right_keys, cfg=None, **kw):
    """left keys with ≥1 right match (DISTINCT semantics), one sort."""
    res, stats = join_aggregate(left_keys, right_keys, cfg=cfg, **kw)
    k = np.asarray(res["keys"])
    mask = (np.asarray(res["n_left"]) > 0) & (np.asarray(res["n_right"]) > 0)
    return k[mask & (k != empty_key(k.dtype))], stats


def anti_semi_join(left_keys, right_keys, cfg=None, **kw):
    """left keys with NO right match — per the paper these 'cannot be
    produced early'; they fall out at the END of the same single sort."""
    res, stats = join_aggregate(left_keys, right_keys, cfg=cfg, **kw)
    k = np.asarray(res["keys"])
    mask = (np.asarray(res["n_left"]) > 0) & (np.asarray(res["n_right"]) == 0)
    return k[mask & (k != empty_key(k.dtype))], stats
