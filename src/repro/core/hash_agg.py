"""Hash-aggregation baselines the paper compares against.

Two variants, both with exact spill accounting:

* ``hash_aggregate``      — textbook hybrid hash aggregation: an in-memory
  table absorbs matches; on overflow the key space is hash-partitioned
  into F spill partitions per level, recursively, until a partition's
  output fits memory (Examples 3/4/5, Fig 23/24 "hash + hybrid hashing").
  A resident fraction of the hash domain stays in memory (hybrid hashing),
  absorbing ~M/O of the input before any spill.

* ``f1_hash_aggregate``   — F1 Query's pre-paper production scheme (§5,
  Figs 17/18): "hash-based early aggregation in a sort-based spilling
  approach" [4] — the overflowing hash table is *sorted and written as a
  run*; runs are merged with traditional non-aggregating merge steps and
  duplicates are removed only in the final merge.

Hashing uses a fixed odd multiplicative constant per key width, a
**bijection** on uint32/uint64 — so equality on hashes is equality on
keys, spelling out the paper's observation that "hashing is in fact
equivalent to sorting by hash value" [25]: the machinery below literally
reuses the ordered-index primitives on hashed keys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core import merge as merge_mod
from repro.core import run_generation as rg
from repro.core import sorted_ops
from repro.core.types import (
    AggState,
    ExecConfig,
    SpillStats,
    empty_key,
    key_dtype_context,
)

_KNUTH = np.uint32(2654435761)
_KNUTH_INV = np.uint32(pow(int(_KNUTH), -1, 1 << 32))
# 64-bit twin: the odd Fibonacci-hashing constant ⌊2^64/φ⌋ | 1.
_KNUTH64 = np.uint64(0x9E3779B97F4A7C15)
_KNUTH64_INV = np.uint64(pow(int(_KNUTH64), -1, 1 << 64))


def _consts(dtype) -> tuple[np.unsignedinteger, np.unsignedinteger, int]:
    if np.dtype(dtype) == np.uint64:
        return _KNUTH64, _KNUTH64_INV, 64
    return _KNUTH, _KNUTH_INV, 32


def hash_u32(keys):
    return (keys.astype(jnp.uint32) * _KNUTH).astype(jnp.uint32)


def unhash_u32(hkeys):
    return (hkeys.astype(jnp.uint32) * _KNUTH_INV).astype(jnp.uint32)


def unhash_keys(hkeys):
    """Invert the multiplicative hash at the stored key dtype."""
    _, inv, _ = _consts(hkeys.dtype)
    return (hkeys * inv.astype(hkeys.dtype)).astype(hkeys.dtype)


def _np_hash(keys: np.ndarray) -> np.ndarray:
    mul, _, bits = _consts(keys.dtype)
    if bits == 64:
        with np.errstate(over="ignore"):
            return (keys.astype(np.uint64) * mul).astype(np.uint64)
    return (keys.astype(np.uint64) * np.uint64(int(mul)) % (1 << 32)).astype(
        np.uint32
    )


def _np_unhash(hkeys: np.ndarray) -> np.ndarray:
    mul, inv, bits = _consts(hkeys.dtype)
    if bits == 64:
        with np.errstate(over="ignore"):
            return (hkeys.astype(np.uint64) * inv).astype(np.uint64)
    return (hkeys.astype(np.uint64) * np.uint64(int(inv)) % (1 << 32)).astype(
        np.uint32
    )


def _checked_hash(keys: np.ndarray) -> np.ndarray:
    """Hash + sentinel guard: the multiplicative hash is a bijection, so
    exactly ONE valid key maps onto the EMPTY sentinel (EMPTY · mul⁻¹);
    a row carrying it would silently vanish inside the engine.  Fail
    loudly instead — the in-sort operator (algorithm="auto") has no such
    restriction."""
    hk = _np_hash(keys)
    sentinel = empty_key(keys.dtype)
    if bool((hk == sentinel).any()):
        bad = _np_unhash(np.asarray([sentinel], dtype=keys.dtype))[0]
        raise ValueError(
            f"key {int(bad)} hashes to the reserved EMPTY sentinel and is "
            "unsupported by the hash baselines; use the sort-based operator"
        )
    return hk


def _in_memory_agg(keys_h, payload, backend, widths):
    return sorted_ops.sorted_groupby(keys_h, payload, backend=backend, widths=widths)


def hash_aggregate(
    keys: np.ndarray,
    payload: np.ndarray | None = None,
    cfg: ExecConfig | None = None,
    *,
    output_estimate: int | None = None,
    hybrid: bool = True,
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
) -> tuple[AggState, SpillStats]:
    """Hybrid hash aggregation with recursive overflow partitioning.

    Result keys are returned un-hashed but the state is ordered by hash —
    i.e. *not* usefully sorted for downstream consumers, which is exactly
    the interesting-orderings deficit the paper's operator removes.
    """
    cfg = cfg or ExecConfig()
    backend = dispatch.resolve_backend_name(backend)
    stats = SpillStats()
    keys = rg._np_keys(keys)
    sentinel = empty_key(keys.dtype)
    key_bits = 64 if keys.dtype == np.uint64 else 32
    if payload is not None:
        payload = np.asarray(payload, dtype=np.float32)
        if payload.ndim == 1:
            payload = payload[:, None]
    mask = keys != sentinel  # sentinel rows are not data
    if not mask.all():
        keys = keys[mask]
        payload = None if payload is None else payload[mask]
    hk = _checked_hash(keys)
    M, F = cfg.memory_rows, cfg.fanin

    outputs: list[AggState] = []

    def process(hkeys, pay, level: int, lo: int, hi: int):
        """Aggregate the hash sub-range [lo, hi); recurse on overflow."""
        uniq = len(np.unique(hkeys))
        if uniq <= M:
            outputs.append(
                _in_memory_agg(
                    hkeys, None if pay is None else jnp.asarray(pay), backend, widths
                )
            )
            return
        # overflow: hybrid hashing keeps a resident slice of THIS sub-range
        resident_frac = (M / uniq) if hybrid else 0.0
        cut = lo + int(resident_frac * (hi - lo))
        resident = hkeys < np.asarray(cut, dtype=hkeys.dtype) if cut < (1 << key_bits) else np.ones_like(hkeys, bool)
        if resident.any():
            outputs.append(
                _in_memory_agg(
                    hkeys[resident],
                    None if pay is None else jnp.asarray(pay[resident]),
                    backend,
                    widths,
                )
            )
        rest_k, rest_p = hkeys[~resident], None if pay is None else pay[~resident]
        # hash-partition the overflow into F spill partitions (1 write each)
        stats.rows_spilled_merge += len(rest_k)
        stats.merge_levels = max(stats.merge_levels, level + 1)
        # integer edge arithmetic: float linspace loses precision at 2^64
        edges = [cut + (hi - cut) * i // F for i in range(F + 1)]
        inner = np.asarray(edges[1:-1], dtype=hkeys.dtype)
        part = np.digitize(rest_k, inner, right=False)
        for f in range(F):
            m = part == f
            if m.any():
                stats.merge_steps += 1
                process(rest_k[m], None if rest_p is None else rest_p[m],
                        level + 1, edges[f], edges[f + 1])

    with key_dtype_context(keys):
        process(hk, payload, 0, 0, 1 << key_bits)
        # splice partition outputs together: each is sorted (by hash) over a
        # disjoint hash range, so a tree of linear merges orders the union —
        # no full sort of the spliced result.
        cat = sorted_ops.merge_absorb_many(
            outputs, backend=backend, assume_unique=True
        )
        # report user keys (un-hash), order remains hash order
        out = AggState(
            keys=jnp.where(cat.keys != sentinel, unhash_keys(cat.keys), sentinel),
            count=cat.count,
            sum=cat.sum,
            min=cat.min,
            max=cat.max,
        )
    return out, stats


def f1_hash_aggregate(
    keys: np.ndarray,
    payload: np.ndarray | None = None,
    cfg: ExecConfig | None = None,
    *,
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
) -> tuple[AggState, SpillStats]:
    """Pre-paper F1 scheme: hash-table early aggregation, sorted-run spill,
    non-aggregating merges, dedup only at the final merge (Figs 17/18)."""
    cfg = cfg or ExecConfig()
    backend = dispatch.resolve_backend_name(backend)
    keys = rg._np_keys(keys)
    sentinel = empty_key(keys.dtype)
    mask = keys != sentinel
    if not mask.all():
        keys = keys[mask]
        if payload is not None:
            payload = np.asarray(payload, dtype=np.float32)[mask]
    hk = _checked_hash(keys)
    # The overflowing hash table == our early-aggregation index on hashes:
    # identical in-memory absorption, identical run counts/sizes (§6.2).
    with key_dtype_context(keys):
        runs, table, stats = rg.generate_runs(
            hk, payload, cfg, policy="early_agg", backend=backend, widths=widths
        )
        if table is not None:
            out = table
        else:
            out = merge_mod.final_merge_traditional(
                runs, cfg, aggregate=False, stats=stats, backend=backend
            )
        out = AggState(
            keys=jnp.where(out.keys != sentinel, unhash_keys(out.keys), sentinel),
            count=out.count,
            sum=out.sum,
            min=out.min,
            max=out.max,
        )
    return out, stats
