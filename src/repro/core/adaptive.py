"""Mid-flight policy governor for the streamed aggregation pipeline.

The paper's claim that the in-sort operator "always performs at least
as well" holds for *volume*; which run-generation policy wins in
*seconds* is machine- and skew-dependent (the hash-vs-sort empirical
study in PAPERS.md).  Instead of trusting a pre-execution estimate, the
streamed pipeline observes the ground truth as it runs — rows absorbed,
duplicate rows, run-slot occupancy live in the ``lax.scan`` carry — and
this governor re-decides the policy between super-batches using the
calibrated cost model (:mod:`repro.core.cost_model`).  A wrong initial
guess then costs one observation window, not the whole query.

Mechanics: every ``interval`` chunks the host pays ONE scalar readback
(a stacked int vector — the zero-readback contract of the streamed
pipeline relaxes to O(stream / interval), counted in
``SpillStats.readbacks_paid`` and pinned by tests).  The governor
computes the duplicate rate over the window since its last decision,
asks :func:`repro.core.cost_model.choose_policy` which arm is cheapest
at that rate, and switches when the predicted advantage clears a
hysteresis band (switching flushes the resident window as one sorted
run, so flapping has a real cost — the band keeps the governor from
paying it on noise).

Every decision is recorded in ``PolicyGovernor.events`` with the path
taken (``"start" | "hold" | "hysteresis" | "small_window" | "switch"``)
so tests can assert each decision path was actually exercised.
"""
from __future__ import annotations

import dataclasses

from repro.core import cost_model
from repro.core.types import ExecConfig

#: arms the governor switches between.  ``inrun_dedup`` is deliberately
#: not an arm: it pays the per-batch sort AND the dedup without keeping
#: a persistent window, so it can't win either regime (traditional wins
#: unique-heavy input, early_agg wins duplicate-heavy input).
ARMS = ("early_agg", "rs", "traditional")


@dataclasses.dataclass(frozen=True)
class Observation:
    """One readback of the engine's device-side observation block
    (cumulative since stream start)."""

    rows_absorbed: int
    dup_rows: int
    rows_spilled: int
    table_rows: int
    run_slots_used: int

    @property
    def duplicate_rate(self) -> float:
        if self.rows_absorbed <= 0:
            return 0.0
        return self.dup_rows / self.rows_absorbed


@dataclasses.dataclass
class GovernorConfig:
    """Knobs for :class:`PolicyGovernor`.

    ``interval_chunks``: decide every k-th absorbed chunk (the k in the
    O(stream/k) readback contract).  ``hysteresis``: relative per-row
    cost advantage the challenger must show before a switch is paid.
    ``min_window_rows``: below this many rows since the last decision
    the duplicate-rate estimate is noise — hold.  ``start``: force the
    opening arm (None → ask the cost model).  ``arms``: the candidate
    set.  ``merge_levels``: spill amortization depth fed to the cost
    model (defaults to one pre-merge level)."""

    interval_chunks: int = 4
    hysteresis: float = 0.10
    min_window_rows: int = 256
    start: str | None = None
    arms: tuple = ARMS
    merge_levels: int = 1
    constants: dict | None = None
    backend: str | None = None

    def __post_init__(self):
        if self.interval_chunks < 1:
            raise ValueError(
                f"interval_chunks must be >= 1, got {self.interval_chunks}"
            )
        bad = [a for a in self.arms if a not in ARMS]
        if bad:
            raise ValueError(f"unknown governor arms {bad}; choose from {ARMS}")
        if self.start is not None and self.start not in self.arms:
            raise ValueError(
                f"start arm {self.start!r} not in arms {self.arms}"
            )


class PolicyGovernor:
    """Decides which run-generation policy the next chunks should use.

    Stateless w.r.t. the device — it only ever sees the cumulative
    :class:`Observation` the pipeline reads back — and deterministic
    given the calibrated constants, which is what makes every decision
    path unit-testable with injected constants."""

    def __init__(self, cfg: ExecConfig, config: GovernorConfig | dict | None = None):
        if config is None:
            config = GovernorConfig()
        elif isinstance(config, dict):
            config = GovernorConfig(**config)
        self.cfg = cfg
        self.config = config
        self.events: list[dict] = []
        self._constants = (
            config.constants
            if config.constants is not None
            else cost_model.load_cost_constants(config.backend)
        )
        self._prev: Observation | None = None

    @property
    def interval(self) -> int:
        return self.config.interval_chunks

    def _choose(self, dup_rate: float) -> str:
        return cost_model.choose_policy(
            dup_rate,
            arms=self.config.arms,
            constants=self._constants,
            merge_levels=self.config.merge_levels,
        )

    def _cost(self, arm: str, dup_rate: float) -> float:
        return cost_model.policy_cost_per_row(
            arm,
            dup_rate,
            constants=self._constants,
            merge_levels=self.config.merge_levels,
        )

    def start_arm(self, output_estimate: int | None = None) -> str:
        """The opening arm, before any observation exists.  With an
        output estimate the prior duplicate rate is derived the same way
        the planner does it; otherwise an agnostic 0.5 prior."""
        if self.config.start is not None:
            arm = self.config.start
            prior = None
        else:
            prior = 0.5
            if output_estimate and output_estimate > 0:
                # O unique keys across ~O·F input rows is the planner's
                # memory-pressure prior; without N we only know O, so
                # treat the estimate as "output fits the merge fan-in".
                n_guess = output_estimate * self.cfg.fanin
                prior = min(1.0, max(0.0, 1.0 - output_estimate / n_guess))
            arm = self._choose(prior)
        self.events.append(
            {"path": "start", "arm": arm, "prior_dup_rate": prior}
        )
        return arm

    def decide(self, obs: Observation, current: str) -> str:
        """The arm the NEXT chunks should run under, given the latest
        cumulative observation.  Appends one event per call."""
        prev = self._prev
        self._prev = obs
        window_rows = obs.rows_absorbed - (prev.rows_absorbed if prev else 0)
        window_dups = obs.dup_rows - (prev.dup_rows if prev else 0)
        if window_rows < self.config.min_window_rows:
            self.events.append(
                {"path": "small_window", "arm": current,
                 "window_rows": window_rows}
            )
            return current
        d = min(1.0, max(0.0, window_dups / window_rows))
        best = self._choose(d)
        if best == current:
            self.events.append(
                {"path": "hold", "arm": current, "window_dup_rate": d}
            )
            return current
        cur_cost = self._cost(current, d)
        best_cost = self._cost(best, d)
        advantage = (cur_cost - best_cost) / cur_cost if cur_cost > 0 else 0.0
        if advantage < self.config.hysteresis:
            self.events.append(
                {"path": "hysteresis", "arm": current, "challenger": best,
                 "window_dup_rate": d, "advantage": advantage}
            )
            return current
        self.events.append(
            {"path": "switch", "arm": best, "from": current,
             "window_dup_rate": d, "advantage": advantage}
        )
        return best
