"""Merging for external in-sort aggregation: traditional F-way merge and
the paper's wide merge (§4).

Traditional merging is limited to fan-in F (one input buffer per run);
aggregation during a merge step caps its output at the operation's final
output size O.  Wide merging instead keeps an ordered in-memory index over
the *active key range* and streams pages from **any** number of runs
through a single shared input buffer, guided by a priority queue over each
run's next unread page's low key.  Keys below the merge frontier (the
minimum unread key across all runs) are final and stream out of the left
edge of the index (Fig 9/10).

Shapes are static: runs live in a stacked "temporary storage" buffer, the
page loop is a ``lax.while_loop``, and emission scatters into a fixed
output buffer — the JAX rendering of paged I/O.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sorted_ops
from repro.core.run_generation import Run
from repro.core.types import (
    AggState,
    ExecConfig,
    SpillStats,
    concat_states,
    empty_key,
    empty_like,
    empty_state,
    key_dtype_context,
    slice_rows,
)


# ---------------------------------------------------------------------------
# stacked run storage ("temporary storage")
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RunStore:
    """R runs padded to a common page-aligned capacity C."""

    state: AggState  # fields have leading dims (R, C)
    lens: jax.Array  # (R,) int32

    @property
    def num_runs(self) -> int:
        return self.state.keys.shape[0]

    @property
    def capacity(self) -> int:
        return self.state.keys.shape[1]


def stack_runs(runs: list[Run], page_rows: int, width: int) -> RunStore:
    cap = max(1, max(r.length for r in runs))
    cap = int(math.ceil(cap / page_rows) * page_rows)
    padded = []
    for r in runs:
        s = r.state
        if s.capacity < cap:
            s = concat_states(s, empty_like(s, cap - s.capacity))
        else:
            s = jax.tree.map(lambda x: x[:cap], s)
        padded.append(s)
    state = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *padded)
    lens = jnp.asarray([r.length for r in runs], dtype=jnp.int32)
    return RunStore(state=state, lens=lens)


def fragments_to_store(recv: AggState, world: int, quota: int):
    """View ``world`` concatenated fixed-``quota`` sorted fragments (the
    cross-shard exchange's receive buffer, fields shaped
    ``(world * quota, ...)``) as the stacked run-store layout the wide
    merge consumes: fields reshaped to ``(R=world, C=quota)`` plus the
    per-fragment live lengths (fragments are left-packed, EMPTY-padded).
    ``quota`` must be a multiple of the merge page size the caller will
    use — :func:`_page_of`'s clamped ``dynamic_slice`` double-reads rows
    otherwise."""
    store = jax.tree.map(
        lambda x: x.reshape((world, quota) + x.shape[1:]), recv
    )
    lens = jnp.sum(
        store.keys != empty_key(store.keys.dtype), axis=1, dtype=jnp.int32
    )
    return store, lens


def _page_of(store_state: AggState, r, start, page_rows: int) -> AggState:
    """DMA one page (P rows) of run ``r`` into the shared input buffer."""

    r = jnp.asarray(r, jnp.int32)
    start = jnp.asarray(start, jnp.int32)

    def f(x):
        sizes = (1, page_rows) + x.shape[2:]
        # uniform index dtype: x64 mode would otherwise mix int64/int32
        starts = (r, start) + (jnp.int32(0),) * (x.ndim - 2)
        return jax.lax.dynamic_slice(x, starts, sizes)[0]

    return jax.tree.map(f, store_state)


# ---------------------------------------------------------------------------
# traditional F-way merge (with/without aggregation during the merge)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("aggregate", "backend"))
def _merge_group(states: tuple[AggState, ...], *, aggregate: bool, backend="xla"):
    """Merge a group of **already-sorted** runs with a balanced tree of
    linear merges — the runs carry the sorted invariant from run
    generation, so the former concat + full-argsort of the union was pure
    waste.  ``aggregate=True`` combines duplicates as it merges (the
    shared :func:`sorted_ops.merge_absorb_many` tree); ``aggregate=False``
    keeps the raw sorted multiset (a tree of interleaves) for merge plans
    that defer aggregation (Fig 2 top)."""
    states = list(states)
    if len(states) == 1 and aggregate:
        # a lone run may still carry intra-run duplicates (traditional
        # policy): combining a sorted state needs no merge at all
        out = sorted_ops.segmented_combine(states[0], backend=backend)
        return out, out.occupancy()
    if aggregate:
        out = sorted_ops.merge_absorb_many(states, backend=backend)
    else:
        out = sorted_ops.interleave_many(states, backend=backend)
    return out, out.occupancy()


def _pad_group(states: tuple[AggState, ...]) -> tuple[AggState, ...]:
    """Pad group members to their common max capacity before the jitted
    merge tree: heterogeneous run lengths (replacement selection) would
    otherwise key a fresh compilation on every distinct capacity tuple."""
    cap = max(s.capacity for s in states)
    return tuple(
        s if s.capacity == cap else concat_states(s, empty_like(s, cap - s.capacity))
        for s in states
    )


def traditional_merge(
    runs: list[Run],
    cfg: ExecConfig,
    *,
    aggregate_during_merge: bool,
    stats: SpillStats,
    backend: str = "xla",
    stop_at: int = 1,
) -> list[Run]:
    """Merge runs F at a time until ``stop_at`` or fewer remain.

    Every merge step's output is written back to temporary storage and
    counted as spill — except the final step when ``stop_at == 1`` (its
    output streams to the consumer, Fig 2).
    """
    F = cfg.fanin
    width = runs[0].state.width if runs else 0
    while len(runs) > stop_at:
        nxt: list[Run] = []
        level_groups = [runs[i : i + F] for i in range(0, len(runs), F)]
        for group in level_groups:
            if len(group) == 1:  # singleton: carried over, no re-write I/O
                nxt.append(group[0])
                continue
            merged, occ = _merge_group(
                _pad_group(tuple(g.state for g in group)),
                aggregate=aggregate_during_merge, backend=backend,
            )
            length = int(occ)
            nxt.append(Run(state=merged, length=length))
            stats.merge_steps += 1
            is_final = len(level_groups) == 1 and len(nxt) <= stop_at
            if not is_final:
                stats.rows_spilled_merge += length
        stats.merge_levels += 1
        runs = nxt
    return runs


def final_merge_traditional(
    runs: list[Run], cfg: ExecConfig, *, aggregate: bool, stats: SpillStats,
    backend: str = "xla",
) -> AggState:
    """Reduce to ≤F runs with traditional merging, then stream the final
    merge (never spilled) — optionally aggregating in-stream (Fig 2 top)."""
    runs = traditional_merge(
        runs, cfg, aggregate_during_merge=aggregate, stats=stats, backend=backend,
        stop_at=cfg.fanin,
    )
    # output phase: one last merge tree, aggregating in-stream
    out, _ = _merge_group(
        _pad_group(tuple(r.state for r in runs)), aggregate=True, backend=backend
    )
    stats.merge_steps += 1
    stats.merge_levels += 1
    return out


def trim_to_capacity(state: AggState, capacity: int):
    """Trim a compacted (sorted, EMPTY-padded) state to ``capacity`` rows,
    returning ``(trimmed, dropped)`` where ``dropped`` flags that the cut
    removed LIVE rows — data loss, never acceptable silently.  Traceable;
    the flag is a device scalar so callers inside ``jit``/``shard_map``
    reduce and surface it exactly like the wide merge's
    ``merge_dropped_rows`` (raise at the one host readback)."""
    dropped = state.occupancy() > capacity
    return jax.tree.map(lambda x: x[:capacity], state), dropped


# ---------------------------------------------------------------------------
# wide merge (§4)
# ---------------------------------------------------------------------------


def wide_merge_device(
    store_state: AggState,
    lens: jax.Array,
    *,
    page_rows: int,
    index_rows: int,
    out_capacity: int | None = None,
    backend: str = "xla",
    out: AggState | None = None,
):
    """Traceable core of the wide merge (§4): page loop as a
    ``lax.while_loop`` over a stacked run store.  Jit-wrapped by
    :func:`wide_merge` for standalone use and inlined into the fused
    device-resident pipeline (:mod:`repro.core.pipeline`) so run
    generation + merge compile to ONE program.  Returns device scalars
    ``(out, rows_emitted, pages_read, max_index_occupancy, overflow,
    dropped)`` — no host syncs; ``dropped`` is the hard failure signal
    (live rows trimmed), ``overflow`` the soft model-exceeded flag.

    ``out`` lets the caller provide the output buffer (an all-invalid
    :class:`AggState` matching the store's key dtype and plane widths) —
    the merge-on-read snapshot path emits into a *fresh* caller buffer
    so the program never aliases live engine state.  When absent, a
    fresh buffer of ``out_capacity`` rows is allocated here."""
    R, C = store_state.keys.shape
    P = page_rows
    W = index_rows + P  # index tile + headroom for one incoming page
    kd = store_state.keys.dtype
    width = store_state.sum.shape[-1]
    widths = (
        store_state.sum.shape[-1],
        store_state.min.shape[-1],
        store_state.max.shape[-1],
    )
    n_pages = (lens + P - 1) // P
    arange_R = jnp.arange(R)

    def next_low_keys(cursors):
        # priority queue over each run's next unread page's low key
        pos = jnp.clip(cursors * P, 0, C - 1)
        k = store_state.keys[arange_R, pos]
        return jnp.where(cursors < n_pages, k, empty_key(kd))

    if out is None:
        if out_capacity is None:
            raise ValueError("wide_merge_device needs out= or out_capacity=")
        out0 = empty_state(out_capacity, width, key_dtype=kd, widths=widths)
    else:
        if out.key_dtype != np.dtype(kd) or out.widths != widths:
            raise ValueError(
                f"caller-provided out buffer (dtype {out.key_dtype}, widths "
                f"{out.widths}) does not match the run store (dtype "
                f"{np.dtype(kd)}, widths {widths})"
            )
        out0 = out
        out_capacity = out.capacity

    def cond(carry):
        cursors, *_ = carry
        return jnp.any(cursors < n_pages)

    def body(carry):
        cursors, index, out, out_cur, pages_read, max_occ, overflow = carry
        low = next_low_keys(cursors)
        rstar = jnp.argmin(low)  # EMPTY == uint32 max ⇒ exhausted runs lose
        start = cursors[rstar] * P
        page = _page_of(store_state, rstar, start, P)
        # absorb the page into the ordered index (batched insert, §3.4):
        # both sides are sorted, so this is a linear merge — O(W+P) per
        # page instead of the former O((W+P)·log(W+P)) re-sort.  Pages
        # may carry intra-run duplicates (replacement-selection runs), so
        # the general combine path is used, not the pair-combine.
        merged = sorted_ops.merge_absorb(index, page, backend=backend)  # cap W + P
        cursors = cursors.at[rstar].add(1)
        # merge frontier: the least key any run can still deliver
        frontier = jnp.min(next_low_keys(cursors))
        keys = merged.keys
        # int32 throughout: x64 mode would silently promote sums to int64
        # and break the while_loop carry signature
        occ = merged.occupancy().astype(jnp.int32)
        final_mask = keys < frontier  # EMPTY never < frontier unless frontier==EMPTY
        e = jnp.sum(final_mask.astype(jnp.int32)).astype(jnp.int32)
        # emit the final prefix out of the left edge of the index
        idx = jnp.where(jnp.arange(W + P) < e, out_cur + jnp.arange(W + P), out_capacity)

        def scatter(dst, src):
            return dst.at[idx].set(src, mode="drop")

        out = jax.tree.map(scatter, out, merged)
        out_cur = out_cur + e
        # shift the index left by e (drop emitted rows), trim back to W
        src = jnp.minimum(jnp.arange(W) + e, W + P - 1)
        shifted = jax.tree.map(lambda x: jnp.take(x, src, axis=0), merged)
        live = jnp.arange(W) < (occ - e)
        new_keys = jnp.where(live, shifted.keys, empty_key(kd))
        index = AggState(new_keys, shifted.count, shifted.sum, shifted.min, shifted.max)
        resident = occ - e
        max_occ = jnp.maximum(max_occ, resident)
        overflow = overflow | (resident > index_rows)
        return (cursors, index, out, out_cur, pages_read + 1, max_occ, overflow)

    carry = (
        jnp.zeros((R,), jnp.int32),
        empty_state(W, width, key_dtype=kd, widths=widths),
        out0,
        jnp.int32(0),
        jnp.int32(0),
        jnp.int32(0),
        jnp.bool_(False),
    )
    cursors, index, out, out_cur, pages_read, max_occ, overflow = jax.lax.while_loop(
        cond, body, carry
    )
    # resident > W means the left-shift trim cut live rows, and out_cur
    # past out_capacity means emitted rows fell off the scatter's "drop"
    # edge: either way that is data loss, not just "more memory than the
    # model allows" (the soft `overflow` flag at resident > index_rows).
    # Callers must fail loudly.
    dropped = (max_occ > W) | (out_cur > out_capacity)
    return out, out_cur, pages_read, max_occ, overflow, dropped


_wide_merge_jit = functools.partial(
    jax.jit, static_argnames=("page_rows", "index_rows", "out_capacity", "backend")
)(wide_merge_device)


def wide_merge(
    runs: list[Run],
    cfg: ExecConfig,
    *,
    stats: SpillStats,
    out_capacity: int | None = None,
    index_rows: int | None = None,
    backend: str = "xla",
) -> AggState:
    """Final merge step with unbounded fan-in (§4). Never spills.

    ``index_rows`` defaults to the memory allocation M; the paper shows the
    wide merge often needs well under M (Example 4: ~40%).
    """
    width = runs[0].state.width
    with key_dtype_context(runs[0].state):
        store = stack_runs(runs, cfg.page_rows, width)
        if out_capacity is None:
            out_capacity = int(sum(r.length for r in runs))
        out, out_cur, pages_read, max_occ, overflow, dropped = _wide_merge_jit(
            store.state,
            store.lens,
            page_rows=cfg.page_rows,
            index_rows=index_rows or cfg.memory_rows,
            out_capacity=out_capacity,
            backend=backend,
        )
    if bool(dropped):
        # name the actual condition: the two drop sites have different fixes
        w_cap = (index_rows or cfg.memory_rows) + cfg.page_rows
        if int(max_occ) > w_cap:
            cause = (f"the merge index overflowed (resident {int(max_occ)} "
                     f"> index_rows + page_rows = {w_cap})")
        else:
            cause = (f"the output overran its capacity (emitted "
                     f"{int(out_cur)} > {out_capacity})")
        raise RuntimeError(
            f"wide merge during finalize dropped rows: {cause}; merge "
            "fewer runs at once (pre-merge levels) or raise index_rows / "
            "the output estimate"
        )
    stats.merge_steps += 1
    stats.merge_levels += 1
    stats.pages_read += int(pages_read)
    stats.max_index_occupancy = max(stats.max_index_occupancy, int(max_occ))
    stats.index_overflowed = bool(overflow) or stats.index_overflowed
    emitted = int(out_cur)
    stats.rows_emitted += emitted
    # Accounting invariants: the merge emits every distinct key exactly
    # once, and never more rows than the runs held.
    total_in = int(sum(r.length for r in runs))
    assert emitted <= total_in, (emitted, total_in)
    if out_capacity >= total_in:  # nothing could have been dropped
        assert emitted == int(out.occupancy()), (emitted, int(out.occupancy()))
    return out
