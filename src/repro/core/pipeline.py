"""Device-resident external aggregation pipeline: scan-based run
generation fused with the wide merge into ONE compiled program.

The host drivers in :mod:`repro.core.run_generation` mirror the paper's
I/O loop: dispatch one jitted step per batch, then **block on an
occupancy readback** to decide whether to flush a run.  That round trip
— not comparisons — dominates once the per-record work is vectorized
(cf. the external-sort implementation studies in PAPERS.md), so the
external pipeline runs at host-latency instead of hardware speed.

This module removes the host from the loop.  All three read-sort-write
policies (``traditional``, ``inrun_dedup``, ``early_agg``) and
replacement selection (``rs``) run as a single jitted ``lax.scan`` over
the pre-batched input:

* runs are written into a preallocated, stacked RunStore-shaped device
  buffer via a data-dependent run-slot index carried through the scan
  (out-of-range slots drop, so "don't flush" is a no-op scatter);
* occupancy, spill counters, and the replacement-selection frontier are
  device carries; eviction is a bounded inner ``while_loop`` in the scan
  body (the same :func:`~repro.core.run_generation.rs_split_absorb` /
  :func:`~repro.core.run_generation.rs_evict_step` state machine as the
  host reference);
* the §4.3 pre-wide traditional merge levels (needed when O/M exceeds
  the fan-in, or the wide merge's index outgrows memory) are planned
  statically from the output estimate and run on device as pairwise
  linear merges over run slots (:func:`_device_premerge`);
* the wide merge (§4) consumes the run buffer directly
  (:func:`repro.core.merge.wide_merge_device`), so
  ``repro.aggregate(..., algorithm="insort")`` compiles end-to-end;
* spill accounting is a :class:`~repro.core.types.DeviceSpillStats`
  pytree — the only host synchronization in the whole pipeline is the
  final ``finalize()`` readback of stats + run lengths.

Sizing is static, derived from shapes alone: a run buffer of
``ceil(N/M)+O(1)`` slots (every flushed run carries > M unique rows, so
the slot count is bounded by input over memory), each slot page-aligned.
The batch count is bucketed to the next power of two (EMPTY batches are
no-ops) so recompiles scale with log(N), not N.

The host loops remain the reference path for oracle-parity testing and
for the paper's exact per-level accounting (Fig 14); the device
pre-merge accounting deviates from the host's only for non-power-of-two
fan-ins and over-estimated run counts (it plans levels from the static
slot bound rather than the dynamic run count).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core import merge as merge_mod
from repro.core import run_generation as rg
from repro.core import sorted_ops
from repro.core.types import (
    AggState,
    DeviceSpillStats,
    ExecConfig,
    SpillStats,
    as_key_array,
    concat_states,
    empty_key,
    empty_like,
    empty_state,
    key_dtype_context,
    rows_to_state,
)

POLICIES = ("traditional", "inrun_dedup", "early_agg", "rs")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _num_batches(n: int, chunk: int) -> int:
    """Batch count bucketed to the next power of two (EMPTY-padded batches
    are no-ops) so distinct input sizes share compilations."""
    t = (n + chunk - 1) // chunk
    return 1 << (t - 1).bit_length() if t > 1 else t


def _pad_flat(keys, payload, total: int):
    """(traced) EMPTY/zero-pad flat (keys, payload) to ``total`` rows —
    EMPTY rows are no-ops in every policy; device-side, no host
    transfer."""
    padn = total - keys.shape[0]
    kd = keys.dtype
    keys = jnp.concatenate([keys, jnp.full((padn,), empty_key(kd), kd)])
    if payload is not None:
        pad = jnp.zeros((padn,) + payload.shape[1:], payload.dtype)
        payload = jnp.concatenate([payload, pad])
    return keys, payload


def _batch(keys, payload, chunk: int, t: int):
    """(traced) pad the flat input to ``t * chunk`` rows and reshape into
    scan batches."""
    keys, payload = _pad_flat(keys, payload, t * chunk)
    bk = keys.reshape(t, chunk)
    bp = None
    if payload is not None:
        bp = payload.reshape(t, chunk, payload.shape[1])
    return bk, bp


def _stacked_empty(slots: int, rows: int, width: int, *, key_dtype, widths):
    proto = empty_state(rows, width, key_dtype=key_dtype, widths=widths)
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (slots,) + x.shape), proto)


def _pad_rows(state: AggState, rows: int) -> AggState:
    if state.capacity >= rows:
        return state
    return concat_states(state, empty_like(state, rows - state.capacity))


# ---------------------------------------------------------------------------
# run generation as a lax.scan, per policy
# ---------------------------------------------------------------------------


def _rungen_sortwrite(bk, bp, *, dedup: bool, C: int, backend: str, widths):
    """``traditional`` / ``inrun_dedup``: one run per M-row chunk.  The
    run-slot index is the scan step itself, so runs stream out as stacked
    scan outputs — no carried buffer needed."""

    def body(carry, xs):
        ck, cp = xs
        st = rows_to_state(ck, cp, widths=widths)
        if dedup:
            st = sorted_ops.absorb(st, backend=backend)
        else:
            st = sorted_ops.sort_state(st, backend=backend)
        occ = st.occupancy()
        return carry, (_pad_rows(st, C), occ)

    _, (store, lens) = jax.lax.scan(body, jnp.int32(0), (bk, bp))
    spilled = jnp.sum(lens, dtype=jnp.int32)
    nruns = jnp.sum(lens > 0, dtype=jnp.int32)
    kd = bk.dtype
    width = 0 if bp is None else bp.shape[-1]
    table = empty_state(0, width, key_dtype=kd, widths=widths)
    return store, lens, table, spilled, nruns, jnp.bool_(False)


def _rungen_early_agg(bk, bp, *, M: int, R: int, C: int, backend: str, widths):
    """``early_agg`` (§3): the ordered in-memory index absorbs each sorted
    batch; when occupancy exceeds M the whole index content is written to
    the run slot carried in the scan and memory restarts empty."""
    t, B = bk.shape
    kd = bk.dtype
    width = 0 if bp is None else bp.shape[-1]
    ws = widths if widths is not None else (width, width, width)
    table0 = empty_state(M, width, key_dtype=kd, widths=ws)
    buf0 = _stacked_empty(R, C, width, key_dtype=kd, widths=ws)
    lens0 = jnp.zeros((R,), jnp.int32)

    def body(carry, xs):
        table, buf, lens, ridx, spilled = carry
        ck, cp = xs
        batch = sorted_ops.absorb(rows_to_state(ck, cp, widths=ws), backend=backend)
        merged = sorted_ops.merge_absorb(
            table, batch, backend=backend, assume_unique=True
        )  # capacity M + B
        occ = merged.occupancy()
        flush = occ > M
        # memory full: the entire index content becomes one sorted run in
        # the carried slot; otherwise the (out-of-range) write drops.
        slot = jnp.where(flush, ridx, R)
        buf = jax.tree.map(
            lambda d, s: d.at[slot].set(s, mode="drop"), buf, _pad_rows(merged, C)
        )
        lens = lens.at[slot].set(occ, mode="drop")
        ridx = ridx + flush.astype(jnp.int32)
        spilled = spilled + jnp.where(flush, occ, 0)
        kept = jax.tree.map(lambda x: x[:M], merged)  # trim back to M
        table = jax.tree.map(lambda e, k: jnp.where(flush, e, k), table0, kept)
        return (table, buf, lens, ridx, spilled), None

    init = (table0, buf0, lens0, jnp.int32(0), jnp.int32(0))
    (table, buf, lens, ridx, spilled), _ = jax.lax.scan(body, init, (bk, bp))
    # mirror the resident table into the next slot so a downstream wide
    # merge always consumes the complete picture; it counts as a spilled
    # run only when earlier slots spilled (host-reference semantics).
    occ_t = table.occupancy()
    buf = jax.tree.map(
        lambda d, s: d.at[ridx].set(s, mode="drop"), buf, _pad_rows(table, C)
    )
    lens = lens.at[ridx].set(occ_t, mode="drop")
    spilled = spilled + jnp.where(ridx > 0, occ_t, 0)
    nruns = ridx + ((ridx > 0) & (occ_t > 0)).astype(jnp.int32)
    overflow = ridx + 1 > R
    return buf, lens, table, jnp.where(ridx > 0, spilled, 0), nruns, overflow


def _rungen_rs(bk, bp, *, M: int, B: int, R: int, C: int, backend: str, widths):
    """Replacement selection (§3.3) folded into the scan: the two-table
    partitioned b-tree is the carry, and the eviction scan is a bounded
    inner ``while_loop`` writing B-row quanta at the carried
    (run-slot, cursor) position.  A run closes when the open partition
    drains (host semantics) or when its slot is within one quantum of
    capacity (the device buffer's close-early rule — always legal, runs
    only need to be sorted)."""
    t, _B = bk.shape
    kd = bk.dtype
    width = 0 if bp is None else bp.shape[-1]
    ws = widths if widths is not None else (width, width, width)
    cap = M + 2 * B
    table0 = empty_state(cap, width, key_dtype=kd, widths=ws)
    buf0 = _stacked_empty(R, C, width, key_dtype=kd, widths=ws)
    lens0 = jnp.zeros((R,), jnp.int32)
    arB = jnp.arange(B, dtype=jnp.int32)
    arC = jnp.arange(cap, dtype=jnp.int32)

    def close_fn(c):
        # the open run is exhausted (or its slot is full): record its
        # length, then merge both partitions into a fresh open partition —
        # with occ_r == 0 this is exactly the host's promote-next-table.
        rt, nt, frontier, buf, lens, cursor, ridx, spilled = c
        lens = lens.at[jnp.where(cursor > 0, ridx, R)].set(cursor, mode="drop")
        ridx = ridx + (cursor > 0).astype(jnp.int32)
        rt = jax.tree.map(
            lambda x: x[:cap],
            sorted_ops.merge_absorb(rt, nt, backend=backend, assume_unique=True),
        )
        return (rt, table0, jnp.zeros((), kd), buf, lens, jnp.int32(0), ridx, spilled)

    def evict_fn(c):
        rt, nt, frontier, buf, lens, cursor, ridx, spilled = c
        evicted, rest, frontier, n_ev = rg.rs_evict_step(rt, B)
        rows = cursor + arB
        buf = jax.tree.map(
            lambda d, s: d.at[ridx, rows].set(s, mode="drop"), buf, evicted
        )
        return (rest, nt, frontier, buf, lens, cursor + n_ev, ridx, spilled + n_ev)

    def overflow_step(c):
        rt = c[0]
        cursor = c[5]
        return jax.lax.cond(
            (rt.occupancy() == 0) | (cursor + B > C), close_fn, evict_fn, c
        )

    def overflow_cond(c):
        rt, nt = c[0], c[1]
        return rt.occupancy() + nt.occupancy() > M

    def body(carry, xs):
        rt, nt, frontier, buf, lens, cursor, ridx, spilled = carry
        ck, cp = xs
        batch = sorted_ops.absorb(rows_to_state(ck, cp, widths=ws), backend=backend)
        rt, nt = rg.rs_split_absorb(rt, nt, frontier, batch, backend=backend)
        carry = jax.lax.while_loop(
            overflow_cond, overflow_step,
            (rt, nt, frontier, buf, lens, cursor, ridx, spilled),
        )
        return carry, None

    init = (
        table0, table0, jnp.zeros((), kd), buf0, lens0,
        jnp.int32(0), jnp.int32(0), jnp.int32(0),
    )
    (rt, nt, frontier, buf, lens, cursor, ridx, spilled), _ = jax.lax.scan(
        body, init, (bk, bp)
    )

    # drain: finish the open run with the open partition's remainder (its
    # own slot when there is room, the next slot otherwise), then write
    # the next-run partition as the last run.
    occ_r = rt.occupancy()
    occ_n = nt.occupancy()
    evicted_any = (ridx > 0) | (cursor > 0)

    def drain_append(args):
        buf, lens, ridx = args
        buf = jax.tree.map(
            lambda d, s: d.at[ridx, cursor + arC].set(s, mode="drop"), buf, rt
        )
        ln = cursor + occ_r
        lens = lens.at[jnp.where(ln > 0, ridx, R)].set(ln, mode="drop")
        return buf, lens, ridx + (ln > 0).astype(jnp.int32)

    def drain_split(args):
        buf, lens, ridx = args
        lens = lens.at[ridx].set(cursor, mode="drop")  # cursor > 0 here
        ridx = ridx + 1
        buf = jax.tree.map(
            lambda d, s: d.at[ridx, arC].set(s, mode="drop"), buf, rt
        )
        lens = lens.at[jnp.where(occ_r > 0, ridx, R)].set(occ_r, mode="drop")
        return buf, lens, ridx + (occ_r > 0).astype(jnp.int32)

    buf, lens, ridx = jax.lax.cond(
        cursor + occ_r <= C, drain_append, drain_split, (buf, lens, ridx)
    )
    buf = jax.tree.map(lambda d, s: d.at[ridx, arC].set(s, mode="drop"), buf, nt)
    lens = lens.at[jnp.where(occ_n > 0, ridx, R)].set(occ_n, mode="drop")
    ridx = ridx + (occ_n > 0).astype(jnp.int32)
    spilled = spilled + occ_r + occ_n
    nruns = jnp.where(evicted_any, ridx, 0)
    overflow = ridx > R
    return buf, lens, rt, jnp.where(evicted_any, spilled, 0), nruns, overflow


# ---------------------------------------------------------------------------
# the fused program
# ---------------------------------------------------------------------------


def _slots_for(n_pad: int, M: int, extra: int) -> int:
    # every closed run carries > M unique rows (early-agg flushes at
    # occupancy > M; every RS run drains a partition that held > M rows),
    # so input-over-memory bounds the slot count.
    return n_pad // (M + 1) + extra


def _static_run_slots(policy: str, n: int, M: int, B: int) -> int:
    """Run-slot bound from shapes alone (host-side twin of the sizing in
    :func:`_pipeline_jit`, used to plan pre-merge levels statically)."""
    chunk = M if policy in ("traditional", "inrun_dedup") else B
    t = _num_batches(n, chunk)
    if policy in ("traditional", "inrun_dedup"):
        return t
    return _slots_for(t * chunk, M, 2 if policy == "early_agg" else 4)


def _pad_slots(store: AggState, lens, R_new: int):
    R, C = store.keys.shape
    widths = (store.sum.shape[-1], store.min.shape[-1], store.max.shape[-1])
    extra = _stacked_empty(
        R_new - R, C, max(widths), key_dtype=store.keys.dtype, widths=widths
    )
    store = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), store, extra)
    lens = jnp.concatenate([lens, jnp.zeros((R_new - R,), jnp.int32)])
    return store, lens


def _device_premerge(store: AggState, lens, *, fanin: int, levels: int, backend: str):
    """§4.3 pre-wide traditional merge levels, on device.

    Each level merges groups of ``2^ceil(log2 F)`` run slots as a
    balanced tree of pairwise linear merge-absorbs (``lax.map`` over slot
    pairs; each pass halves the slot count and doubles slot capacity, so
    the buffer footprint is constant).  Empty slots merge as no-ops, so
    the statically planned level count is safe whatever the dynamic run
    count.  Spill accounting matches the host planner: a group's merged
    output counts as merge spill only if the group actually combined ≥ 2
    live runs (singletons are carried, not rewritten).  For non-power-of-
    two fan-ins the effective group width rounds up to the next power of
    two (slightly fewer, wider groups than the host reference).
    """
    spilled = jnp.int32(0)
    steps = jnp.int32(0)
    nlev = jnp.int32(0)
    sub = max(1, (fanin - 1).bit_length())  # pairwise passes per level
    G = 1 << sub
    for _ in range(levels):
        R = store.keys.shape[0]
        if R <= 1:
            break
        Rpad = _round_up(R, G)
        if Rpad > R:
            store, lens = _pad_slots(store, lens, Rpad)
        sizes = jnp.sum(lens.reshape(-1, G) > 0, axis=1, dtype=jnp.int32)
        for _ in range(sub):

            def step(pair):
                sa, sb = pair
                m = sorted_ops.merge_absorb(sa, sb, backend=backend)
                return m, m.occupancy()

            a = jax.tree.map(lambda x: x[0::2], store)
            b = jax.tree.map(lambda x: x[1::2], store)
            store, lens = jax.lax.map(step, (a, b))
        active = sizes >= 2
        spilled = spilled + jnp.sum(jnp.where(active, lens, 0), dtype=jnp.int32)
        steps = steps + jnp.sum(active, dtype=jnp.int32)
        nlev = nlev + jnp.any(active).astype(jnp.int32)
    return store, lens, spilled, steps, nlev


def _pipeline_body(
    keys,
    payload,
    *,
    policy: str,
    memory_rows: int,
    batch_rows: int,
    page_rows: int,
    index_rows: int,
    fanin: int,
    premerge_levels: int,
    backend: str,
    widths,
    merge: bool,
):
    """Traceable single-device pipeline: run generation scan → §4.3
    pre-merge levels → wide merge.  Jitted directly as
    :func:`_pipeline_jit`; the mesh-sharded program traces it once per
    shard inside ``shard_map`` (:func:`_sharded_fn`)."""
    M, B, P = memory_rows, batch_rows, page_rows
    chunk = M if policy in ("traditional", "inrun_dedup") else B
    t = _num_batches(keys.shape[0], chunk)
    n_pad = t * chunk
    bk, bp = _batch(keys, payload, chunk, t)
    if policy in ("traditional", "inrun_dedup"):
        store, lens, table, spilled, nruns, overflow = _rungen_sortwrite(
            bk, bp, dedup=(policy == "inrun_dedup"), C=_round_up(M, P),
            backend=backend, widths=widths,
        )
    elif policy == "early_agg":
        store, lens, table, spilled, nruns, overflow = _rungen_early_agg(
            bk, bp, M=M, R=_slots_for(n_pad, M, 2), C=_round_up(M + B, P),
            backend=backend, widths=widths,
        )
    elif policy == "rs":
        store, lens, table, spilled, nruns, overflow = _rungen_rs(
            bk, bp, M=M, B=B, R=_slots_for(n_pad, M, 4),
            C=_round_up(2 * M + 2 * B, P), backend=backend, widths=widths,
        )
    else:
        raise ValueError(f"unknown run-generation policy {policy!r}")

    zero = jnp.int32(0)
    rg_stats = DeviceSpillStats(
        rows_spilled_run_generation=spilled,
        rows_spilled_merge=zero,
        runs_generated=nruns,
        merge_steps=zero,
        merge_levels=zero,
        pages_read=zero,
        rows_emitted=zero,
        index_overflowed=jnp.bool_(False),
        max_index_occupancy=zero,
        run_buffer_overflowed=overflow,
        merge_dropped_rows=jnp.bool_(False),
        rows_exchanged=zero,
    )
    if not merge:
        return store, lens, table, rg_stats

    # §4.3: statically planned pre-wide traditional merge levels keep the
    # number of runs entering the wide merge small enough for its index to
    # fit the memory allocation (deep-merge regime, O/M > F).
    store, lens, spill_m, msteps, mlevels = _device_premerge(
        store, lens, fanin=fanin, levels=premerge_levels, backend=backend
    )
    out, out_cur, pages_read, max_occ, ix_overflow, dropped = (
        merge_mod.wide_merge_device(
            store, lens, page_rows=P, index_rows=index_rows,
            out_capacity=max(n_pad, 1), backend=backend,
        )
    )
    # merge/emission stats are charged only when run generation actually
    # spilled — the in-memory case's pass through the merge is a formality
    # the host reference never pays (it returns the table directly).
    spilled_any = nruns > 0
    one = jnp.where(spilled_any, 1, 0).astype(jnp.int32)
    stats = DeviceSpillStats(
        rows_spilled_run_generation=spilled,
        rows_spilled_merge=spill_m,  # pre-levels only; the wide merge never spills
        runs_generated=nruns,
        merge_steps=msteps + one,
        merge_levels=mlevels + one,
        pages_read=jnp.where(spilled_any, pages_read, 0).astype(jnp.int32),
        rows_emitted=jnp.where(spilled_any, out_cur, 0).astype(jnp.int32),
        index_overflowed=spilled_any & ix_overflow,
        max_index_occupancy=jnp.where(spilled_any, max_occ, 0).astype(jnp.int32),
        run_buffer_overflowed=overflow,
        merge_dropped_rows=dropped,
        rows_exchanged=zero,
    )
    return out, stats


_pipeline_jit = functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "memory_rows", "batch_rows", "page_rows", "index_rows",
        "fanin", "premerge_levels", "backend", "widths", "merge",
    ),
)(_pipeline_body)


# ---------------------------------------------------------------------------
# mesh-sharded pipeline: per-shard run generation + key-range exchange
# ---------------------------------------------------------------------------


def resolve_mesh_axis(mesh, mesh_axis: str | None) -> str:
    """The mesh axis the pipeline shards over (default: the first)."""
    if mesh_axis is None:
        return mesh.axis_names[0]
    if mesh_axis not in mesh.axis_names:
        raise ValueError(
            f"mesh has no axis {mesh_axis!r}; axes: {mesh.axis_names}"
        )
    return mesh_axis


@functools.lru_cache(maxsize=None)
def _sharded_fn(
    mesh,
    axis: str,
    *,
    policy: str,
    memory_rows: int,
    batch_rows: int,
    page_rows: int,
    index_rows: int,
    fanin: int,
    premerge_levels: int,
    backend: str,
    widths,
):
    """ONE compiled program for the whole mesh (§2.1: partitioning and
    sorting are the same physical property):

    1. each shard runs the full single-device pipeline
       (:func:`_pipeline_body`: run-generation scan into its own run
       buffer, statically planned §4.3 pre-merge levels, local wide
       merge) over its slice of the input — local early aggregation
       before any wire traffic;
    2. the shards exchange their sorted, duplicate-free outputs by
       sampled key range (:func:`~repro.distributed.groupby.
       exchange_sorted_fragments` — the same searchsorted cuts +
       ``all_to_all`` as the distributed group-by), so only unique rows
       travel;
    3. each range owner tree-merges the ``world`` sorted fragments it
       received — output globally sorted by (owner, key), EMPTY-padded
       per shard.

    The per-peer quota equals each shard's full output capacity, so the
    exchange can never cut live rows; ``send_dropped`` is still folded
    into ``merge_dropped_rows`` defensively.  Stats are reduced across
    shards on device (:meth:`DeviceSpillStats.cross_shard`), so
    ``finalize()`` remains the program's single host readback and the
    loud-failure invariants hold per shard and globally.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed import groupby as gb_mod
    from repro.distributed._compat import shard_map

    world = mesh.shape[axis]

    def body(keys, payload):
        out, dstats = _pipeline_body(
            keys, payload, policy=policy, memory_rows=memory_rows,
            batch_rows=batch_rows, page_rows=page_rows,
            index_rows=index_rows, fanin=fanin,
            premerge_levels=premerge_levels, backend=backend,
            widths=widths, merge=True,
        )
        quota = out.capacity  # a peer can at most send its whole output
        recv, sent, send_dropped = gb_mod.exchange_sorted_fragments(
            out, axis, world, quota=quota
        )
        merged = gb_mod.merge_received_fragments(
            recv, world, quota, backend=backend
        )
        dstats = dataclasses.replace(
            dstats,
            merge_dropped_rows=dstats.merge_dropped_rows | send_dropped,
            rows_exchanged=sent,
        )
        return merged, dstats.cross_shard(axis)

    state_specs = AggState(
        keys=P(axis), count=P(axis), sum=P(axis, None),
        min=P(axis, None), max=P(axis, None),
    )
    n_stats = len(dataclasses.fields(DeviceSpillStats))
    # check=False: 0.4.x shard_map has no replication rule for while_loop
    # (the wide merge's page loop); the stats out_specs are P() and truly
    # replicated anyway (psum/pmax above).
    inner = shard_map(
        body, mesh=mesh, in_specs=(P(axis), P(axis, None)),
        out_specs=(state_specs, DeviceSpillStats(*(P(),) * n_stats)),
        check=False,
    )

    def run(keys, payload):
        # pad so every shard sees the same static n_loc, then hand each
        # shard its contiguous slice
        n_loc = -(-keys.shape[0] // world)
        keys, payload = _pad_flat(keys, payload, world * n_loc)
        return inner(keys, payload)

    return jax.jit(run)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _canon_inputs(keys, payload):
    """Host-side canonicalization that never touches device values: numpy
    inputs get the reference dtype treatment; jax arrays pass through
    (so pre-placed device inputs incur zero extra transfers)."""
    if not isinstance(keys, jax.Array):
        keys = rg._np_keys(np.asarray(keys))
    if payload is not None:
        if not isinstance(payload, jax.Array):
            payload = np.asarray(payload, dtype=np.float32)
        if payload.ndim == 1:
            payload = payload[:, None]
    return keys, payload


def generate_runs_device(
    keys,
    payload=None,
    cfg: ExecConfig | None = None,
    *,
    policy: str = "early_agg",
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
):
    """Scan-based run generation, entirely device-resident.

    Returns ``(store_state, lens, table, dstats)`` — a stacked run buffer
    (leading dims ``(R, C)``), per-slot run lengths, the resident table,
    and a :class:`DeviceSpillStats` pytree.  Nothing in this call blocks
    on the device; call ``dstats.finalize()`` (or read ``lens``) for the
    single host sync.  The host reference with identical semantics is
    :func:`repro.core.run_generation.generate_runs` (one blocking
    occupancy readback **per batch**).
    """
    cfg = cfg or ExecConfig()
    backend = dispatch.resolve_backend_name(backend)
    keys, payload = _canon_inputs(keys, payload)
    if payload is None:
        widths = (0, 0, 0) if widths is None else widths
    with key_dtype_context(np.dtype(keys.dtype)):
        return _pipeline_jit(
            as_key_array(keys), payload, policy=policy,
            memory_rows=cfg.memory_rows, batch_rows=cfg.batch_rows,
            page_rows=cfg.page_rows, index_rows=cfg.memory_rows,
            fanin=cfg.fanin, premerge_levels=0,
            backend=backend, widths=widths, merge=False,
        )


def aggregate_device(
    keys,
    payload=None,
    cfg: ExecConfig | None = None,
    *,
    policy: str = "rs",
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
    index_rows: int | None = None,
    output_estimate: int | None = None,
    mesh=None,
    mesh_axis: str | None = None,
) -> tuple[AggState, DeviceSpillStats]:
    """Run generation + pre-merge levels + wide merge as ONE compiled
    program (§3 + §4).

    Pure device computation: the returned state and stats are device
    arrays and this function never synchronizes (safe under
    ``jax.transfer_guard("disallow")`` with device-resident inputs,
    once compiled).  Output is sorted by key, duplicate-free, EMPTY-
    padded to the batched input capacity.  ``output_estimate`` drives the
    §4.3 plan exactly like the host path: it fixes the (static) number of
    pre-wide merge levels; a wrong estimate shifts work between merge
    styles but never changes the answer.

    ``mesh`` (a :class:`jax.sharding.Mesh`) shards the whole pipeline
    over ``mesh_axis`` (default: the mesh's first axis): every device
    runs run generation + pre-merge + wide merge over its slice of the
    input, then a sampled key-range ``all_to_all`` exchanges the sorted,
    duplicate-free per-shard outputs and each range owner merges its
    fragments — output globally sorted by (owner, key), each shard's
    slice EMPTY-padded.  Stats are psum/pmax-reduced across shards on
    device, so this still performs zero host syncs.  ``mesh=None`` is
    bit-for-bit today's single-device program.
    """
    cfg = cfg or ExecConfig()
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; expected one of {POLICIES}")
    backend = dispatch.resolve_backend_name(backend)
    keys, payload = _canon_inputs(keys, payload)
    if payload is None:
        widths = (0, 0, 0) if widths is None else widths
    if keys.shape[0] == 0:  # static early-out: nothing to scan or merge
        width = 0 if payload is None else payload.shape[1]
        kd = np.dtype(keys.dtype)
        kd = kd if kd == np.uint64 else np.dtype(np.uint32)
        with key_dtype_context(kd):
            return (
                empty_state(0, width, key_dtype=kd, widths=widths),
                DeviceSpillStats.zeros(),
            )
    from repro.core.insort import plan_pre_merge_levels  # lazy: avoids cycle

    # `is None`, not falsy: an explicit 0 estimate must plan like the host
    est = (cfg.memory_rows * cfg.fanin if output_estimate is None
           else output_estimate)
    if mesh is None:
        r_static = _static_run_slots(policy, keys.shape[0], cfg.memory_rows,
                                     cfg.batch_rows)
        pre = plan_pre_merge_levels(est, cfg, r_static)
        with key_dtype_context(np.dtype(keys.dtype)):
            return _pipeline_jit(
                as_key_array(keys), payload, policy=policy,
                memory_rows=cfg.memory_rows, batch_rows=cfg.batch_rows,
                page_rows=cfg.page_rows, index_rows=index_rows or cfg.memory_rows,
                fanin=cfg.fanin, premerge_levels=pre,
                backend=backend, widths=widths, merge=True,
            )
    dispatch.check_shardable(backend)
    axis = resolve_mesh_axis(mesh, mesh_axis)
    world = int(mesh.shape[axis])
    # the §4.3 plan is per shard: levels from the shard's static run-slot
    # bound (each shard generates runs over ~N/world rows)
    n_loc = -(-keys.shape[0] // world)
    r_static = _static_run_slots(policy, n_loc, cfg.memory_rows,
                                 cfg.batch_rows)
    pre = plan_pre_merge_levels(est, cfg, r_static)
    if payload is None:  # fixed (n, 0) payload: one in_spec tree
        payload = np.zeros((keys.shape[0], 0), np.float32)
    fn = _sharded_fn(
        mesh, axis, policy=policy,
        memory_rows=cfg.memory_rows, batch_rows=cfg.batch_rows,
        page_rows=cfg.page_rows, index_rows=index_rows or cfg.memory_rows,
        fanin=cfg.fanin, premerge_levels=pre,
        backend=backend, widths=widths,
    )
    with key_dtype_context(np.dtype(keys.dtype)):
        return fn(as_key_array(keys), payload)


def insort_aggregate_device(
    keys,
    payload=None,
    cfg: ExecConfig | None = None,
    *,
    policy: str = "rs",
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
    index_rows: int | None = None,
    output_estimate: int | None = None,
    mesh=None,
    mesh_axis: str | None = None,
) -> tuple[AggState, SpillStats]:
    """:func:`aggregate_device` + the one host readback of spill stats —
    the device twin of :func:`repro.core.insort.insort_aggregate`."""
    state, dstats = aggregate_device(
        keys, payload, cfg, policy=policy, backend=backend, widths=widths,
        index_rows=index_rows, output_estimate=output_estimate,
        mesh=mesh, mesh_axis=mesh_axis,
    )
    return state, dstats.finalize()
