"""Device-resident external aggregation pipeline: scan-based run
generation fused with the wide merge into ONE compiled program.

The host drivers in :mod:`repro.core.run_generation` mirror the paper's
I/O loop: dispatch one jitted step per batch, then **block on an
occupancy readback** to decide whether to flush a run.  That round trip
— not comparisons — dominates once the per-record work is vectorized
(cf. the external-sort implementation studies in PAPERS.md), so the
external pipeline runs at host-latency instead of hardware speed.

This module removes the host from the loop.  All three read-sort-write
policies (``traditional``, ``inrun_dedup``, ``early_agg``) and
replacement selection (``rs``) run as a single jitted ``lax.scan`` over
the pre-batched input:

* the scan carry is an explicit, reusable pytree —
  :class:`~repro.core.types.StreamEngineState` — holding the stacked
  run buffer, the early-agg / replacement-selection tables, and all
  spill counters as device scalars;
* runs are written into the preallocated, stacked RunStore-shaped
  buffer via a data-dependent run-slot index carried through the scan
  (out-of-range slots drop, so "don't flush" is a no-op scatter);
* the §4.3 pre-wide traditional merge levels (needed when O/M exceeds
  the fan-in, or the wide merge's index outgrows memory) are planned
  statically from the output estimate and run on device as pairwise
  linear merges over run slots (:func:`_device_premerge`);
* the wide merge (§4) consumes the run buffer directly
  (:func:`repro.core.merge.wide_merge_device`), so
  ``repro.aggregate(..., algorithm="insort")`` compiles end-to-end;
* spill accounting is a :class:`~repro.core.types.DeviceSpillStats`
  pytree — the only host synchronization in the whole pipeline is the
  final ``finalize()`` readback of stats + run lengths.

Because the carry is a first-class pytree, the same engine also runs
**streamed**: :class:`StreamingAggregator` / :func:`aggregate_device_stream`
feed the scan super-batch by super-batch from the host, double-buffering
the ``jax.device_put`` of chunk k+1 behind the absorb of chunk k, so
inputs far larger than device memory flow through at compute speed with
zero per-chunk readbacks (finalize stays the single sync).  Chunk count
never enters trace shapes: one compile per super-batch geometry, with a
pow2-bucketed tail.

Sizing is static, derived from shapes alone: a run buffer of
``ceil(N/M)+O(1)`` slots (every flushed run carries > M unique rows, so
the slot count is bounded by input over memory), each slot page-aligned.
The batch count is bucketed to the next power of two (EMPTY batches are
no-ops) so recompiles scale with log(N), not N.  Host (NumPy) inputs are
padded to that bucketed geometry *before* the jit boundary, so calls
that differ only in N share one compilation.

The host loops remain the reference path for oracle-parity testing and
for the paper's exact per-level accounting (Fig 14); the device
pre-merge accounting deviates from the host's only for non-power-of-two
fan-ins and over-estimated run counts (it plans levels from the static
slot bound rather than the dynamic run count).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core import merge as merge_mod
from repro.core import run_generation as rg
from repro.core import sorted_ops
from repro.core.types import (
    AggState,
    DeviceSpillStats,
    ExchangeOverflowError,
    ExecConfig,
    MergeOverflowError,
    SpillStats,
    StreamEngineState,
    as_key_array,
    concat_states,
    empty_key,
    empty_like,
    empty_state,
    expand_engine_scalars,
    key_dtype_context,
    max_key,
    rows_to_state,
    squeeze_engine_scalars,
)

POLICIES = ("traditional", "inrun_dedup", "early_agg", "rs")

# The adaptive streaming mode: STREAM_POLICIES is what StreamingAggregator
# accepts — "adaptive" runs the engine on the current arm's NATIVE
# geometry (so holding an arm costs exactly what the fixed policy costs)
# and lets a PolicyGovernor (repro.core.adaptive) switch the concrete
# run-generation arm between super-batches; a switch flushes the tables
# and re-shapes the state to the incoming arm's geometry (the run store
# only ever ratchets wider — closed runs own their columns).
STREAM_POLICIES = POLICIES + ("adaptive",)

# Arms the governor may switch between.  inrun_dedup is never an arm: on
# unique-heavy input it pays traditional's spill plus a useless segmented
# combine, and on duplicate-heavy input early_agg's persistent M-row
# window strictly beats its per-batch window — it cannot win either
# regime.
ADAPTIVE_ARMS = ("early_agg", "rs", "traditional")

_log = logging.getLogger(__name__)

# Trace-time log: every traced pipeline/stream program appends one entry
# here.  Tests use it as a compile counter — a second call with a
# different N but the same bucketed geometry must NOT append (the jit
# cache hits, nothing retraces).
TRACE_LOG: list[tuple] = []


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pow2_ceil(x: int) -> int:
    return 1 << (x - 1).bit_length() if x > 1 else 1


def _num_batches(n: int, chunk: int) -> int:
    """Batch count bucketed to the next power of two (EMPTY-padded batches
    are no-ops) so distinct input sizes share compilations."""
    t = (n + chunk - 1) // chunk
    return 1 << (t - 1).bit_length() if t > 1 else t


def _pad_flat(keys, payload, total: int):
    """(traced) EMPTY/zero-pad flat (keys, payload) to ``total`` rows —
    EMPTY rows are no-ops in every policy; device-side, no host
    transfer."""
    padn = total - keys.shape[0]
    kd = keys.dtype
    keys = jnp.concatenate([keys, jnp.full((padn,), empty_key(kd), kd)])
    if payload is not None:
        pad = jnp.zeros((padn,) + payload.shape[1:], payload.dtype)
        payload = jnp.concatenate([payload, pad])
    return keys, payload


def _batch(keys, payload, chunk: int, t: int):
    """(traced) pad the flat input to ``t * chunk`` rows and reshape into
    scan batches."""
    keys, payload = _pad_flat(keys, payload, t * chunk)
    bk = keys.reshape(t, chunk)
    bp = None
    if payload is not None:
        bp = payload.reshape(t, chunk, payload.shape[1])
    return bk, bp


def _stacked_empty(slots: int, rows: int, width: int, *, key_dtype, widths):
    proto = empty_state(rows, width, key_dtype=key_dtype, widths=widths)
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (slots,) + x.shape), proto)


def _pad_rows(state: AggState, rows: int) -> AggState:
    if state.capacity >= rows:
        return state
    return concat_states(state, empty_like(state, rows - state.capacity))


# ---------------------------------------------------------------------------
# the engine: init / step / finish over an explicit StreamEngineState
# ---------------------------------------------------------------------------


def _engine_geometry(policy: str, M: int, B: int, P: int):
    """Static per-policy geometry: (input chunk rows, run-slot rows,
    table capacity, second-table capacity).  Unused tables carry
    capacity 0 so the engine-state pytree stays uniform per policy."""
    if policy in ("traditional", "inrun_dedup"):
        return M, _round_up(M, P), 0, 0
    if policy == "early_agg":
        return B, _round_up(M + B, P), M, 0
    if policy == "rs":
        return B, _round_up(2 * M + 2 * B, P), M + 2 * B, M + 2 * B
    if policy == "adaptive":
        # Adaptive streams STAGE chunks at unit-M granularity (re-shaped
        # to the current arm's input unit at absorb time); the engine
        # state itself lives at the current ARM's native geometry, with
        # the slot width ratcheting up at switches (see _switch_reshape).
        # The widest (rs) shape here is the staging unit + upper bound.
        return M, _round_up(2 * M + 2 * B, P), M + 2 * B, M + 2 * B
    raise ValueError(f"unknown run-generation policy {policy!r}")


def _engine_init(policy: str, *, M: int, B: int, P: int, R: int, width: int,
                 key_dtype, widths) -> StreamEngineState:
    """A fresh engine state with ``R`` preallocated run slots (traced —
    call under jit so the buffers are born on device)."""
    _, C, capT, capT2 = _engine_geometry(policy, M, B, P)
    kd = np.dtype(key_dtype)
    ws = widths if widths is not None else (width, width, width)
    return StreamEngineState(
        table=empty_state(capT, width, key_dtype=kd, widths=ws),
        table2=empty_state(capT2, width, key_dtype=kd, widths=ws),
        frontier=jnp.zeros((), dtype=kd),
        store=_stacked_empty(R, C, width, key_dtype=kd, widths=ws),
        lens=jnp.zeros((R,), jnp.int32),
        cursor=jnp.int32(0),
        ridx=jnp.int32(0),
        spilled=jnp.int32(0),
        absorbed=jnp.int32(0),
        dups=jnp.int32(0),
    )


def _valid_rows(ck) -> jax.Array:
    """Valid (non-EMPTY) input rows in one batch (int32 device scalar)."""
    return jnp.sum(ck != empty_key(ck.dtype), dtype=jnp.int32)


def _step_sortwrite(es: StreamEngineState, ck, cp, *, dedup: bool,
                    backend: str, ws) -> StreamEngineState:
    """``traditional`` / ``inrun_dedup``: one run per M-row batch, written
    to the carried run slot (EMPTY batches are no-ops)."""
    valid = _valid_rows(ck)
    st = rows_to_state(ck, cp, widths=ws)
    if dedup:
        st = sorted_ops.absorb(st, backend=backend)
    else:
        st = sorted_ops.sort_state(st, backend=backend)
    occ = st.occupancy()
    if dedup:
        dups = valid - occ  # rows that combined within the batch
    else:
        # no combining happens, but the sorted batch still *observes* its
        # duplicates: adjacent equal-key pairs (EMPTY pads sort last and
        # never match a valid key, so no masking is needed beyond EMPTY)
        k = st.keys
        dups = jnp.sum(
            (k[1:] == k[:-1]) & (k[1:] != empty_key(k.dtype)),
            dtype=jnp.int32,
        )
    R, C = es.run_slots, es.slot_rows
    slot = jnp.where(occ > 0, es.ridx, R)
    store = jax.tree.map(
        lambda d, s: d.at[slot].set(s, mode="drop"), es.store, _pad_rows(st, C)
    )
    lens = es.lens.at[slot].set(occ, mode="drop")
    return dataclasses.replace(
        es, store=store, lens=lens,
        ridx=es.ridx + (occ > 0).astype(jnp.int32),
        spilled=es.spilled + occ,
        absorbed=es.absorbed + valid,
        dups=es.dups + dups,
    )


def _step_early_agg(es: StreamEngineState, ck, cp, *, M: int, backend: str,
                    ws) -> StreamEngineState:
    """``early_agg`` (§3): the ordered in-memory index absorbs each sorted
    batch; when occupancy exceeds M the whole index content is written to
    the carried run slot and memory restarts empty."""
    R, C = es.run_slots, es.slot_rows
    capT = es.table.capacity  # M for the fixed policy; M + 2B under adaptive
    valid = _valid_rows(ck)
    occ_before = es.table.occupancy()
    batch = sorted_ops.absorb(rows_to_state(ck, cp, widths=ws), backend=backend)
    merged = sorted_ops.merge_absorb(
        es.table, batch, backend=backend, assume_unique=True
    )  # capacity capT + B
    occ = merged.occupancy()
    flush = occ > M
    # memory full: the entire index content becomes one sorted run in the
    # carried slot; otherwise the (out-of-range) write drops.
    slot = jnp.where(flush, es.ridx, R)
    store = jax.tree.map(
        lambda d, s: d.at[slot].set(s, mode="drop"), es.store,
        _pad_rows(merged, C),
    )
    lens = es.lens.at[slot].set(occ, mode="drop")
    kept = jax.tree.map(lambda x: x[:capT], merged)  # trim to table capacity
    table0 = empty_like(es.table, capT)
    table = jax.tree.map(lambda e, k: jnp.where(flush, e, k), table0, kept)
    return dataclasses.replace(
        es, table=table, store=store, lens=lens,
        ridx=es.ridx + flush.astype(jnp.int32),
        spilled=es.spilled + jnp.where(flush, occ, 0),
        absorbed=es.absorbed + valid,
        dups=es.dups + (valid - (occ - occ_before)),
    )


def _step_rs(es: StreamEngineState, ck, cp, *, M: int, B: int, backend: str,
             ws) -> StreamEngineState:
    """Replacement selection (§3.3): the two-table partitioned b-tree is
    the carry, and the eviction scan is a bounded inner ``while_loop``
    writing B-row quanta at the carried (run-slot, cursor) position.  A
    run closes when the open partition drains (host semantics) or when
    its slot is within one quantum of capacity (the device buffer's
    close-early rule — always legal, runs only need to be sorted)."""
    C = es.slot_rows
    cap = es.table.capacity  # M + 2B
    arB = jnp.arange(B, dtype=jnp.int32)
    valid = _valid_rows(ck)
    occ_before = es.table.occupancy() + es.table2.occupancy()
    batch = sorted_ops.absorb(rows_to_state(ck, cp, widths=ws), backend=backend)
    rt, nt = rg.rs_split_absorb(es.table, es.table2, es.frontier, batch,
                                backend=backend)
    dups = valid - (rt.occupancy() + nt.occupancy() - occ_before)
    es = dataclasses.replace(
        es, table=rt, table2=nt,
        absorbed=es.absorbed + valid, dups=es.dups + dups,
    )

    def close_fn(s):
        # the open run is exhausted (or its slot is full): record its
        # length, then merge both partitions into a fresh open partition —
        # with occupancy 0 this is exactly the host's promote-next-table.
        lens = s.lens.at[jnp.where(s.cursor > 0, s.ridx, s.run_slots)].set(
            s.cursor, mode="drop"
        )
        ridx = s.ridx + (s.cursor > 0).astype(jnp.int32)
        merged = jax.tree.map(
            lambda x: x[:cap],
            sorted_ops.merge_absorb(s.table, s.table2, backend=backend,
                                    assume_unique=True),
        )
        return dataclasses.replace(
            s, table=merged, table2=empty_like(s.table2, cap),
            frontier=jnp.zeros((), s.frontier.dtype), lens=lens,
            cursor=jnp.int32(0), ridx=ridx,
        )

    def evict_fn(s):
        evicted, rest, frontier, n_ev = rg.rs_evict_step(s.table, B)
        rows = s.cursor + arB
        store = jax.tree.map(
            lambda d, v: d.at[s.ridx, rows].set(v, mode="drop"), s.store,
            evicted,
        )
        return dataclasses.replace(
            s, table=rest, frontier=frontier, store=store,
            cursor=s.cursor + n_ev, spilled=s.spilled + n_ev,
        )

    def overflow_step(s):
        return jax.lax.cond(
            (s.table.occupancy() == 0) | (s.cursor + B > C), close_fn,
            evict_fn, s,
        )

    def overflow_cond(s):
        return s.table.occupancy() + s.table2.occupancy() > M

    return jax.lax.while_loop(overflow_cond, overflow_step, es)


def _engine_step(es: StreamEngineState, ck, cp, *, policy: str, M: int,
                 B: int, backend: str, ws) -> StreamEngineState:
    """Advance the engine by one input batch (the ``lax.scan`` body)."""
    if policy in ("traditional", "inrun_dedup"):
        return _step_sortwrite(es, ck, cp, dedup=(policy == "inrun_dedup"),
                               backend=backend, ws=ws)
    if policy == "early_agg":
        return _step_early_agg(es, ck, cp, M=M, backend=backend, ws=ws)
    if policy == "rs":
        return _step_rs(es, ck, cp, M=M, B=B, backend=backend, ws=ws)
    raise ValueError(f"unknown run-generation policy {policy!r}")


def _engine_finish(es: StreamEngineState, *, policy: str, backend: str):
    """Drain the engine: flush resident tables into run slots.

    Returns ``(store, lens, table, spilled, nruns, overflow)`` — the
    inputs of the merge phase.  For ``early_agg`` the resident table is
    mirrored into the next slot so a downstream wide merge always
    consumes the complete picture; it counts as a spilled run only when
    earlier slots spilled (host-reference semantics).  For ``rs`` the
    open run finishes with the open partition's remainder (its own slot
    when there is room, the next slot otherwise), then the next-run
    partition is written as the last run."""
    R, C = es.run_slots, es.slot_rows
    if policy in ("traditional", "inrun_dedup"):
        return es.store, es.lens, es.table, es.spilled, es.ridx, es.ridx > R
    if policy == "early_agg":
        occ_t = es.table.occupancy()
        store = jax.tree.map(
            lambda d, s: d.at[es.ridx].set(s, mode="drop"), es.store,
            _pad_rows(es.table, C),
        )
        lens = es.lens.at[es.ridx].set(occ_t, mode="drop")
        spilled = es.spilled + jnp.where(es.ridx > 0, occ_t, 0)
        nruns = es.ridx + ((es.ridx > 0) & (occ_t > 0)).astype(jnp.int32)
        overflow = es.ridx + 1 > R
        return (store, lens, es.table, jnp.where(es.ridx > 0, spilled, 0),
                nruns, overflow)
    # rs drain
    rt, nt = es.table, es.table2
    occ_r = rt.occupancy()
    occ_n = nt.occupancy()
    cursor = es.cursor
    evicted_any = (es.ridx > 0) | (cursor > 0)
    arC = jnp.arange(rt.capacity, dtype=jnp.int32)

    def drain_append(args):
        buf, lens, ridx = args
        buf = jax.tree.map(
            lambda d, s: d.at[ridx, cursor + arC].set(s, mode="drop"), buf, rt
        )
        ln = cursor + occ_r
        lens = lens.at[jnp.where(ln > 0, ridx, R)].set(ln, mode="drop")
        return buf, lens, ridx + (ln > 0).astype(jnp.int32)

    def drain_split(args):
        buf, lens, ridx = args
        lens = lens.at[ridx].set(cursor, mode="drop")  # cursor > 0 here
        ridx = ridx + 1
        buf = jax.tree.map(
            lambda d, s: d.at[ridx, arC].set(s, mode="drop"), buf, rt
        )
        lens = lens.at[jnp.where(occ_r > 0, ridx, R)].set(occ_r, mode="drop")
        return buf, lens, ridx + (occ_r > 0).astype(jnp.int32)

    buf, lens, ridx = jax.lax.cond(
        cursor + occ_r <= C, drain_append, drain_split,
        (es.store, es.lens, es.ridx),
    )
    buf = jax.tree.map(lambda d, s: d.at[ridx, arC].set(s, mode="drop"), buf, nt)
    lens = lens.at[jnp.where(occ_n > 0, ridx, R)].set(occ_n, mode="drop")
    ridx = ridx + (occ_n > 0).astype(jnp.int32)
    spilled = es.spilled + occ_r + occ_n
    nruns = jnp.where(evicted_any, ridx, 0)
    overflow = ridx > R
    return buf, lens, rt, jnp.where(evicted_any, spilled, 0), nruns, overflow


# ---------------------------------------------------------------------------
# the fused program
# ---------------------------------------------------------------------------


def _slots_for(n_pad: int, M: int, extra: int) -> int:
    # every closed run carries > M unique rows (early-agg flushes at
    # occupancy > M; every RS run drains a partition that held > M rows),
    # so input-over-memory bounds the slot count.
    return n_pad // (M + 1) + extra


def _stream_run_slots(policy: str, n_pad: int, M: int) -> int:
    """Run-slot bound from the padded row count alone — the host can size
    (and grow) the store with zero device readbacks."""
    if policy in ("traditional", "inrun_dedup"):
        return max(1, n_pad // M)  # one run per M-row batch
    if policy == "adaptive":
        # worst arm mix: the traditional arm writes one run per M-row
        # batch, and each mid-flight switch can close at most two extra
        # (< M-row) runs — those are re-anchored into _base_slots at
        # switch time, so the rolling bound only needs the rs finish
        # slack on top of input-over-memory.
        return max(1, n_pad // M) + 4
    return _slots_for(n_pad, M, 2 if policy == "early_agg" else 4)


def _static_run_slots(policy: str, n: int, M: int, B: int) -> int:
    """Run-slot bound from shapes alone (host-side twin of the sizing in
    :func:`_pipeline_body`, used to plan pre-merge levels statically)."""
    chunk = _engine_geometry(policy, M, B, 1)[0]
    return _stream_run_slots(policy, _num_batches(n, chunk) * chunk, M)


def _pad_slots(store: AggState, lens, R_new: int):
    R, C = store.keys.shape
    widths = (store.sum.shape[-1], store.min.shape[-1], store.max.shape[-1])
    extra = _stacked_empty(
        R_new - R, C, max(widths), key_dtype=store.keys.dtype, widths=widths
    )
    store = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), store, extra)
    lens = jnp.concatenate([lens, jnp.zeros((R_new - R,), jnp.int32)])
    return store, lens


def _device_premerge(store: AggState, lens, *, fanin: int, levels: int, backend: str):
    """§4.3 pre-wide traditional merge levels, on device.

    Each level merges groups of ``2^ceil(log2 F)`` run slots as a
    balanced tree of pairwise linear merge-absorbs (``lax.map`` over slot
    pairs; each pass halves the slot count and doubles slot capacity, so
    the buffer footprint is constant).  Empty slots merge as no-ops, so
    the statically planned level count is safe whatever the dynamic run
    count.  Spill accounting matches the host planner: a group's merged
    output counts as merge spill only if the group actually combined ≥ 2
    live runs (singletons are carried, not rewritten).  For non-power-of-
    two fan-ins the effective group width rounds up to the next power of
    two (slightly fewer, wider groups than the host reference).
    """
    spilled = jnp.int32(0)
    steps = jnp.int32(0)
    nlev = jnp.int32(0)
    sub = max(1, (fanin - 1).bit_length())  # pairwise passes per level
    G = 1 << sub
    for _ in range(levels):
        R = store.keys.shape[0]
        if R <= 1:
            break
        Rpad = _round_up(R, G)
        if Rpad > R:
            store, lens = _pad_slots(store, lens, Rpad)
        sizes = jnp.sum(lens.reshape(-1, G) > 0, axis=1, dtype=jnp.int32)
        for _ in range(sub):

            def step(pair):
                sa, sb = pair
                m = sorted_ops.merge_absorb(sa, sb, backend=backend)
                return m, m.occupancy()

            a = jax.tree.map(lambda x: x[0::2], store)
            b = jax.tree.map(lambda x: x[1::2], store)
            store, lens = jax.lax.map(step, (a, b))
        active = sizes >= 2
        spilled = spilled + jnp.sum(jnp.where(active, lens, 0), dtype=jnp.int32)
        steps = steps + jnp.sum(active, dtype=jnp.int32)
        nlev = nlev + jnp.any(active).astype(jnp.int32)
    return store, lens, spilled, steps, nlev


def _merge_phase(store, lens, spilled, nruns, overflow, *, page_rows: int,
                 index_rows: int, fanin: int, premerge_levels: int,
                 backend: str, out_capacity: int, rows_retired=None,
                 out_buffer=None):
    """§4.3 pre-merge levels + the wide merge + stats assembly — shared
    by the one-shot program, the streamed finalize, and the merge-on-read
    snapshot (which passes a fresh ``out_buffer`` so emission never
    aliases live engine state, plus the ``rows_retired`` accumulator)."""
    zero = jnp.int32(0)
    store, lens, spill_m, msteps, mlevels = _device_premerge(
        store, lens, fanin=fanin, levels=premerge_levels, backend=backend
    )
    out, out_cur, pages_read, max_occ, ix_overflow, dropped = (
        merge_mod.wide_merge_device(
            store, lens, page_rows=page_rows, index_rows=index_rows,
            out_capacity=out_capacity, backend=backend, out=out_buffer,
        )
    )
    # merge/emission stats are charged only when run generation actually
    # spilled — the in-memory case's pass through the merge is a formality
    # the host reference never pays (it returns the table directly).
    spilled_any = nruns > 0
    one = jnp.where(spilled_any, 1, 0).astype(jnp.int32)
    stats = DeviceSpillStats(
        rows_spilled_run_generation=spilled,
        rows_spilled_merge=spill_m,  # pre-levels only; the wide merge never spills
        runs_generated=nruns,
        merge_steps=msteps + one,
        merge_levels=mlevels + one,
        pages_read=jnp.where(spilled_any, pages_read, 0).astype(jnp.int32),
        rows_emitted=jnp.where(spilled_any, out_cur, 0).astype(jnp.int32),
        index_overflowed=spilled_any & ix_overflow,
        max_index_occupancy=jnp.where(spilled_any, max_occ, 0).astype(jnp.int32),
        run_buffer_overflowed=overflow,
        merge_dropped_rows=dropped,
        rows_exchanged=zero,
        rows_retired=zero if rows_retired is None else rows_retired,
        exchange_dropped=jnp.bool_(False),
        exchange_quota=zero,
        exchange_max_fill=zero,
    )
    return out, stats


def _pipeline_body(
    keys,
    payload,
    *,
    policy: str,
    memory_rows: int,
    batch_rows: int,
    page_rows: int,
    index_rows: int,
    fanin: int,
    premerge_levels: int,
    backend: str,
    widths,
    merge: bool,
):
    """Traceable single-device pipeline: run generation scan → §4.3
    pre-merge levels → wide merge.  Jitted directly as
    :func:`_pipeline_jit`; the mesh-sharded program traces it once per
    shard inside ``shard_map`` (:func:`_sharded_fn`)."""
    TRACE_LOG.append(("pipeline", policy, int(keys.shape[0]), merge))
    M, B, P = memory_rows, batch_rows, page_rows
    chunk, _, _, _ = _engine_geometry(policy, M, B, P)
    t = _num_batches(keys.shape[0], chunk)
    n_pad = t * chunk
    bk, bp = _batch(keys, payload, chunk, t)
    width = 0 if payload is None else payload.shape[-1]
    ws = widths if widths is not None else (width, width, width)
    R = _stream_run_slots(policy, n_pad, M)
    es = _engine_init(policy, M=M, B=B, P=P, R=R, width=width,
                      key_dtype=keys.dtype, widths=ws)

    def body(carry, xs):
        ck, cp = xs
        return _engine_step(carry, ck, cp, policy=policy, M=M, B=B,
                            backend=backend, ws=ws), None

    es, _ = jax.lax.scan(body, es, (bk, bp))
    store, lens, table, spilled, nruns, overflow = _engine_finish(
        es, policy=policy, backend=backend
    )

    if not merge:
        zero = jnp.int32(0)
        rg_stats = DeviceSpillStats(
            rows_spilled_run_generation=spilled,
            rows_spilled_merge=zero,
            runs_generated=nruns,
            merge_steps=zero,
            merge_levels=zero,
            pages_read=zero,
            rows_emitted=zero,
            index_overflowed=jnp.bool_(False),
            max_index_occupancy=zero,
            run_buffer_overflowed=overflow,
            merge_dropped_rows=jnp.bool_(False),
            rows_exchanged=zero,
            rows_retired=zero,
            exchange_dropped=jnp.bool_(False),
            exchange_quota=zero,
            exchange_max_fill=zero,
        )
        return store, lens, table, rg_stats

    return _merge_phase(
        store, lens, spilled, nruns, overflow, page_rows=P,
        index_rows=index_rows, fanin=fanin, premerge_levels=premerge_levels,
        backend=backend, out_capacity=max(n_pad, 1),
    )


_pipeline_jit = functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "memory_rows", "batch_rows", "page_rows", "index_rows",
        "fanin", "premerge_levels", "backend", "widths", "merge",
    ),
)(_pipeline_body)


# ---------------------------------------------------------------------------
# mesh-sharded pipeline: per-shard run generation + key-range exchange
# ---------------------------------------------------------------------------


def resolve_mesh_axis(mesh, mesh_axis: str | None) -> str:
    """The mesh axis the pipeline shards over (default: the first)."""
    if mesh_axis is None:
        return mesh.axis_names[0]
    if mesh_axis not in mesh.axis_names:
        raise ValueError(
            f"mesh has no axis {mesh_axis!r}; axes: {mesh.axis_names}"
        )
    return mesh_axis


@functools.lru_cache(maxsize=None)
def _sharded_fn(
    mesh,
    axis: str,
    *,
    policy: str,
    memory_rows: int,
    batch_rows: int,
    page_rows: int,
    index_rows: int,
    fanin: int,
    premerge_levels: int,
    backend: str,
    widths,
    exchange_quota: int | None = None,
):
    """ONE compiled program for the whole mesh (§2.1: partitioning and
    sorting are the same physical property):

    1. each shard runs the full single-device pipeline
       (:func:`_pipeline_body`: run-generation scan into its own run
       buffer, statically planned §4.3 pre-merge levels, local wide
       merge) over its slice of the input — local early aggregation
       before any wire traffic;
    2. the shards exchange their sorted, duplicate-free outputs by
       sampled key range (:func:`~repro.distributed.groupby.
       exchange_sorted_fragments` — the same searchsorted cuts +
       ``all_to_all`` as the distributed group-by), so only unique rows
       travel;
    3. each range owner PAGE-STREAMS the ``world`` sorted fragments it
       received through the §4 wide merge — output globally sorted by
       (owner, key), EMPTY-padded per shard.

    The per-peer quota is capacity-bounded
    (:func:`~repro.distributed.groupby.default_exchange_quota` unless
    ``exchange_quota`` overrides — the host retry path passes a wider
    one), so the wire + fragment-merge footprint per shard is
    O(quota_bound + merge_page) instead of O(world × capacity); a send
    segment over quota trips ``exchange_dropped``, which ``finalize()``
    raises as the retryable
    :class:`~repro.core.types.ExchangeOverflowError`.  Stats are reduced
    across shards on device (:meth:`DeviceSpillStats.cross_shard`), so
    ``finalize()`` remains the program's single host readback and the
    loud-failure invariants hold per shard and globally.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed import groupby as gb_mod
    from repro.distributed._compat import shard_map

    world = mesh.shape[axis]

    def body(keys, payload):
        out, dstats = _pipeline_body(
            keys, payload, policy=policy, memory_rows=memory_rows,
            batch_rows=batch_rows, page_rows=page_rows,
            index_rows=index_rows, fanin=fanin,
            premerge_levels=premerge_levels, backend=backend,
            widths=widths, merge=True,
        )
        merged, ex = gb_mod.exchange_and_merge(
            out, axis, world, backend=backend, quota=exchange_quota,
            page_rows=page_rows,
        )
        dstats = dataclasses.replace(
            dstats,
            merge_dropped_rows=dstats.merge_dropped_rows | ex.merge_dropped,
            rows_exchanged=ex.rows_sent,
            exchange_dropped=ex.send_dropped,
            exchange_quota=jnp.int32(ex.quota),
            exchange_max_fill=ex.max_fill,
        )
        return merged, dstats.cross_shard(axis)

    state_specs = AggState(
        keys=P(axis), count=P(axis), sum=P(axis, None),
        min=P(axis, None), max=P(axis, None),
    )
    n_stats = len(dataclasses.fields(DeviceSpillStats))
    # the replication-check default is version-gated in _compat.shard_map
    # (0.4.x check_rep has no while_loop rule); the stats out_specs are
    # P() and truly replicated anyway (psum/pmax above).
    inner = shard_map(
        body, mesh=mesh, in_specs=(P(axis), P(axis, None)),
        out_specs=(state_specs, DeviceSpillStats(*(P(),) * n_stats)),
    )

    def run(keys, payload):
        # pad so every shard sees the same static n_loc, then hand each
        # shard its contiguous slice
        n_loc = -(-keys.shape[0] // world)
        keys, payload = _pad_flat(keys, payload, world * n_loc)
        return inner(keys, payload)

    return jax.jit(run)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _canon_inputs(keys, payload):
    """Host-side canonicalization that never touches device values: numpy
    inputs get the reference dtype treatment; jax arrays pass through
    (so pre-placed device inputs incur zero extra transfers)."""
    if not isinstance(keys, jax.Array):
        keys = rg._np_keys(np.asarray(keys))
    if payload is not None:
        if not isinstance(payload, jax.Array):
            payload = np.asarray(payload, dtype=np.float32)
        if payload.ndim == 1:
            payload = payload[:, None]
    return keys, payload


def _host_pad_for_geometry(keys, payload, policy: str, cfg: ExecConfig):
    """Pad HOST (NumPy) inputs to the pow2-bucketed batch geometry before
    the jit boundary, so the jit cache keys on geometry rather than N —
    a second call with a different N in the same bucket reuses the
    compiled program.  Device-resident (jax.Array) inputs pass through
    and pad inside the jit instead (no host round trip, at the cost of a
    per-N trace)."""
    if isinstance(keys, jax.Array) or isinstance(payload, jax.Array):
        return keys, payload
    chunk, _, _, _ = _engine_geometry(policy, cfg.memory_rows,
                                      cfg.batch_rows, cfg.page_rows)
    n = keys.shape[0]
    n_pad = _num_batches(n, chunk) * chunk
    if n_pad == n:
        return keys, payload
    keys = np.concatenate(
        [keys, np.full(n_pad - n, empty_key(keys.dtype), keys.dtype)]
    )
    if payload is not None:
        payload = np.concatenate(
            [payload, np.zeros((n_pad - n,) + payload.shape[1:], payload.dtype)]
        )
    return keys, payload


def generate_runs_device(
    keys,
    payload=None,
    cfg: ExecConfig | None = None,
    *,
    policy: str = "early_agg",
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
):
    """Scan-based run generation, entirely device-resident.

    Returns ``(store_state, lens, table, dstats)`` — a stacked run buffer
    (leading dims ``(R, C)``), per-slot run lengths, the resident table,
    and a :class:`DeviceSpillStats` pytree.  Nothing in this call blocks
    on the device; call ``dstats.finalize()`` (or read ``lens``) for the
    single host sync.  The host reference with identical semantics is
    :func:`repro.core.run_generation.generate_runs` (one blocking
    occupancy readback **per batch**).
    """
    cfg = cfg or ExecConfig()
    backend = dispatch.resolve_backend_name(backend)
    keys, payload = _canon_inputs(keys, payload)
    if payload is None:
        widths = (0, 0, 0) if widths is None else widths
    keys, payload = _host_pad_for_geometry(keys, payload, policy, cfg)
    with key_dtype_context(np.dtype(keys.dtype)):
        return _pipeline_jit(
            as_key_array(keys), payload, policy=policy,
            memory_rows=cfg.memory_rows, batch_rows=cfg.batch_rows,
            page_rows=cfg.page_rows, index_rows=cfg.memory_rows,
            fanin=cfg.fanin, premerge_levels=0,
            backend=backend, widths=widths, merge=False,
        )


def aggregate_device(
    keys,
    payload=None,
    cfg: ExecConfig | None = None,
    *,
    policy: str = "rs",
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
    index_rows: int | None = None,
    output_estimate: int | None = None,
    mesh=None,
    mesh_axis: str | None = None,
    exchange_quota: int | None = None,
) -> tuple[AggState, DeviceSpillStats]:
    """Run generation + pre-merge levels + wide merge as ONE compiled
    program (§3 + §4).

    Pure device computation: the returned state and stats are device
    arrays and this function never synchronizes (safe under
    ``jax.transfer_guard("disallow")`` with device-resident inputs,
    once compiled).  Output is sorted by key, duplicate-free, EMPTY-
    padded to the batched input capacity.  ``output_estimate`` drives the
    §4.3 plan exactly like the host path: it fixes the (static) number of
    pre-wide merge levels; a wrong estimate shifts work between merge
    styles but never changes the answer.

    ``mesh`` (a :class:`jax.sharding.Mesh`) shards the whole pipeline
    over ``mesh_axis`` (default: the mesh's first axis): every device
    runs run generation + pre-merge + wide merge over its slice of the
    input, then a sampled key-range ``all_to_all`` exchanges the sorted,
    duplicate-free per-shard outputs (capacity-bounded per-peer quota —
    ``exchange_quota`` overrides the sampled-cut default) and each range
    owner page-streams its fragments through the §4 wide merge — output
    globally sorted by (owner, key), each shard's slice EMPTY-padded.
    Stats are psum/pmax-reduced across shards on device, so this still
    performs zero host syncs.  ``mesh=None`` is bit-for-bit today's
    single-device program.
    """
    cfg = cfg or ExecConfig()
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r}; expected one of {POLICIES}")
    backend = dispatch.resolve_backend_name(backend)
    keys, payload = _canon_inputs(keys, payload)
    if payload is None:
        widths = (0, 0, 0) if widths is None else widths
    if keys.shape[0] == 0:  # static early-out: nothing to scan or merge
        width = 0 if payload is None else payload.shape[1]
        kd = np.dtype(keys.dtype)
        kd = kd if kd == np.uint64 else np.dtype(np.uint32)
        with key_dtype_context(kd):
            return (
                empty_state(0, width, key_dtype=kd, widths=widths),
                DeviceSpillStats.zeros(),
            )
    from repro.core.insort import plan_pre_merge_levels  # lazy: avoids cycle

    # `is None`, not falsy: an explicit 0 estimate must plan like the host
    est = (cfg.memory_rows * cfg.fanin if output_estimate is None
           else output_estimate)
    if mesh is None:
        r_static = _static_run_slots(policy, keys.shape[0], cfg.memory_rows,
                                     cfg.batch_rows)
        pre = plan_pre_merge_levels(est, cfg, r_static)
        keys, payload = _host_pad_for_geometry(keys, payload, policy, cfg)
        with key_dtype_context(np.dtype(keys.dtype)):
            return _pipeline_jit(
                as_key_array(keys), payload, policy=policy,
                memory_rows=cfg.memory_rows, batch_rows=cfg.batch_rows,
                page_rows=cfg.page_rows, index_rows=index_rows or cfg.memory_rows,
                fanin=cfg.fanin, premerge_levels=pre,
                backend=backend, widths=widths, merge=True,
            )
    dispatch.check_shardable(backend)
    axis = resolve_mesh_axis(mesh, mesh_axis)
    world = int(mesh.shape[axis])
    # the §4.3 plan is per shard: levels from the shard's static run-slot
    # bound (each shard generates runs over ~N/world rows)
    n_loc = -(-keys.shape[0] // world)
    r_static = _static_run_slots(policy, n_loc, cfg.memory_rows,
                                 cfg.batch_rows)
    pre = plan_pre_merge_levels(est, cfg, r_static)
    if payload is None:  # fixed (n, 0) payload: one in_spec tree
        payload = np.zeros((keys.shape[0], 0), np.float32)
    fn = _sharded_fn(
        mesh, axis, policy=policy,
        memory_rows=cfg.memory_rows, batch_rows=cfg.batch_rows,
        page_rows=cfg.page_rows, index_rows=index_rows or cfg.memory_rows,
        fanin=cfg.fanin, premerge_levels=pre,
        backend=backend, widths=widths, exchange_quota=exchange_quota,
    )
    with key_dtype_context(np.dtype(keys.dtype)):
        return fn(as_key_array(keys), payload)


def _shard_out_capacity(policy: str, n: int, world: int,
                        cfg: ExecConfig) -> int:
    """Host twin of the mesh pipeline's per-shard merge output capacity
    (``max(n_pad, 1)`` inside :func:`_pipeline_body` for the shard's
    padded slice) — the statically lossless ceiling of the exchange
    retry ladder (a quota >= the per-shard capacity cannot drop)."""
    n_loc = -(-n // world)
    chunk, _, _, _ = _engine_geometry(policy, cfg.memory_rows,
                                      cfg.batch_rows, cfg.page_rows)
    return max(_num_batches(n_loc, chunk) * chunk, 1)


def insort_aggregate_device(
    keys,
    payload=None,
    cfg: ExecConfig | None = None,
    *,
    policy: str = "rs",
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
    index_rows: int | None = None,
    output_estimate: int | None = None,
    mesh=None,
    mesh_axis: str | None = None,
    exchange_quota: int | None = None,
) -> tuple[AggState, SpillStats]:
    """:func:`aggregate_device` + the one host readback of spill stats —
    the device twin of :func:`repro.core.insort.insort_aggregate`.

    On a mesh, a cross-shard exchange whose sampled quota proved too
    small for the data's skew retries ONCE at the next pow2 quota
    (capped at the statically lossless per-shard capacity) with a loud
    log — the readback already paid here is the same one the retry
    needs, so this is the natural host decision point.  A second
    overflow propagates the :class:`ExchangeOverflowError`."""
    state, dstats = aggregate_device(
        keys, payload, cfg, policy=policy, backend=backend, widths=widths,
        index_rows=index_rows, output_estimate=output_estimate,
        mesh=mesh, mesh_axis=mesh_axis, exchange_quota=exchange_quota,
    )
    try:
        return state, dstats.finalize()
    except ExchangeOverflowError as e:
        if mesh is None:
            raise  # impossible without an exchange; don't mask bugs
        cfg_ = cfg or ExecConfig()
        axis = resolve_mesh_axis(mesh, mesh_axis)
        world = int(mesh.shape[axis])
        cap_loc = _shard_out_capacity(policy, np.asarray(keys).shape[0],
                                      world, cfg_)
        quota2 = min(_pow2_ceil(e.quota + 1), _pow2_ceil(cap_loc))
        if quota2 <= e.quota:
            raise  # already at the lossless ceiling; a retry cannot help
        _log.warning(
            "mesh exchange overflowed its per-peer quota=%d (fullest "
            "segment %d rows); retrying once at quota=%d",
            e.quota, e.max_fill, quota2,
        )
        state, dstats = aggregate_device(
            keys, payload, cfg, policy=policy, backend=backend,
            widths=widths, index_rows=index_rows,
            output_estimate=output_estimate, mesh=mesh, mesh_axis=mesh_axis,
            exchange_quota=quota2,
        )
        stats = dstats.finalize()
        return state, dataclasses.replace(stats, exchange_retries=1)


# ---------------------------------------------------------------------------
# streamed pipeline: double-buffered super-batches over the same engine
# ---------------------------------------------------------------------------
#
# The jitted pieces below advance / grow / finalize a StreamEngineState.
# All three donate the incoming state (argnum 0): XLA reuses its buffers
# for the output, so the steady-state device footprint is ONE engine
# state plus the (at most two) staged input chunks in flight.


def _absorb_chunk_body(es, bk, bp, *, policy, memory_rows, batch_rows,
                       backend, widths, local_slots, with_obs=False):
    TRACE_LOG.append(("absorb", policy, tuple(bk.shape), es.run_slots))
    # The scan carries only a LOCAL window of the run store — the slots
    # this chunk can actually reach (its exact run bound + the open
    # slot), spliced back in one dynamic_update_slice.  Carrying the full
    # store would make every scan step pay O(R) for the carry, i.e. each
    # absorb would slow down as the stream grows; with the window the
    # per-chunk cost is independent of how much has already streamed.
    # The host grow schedule guarantees R >= ridx + local_slots, so the
    # clamp below never actually moves the window over occupied slots.
    R, L = es.run_slots, min(local_slots, es.run_slots)
    ridx0 = jnp.clip(es.ridx, 0, R - L)
    loc = dataclasses.replace(
        es,
        store=jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, ridx0, L, axis=0),
            es.store),
        lens=jax.lax.dynamic_slice_in_dim(es.lens, ridx0, L, axis=0),
        ridx=es.ridx - ridx0,
    )

    def body(carry, xs):
        ck, cp = xs
        return _engine_step(carry, ck, cp, policy=policy, M=memory_rows,
                            B=batch_rows, backend=backend, ws=widths), None

    loc, _ = jax.lax.scan(body, loc, (bk, bp))
    es = dataclasses.replace(
        loc,
        store=jax.tree.map(
            lambda a, l: jax.lax.dynamic_update_slice_in_dim(
                a, l, ridx0, axis=0),
            es.store, loc.store),
        lens=jax.lax.dynamic_update_slice_in_dim(es.lens, loc.lens, ridx0,
                                                 axis=0),
        ridx=ridx0 + loc.ridx,
    )
    if not with_obs:
        return es
    # adaptive streams get the governor's decision scalars as an extra
    # output of the SAME program: a separate _observe dispatch would hold
    # a pending read on the engine buffers and force the next (donating)
    # absorb into a defensive copy of the whole state — folded in here,
    # donation stays clean and the observation is free.
    return es, _observe_body(es)


_absorb_chunk = jax.jit(
    _absorb_chunk_body, donate_argnums=(0,),
    static_argnames=("policy", "memory_rows", "batch_rows", "backend",
                     "widths", "local_slots", "with_obs"),
)


def _engine_init_body(*, policy, memory_rows, batch_rows, page_rows,
                      run_slots, width, key_dtype, widths):
    TRACE_LOG.append(("init", policy, run_slots))
    return _engine_init(
        policy, M=memory_rows, B=batch_rows, P=page_rows, R=run_slots,
        width=width, key_dtype=key_dtype, widths=widths,
    )


# every argument is static: the jit exists so the state is BORN on device
# (no eager host constants — streaming works under a transfer guard)
_engine_init_jit = jax.jit(
    _engine_init_body,
    static_argnames=("policy", "memory_rows", "batch_rows", "page_rows",
                     "run_slots", "width", "key_dtype", "widths"),
)


def _grow_store_body(es, *, run_slots):
    TRACE_LOG.append(("grow", run_slots))
    store, lens = _pad_slots(es.store, es.lens, run_slots)
    return dataclasses.replace(es, store=store, lens=lens)


# no donation: the grown store's shapes differ from the old state's, so
# XLA could not reuse the buffers anyway (it would only warn)
_grow_store = jax.jit(_grow_store_body, static_argnames=("run_slots",))


def _trim_slots(es, trim: int):
    """Drop the run slots past the exact bound: the pow2 growth schedule
    overshoots so absorbs stay cache hits, but by finalize the total row
    count is host-known and runs can only occupy the first ``trim``
    slots — merging the (empty) overshoot would cost real merge work."""
    if trim >= es.store.keys.shape[0]:
        return es
    store = jax.tree.map(lambda a: a[:trim], es.store)
    return dataclasses.replace(es, store=store, lens=es.lens[:trim])


def _finalize_stream_body(es, retired, *, policy, page_rows, index_rows,
                          fanin, premerge_levels, backend, out_capacity,
                          trim):
    """Drain + pre-merge + wide merge of a stream engine state.

    This ONE program serves both the destructive finalize and the
    merge-on-read snapshot: it only *reads* ``es`` and emits into a
    fresh output buffer, so (un-donated) it is non-destructive by
    construction — the snapshot path simply keeps the input state alive.
    ``retired`` threads the service's eviction accumulator into the
    stats (``None`` when no eviction ever ran)."""
    TRACE_LOG.append(("finalize", policy, out_capacity))
    es = _trim_slots(es, trim)
    ws = (es.store.sum.shape[-1], es.store.min.shape[-1],
          es.store.max.shape[-1])  # store planes are stacked (R, C, w)
    fresh_out = empty_state(out_capacity, max(ws), key_dtype=es.key_dtype,
                            widths=ws)
    store, lens, table, spilled, nruns, overflow = _engine_finish(
        es, policy=policy, backend=backend
    )
    return _merge_phase(
        store, lens, spilled, nruns, overflow, page_rows=page_rows,
        index_rows=index_rows, fanin=fanin, premerge_levels=premerge_levels,
        backend=backend, out_capacity=out_capacity, rows_retired=retired,
        out_buffer=fresh_out,
    )


# no donation: the merged output's shapes differ from the engine state's
# leaves, so the donated buffers would go unused (XLA warns, no benefit).
# Non-donation is also load-bearing for the service: snapshot_device()
# runs this very program on the LIVE engine state.
_finalize_stream = jax.jit(
    _finalize_stream_body,
    static_argnames=("policy", "page_rows", "index_rows", "fanin",
                     "premerge_levels", "backend", "out_capacity", "trim"),
)


# ---------------------------------------------------------------------------
# key eviction / TTL: retire expired key ranges from the live engine
# ---------------------------------------------------------------------------


def _retire_sorted_prefix(planes: AggState, cut, valid):
    """Shift every slot's sorted row-planes left by its prefix ``cut`` and
    restore the EMPTY fill beyond the surviving rows.

    ``planes`` leaves are (R, C[, w]); ``cut``/``valid`` are (R,) with
    ``cut <= valid`` (every slot is ascending-sorted with EMPTY — the max
    sentinel — padding its tail, so a ``searchsorted`` cut can never
    reach into the pad).  The fills reproduce :func:`empty_state` byte
    for byte, so a fully retired slot is indistinguishable from a fresh
    one."""
    C = planes.keys.shape[1]
    kd = planes.keys.dtype
    ar = jnp.arange(C, dtype=jnp.int32)
    idx2 = jnp.minimum(ar[None, :] + cut[:, None], max(C - 1, 0))
    live = ar[None, :] < (valid - cut)[:, None]
    inf = np.float32(np.inf)
    fills = AggState(keys=jnp.asarray(empty_key(kd), kd),
                     count=jnp.int32(0), sum=jnp.float32(0),
                     min=jnp.float32(inf), max=jnp.float32(-inf))

    def shift(a, f):
        if a.shape[1] == 0:
            return a
        if a.ndim == 2:
            return jnp.where(live, jnp.take_along_axis(a, idx2, axis=1), f)
        return jnp.where(live[:, :, None],
                         jnp.take_along_axis(a, idx2[:, :, None], axis=1), f)

    return jax.tree.map(shift, planes, fills)


def _evict_compact_body(es, threshold, retired, *, policy, backend):
    """Retire every resident row with key < ``threshold`` from the live
    engine state and compact the surviving run slots to the store prefix.

    Every component of the engine keeps its rows ascending-sorted with
    EMPTY-padded tails (closed slots are whole sorted runs, the RS open
    slot's ``[0, cursor)`` prefix is ascending by the frontier invariant,
    tables are OrderedIndexes), so retirement is a per-slot
    ``searchsorted`` prefix cut — no scatter, no readback.  Surviving
    closed runs are permuted to the slot prefix (stable, order
    preserving) so the host's input-over-memory slot bound can be
    re-baselined from the returned ``ridx`` and absorbs keep splicing at
    the high-water mark.  ``retired`` accumulates the number of state
    rows removed (``None`` on first eviction): nothing leaves the engine
    without being counted here or emitted by a snapshot/finalize."""
    del backend  # uniform across backends: pure lax gather/permute
    TRACE_LOG.append(("evict", policy))
    R, C = es.run_slots, es.slot_rows
    arR = jnp.arange(R, dtype=jnp.int32)
    thr = jnp.asarray(threshold, es.store.keys.dtype)
    is_open = arR == es.ridx
    # per-slot valid rows: closed slots carry lens, the RS open slot's
    # prefix length is the cursor (its lens stays 0 until the run closes)
    valid = jnp.maximum(es.lens, jnp.where(is_open, es.cursor, 0))
    cut = jax.vmap(
        lambda row: jnp.searchsorted(row, thr, side="left").astype(jnp.int32)
    )(es.store.keys)
    cut = jnp.minimum(cut, valid)
    store = _retire_sorted_prefix(es.store, cut, valid)
    lens_new = es.lens - jnp.minimum(cut, es.lens)
    cursor = es.cursor - jnp.where(
        (es.ridx >= 0) & (es.ridx < R),
        cut[jnp.clip(es.ridx, 0, max(R - 1, 0))], 0,
    )
    # compact: surviving closed runs first (order preserved), then the
    # open slot, then the all-EMPTY retired slots
    order = jnp.where(lens_new > 0, 0, jnp.where(is_open, 1, 2))
    perm = jnp.argsort(order, stable=True)
    store = jax.tree.map(lambda a: a[perm], store)
    lens_new = lens_new[perm]
    ridx = jnp.sum(lens_new > 0, dtype=jnp.int32)
    delta = jnp.sum(cut, dtype=jnp.int32)
    # resident tables (early-agg index / RS partitions): same prefix cut,
    # lifted to one (1, capT) slot
    table, table2 = es.table, es.table2
    for name in ("table", "table2"):
        t = getattr(es, name)
        if t.capacity == 0:
            continue
        occ = t.occupancy()
        cut_t = jnp.searchsorted(t.keys, thr, side="left").astype(jnp.int32)
        cut_t = jnp.minimum(cut_t, occ)
        lifted = jax.tree.map(lambda a: a[None], t)
        lifted = _retire_sorted_prefix(lifted, cut_t[None], occ[None])
        t = jax.tree.map(lambda a: a[0], lifted)
        delta = delta + cut_t
        if name == "table":
            table = t
        else:
            table2 = t
    zero = jnp.int32(0)
    retired = delta + (zero if retired is None else retired)
    es = dataclasses.replace(
        es, table=table, table2=table2, store=store, lens=lens_new,
        cursor=cursor, ridx=ridx,
    )
    return es, retired


# donated: eviction rewrites the state in place (same shapes throughout)
_evict_compact = jax.jit(
    _evict_compact_body, static_argnames=("policy", "backend"),
    donate_argnums=(0,),
)


# ---------------------------------------------------------------------------
# adaptive streaming: observation readback + the policy-transition program
# ---------------------------------------------------------------------------


def _observe_body(es: StreamEngineState):
    """The decision scalars the adaptive governor steers on, packed into
    ONE int32 vector so the amortized readback is a single small
    transfer: (rows absorbed, duplicate encounters, rows spilled,
    resident table occupancy, run slots used)."""
    TRACE_LOG.append(("observe", es.run_slots))
    occ_t = es.table.occupancy() + es.table2.occupancy()
    return jnp.stack([es.absorbed, es.dups, es.spilled, occ_t, es.ridx])


_observe = jax.jit(_observe_body)


def _switch_flush_body(es: StreamEngineState, *, policy: str,
                       backend: str) -> StreamEngineState:
    """Close out the CURRENT policy arm so the next chunk can be absorbed
    under a different one: close the open replacement-selection run (rs
    only), then flush the resident table content as one closed sorted run
    and reset the tables/frontier/cursor to their fresh state.

    This is what makes mid-flight switching legal: after the transition
    every arm sees exactly the state it would after its own ``init`` —
    empty tables, closed sorted runs in the store — and the finalize
    merge is policy-agnostic over the store (each arm's runs are sorted;
    the wide merge aggregates across and within runs)."""
    TRACE_LOG.append(("switch", policy, es.run_slots))
    R, C = es.run_slots, es.slot_rows
    if policy == "rs":
        # close the open run at its current cursor (no-op when cursor==0)
        lens = es.lens.at[jnp.where(es.cursor > 0, es.ridx, R)].set(
            es.cursor, mode="drop"
        )
        ridx = es.ridx + (es.cursor > 0).astype(jnp.int32)
        # collapse both partitions into one sorted resident table (the
        # run/next distinction is meaningless once the run is closed)
        cap = es.table.capacity
        merged = jax.tree.map(
            lambda x: x[:cap],
            sorted_ops.merge_absorb(es.table, es.table2, backend=backend,
                                    assume_unique=True),
        )
        es = dataclasses.replace(
            es, table=merged, table2=empty_like(es.table2, es.table2.capacity),
            frontier=jnp.zeros((), es.frontier.dtype), lens=lens,
            cursor=jnp.int32(0), ridx=ridx,
        )
    if es.table.capacity:
        # flush the resident (sorted, unique) table as one closed run
        occ = es.table.occupancy()
        slot = jnp.where(occ > 0, es.ridx, R)
        store = jax.tree.map(
            lambda d, s: d.at[slot].set(s, mode="drop"), es.store,
            _pad_rows(es.table, C),
        )
        lens = es.lens.at[slot].set(occ, mode="drop")
        es = dataclasses.replace(
            es, store=store, lens=lens,
            ridx=es.ridx + (occ > 0).astype(jnp.int32),
            spilled=es.spilled + occ,
            table=empty_like(es.table, es.table.capacity),
        )
    return es


# donated: the transition rewrites the state in place (same shapes), so
# back-to-back switch → absorb reuses the engine buffers
_switch_flush = jax.jit(
    _switch_flush_body, static_argnames=("policy", "backend"),
    donate_argnums=(0,),
)


def _switch_reshape_body(es: StreamEngineState, *, slot_rows, capT, capT2,
                         width, widths):
    """Re-shape a just-flushed engine state to the incoming arm's NATIVE
    geometry.  The tables are empty after :func:`_switch_flush_body`, so
    they are simply re-allocated at the new capacities; the run store
    only ever RATCHETS wider (closed runs own their columns, narrowing
    could drop rows) — every slot's rows are left-packed with EMPTY
    tails, so splicing the old store into a fresh wider empty one
    preserves the per-slot invariant.

    Keeping each arm on its native shapes is what makes an adaptive
    stream that holds one arm run the exact per-chunk programs the fixed
    policy runs — no wide-geometry tax on the steady state; only an
    actual switch pays this (one state copy)."""
    TRACE_LOG.append(("reshape", slot_rows, capT, capT2))
    kd = es.store.keys.dtype
    ws = widths if widths is not None else (width, width, width)
    store = es.store
    if slot_rows != es.slot_rows:
        empty = _stacked_empty(es.run_slots, slot_rows, width,
                               key_dtype=kd, widths=ws)
        store = jax.tree.map(
            lambda e, a: jax.lax.dynamic_update_slice(e, a, (0,) * e.ndim),
            empty, store)
    return dataclasses.replace(
        es,
        table=empty_state(capT, width, key_dtype=kd, widths=ws),
        table2=empty_state(capT2, width, key_dtype=kd, widths=ws),
        store=store,
    )


# no donation: the reshaped state's buffer shapes differ from the input's
# so XLA could not alias them anyway — switches are rare (one copy each)
_switch_reshape = jax.jit(
    _switch_reshape_body,
    static_argnames=("slot_rows", "capT", "capT2", "width", "widths"),
)


@dataclasses.dataclass
class StagedChunk:
    """A super-batch already on device: ``jax.device_put`` was dispatched
    (asynchronously) but the engine has not absorbed it yet — the unit of
    double buffering."""

    bk: jax.Array  # (t, chunk) batched keys, EMPTY-padded tail
    bp: jax.Array  # (t, chunk, V) batched payload
    rows: int  # valid input rows in this chunk
    rows_padded: int  # t * chunk


class StreamingAggregator:
    """Feed the fused external-aggregation engine super-batch by
    super-batch from the host.

    The carry between chunks is a :class:`StreamEngineState` that never
    leaves the device; absorbing a chunk is ONE jitted dispatch with the
    previous state donated, and the host performs **zero** readbacks
    until :meth:`finalize` (the single sync — same contract as the
    one-shot :func:`aggregate_device`).

    Typical use is through :func:`aggregate_device_stream`, which adds
    the double-buffered drive loop; the raw protocol is::

        agg = StreamingAggregator(cfg, policy="rs", key_dtype=np.uint32,
                                  width=V)
        staged = agg.stage(keys0, pay0)     # async H2D of chunk 0
        for keys, pay in chunks:
            nxt = agg.stage(keys, pay)      # H2D of k+1 in flight while…
            agg.absorb_staged(staged)       # …the device absorbs chunk k
            staged = nxt
        agg.absorb_staged(staged)
        state, stats = agg.finalize()

    Sizing is host-computed from the cumulative padded row count (every
    flushed run holds > M rows, so slots are bounded by input over
    memory): the run store grows geometrically (pow2 slot counts) with a
    jitted, donated concat — never a readback.  Chunk geometry is
    pow2-bucketed, so the number of distinct compiled programs is
    O(log max-chunk-rows + log total-rows), independent of chunk count.

    ``mesh`` streams per-shard slices of every chunk through the same
    engine under ``shard_map``; finalize then runs the key-range exchange
    + per-owner merge of the one-shot sharded pipeline, returning a
    globally (owner, key)-sorted state and cross-shard-reduced stats.

    ``policy="adaptive"`` keeps the engine state at the current arm's
    NATIVE geometry (a switch re-shapes it — tables re-allocated, the
    run store ratcheting wider only) and lets a
    :class:`repro.core.adaptive.PolicyGovernor` pick the concrete
    run-generation arm (early_agg / rs / traditional) from the engine's
    own observed duplicate rate: every ``k``-th chunk the
    host reads ONE small decision vector back (an explicit
    ``jax.device_get`` — legal under ``jax.transfer_guard("disallow")``)
    and may dispatch a policy-transition program before the next absorb.
    The zero-readback contract relaxes to **O(stream/k) scalar
    readbacks**, counted in ``readbacks_paid`` and surfaced via
    ``SpillStats``.  Adaptive mode requires ``mesh=None`` and
    ``memory_rows % batch_rows == 0`` (chunks are staged at unit-M
    granularity and re-shaped per arm).
    """

    def __init__(
        self,
        cfg: ExecConfig | None = None,
        *,
        policy: str = "rs",
        key_dtype=np.uint32,
        width: int = 0,
        widths: tuple[int, int, int] | None = None,
        backend: str = "auto",
        index_rows: int | None = None,
        output_estimate: int | None = None,
        output_rows: int | None = None,
        mesh=None,
        mesh_axis: str | None = None,
        governor=None,
    ):
        cfg = cfg or ExecConfig()
        if policy not in STREAM_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; expected one of {STREAM_POLICIES}"
            )
        self.cfg = cfg
        self.policy = policy
        self.backend = dispatch.resolve_backend_name(backend)
        self.key_dtype = np.dtype(key_dtype)
        if self.key_dtype not in (np.dtype(np.uint32), np.dtype(np.uint64)):
            raise TypeError(
                f"key_dtype must be uint32 or uint64, got {self.key_dtype}"
            )
        self.width = int(width)
        self.widths = (tuple(widths) if widths is not None
                       else (self.width,) * 3)
        self.index_rows = index_rows or cfg.memory_rows
        self.output_estimate = output_estimate
        self.output_rows = output_rows
        self._chunk = _engine_geometry(policy, cfg.memory_rows,
                                       cfg.batch_rows, cfg.page_rows)[0]
        self.mesh = mesh
        self.axis = (resolve_mesh_axis(mesh, mesh_axis)
                     if mesh is not None else None)
        self.world = int(mesh.shape[self.axis]) if mesh is not None else 1
        if mesh is not None:
            dispatch.check_shardable(self.backend)
            self._fns = _mesh_stream_fns(
                mesh, self.axis, policy=policy,
                memory_rows=cfg.memory_rows, batch_rows=cfg.batch_rows,
                page_rows=cfg.page_rows, index_rows=self.index_rows,
                fanin=cfg.fanin, backend=self.backend, widths=self.widths,
                width=self.width, key_dtype_name=self.key_dtype.name,
            )
        self._es: StreamEngineState | None = None
        self._R = 0  # per-shard run slots currently allocated
        self._finalized = False
        self.rows_seen = 0
        self.rows_padded = 0  # cumulative padded rows (all shards)
        # service-mode extras (inert until snapshot()/evict_below() are
        # used): the device-resident retired-row accumulator, and the
        # slot-accounting baseline taken at the last eviction — eviction
        # compacts live runs to the store prefix and re-anchors the
        # host's input-over-memory slot bound there.
        self._retired = None  # created device-side by the first evict
        self._base_slots = 0  # live closed runs (+ slack) at the baseline
        self._rows_since_evict = 0  # padded rows absorbed since baseline
        # adaptive-mode extras (inert for fixed policies): the concrete
        # run-generation arm the next absorb uses, the governor steering
        # it, and the observation/switch accounting.
        self.policy_events: list[dict] = []
        self.readbacks_paid = 0
        self._chunks_absorbed = 0
        self._last_dup_rate = 0.0
        self._pending_obs = None  # boundary observation awaiting harvest
        self._last_obs_vec = None  # newest boundary-chunk observation
        if policy == "adaptive":
            if mesh is not None:
                raise ValueError(
                    "policy='adaptive' does not compose with mesh= yet — "
                    "pick a fixed policy for sharded streams"
                )
            if cfg.memory_rows % cfg.batch_rows:
                raise ValueError(
                    "policy='adaptive' needs memory_rows divisible by "
                    f"batch_rows (chunks are staged at unit-M granularity "
                    f"and re-shaped per arm), got M={cfg.memory_rows} "
                    f"B={cfg.batch_rows}"
                )
            from repro.core import adaptive as adaptive_mod

            self._governor = (governor if isinstance(
                governor, adaptive_mod.PolicyGovernor)
                else adaptive_mod.PolicyGovernor(cfg, config=governor))
            # the engine state is created lazily at the first absorb, at
            # THIS arm's native geometry — not at a one-size-fits-all
            # wide shape (see _switch_reshape for the switch-time cost)
            self._arm = self._governor.start_arm(
                output_estimate=output_estimate)
        else:
            if governor is not None:
                # refusing loudly here is the satellite contract: a
                # governor that silently never steers is indistinguishable
                # from a working adaptive stream until the bench lies.
                if mesh is not None:
                    raise ValueError(
                        "governor= was passed on a mesh= stream, but the "
                        "adaptive governor does not compose with mesh= "
                        "yet (it needs a cross-shard observation reduce — "
                        "a documented ROADMAP follow-on).  It would have "
                        "silently run the fixed policy "
                        f"{policy!r}; pick a fixed policy and drop "
                        "governor=, or run unsharded with "
                        "policy='adaptive'"
                    )
                raise ValueError(
                    f"governor= was passed with fixed policy {policy!r}; "
                    "it would have been silently ignored — use "
                    "policy='adaptive' to let the governor steer, or "
                    "drop governor="
                )
            self._governor = None
            self._arm = policy

    # -- staging ---------------------------------------------------------

    def _prep(self, keys, payload):
        """Host-side canonicalize + pad one chunk to its pow2-bucketed
        batch geometry (NumPy only — under a transfer guard the explicit
        ``device_put`` in :meth:`stage` is the sole device touch)."""
        keys = rg._np_keys(np.asarray(keys))
        if keys.dtype != self.key_dtype:
            raise TypeError(
                f"chunk key dtype {keys.dtype} != aggregator key_dtype "
                f"{self.key_dtype}"
            )
        n = keys.shape[0]
        if payload is None:
            payload = np.zeros((n, self.width), np.float32)
        else:
            payload = np.asarray(payload, dtype=np.float32)
            if payload.ndim == 1:
                payload = payload[:, None]
        if payload.shape != (n, self.width):
            raise ValueError(
                f"chunk payload shape {payload.shape} != "
                f"({n}, width={self.width})"
            )
        n_loc = -(-n // self.world)
        t = _num_batches(n_loc, self._chunk)
        n_pad = self.world * t * self._chunk
        if n_pad > n:
            keys = np.concatenate([
                keys,
                np.full(n_pad - n, empty_key(self.key_dtype), self.key_dtype),
            ])
            payload = np.concatenate([
                payload, np.zeros((n_pad - n, self.width), np.float32),
            ])
        bk = keys.reshape(self.world * t, self._chunk)
        bp = payload.reshape(self.world * t, self._chunk, self.width)
        return bk, bp, n, n_pad

    def stage(self, keys, payload=None) -> StagedChunk | None:
        """Start the (asynchronous) host→device transfer of one chunk.

        Returns a :class:`StagedChunk` to pass to :meth:`absorb_staged`
        later — staging chunk k+1 before absorbing chunk k is what hides
        the transfer behind compute.  Empty chunks return None."""
        if np.asarray(keys).shape[0] == 0:
            return None
        bk, bp, n, n_pad = self._prep(keys, payload)
        with key_dtype_context(self.key_dtype):
            if self.mesh is not None:
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as P

                bk = jax.device_put(bk, NamedSharding(self.mesh, P(self.axis)))
                bp = jax.device_put(bp, NamedSharding(self.mesh, P(self.axis)))
            else:
                bk, bp = jax.device_put((bk, bp))
        return StagedChunk(bk=bk, bp=bp, rows=n, rows_padded=n_pad)

    # -- absorbing -------------------------------------------------------

    def _bound(self, rows_padded: int) -> int:
        return _stream_run_slots(self.policy, rows_padded // self.world,
                                 self.cfg.memory_rows)

    def _local_slots(self, chunk_padded: int) -> int:
        """Run slots one chunk can reach: its exact bound + the open slot
        (the absorb scan carries only this window of the store).
        Adaptive streams size the window for the CURRENT arm — the
        traditional arm's unit-M chunk reaches 2 slots, not the
        conservative arm-mix bound the cumulative schedule uses."""
        arm = self._arm if self.policy == "adaptive" else self.policy
        return _stream_run_slots(arm, chunk_padded // self.world,
                                 self.cfg.memory_rows) + 1

    def _bound_total(self, rows_since_baseline: int) -> int:
        """Slot bound honouring the eviction baseline: live runs present
        at the last evict (``_base_slots``, with finish slack) plus the
        input-over-memory bound of the rows absorbed since."""
        return self._base_slots + self._bound(rows_since_baseline)

    def _slots_needed(self, rows_padded_total: int, chunk_padded: int) -> int:
        # the store must cover the cumulative bound AND the local window
        # the next absorb splices at the current high-water mark (the
        # dynamic_update_slice must never clamp over occupied slots)
        prev = rows_padded_total - chunk_padded
        return _pow2_ceil(max(
            self._bound_total(rows_padded_total),
            self._bound_total(prev) + self._local_slots(chunk_padded),
        ))

    def absorb_staged(self, staged: StagedChunk | None) -> None:
        """Absorb a previously staged chunk: one jitted scan dispatch, the
        engine state donated — no host synchronization."""
        if staged is None:
            return
        if self._finalized:
            raise RuntimeError("StreamingAggregator already finalized")
        needed = self._slots_needed(
            self._rows_since_evict + staged.rows_padded, staged.rows_padded
        )
        local = self._local_slots(staged.rows_padded)
        with key_dtype_context(self.key_dtype):
            if self._es is None:
                self._R = needed
                if self.mesh is None:
                    # adaptive: init at the START ARM's native geometry
                    # (self._arm == self.policy for fixed streams)
                    self._es = _engine_init_jit(
                        policy=self._arm,
                        memory_rows=self.cfg.memory_rows,
                        batch_rows=self.cfg.batch_rows,
                        page_rows=self.cfg.page_rows, run_slots=needed,
                        width=self.width, key_dtype=self.key_dtype.name,
                        widths=self.widths,
                    )
                else:
                    self._es = self._fns.init(needed)()
            elif needed > self._R:
                self._R = needed
                if self.mesh is None:
                    self._es = _grow_store(self._es, run_slots=needed)
                else:
                    self._es = self._fns.grow(needed)(self._es)
            if self.mesh is None:
                bk, bp = staged.bk, staged.bp
                arm_chunk = _engine_geometry(
                    self._arm, self.cfg.memory_rows, self.cfg.batch_rows,
                    self.cfg.page_rows)[0]
                if arm_chunk != bk.shape[-1]:
                    # adaptive: chunks are staged at unit-M granularity;
                    # re-batch (a device-side reshape, no transfer) to the
                    # current arm's input granularity.  The batch count is
                    # spelled out because a width-0 payload has zero
                    # elements and cannot infer a -1 dimension.
                    t_arm = bk.shape[0] * (bk.shape[-1] // arm_chunk)
                    bk = bk.reshape(t_arm, arm_chunk)
                    bp = bp.reshape(t_arm, arm_chunk, bp.shape[-1])
                # the observation vector is only harvested at governor
                # boundaries (every k-th chunk), so only the absorb that
                # completes an interval pays for emitting it — the other
                # k-1 chunks run the same program a fixed-policy stream
                # does (two jit cache entries per arm, not 2x compiles
                # per chunk)
                want_obs = (
                    self._governor is not None
                    and (self._chunks_absorbed + 1)
                    % self._governor.interval == 0
                )
                out = _absorb_chunk(
                    self._es, bk, bp, policy=self._arm,
                    memory_rows=self.cfg.memory_rows,
                    batch_rows=self.cfg.batch_rows, backend=self.backend,
                    widths=self.widths, local_slots=local,
                    with_obs=want_obs,
                )
                if want_obs:
                    self._es, self._last_obs_vec = out
                else:
                    self._es = out
            else:
                self._es = self._fns.absorb(local)(
                    self._es, staged.bk, staged.bp)
        self.rows_seen += staged.rows
        self.rows_padded += staged.rows_padded
        self._rows_since_evict += staged.rows_padded
        self._chunks_absorbed += 1
        if (self._governor is not None
                and self._chunks_absorbed % self._governor.interval == 0):
            self._maybe_adapt()

    def absorb(self, keys, payload=None) -> None:
        """stage + absorb in one call (no overlap — prefer the staged
        protocol or :func:`aggregate_device_stream` for throughput)."""
        self.absorb_staged(self.stage(keys, payload))

    # -- adaptive policy switching ----------------------------------------

    @property
    def arm(self) -> str:
        """The concrete run-generation policy the next absorb will use
        (== ``policy`` for fixed-policy streams)."""
        return self._arm

    def observe(self):
        """Read the engine's decision scalars back (ONE explicit
        ``jax.device_get`` of a 5-int vector — counted in
        ``readbacks_paid``).  Returns a
        :class:`repro.core.adaptive.Observation`."""
        from repro.core import adaptive as adaptive_mod

        if self._es is None:
            return adaptive_mod.Observation(0, 0, 0, 0, 0)
        with key_dtype_context(self.key_dtype):
            vec = jax.device_get(_observe(self._es))
        self.readbacks_paid += 1
        return adaptive_mod.Observation(
            rows_absorbed=int(vec[0]), dup_rows=int(vec[1]),
            rows_spilled=int(vec[2]), table_rows=int(vec[3]),
            run_slots_used=int(vec[4]),
        )

    def _maybe_adapt(self) -> None:
        """Governor boundary: harvest the observation that rode out of
        the PREVIOUS boundary's absorb (its chunk retired an interval
        ago, so the explicit ``device_get`` returns without draining the
        dispatch queue), keep this boundary's for the next one, and ask
        the governor for the next arm.  Pipelining the readback costs
        the governor one interval of decision lag but keeps the ingest
        loop free of host→device sync bubbles; only an actual switch
        pays a fresh synchronous :meth:`observe` (its slot re-anchor
        needs the current high-water mark, not the lagged one)."""
        from repro.core import adaptive as adaptive_mod

        pending = self._pending_obs
        self._pending_obs = self._last_obs_vec
        if pending is None:
            return  # first boundary: the observation pipeline is priming
        vec = jax.device_get(pending)
        self.readbacks_paid += 1
        obs = adaptive_mod.Observation(
            rows_absorbed=int(vec[0]), dup_rows=int(vec[1]),
            rows_spilled=int(vec[2]), table_rows=int(vec[3]),
            run_slots_used=int(vec[4]),
        )
        self._last_dup_rate = obs.duplicate_rate
        nxt = self._governor.decide(obs, current=self._arm)
        if nxt != self._arm:
            obs_now = self.observe()  # fresh + synchronous, counted
            self._last_dup_rate = obs_now.duplicate_rate
            self._switch_arm(nxt, obs_now)

    def _switch_arm(self, to: str, obs) -> None:
        """Transition the engine to arm ``to``: close the open rs run,
        flush the resident tables as one closed run (a donated in-place
        program), re-shape the state to ``to``'s native geometry (tables
        re-allocated at the new capacity, store ratcheted wider if
        needed), and re-anchor the host's run-slot accounting at the
        observed high-water mark (the flushed runs can carry < M rows,
        so input-over-memory alone no longer bounds the slot count)."""
        with key_dtype_context(self.key_dtype):
            self._es = _switch_flush(self._es, policy=self._arm,
                                     backend=self.backend)
            _, C_to, capT_to, capT2_to = _engine_geometry(
                to, self.cfg.memory_rows, self.cfg.batch_rows,
                self.cfg.page_rows)
            C_new = max(C_to, self._es.slot_rows)
            if (C_new != self._es.slot_rows
                    or capT_to != self._es.table.capacity
                    or capT2_to != self._es.table2.capacity):
                self._es = _switch_reshape(
                    self._es, slot_rows=C_new, capT=capT_to,
                    capT2=capT2_to, width=self.width, widths=self.widths)
        self.policy_events.append({
            "rows_seen": self.rows_seen,
            "from": self._arm,
            "to": to,
            "duplicate_rate": round(obs.duplicate_rate, 4),
        })
        self._arm = to
        # observed ridx + ≤2 transition runs + the rs finish slack
        self._base_slots = int(obs.run_slots_used) + 2 + 4
        self._rows_since_evict = 0
        self._pending_obs = None  # observed the pre-flush state: stale
        self._last_obs_vec = None

    def _patch_stats(self, stats: SpillStats) -> SpillStats:
        """Surface the adaptive observation block on the host stats.
        Fixed-policy streams that never observed return ``stats``
        unchanged, preserving exact as_dict parity with the one-shot
        pipeline."""
        if self._governor is None and not self.readbacks_paid:
            return stats
        return dataclasses.replace(
            stats,
            duplicate_rate=self._last_dup_rate,
            policy_switches=len(self.policy_events),
            readbacks_paid=self.readbacks_paid,
        )

    def wait(self) -> None:
        """Block until every dispatched absorb/switch has completed on
        device (benchmark phase boundaries; never needed for
        correctness)."""
        if self._es is not None:
            jax.block_until_ready(jax.tree.leaves(self._es))

    # -- finalizing ------------------------------------------------------

    def finalize_device(self) -> tuple[AggState, DeviceSpillStats]:
        """Drain + pre-merge + wide merge (+ mesh exchange).  Returns
        device values and performs NO host sync — the transfer-guard-safe
        half of :meth:`finalize`.  Consumes (donates) the engine state."""
        if self._finalized:
            raise RuntimeError("StreamingAggregator already finalized")
        self._finalized = True
        if self._es is None:  # nothing absorbed: empty result
            with key_dtype_context(self.key_dtype):
                return (
                    empty_state(0, self.width, key_dtype=self.key_dtype,
                                widths=self.widths),
                    DeviceSpillStats.zeros(),
                )
        pre, out_cap, trim = self._merge_plan(bucketed=False)
        es, self._es = self._es, None
        return self._run_merge(es, pre, out_cap, trim)

    def _retry_capacity(self, entry_point: str, err: Exception, es,
                        pre: int, out_cap: int, trim: int):
        """The wide merge dropped rows: re-run the (non-donating) merge
        program ONCE with the output capacity at the next pow2 and one
        more pre-merge level (fewer, bigger runs also shrink the merge
        index's resident width).  Loud by design; a second overflow
        propagates."""
        out_cap2 = _pow2_ceil(out_cap + 1)
        _log.warning(
            "%s overflowed its out_capacity=%d (%s); retrying once at "
            "out_capacity=%d with %d pre-merge levels",
            entry_point, out_cap, err, out_cap2, pre + 1,
        )
        state, dstats = self._run_merge(es, pre + 1, out_cap2, trim)
        return state, dstats.finalize(entry_point=entry_point)

    def _retry_exchange(self, entry_point: str, err, es,
                        pre: int, out_cap: int, trim: int):
        """The mesh exchange's capacity-bounded quota was too small for
        the data's skew: re-run the (non-donating) merge + exchange
        program ONCE at the next pow2 quota, capped at the statically
        lossless per-shard output capacity.  Loud by design; a second
        overflow propagates (same contract as :meth:`_retry_capacity`)."""
        quota2 = min(_pow2_ceil(err.quota + 1), _pow2_ceil(out_cap))
        if quota2 <= err.quota:
            raise err  # already at the lossless ceiling
        _log.warning(
            "%s exchange overflowed its per-peer quota=%d (fullest "
            "segment %d rows); retrying once at quota=%d",
            entry_point, err.quota, err.max_fill, quota2,
        )
        state, dstats = self._run_merge(es, pre, out_cap, trim,
                                        exchange_quota=quota2)
        stats = dstats.finalize(entry_point=entry_point)
        return state, dataclasses.replace(
            stats, exchange_retries=stats.exchange_retries + 1)

    def finalize(self) -> tuple[AggState, SpillStats]:
        """:meth:`finalize_device` + the ONE host readback of spill stats
        (raises loudly on run-buffer overflow; a merge-output overflow —
        or, on a mesh, an exchange-quota overflow — is retried once at
        the next pow2 before raising)."""
        if self._finalized:
            raise RuntimeError("StreamingAggregator already finalized")
        if self._es is None:  # nothing absorbed: empty result
            state, dstats = self.finalize_device()
            return state, self._patch_stats(dstats.finalize())
        pre, out_cap, trim = self._merge_plan(bucketed=False)
        es, self._es = self._es, None
        self._finalized = True
        state, dstats = self._run_merge(es, pre, out_cap, trim)
        try:
            stats = dstats.finalize()
        except ExchangeOverflowError as e:
            state, stats = self._retry_exchange(
                "finalize", e, es, pre, out_cap, trim)
        except MergeOverflowError as e:
            state, stats = self._retry_capacity(
                "finalize", e, es, pre, out_cap, trim)
        return state, self._patch_stats(stats)

    # -- merge-on-read snapshots + eviction (the service protocol) -------

    def _merge_plan(self, *, bucketed: bool) -> tuple[int, int, int]:
        """Static merge-phase plan ``(premerge_levels, out_capacity,
        trim)``.  ``bucketed`` pow2-buckets the capacity statics so a
        long-lived session's periodic snapshots hit O(log N) compiled
        programs instead of one per snapshot; pre-merge levels are always
        planned from the EXACT slot bound (extra all-EMPTY trim slots are
        merge no-ops and never perturb stats, but the level plan itself
        must match the one-shot pipeline's for stats parity)."""
        from repro.core.insort import plan_pre_merge_levels  # lazy: cycle

        est = (self.cfg.memory_rows * self.cfg.fanin
               if self.output_estimate is None else self.output_estimate)
        rows_loc = self.rows_padded // self.world
        r_static = self._bound_total(self._rows_since_evict)
        pre = plan_pre_merge_levels(est, self.cfg, r_static)
        if bucketed:
            out_cap = self.output_rows or _pow2_ceil(max(1, rows_loc))
            trim = min(_pow2_ceil(r_static), self._R)
        else:
            out_cap = max(1, self.output_rows or rows_loc)
            trim = min(r_static, self._R)  # merge the exact bound, not pow2
        return pre, out_cap, trim

    def _run_merge(self, es, pre: int, out_cap: int, trim: int,
                   exchange_quota: int | None = None):
        """Dispatch the (non-donating) drain + merge program on ``es``.
        ``exchange_quota`` overrides the mesh exchange's derived per-peer
        quota (the :meth:`_retry_exchange` path)."""
        with key_dtype_context(self.key_dtype):
            if self.mesh is None:
                return _finalize_stream(
                    es, self._retired, policy=self._arm,
                    page_rows=self.cfg.page_rows, index_rows=self.index_rows,
                    fanin=self.cfg.fanin, premerge_levels=pre,
                    backend=self.backend, out_capacity=out_cap, trim=trim,
                )
            if self._retired is None:
                return self._fns.finalize(
                    pre, out_cap, trim, False, exchange_quota)(es)
            return self._fns.finalize(
                pre, out_cap, trim, True, exchange_quota)(es, self._retired)

    def snapshot_device(self) -> tuple[AggState, DeviceSpillStats]:
        """Merge-on-read snapshot: answer the current aggregate WITHOUT
        consuming the engine.

        Runs the same statically planned drain + pre-merge + wide merge
        program as :meth:`finalize_device` — it is non-donating and emits
        into a fresh output buffer, so the live engine state is untouched
        (byte-for-byte) and ingest continues afterwards.  Zero host
        syncs; snapshot dispatch is ordered before any subsequent donated
        absorb by JAX's program-order execution, so overlapping ingest is
        safe.  Capacity statics are pow2-bucketed to bound compile count
        over a session's lifetime."""
        if self._finalized:
            raise RuntimeError("StreamingAggregator already finalized")
        if self._es is None:  # nothing absorbed (or created) yet
            with key_dtype_context(self.key_dtype):
                return (
                    empty_state(0, self.width, key_dtype=self.key_dtype,
                                widths=self.widths),
                    DeviceSpillStats.zeros(),
                )
        pre, out_cap, trim = self._merge_plan(bucketed=True)
        return self._run_merge(self._es, pre, out_cap, trim)

    def snapshot(self) -> tuple[AggState, SpillStats]:
        """:meth:`snapshot_device` + the host readback of spill stats
        (overflow errors name the snapshot entry point; a merge-output
        overflow is retried once at the next pow2 capacity — legal
        because the snapshot program never consumes the live state)."""
        state, dstats = self.snapshot_device()
        try:
            stats = dstats.finalize(entry_point="snapshot")
        except ExchangeOverflowError as e:
            pre, out_cap, trim = self._merge_plan(bucketed=True)
            state, stats = self._retry_exchange(
                "snapshot", e, self._es, pre, out_cap, trim)
        except MergeOverflowError as e:
            pre, out_cap, trim = self._merge_plan(bucketed=True)
            state, stats = self._retry_capacity(
                "snapshot", e, self._es, pre, out_cap, trim)
        return state, self._patch_stats(stats)

    def evict_below(self, threshold) -> int:
        """Retire every resident row whose key is ``< threshold`` from
        the live engine (TTL / watermark eviction for sessionization).

        Keys are retired from the run store AND the resident tables by
        sorted prefix cuts, surviving runs are compacted to the store
        prefix, and the host re-anchors its slot accounting at the new
        high-water mark — this is the ONE host sync of the service
        protocol (a single scalar readback at the eviction boundary).
        Retired rows are accumulated device-side and surface as
        ``SpillStats.rows_retired`` on every later snapshot/finalize:
        nothing is silently dropped.  Returns the cumulative retired-row
        count."""
        if self._finalized:
            raise RuntimeError("StreamingAggregator already finalized")
        thr = int(threshold)
        if not (0 <= thr <= int(max_key(self.key_dtype))):
            raise ValueError(
                f"eviction threshold {threshold!r} out of range for "
                f"{self.key_dtype} keys (must be <= max_key, below the "
                f"EMPTY sentinel)"
            )
        if self._es is None:  # nothing resident: nothing to retire
            return 0 if self._retired is None else int(
                np.sum(np.asarray(self._retired)))
        with key_dtype_context(self.key_dtype):
            if self.mesh is None:
                thr_dev = jax.device_put(np.asarray(thr, self.key_dtype))
                self._es, self._retired = _evict_compact(
                    self._es, thr_dev, self._retired, policy=self.policy,
                    backend=self.backend,
                )
                new_ridx = int(self._es.ridx)
            else:
                from jax.sharding import NamedSharding
                from jax.sharding import PartitionSpec as P

                thr_dev = jax.device_put(
                    np.asarray(thr, self.key_dtype),
                    NamedSharding(self.mesh, P()),
                )
                args = (() if self._retired is None else (self._retired,))
                self._es, self._retired, ridx_max = self._fns.evict(
                    self._retired is not None
                )(self._es, thr_dev, *args)
                new_ridx = int(ridx_max)
        slack = {"traditional": 0, "inrun_dedup": 0,
                 "early_agg": 2, "rs": 4, "adaptive": 6}[self.policy]
        self._base_slots = new_ridx + slack
        self._rows_since_evict = 0
        return int(np.sum(np.asarray(self._retired)))


def _as_chunk(c):
    """Normalize one element of a chunk stream to ``(keys, payload)``."""
    if isinstance(c, (tuple, list)):
        if len(c) != 2:
            raise ValueError(
                "chunk must be a keys array or a (keys, payload) pair, got "
                f"a {type(c).__name__} of length {len(c)}"
            )
        return c[0], c[1]
    return c, None


def rebatch_chunks(chunks, rows: int):
    """Re-chunk an iterable of ``keys`` / ``(keys, payload)`` chunks into
    ``rows``-row super-batches (host NumPy — the chunked source adapter
    for arbitrary-granularity producers).  The final partial super-batch
    is yielded as-is."""
    if rows <= 0:
        raise ValueError(f"rows must be positive, got {rows}")
    kbuf: list[np.ndarray] = []
    pbuf: list = []
    have = 0
    for c in chunks:
        k, p = _as_chunk(c)
        k = np.asarray(k)
        if k.shape[0] == 0:
            continue
        kbuf.append(k)
        pbuf.append(None if p is None else np.asarray(p))
        have += k.shape[0]
        while have >= rows:
            keys = np.concatenate(kbuf) if len(kbuf) > 1 else kbuf[0]
            if any(p is None for p in pbuf):
                pay = None
            else:
                pb = [p[:, None] if p.ndim == 1 else p for p in pbuf]
                pay = np.concatenate(pb) if len(pb) > 1 else pb[0]
            yield keys[:rows], None if pay is None else pay[:rows]
            kbuf = [keys[rows:]] if keys.shape[0] > rows else []
            pbuf = [pay[rows:]] if (pay is not None and keys.shape[0] > rows) \
                else ([None] * len(kbuf))
            have -= rows
    if have:
        keys = np.concatenate(kbuf) if len(kbuf) > 1 else kbuf[0]
        if any(p is None for p in pbuf):
            pay = None
        else:
            pb = [p[:, None] if p.ndim == 1 else p for p in pbuf]
            pay = np.concatenate(pb) if len(pb) > 1 else pb[0]
        yield keys, pay


def aggregate_device_stream(
    chunks,
    cfg: ExecConfig | None = None,
    *,
    policy: str = "rs",
    backend: str = "auto",
    widths: tuple[int, int, int] | None = None,
    key_dtype=None,
    width: int | None = None,
    index_rows: int | None = None,
    output_estimate: int | None = None,
    output_rows: int | None = None,
    super_batch_rows: int | None = None,
    mesh=None,
    mesh_axis: str | None = None,
    governor=None,
) -> tuple[AggState, DeviceSpillStats]:
    """The streamed, double-buffered twin of :func:`aggregate_device`:
    aggregate an input that never needs to be device- (or even host-)
    resident at once.

    ``chunks`` is an iterable/generator of ``keys`` arrays or
    ``(keys, payload)`` pairs (host NumPy).  Each chunk is staged with an
    explicit ``jax.device_put`` *before* the previous chunk's absorb is
    dispatched, so the k+1 transfer overlaps the k compute (JAX async
    dispatch); the device carries one engine state (donated between
    steps) plus at most two staged chunks — the peak device footprint is
    bounded by the super-batch size, not N.  ``super_batch_rows``
    re-chunks the stream to that many rows per absorb (default: chunks
    are absorbed as produced).

    ``key_dtype`` / ``width`` pin the stream's schema; by default they
    are inferred from the first chunk.  ``output_rows`` bounds the merge
    output capacity (device bytes) when the unique-key count is known to
    be far below the input size; an under-estimate is flagged loudly via
    ``merge_dropped_rows`` — never a silent truncation.

    Returns ``(state, DeviceSpillStats)`` with zero host syncs performed;
    see :func:`insort_aggregate_device_stream` for the finalized-stats
    variant.  Exact parity: for any chunking whose chunk sizes are
    multiples of the engine's input batch (``memory_rows`` for the
    read-sort-write policies, ``batch_rows`` for early-agg/RS), the
    result state AND SpillStats are identical to the one-shot pipeline
    on the concatenated input — EMPTY-padded batches are no-ops in every
    policy.
    """
    cfg = cfg or ExecConfig()
    agg, stream = _stream_setup(
        chunks, cfg, policy=policy, backend=backend, widths=widths,
        key_dtype=key_dtype, width=width, index_rows=index_rows,
        output_estimate=output_estimate, output_rows=output_rows,
        super_batch_rows=super_batch_rows, mesh=mesh, mesh_axis=mesh_axis,
        governor=governor,
    )
    if agg is None:  # empty stream
        return stream
    staged = None
    for keys, payload in stream:
        nxt = agg.stage(keys, payload)  # H2D of k+1 in flight while …
        if staged is not None:
            agg.absorb_staged(staged)  # … the device absorbs chunk k
        staged = nxt
    agg.absorb_staged(staged)
    return agg.finalize_device()


def _stream_setup(
    chunks,
    cfg: ExecConfig,
    *,
    policy: str = "rs",
    backend: str = "auto",
    widths=None,
    key_dtype=None,
    width=None,
    index_rows=None,
    output_estimate=None,
    output_rows=None,
    super_batch_rows=None,
    mesh=None,
    mesh_axis=None,
    governor=None,
):
    """Shared stream-driver setup: peek the first non-empty chunk to fix
    the schema, build the aggregator.  Returns ``(agg, stream)``; for an
    empty stream ``agg`` is None and ``stream`` is the empty
    ``(state, DeviceSpillStats)`` result."""
    it = iter(chunks)
    first = None
    for c in it:
        k, p = _as_chunk(c)
        if np.asarray(k).shape[0]:
            first = (np.asarray(k), p)
            break
    if first is None:  # empty stream: mirror the one-shot empty early-out
        kd = np.dtype(key_dtype or np.uint32)
        w = int(width or 0)
        with key_dtype_context(kd):
            return None, (
                empty_state(0, w, key_dtype=kd, widths=widths),
                DeviceSpillStats.zeros(),
            )
    if key_dtype is None:
        key_dtype = rg._np_keys(first[0]).dtype
    if width is None:
        if first[1] is None:
            width = 0
        else:
            p0 = np.asarray(first[1])
            width = 1 if p0.ndim == 1 else p0.shape[1]
    stream = itertools.chain([first], (_as_chunk(c) for c in it))
    if super_batch_rows:
        stream = rebatch_chunks(stream, super_batch_rows)
    agg = StreamingAggregator(
        cfg, policy=policy, key_dtype=key_dtype, width=width, widths=widths,
        backend=backend, index_rows=index_rows,
        output_estimate=output_estimate, output_rows=output_rows,
        mesh=mesh, mesh_axis=mesh_axis, governor=governor,
    )
    return agg, stream


def insort_aggregate_device_stream(
    chunks, cfg: ExecConfig | None = None, **kw
) -> tuple[AggState, SpillStats]:
    """:func:`aggregate_device_stream` + the one host readback of spill
    stats — the streamed twin of :func:`insort_aggregate_device`.

    ``policy="adaptive"`` streams cannot use this one-dispatch form's
    device-only return (the governor needs its periodic readbacks
    anyway), so they are driven through the same loop but finalized with
    the retrying host path and observation-annotated stats."""
    if kw.get("policy") == "adaptive":
        cfg = cfg or ExecConfig()
        agg, stream = _stream_setup(chunks, cfg, **kw)
        if agg is None:  # empty stream
            state, dstats = stream
            return state, dstats.finalize()
        staged = None
        for keys, payload in stream:
            nxt = agg.stage(keys, payload)
            if staged is not None:
                agg.absorb_staged(staged)
            staged = nxt
        agg.absorb_staged(staged)
        return agg.finalize()
    state, dstats = aggregate_device_stream(chunks, cfg, **kw)
    return state, dstats.finalize()


# ---------------------------------------------------------------------------
# mesh-sharded streaming: the same engine under shard_map
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _mesh_stream_fns(
    mesh,
    axis: str,
    *,
    policy: str,
    memory_rows: int,
    batch_rows: int,
    page_rows: int,
    index_rows: int,
    fanin: int,
    backend: str,
    widths,
    width: int,
    key_dtype_name: str,
):
    """Jitted shard_map programs advancing a PER-SHARD engine state:
    ``init(R)()``, ``absorb(es, bk, bp)``, ``grow(R)(es)``, and
    ``finalize(premerge_levels, out_capacity)(es)`` (per-shard drain +
    merge, then the key-range exchange + per-owner merge of the sharded
    one-shot pipeline).  Scalar engine leaves are carried (1,)-shaped so
    every leaf has a shardable leading axis
    (:func:`~repro.core.types.expand_engine_scalars`)."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed import groupby as gb_mod
    from repro.distributed._compat import shard_map

    kd = np.dtype(key_dtype_name)
    world = mesh.shape[axis]
    agg_spec = AggState(
        keys=P(axis), count=P(axis), sum=P(axis, None),
        min=P(axis, None), max=P(axis, None),
    )
    store_spec = AggState(
        keys=P(axis, None), count=P(axis, None), sum=P(axis, None, None),
        min=P(axis, None, None), max=P(axis, None, None),
    )
    state_spec = StreamEngineState(
        table=agg_spec, table2=agg_spec, frontier=P(axis), store=store_spec,
        lens=P(axis), cursor=P(axis), ridx=P(axis), spilled=P(axis),
        absorbed=P(axis), dups=P(axis),
    )
    n_stats = len(dataclasses.fields(DeviceSpillStats))

    @functools.lru_cache(maxsize=None)
    def init_fn(run_slots: int):
        def body():
            es = _engine_init(
                policy, M=memory_rows, B=batch_rows, P=page_rows,
                R=run_slots, width=width, key_dtype=kd, widths=widths,
            )
            return expand_engine_scalars(es)

        return jax.jit(shard_map(
            body, mesh=mesh, in_specs=(), out_specs=state_spec,
        ))

    @functools.lru_cache(maxsize=None)
    def absorb_fn(local_slots: int):
        def body(es, bk, bp):
            es = _absorb_chunk_body(
                squeeze_engine_scalars(es), bk, bp, policy=policy,
                memory_rows=memory_rows, batch_rows=batch_rows,
                backend=backend, widths=widths, local_slots=local_slots,
            )
            return expand_engine_scalars(es)

        return jax.jit(
            shard_map(
                body, mesh=mesh,
                in_specs=(state_spec, P(axis, None), P(axis, None, None)),
                out_specs=state_spec,
            ),
            donate_argnums=(0,),
        )

    @functools.lru_cache(maxsize=None)
    def grow_fn(run_slots: int):
        def body(es):
            es = squeeze_engine_scalars(es)
            store, lens = _pad_slots(es.store, es.lens, run_slots)
            return expand_engine_scalars(
                dataclasses.replace(es, store=store, lens=lens)
            )

        # no donation: shapes change across the grow
        return jax.jit(
            shard_map(body, mesh=mesh, in_specs=(state_spec,),
                      out_specs=state_spec),
        )

    @functools.lru_cache(maxsize=None)
    def finalize_fn(premerge_levels: int, out_capacity: int, trim: int,
                    with_retired: bool = False,
                    exchange_quota: int | None = None):
        def body(es, *rest):
            es = _trim_slots(squeeze_engine_scalars(es), trim)
            fresh_out = empty_state(out_capacity, width, key_dtype=kd,
                                    widths=widths)
            store, lens, table, spilled, nruns, overflow = _engine_finish(
                es, policy=policy, backend=backend
            )
            # per-shard retired rows go into the stats BEFORE cross_shard
            # psums them into the global total
            retired = rest[0][0] if with_retired else None
            out, dstats = _merge_phase(
                store, lens, spilled, nruns, overflow, page_rows=page_rows,
                index_rows=index_rows, fanin=fanin,
                premerge_levels=premerge_levels, backend=backend,
                out_capacity=out_capacity, rows_retired=retired,
                out_buffer=fresh_out,
            )
            merged, ex = gb_mod.exchange_and_merge(
                out, axis, world, backend=backend, quota=exchange_quota,
                page_rows=page_rows,
            )
            dstats = dataclasses.replace(
                dstats,
                merge_dropped_rows=dstats.merge_dropped_rows | ex.merge_dropped,
                rows_exchanged=ex.rows_sent,
                exchange_dropped=ex.send_dropped,
                exchange_quota=jnp.int32(ex.quota),
                exchange_max_fill=ex.max_fill,
            )
            return merged, dstats.cross_shard(axis)

        in_specs = (state_spec,) + ((P(axis),) if with_retired else ())
        # no donation: outputs don't share the state leaves' shapes —
        # which is also what makes this program double as the per-shard
        # merge-on-read snapshot (the live state survives the call)
        return jax.jit(
            shard_map(
                body, mesh=mesh, in_specs=in_specs,
                out_specs=(agg_spec, DeviceSpillStats(*(P(),) * n_stats)),
            ),
        )

    @functools.lru_cache(maxsize=None)
    def evict_fn(with_retired: bool):
        def body(es, thr, *rest):
            es, retired = _evict_compact_body(
                squeeze_engine_scalars(es), thr,
                rest[0][0] if with_retired else None,
                policy=policy, backend=backend,
            )
            ridx_max = jax.lax.pmax(es.ridx, axis)
            return expand_engine_scalars(es), retired[None], ridx_max

        in_specs = ((state_spec, P())
                    + ((P(axis),) if with_retired else ()))
        return jax.jit(
            shard_map(
                body, mesh=mesh, in_specs=in_specs,
                out_specs=(state_spec, P(axis), P()),
            ),
            donate_argnums=(0,),
        )

    class _Fns:
        pass

    fns = _Fns()
    fns.init = init_fn
    fns.absorb = absorb_fn
    fns.grow = grow_fn
    fns.finalize = finalize_fn
    fns.evict = evict_fn
    return fns
