"""shard_map across jax versions.

Newer jax exposes ``jax.shard_map`` (with ``check_vma=``); 0.4.x only has
``jax.experimental.shard_map.shard_map`` (with ``check_rep=``).  All
distributed modules import :func:`shard_map` from here.
"""
from __future__ import annotations

import jax

try:
    _impl = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _impl

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check: bool | None = None):
    """Wrap ``f`` with shard_map; ``check=False`` disables the replication
    /varying-manual-axes check under whichever name this jax spells it."""
    kw = {} if check is None else {_CHECK_KW: check}
    try:
        return _impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    except TypeError:
        if check is None:
            raise
        other = "check_rep" if _CHECK_KW == "check_vma" else "check_vma"
        return _impl(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{other: check}
        )
