"""shard_map across jax versions.

Newer jax exposes ``jax.shard_map`` (with ``check_vma=``); 0.4.x only has
``jax.experimental.shard_map.shard_map`` (with ``check_rep=``).  All
distributed modules import :func:`shard_map` from here.

The version gate also owns the replication-check DEFAULT, so call sites
never hard-code ``check=False``:

* 0.4.x ``check_rep`` has no replication rule for ``lax.while_loop``
  (probed: ``check_rep=True`` over the wide merge's page loop fails with
  ``NotImplementedError: No replication rule for while``), and every
  sharded pipeline here carries one — so the default is OFF.  The stats
  out_specs those programs return under ``P()`` are truly replicated
  anyway (explicit psum/pmax before the return).
* ``jax.shard_map``'s ``check_vma`` system handles control flow, so on
  new-enough jax the default is ON (the checker is free correctness
  coverage).  This is the "drop check_rep=False when the jax version is
  bumped" ROADMAP item: bumping jax flips the default here, with no call
  sites to chase.
"""
from __future__ import annotations

import jax

try:
    _impl = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _impl

    _CHECK_KW = "check_rep"

# None = the jax default (on for check_vma); False = forced off for the
# 0.4.x check_rep that cannot handle while_loop bodies.
_CHECK_DEFAULT: bool | None = None if _CHECK_KW == "check_vma" else False

_UNSET = object()


def shard_map(f, *, mesh, in_specs, out_specs, check=_UNSET):
    """Wrap ``f`` with shard_map.  ``check`` overrides the version-gated
    replication/varying-manual-axes check default (see module docstring)
    under whichever keyword this jax spells it; ``check=None`` forces the
    installed jax's own default."""
    if check is _UNSET:
        check = _CHECK_DEFAULT
    kw = {} if check is None else {_CHECK_KW: check}
    try:
        return _impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    except TypeError:
        if check is None:
            raise
        other = "check_rep" if _CHECK_KW == "check_vma" else "check_vma"
        return _impl(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{other: check}
        )
