"""Expert-parallel MoE dispatch — the paper's sort-based grouping as the
production routing engine (shard_map + all_to_all).

Dense one-hot dispatch materializes an (E, T, D) tensor — at deepseek scale
(E=256, T=1M, D=7168) that is 3.7 TB per layer and simply cannot exist.
The sort-based pipeline is the scalable form, and it is exactly the
paper's algorithm applied to routing:

  per device (data-parallel shard of tokens; "model" axis = 16-way EP):
  1. run generation (§3): key-sort local (token, expert) pairs by expert
     id → contiguous per-expert segments, capacity-clamped to C rows
     (fixed shapes; overflow rows drop, like any capacity-factor MoE);
  2. partition ≡ sort (§2.1): the sorted layout reshapes directly into
     (EP_peers, E_local, C, D) — the all_to_all send buffer needs no
     further shuffling because key-range partitioning of a sorted stream
     is a reshape;
  3. all_to_all over "model": each peer receives its 16 experts' rows;
  4. grouped expert FFN on (E_local, peers·C, D) — contiguous blocks, the
     grouped-matmul kernel's layout;
  5. all_to_all back + combine: a weighted aggregation keyed by original
     token position (§4's merge-with-aggregation, scatter-add form).

  Token chunking: the dispatch runs as a lax.scan over token chunks so
  send/recv buffers stay ~(T_chunk·k·cf·D) — production MoEs micro-batch
  the dispatch the same way.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig

from repro.distributed._compat import shard_map


def _local_sorted_dispatch(x_flat, eidx, w, e: int, cap: int):
    """Sort-based grouping of local rows by expert id (paper §3).

    x_flat (T, D); eidx/w (T,) — returns (slots (T,), keep (T,), xs (E*C, D))
    where xs rows are expert-contiguous, capacity-padded."""
    t, d = x_flat.shape
    order = jnp.argsort(eidx * t + jnp.arange(t, dtype=eidx.dtype))  # stable
    se = eidx[order]
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")
    rank = jnp.arange(t) - seg_start[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)
    xs = jnp.zeros((e * cap + 1, d), x_flat.dtype).at[slot].set(
        x_flat[order], mode="drop")[:-1]
    return order, slot, keep, xs


def make_ep_moe(mesh, dp_axes: tuple, ep_axis: str = "model"):
    """Returns moe_fn(params, x, cfg) implementing sorted EP dispatch.

    x (B, S, D) with batch sharded over dp_axes; experts sharded over
    ep_axis.  Differentiable (gather/scatter/all_to_all transposes)."""
    ep = mesh.shape[ep_axis]

    def _ffn(p, xs):
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["wg"])) * jnp.einsum(
            "ecd,edf->ecf", xs, p["wi"])
        return jnp.einsum("ecf,efd->ecd", h, p["wo"])

    def local_fn(p, x, cfg: ModelConfig):
        # everything here sees LOCAL shards: x (b_loc, S, D); experts
        # p["wi"] (E_loc, D, F)
        m = cfg.moe
        e, k = m.num_experts, m.top_k
        e_loc = e // ep
        b, s, d = x.shape
        logits = (x @ p["router"]["kernel"]).astype(jnp.float32)
        # router weights are replicated row-shards over ep: psum partial? —
        # router kernel is small; sharded (D, E): gather E via all_gather
        logits = jax.lax.all_gather(logits, ep_axis, axis=2, tiled=True)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, k)
        if m.router_scale:
            w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        w = w.astype(x.dtype)
        me = probs.mean(axis=(0, 1))
        frac = jax.nn.one_hot(idx, e).mean(axis=(0, 1, 2))
        aux = e * jnp.sum(me * frac)
        aux = jax.lax.pmean(aux, dp_axes)

        tokens = b * s
        chunk = min(getattr(cfg, "moe_chunk", 8192), tokens)
        n_chunks = tokens // chunk
        x_flat = x.reshape(tokens, d)
        eidx = idx.reshape(tokens, k)
        wflat = w.reshape(tokens, k)
        cap = max(8, int(m.capacity_factor * chunk * k / e + 7) // 8 * 8)

        def chunk_step(_, inp):
            xc, ec, wc = inp  # (chunk, D), (chunk, k), (chunk, k)
            t = chunk * k
            xr = jnp.repeat(xc, k, axis=0)  # row per (token, k)
            er = ec.reshape(t)
            wr = wc.reshape(t)
            order, slot, keep, xs = _local_sorted_dispatch(xr, er, wr, e, cap)
            # sorted layout ≡ range partitioning: reshape → a2a
            send = xs.reshape(ep, e_loc * cap, d)
            recv = jax.lax.all_to_all(send, ep_axis, split_axis=0,
                                      concat_axis=0, tiled=False)
            # recv (ep, e_loc*cap, d): peer j's rows for MY e_loc experts
            xs_loc = (recv.reshape(ep, e_loc, cap, d)
                      .transpose(1, 0, 2, 3).reshape(e_loc, ep * cap, d))
            ys_loc = _ffn(p, xs_loc)
            back = (ys_loc.reshape(e_loc, ep, cap, d)
                    .transpose(1, 0, 2, 3).reshape(ep, e_loc * cap, d))
            ys = jax.lax.all_to_all(back, ep_axis,
                                    split_axis=0, concat_axis=0, tiled=False)
            ys = ys.reshape(e * cap, d)
            # combine: weighted aggregation by original token id (§4)
            contrib = ys[jnp.minimum(slot, e * cap - 1)] * wr[order][:, None]
            contrib = jnp.where(keep[:, None], contrib, 0)
            tok = (jnp.arange(t, dtype=jnp.int32) // k)[order]
            out = jnp.zeros((chunk, d), x.dtype).at[tok].add(contrib)
            return None, out

        xcs = x_flat.reshape(n_chunks, chunk, d)
        ecs = eidx.reshape(n_chunks, chunk, k)
        wcs = wflat.reshape(n_chunks, chunk, k)
        _, outs = jax.lax.scan(jax.checkpoint(chunk_step), None,
                               (xcs, ecs, wcs))
        y = outs.reshape(b, s, d)
        return y, aux

    return local_fn


_CURRENT_MESH = [None]


def set_current_mesh(mesh):
    """Launchers register the concrete mesh here; shard_map needs it."""
    _CURRENT_MESH[0] = mesh


def ep_moe_block(p, cfg: ModelConfig, x, mesh=None):
    """shard_map wrapper used by models/moe.py when dispatch='sorted_ep'."""
    mesh = mesh or _CURRENT_MESH[0]
    assert mesh is not None, "call set_current_mesh(mesh) before tracing"
    dp = tuple(a for a in ("pod", "data") if a in cfg.mesh_axes)
    fn = make_ep_moe(mesh, dp)
    dpspec = dp if len(dp) > 1 else dp[0]
    m = cfg.moe

    pspec = {
        "router": {"kernel": P(None, "model")},
        "wi": P("model", None, None),
        "wg": P("model", None, None),
        "wo": P("model", None, None),
    }

    shard_fn = shard_map(
        functools.partial(_wrapped, fn, cfg),
        mesh=mesh,
        in_specs=(pspec, P(dpspec, None, None)),
        out_specs=(P(dpspec, None, None), P()),
    )
    y, aux = shard_fn({k: p[k] for k in pspec}, x)
    if m.num_shared_experts:
        from repro.models.layers import mlp

        y = y + mlp(p["shared"], x, "swiglu")
    return y, aux


def _wrapped(fn, cfg, p, x):
    y, aux = fn(p, x, cfg)
    return y, aux
