"""Distributed duplicate removal / grouping / aggregation (shard_map).

The cluster-scale form of the paper's operator, using its own §2.1
observation that *sorting and partitioning are the same physical
property*:

  1. local early aggregation (§3): each device absorbs its shard's
     duplicates with the in-memory ordered index — this is the paper's
     intro note that best-effort aggregation **before** re-partitioning
     reduces the shuffle volume;
  2. key-range exchange: the key space splits into `world` contiguous
     ranges; because local outputs are sorted, the send buffer is built
     with two searchsorted cuts, and the all_to_all is the paper's
     "partitioning enforced together with sorting";
  3. local wide merge (§4): each device merges the `world` sorted
     fragments it received — output is locally sorted, and globally
     sorted by (range owner, key): a distributed ORDER BY for free.

The exchange core (:func:`exchange_sorted_fragments`) is shared with the
mesh-sharded device-resident pipeline (:mod:`repro.core.pipeline`), which
runs full external run generation per shard before the same key-range
all_to_all.

Overflow is LOUD: every place a fixed-capacity buffer can cut live rows —
the local-aggregation trim to ``capacity``, the per-peer send quota, and
the post-merge trim back to ``capacity`` — returns a device flag instead
of silently dropping, and :func:`make_distributed_groupby` raises on it
(matching the PR 3 wide merge's ``merge_dropped_rows`` contract).

``sparse_embedding_grad`` applies the same pipeline to embedding-table
gradients: (token, grad) pairs dedup-aggregate locally, then only unique
rows travel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import merge as merge_mod
from repro.core import sorted_ops
from repro.core.types import AggState, empty_key, rows_to_state
from repro.distributed._compat import shard_map


def _range_of(keys, world):
    """Owner of each key under contiguous range partitioning of the key
    dtype's domain (uint32 or uint64)."""
    bits = np.dtype(keys.dtype).itemsize * 8
    span = keys.dtype.type((1 << bits) // world)
    return jnp.minimum(keys // span, world - 1).astype(jnp.int32)


def _local_group_sorted(keys, payload, capacity):
    """Local early aggregation trimmed to ``capacity`` — returns the
    trimmed state plus the live-rows-cut flag (more unique keys in this
    shard's slice than ``capacity`` is row loss, the same as the other
    two overflow sites)."""
    st = sorted_ops.sorted_groupby(keys, payload)
    return merge_mod.trim_to_capacity(st, capacity)


def _fill_like(x):
    if x.dtype in (jnp.uint32, jnp.uint64):
        return empty_key(x.dtype)
    return jnp.zeros((), x.dtype)


def _sample_local_keys(st: AggState, nsamp: int):
    """``nsamp`` evenly spaced keys from a sorted local state's valid
    prefix (all-EMPTY shards contribute EMPTY samples, which rank last)."""
    occ = jnp.maximum(st.occupancy(), 1)
    pos = jnp.minimum((jnp.arange(nsamp) * occ) // nsamp, st.capacity - 1)
    return jnp.take(st.keys, pos)


def sample_range_cuts(states, axis: str, world: int, *, nsamp: int = 64):
    """Sampled key-range partition edges over one or MORE sorted local
    states (sample-sort style).  Each shard contributes a sorted sample
    per state; the gathered sample's quantiles give identical,
    data-driven inner edges — shape ``(world - 1,)`` — on every shard.
    Passing both sides of a join here partitions both relations by the
    SAME cuts, which is what makes the post-exchange per-owner join a
    purely local merge join."""
    sample = jnp.concatenate([_sample_local_keys(st, nsamp) for st in states])
    all_samp = jnp.sort(jax.lax.all_gather(sample, axis).reshape(-1))
    eidx = (jnp.arange(1, world) * all_samp.shape[0]) // world
    return jnp.take(all_samp, eidx)


def exchange_sorted_fragments(st: AggState, axis: str, world: int, *, quota: int,
                              nsamp: int = 64, inner_cuts=None):
    """Key-range ``all_to_all`` of a *sorted, duplicate-free* local state.

    Range boundaries are SAMPLED (sample-sort style): fixed uniform ranges
    collapse under key skew, so each shard contributes a sorted sample of
    its keys; the gathered sample's quantiles give identical, data-driven
    edges on every shard.  Sorted local output ⇒ the per-peer send
    segments are two searchsorted cuts, "partitioning enforced together
    with sorting" (§2.1).  Each peer receives a sorted, EMPTY-padded
    fragment of exactly ``quota`` rows.

    ``inner_cuts`` overrides the sampled edges with precomputed ones
    (shape ``(world - 1,)``, identical on every shard — see
    :func:`sample_range_cuts`): the sharded merge join exchanges BOTH
    sides under one shared cut vector so the two partitionings align.

    Returns ``(recv, rows_sent, send_dropped)``:

    * ``recv`` — AggState of ``world * quota`` rows; rows
      ``[i*quota, (i+1)*quota)`` are peer ``i``'s sorted fragment, and
      fragment key ranges ascend with ``i`` (global order = (owner, key));
    * ``rows_sent`` — valid rows this shard put on the wire (shuffle
      volume; ``psum`` it for the global count);
    * ``send_dropped`` — True iff some send segment exceeded ``quota``
      and live rows were cut.  Callers must surface this loudly; with
      ``quota >= st.capacity`` it is statically impossible.
    """
    capacity = st.capacity
    inner = (sample_range_cuts((st,), axis, world, nsamp=nsamp)
             if inner_cuts is None else inner_cuts)
    cuts = jnp.searchsorted(st.keys, inner, side="left").astype(jnp.int32)
    ends = jnp.concatenate([cuts, jnp.asarray([capacity], jnp.int32)])
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), cuts])
    # segment i = rows [starts[i], ends[i]) of the sorted local state; the
    # EMPTY tail beyond occupancy lands in the last segment and pads it.
    seg_valid = jnp.minimum(ends, st.occupancy()) - jnp.minimum(
        starts, st.occupancy()
    )
    rows_sent = jnp.sum(seg_valid, dtype=jnp.int32)
    send_dropped = jnp.any(seg_valid > quota)
    idx = starts[:, None] + jnp.arange(quota, dtype=jnp.int32)[None, :]
    valid_send = idx < ends[:, None]
    idx = jnp.minimum(idx, capacity - 1)

    def gather_rows(x):
        g = jnp.take(x, idx.reshape(-1), axis=0)
        mask = valid_send.reshape(-1)
        return jnp.where(mask.reshape((-1,) + (1,) * (g.ndim - 1)),
                         g, _fill_like(x))

    send = jax.tree.map(gather_rows, st)
    recv = jax.tree.map(
        lambda x: jax.lax.all_to_all(
            x.reshape((world, quota) + x.shape[1:]), axis, 0, 0,
            tiled=False,
        ).reshape((world * quota,) + x.shape[1:]),
        send,
    )
    return recv, rows_sent, send_dropped


def exchange_and_merge(st: AggState, axis: str, world: int, *,
                       backend: str = "auto"):
    """Key-range exchange + per-owner merge of a sorted, duplicate-free
    local state — the shared tail of the mesh-sharded pipelines: the
    one-shot finalize, the streamed finalize, AND the service's
    merge-on-read snapshot all run this same program over their
    per-shard merge output (the snapshot feeds it a fresh buffer, so
    exchanging never perturbs the live per-shard engine states).  The
    per-peer quota is the full local capacity, so the exchange can never
    cut live rows.

    Returns ``(merged, rows_sent, send_dropped)``: the merged state at
    capacity ``world * capacity``, the valid rows this shard put on the
    wire, and the (statically impossible, defensively surfaced) quota
    overflow flag."""
    quota = st.capacity
    recv, rows_sent, send_dropped = exchange_sorted_fragments(
        st, axis, world, quota=quota
    )
    merged = merge_received_fragments(recv, world, quota, backend=backend)
    return merged, rows_sent, send_dropped


def merge_received_fragments(recv: AggState, world: int, quota: int, *,
                             backend: str = "auto"):
    """Local wide merge of the ``world`` sorted fragments an
    :func:`exchange_sorted_fragments` shard received: a balanced tree of
    linear merge-absorbs (§3.4) — each fragment is sorted, duplicate-free
    and EMPTY-padded, so no re-sort is ever needed.  Returns the merged
    state at capacity ``world * quota`` (trim + loud-overflow is the
    caller's policy, see :func:`repro.core.merge.trim_to_capacity`)."""
    frags = [
        jax.tree.map(lambda x: x[i * quota : (i + 1) * quota], recv)
        for i in range(world)
    ]
    return sorted_ops.merge_absorb_many(frags, backend=backend,
                                        assume_unique=True)


def sharded_merge_join_local(a: AggState, b: AggState, axis: str, world: int,
                             *, how: str = "inner", backend: str = "xla",
                             nsamp: int = 64):
    """Per-shard body of the mesh-sharded merge join (call inside
    ``shard_map``; both inputs are this shard's sorted, duplicate-free,
    EMPTY-tailed slices of globally sorted relations).

    Sharded join = the existing key-range machinery, run twice under ONE
    shared cut vector: sample BOTH sides jointly
    (:func:`sample_range_cuts`), exchange each side by those cuts
    (:func:`exchange_sorted_fragments`), per-owner merge of each side's
    received fragments — and then the join is purely local, because
    owner ``i`` now holds *all* rows of *both* relations in key range
    ``i``.  No global sort anywhere: established order survives the
    shuffle, exactly as in the aggregation exchange.

    Returns ``(left, right_or_left, rows_sent, dropped)``: the local join
    output trimmed back to this shard's slice of the global output
    capacity (``|a|`` rows — loud flag if a skewed owner's matches
    exceed its slice), the aligned right side (inner; the left state
    again for semi/anti so the shape structure is static), the global
    shuffle volume (both sides, psum'd), and the pmax'd row-loss flag.
    """
    from repro.core.merge_join import merge_join

    cuts = sample_range_cuts((a, b), axis, world, nsamp=nsamp)
    recv_a, sent_a, drop_a = exchange_sorted_fragments(
        a, axis, world, quota=a.capacity, inner_cuts=cuts)
    recv_b, sent_b, drop_b = exchange_sorted_fragments(
        b, axis, world, quota=b.capacity, inner_cuts=cuts)
    ma = merge_received_fragments(recv_a, world, a.capacity, backend=backend)
    mb = merge_received_fragments(recv_b, world, b.capacity, backend=backend)
    left, right = merge_join(ma, mb, how=how, backend=backend)
    left, trim_l = merge_mod.trim_to_capacity(left, a.capacity)
    if right is not None:
        right, trim_r = merge_mod.trim_to_capacity(right, a.capacity)
    else:
        right, trim_r = left, jnp.bool_(False)
    rows_sent = jax.lax.psum(sent_a + sent_b, axis)
    dropped = jax.lax.pmax(
        (drop_a | drop_b | trim_l | trim_r).astype(jnp.int32), axis) > 0
    return left, right, rows_sent, dropped


def make_distributed_groupby(mesh, axis: str = "data", *, capacity: int,
                             on_overflow: str = "raise"):
    """Returns fn(keys (n_loc,), payload (n_loc, V)) → AggState per device,
    covering this device's key range (globally sorted across devices).

    ``on_overflow`` controls what happens when fixed capacities would cut
    live rows (a send segment over its ``capacity // world`` quota, or a
    shard's merged fragments over ``capacity``): ``"raise"`` (default)
    reads one replicated flag back after the exchange and raises
    RuntimeError — the loud-failure contract of the PR 3 wide merge;
    ``"flag"`` returns ``(state, dropped)`` with the device flag for
    callers embedding the exchange in a larger jitted program.
    """
    if on_overflow not in ("raise", "flag"):
        raise ValueError(f"unknown on_overflow {on_overflow!r}: raise|flag")
    world = mesh.shape[axis]
    quota = capacity // world

    def local_fn(keys, payload):
        keys = keys.reshape(-1)
        payload = payload.reshape(keys.shape[0], -1)
        # 1. local early aggregation — the paper's §3 on-device
        st, local_dropped = _local_group_sorted(keys, payload, capacity)
        # 2. sampled key-range exchange (shared with the sharded pipeline)
        recv, _sent, send_dropped = exchange_sorted_fragments(
            st, axis, world, quota=quota
        )
        # 3. local wide merge of the received sorted fragments
        merged = merge_received_fragments(recv, world, quota)
        merged, recv_dropped = merge_mod.trim_to_capacity(merged, capacity)
        dropped = jax.lax.pmax(
            (local_dropped | send_dropped | recv_dropped).astype(jnp.int32),
            axis,
        ) > 0
        return merged, dropped

    def run(keys, payload):
        fn = shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(axis), P(axis, None)),
            out_specs=(
                AggState(
                    keys=P(axis), count=P(axis), sum=P(axis, None),
                    min=P(axis, None), max=P(axis, None),
                ),
                P(),
            ),
        )
        state, dropped = fn(keys, payload)
        if on_overflow == "flag":
            return state, dropped
        if bool(dropped):  # one replicated-scalar readback, eager callers
            raise RuntimeError(
                "distributed group-by dropped rows: received fragments "
                f"exceeded capacity={capacity} (quota {quota} rows/peer) "
                "on at least one shard — raise `capacity` (results would "
                "be missing keys/counts)"
            )
        return state

    return run


def sparse_embedding_grad(tokens, grads, vocab: int, mesh, axis="data",
                          capacity: int | None = None,
                          on_overflow: str = "raise"):
    """Aggregate (token, grad_row) pairs across devices sort-based, then
    scatter into the dense (V, D) gradient.  Wire volume: unique rows per
    range shard instead of the full dense table all-reduce.

    The default ``on_overflow="raise"`` reads one replicated flag back
    per call and raises on row loss — eager (host-driver) use only.
    Inside ``jit``/``grad`` pass ``on_overflow="flag"``: the result is
    ``(state, dropped)`` with the device flag for the caller to surface.
    """
    d = grads.shape[-1]
    capacity = capacity or tokens.size
    gb = make_distributed_groupby(mesh, axis, capacity=capacity,
                                  on_overflow=on_overflow)
    return gb(tokens.reshape(-1).astype(jnp.uint32), grads.reshape(-1, d))
