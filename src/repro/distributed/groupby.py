"""Distributed duplicate removal / grouping / aggregation (shard_map).

The cluster-scale form of the paper's operator, using its own §2.1
observation that *sorting and partitioning are the same physical
property*:

  1. local early aggregation (§3): each device absorbs its shard's
     duplicates with the in-memory ordered index — this is the paper's
     intro note that best-effort aggregation **before** re-partitioning
     reduces the shuffle volume;
  2. key-range exchange: the key space splits into `world` contiguous
     ranges; because local outputs are sorted, the send buffer is built
     with two searchsorted cuts, and the all_to_all is the paper's
     "partitioning enforced together with sorting";
  3. local wide merge (§4): each device merges the `world` sorted
     fragments it received — output is locally sorted, and globally
     sorted by (range owner, key): a distributed ORDER BY for free.

The exchange core (:func:`exchange_sorted_fragments`) is shared with the
mesh-sharded device-resident pipeline (:mod:`repro.core.pipeline`), which
runs full external run generation per shard before the same key-range
all_to_all.

Overflow is LOUD: every place a fixed-capacity buffer can cut live rows —
the local-aggregation trim to ``capacity``, the per-peer send quota, and
the post-merge trim back to ``capacity`` — returns a device flag instead
of silently dropping, and :func:`make_distributed_groupby` raises on it
(matching the PR 3 wide merge's ``merge_dropped_rows`` contract).

``sparse_embedding_grad`` applies the same pipeline to embedding-table
gradients: (token, grad) pairs dedup-aggregate locally, then only unique
rows travel.
"""
from __future__ import annotations

import functools
import logging
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import merge as merge_mod
from repro.core import sorted_ops
from repro.core.types import AggState, empty_key, max_key, rows_to_state
from repro.distributed._compat import shard_map

_log = logging.getLogger(__name__)

# default merge page for the post-exchange fragment merge when the caller
# has no ExecConfig to thread through (the distributed group-by front door)
_DEFAULT_EXCHANGE_PAGE = 256


def _pow2_ceil(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def default_exchange_quota(capacity: int, world: int, *, headroom: int = 2,
                           floor: int = 64) -> int:
    """Per-peer send quota for a capacity-bounded exchange: the expected
    rows per owner under the sampled cuts (``capacity / world``) times a
    pow2 ``headroom`` for sampling error, never above ``pow2(capacity)``
    (a quota >= capacity is statically lossless, so the retry ladder
    terminates there).  This is what keeps the exchange's receive buffer
    at ``world * quota ~= headroom * capacity`` rows — constant in world
    at fixed rows-per-shard — instead of the old ``world * capacity``.

    ``floor`` guards the SMALL end: when expected rows per owner is a
    handful, sample-quantile noise is additive, not proportional (a
    9-row segment against an expected 4 is routine at 64 samples/shard),
    so multiplicative headroom alone would trip the retry ladder — and a
    retry re-dispatches the whole sharded program.  The floor costs at
    most ``world * floor`` receive rows, noise at the scale where
    ``headroom * expected`` dominates anyway."""
    expected = -(-capacity // world)
    want = max(headroom * expected, floor)
    return max(1, min(_pow2_ceil(want), _pow2_ceil(capacity)))


def exchange_page_rows(quota: int, page_rows: int | None = None) -> int:
    """Merge page size for the fragment merge: the caller's page size,
    shrunk so it divides ``quota`` exactly (a clamped last page would
    double-read rows through :func:`repro.core.merge._page_of`).  Quotas
    from :func:`default_exchange_quota` are pow2, so any pow2 page size
    passes through unchanged."""
    p = max(1, min(page_rows or _DEFAULT_EXCHANGE_PAGE, quota))
    return math.gcd(quota, p)


def exchange_footprint_rows(world: int, quota: int,
                            page_rows: int | None = None) -> int:
    """Analytic per-shard resident footprint of one exchange + fragment
    merge, in rows: the receive buffer (``world * quota``), the wide
    merge's working set (index tile ``world * P`` + one incoming page +
    merge headroom = ``(world + 2) * P``), and the merged output buffer
    (``world * quota``).  O(quota_bound + merge_page); the old scheme was
    ``world * capacity`` on the wire alone."""
    p = exchange_page_rows(quota, page_rows)
    return 2 * world * quota + (world + 2) * p


def _range_of(keys, world):
    """Owner of each key under contiguous range partitioning of the key
    dtype's domain (uint32 or uint64)."""
    bits = np.dtype(keys.dtype).itemsize * 8
    span = keys.dtype.type((1 << bits) // world)
    return jnp.minimum(keys // span, world - 1).astype(jnp.int32)


def _local_group_sorted(keys, payload, capacity):
    """Local early aggregation trimmed to ``capacity`` — returns the
    trimmed state plus the live-rows-cut flag (more unique keys in this
    shard's slice than ``capacity`` is row loss, the same as the other
    two overflow sites)."""
    st = sorted_ops.sorted_groupby(keys, payload)
    return merge_mod.trim_to_capacity(st, capacity)


def _fill_like(x):
    if x.dtype in (jnp.uint32, jnp.uint64):
        return empty_key(x.dtype)
    return jnp.zeros((), x.dtype)


def _sample_local_keys(st: AggState, nsamp: int):
    """``nsamp`` evenly spaced keys from a sorted local state's valid
    prefix (all-EMPTY shards contribute EMPTY samples, which rank last)."""
    occ = jnp.maximum(st.occupancy(), 1)
    pos = jnp.minimum((jnp.arange(nsamp) * occ) // nsamp, st.capacity - 1)
    return jnp.take(st.keys, pos)


def strictify_cuts(cuts):
    """Make sampled inner cut values strictly increasing (and clamped to
    the key domain, below the EMPTY sentinel).  Under heavy skew — a hot
    key holding most rows, or fewer distinct keys than shards — the raw
    sample quantiles repeat, which leaves owner ranges empty and piles
    several ranges' keys onto one peer.  The recurrence

        c'_i = min(max(c_i, min(c'_{i-1}, top - 1) + 1), top)

    (a ``lax.scan`` over the ``world - 1`` scalars; the inner ``min``
    saturates instead of overflowing unsigned arithmetic at ``top``)
    bumps each duplicate one key above its predecessor, so cuts stay
    distinct wherever the domain allows and collapse onto ``top`` only
    when it doesn't — identical and deterministic on every shard."""
    kd = cuts.dtype
    top = jnp.asarray(max_key(kd), kd)
    one = jnp.asarray(1, kd)

    def step(carry, ci):
        prev, started = carry
        lo = jnp.where(started, jnp.minimum(prev, top - one) + one,
                       jnp.zeros((), kd))
        nxt = jnp.minimum(jnp.maximum(ci, lo), top)
        return (nxt, jnp.bool_(True)), nxt

    (_, _), out = jax.lax.scan(
        step, (jnp.zeros((), kd), jnp.bool_(False)), jnp.minimum(cuts, top)
    )
    return out


def sample_range_cuts(states, axis: str, world: int, *, nsamp: int = 64):
    """Sampled key-range partition edges over one or MORE sorted local
    states (sample-sort style).  Each shard contributes a sorted sample
    per state; the gathered sample's quantiles give identical,
    data-driven inner edges — shape ``(world - 1,)`` — on every shard.
    Passing both sides of a join here partitions both relations by the
    SAME cuts, which is what makes the post-exchange per-owner join a
    purely local merge join.  Edges are deduped/clamped
    (:func:`strictify_cuts`) so skewed samples cannot produce empty
    owner ranges from repeated quantile values."""
    sample = jnp.concatenate([_sample_local_keys(st, nsamp) for st in states])
    all_samp = jnp.sort(jax.lax.all_gather(sample, axis).reshape(-1))
    eidx = (jnp.arange(1, world) * all_samp.shape[0]) // world
    return strictify_cuts(jnp.take(all_samp, eidx))


def exchange_sorted_fragments(st: AggState, axis: str, world: int, *, quota: int,
                              nsamp: int = 64, inner_cuts=None):
    """Key-range ``all_to_all`` of a *sorted, duplicate-free* local state.

    Range boundaries are SAMPLED (sample-sort style): fixed uniform ranges
    collapse under key skew, so each shard contributes a sorted sample of
    its keys; the gathered sample's quantiles give identical, data-driven
    edges on every shard.  Sorted local output ⇒ the per-peer send
    segments are two searchsorted cuts, "partitioning enforced together
    with sorting" (§2.1).  Each peer receives a sorted, EMPTY-padded
    fragment of exactly ``quota`` rows.

    ``inner_cuts`` overrides the sampled edges with precomputed ones
    (shape ``(world - 1,)``, identical on every shard — see
    :func:`sample_range_cuts`): the sharded merge join exchanges BOTH
    sides under one shared cut vector so the two partitionings align.

    Returns ``(recv, rows_sent, send_dropped, max_fill)``:

    * ``recv`` — AggState of ``world * quota`` rows; rows
      ``[i*quota, (i+1)*quota)`` are peer ``i``'s sorted fragment, and
      fragment key ranges ascend with ``i`` (global order = (owner, key));
    * ``rows_sent`` — valid rows this shard put on the wire (shuffle
      volume; ``psum`` it for the global count);
    * ``send_dropped`` — True iff some send segment exceeded ``quota``
      and live rows were cut.  Callers must surface this loudly; with
      ``quota >= st.capacity`` it is statically impossible.
    * ``max_fill`` — this shard's fullest send segment in rows (``pmax``
      it for the global view); ``max_fill / quota`` is how close the
      sampled cuts came to overflowing the capacity bound.
    """
    capacity = st.capacity
    inner = (sample_range_cuts((st,), axis, world, nsamp=nsamp)
             if inner_cuts is None else inner_cuts)
    cuts = jnp.searchsorted(st.keys, inner, side="left").astype(jnp.int32)
    ends = jnp.concatenate([cuts, jnp.asarray([capacity], jnp.int32)])
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), cuts])
    # segment i = rows [starts[i], ends[i]) of the sorted local state; the
    # EMPTY tail beyond occupancy lands in the last segment and pads it.
    seg_valid = jnp.minimum(ends, st.occupancy()) - jnp.minimum(
        starts, st.occupancy()
    )
    rows_sent = jnp.sum(seg_valid, dtype=jnp.int32)
    max_fill = jnp.max(seg_valid).astype(jnp.int32)
    send_dropped = jnp.any(seg_valid > quota)
    idx = starts[:, None] + jnp.arange(quota, dtype=jnp.int32)[None, :]
    valid_send = idx < ends[:, None]
    idx = jnp.minimum(idx, capacity - 1)

    def gather_rows(x):
        g = jnp.take(x, idx.reshape(-1), axis=0)
        mask = valid_send.reshape(-1)
        return jnp.where(mask.reshape((-1,) + (1,) * (g.ndim - 1)),
                         g, _fill_like(x))

    send = jax.tree.map(gather_rows, st)
    recv = jax.tree.map(
        lambda x: jax.lax.all_to_all(
            x.reshape((world, quota) + x.shape[1:]), axis, 0, 0,
            tiled=False,
        ).reshape((world * quota,) + x.shape[1:]),
        send,
    )
    return recv, rows_sent, send_dropped, max_fill


class ExchangeInfo(NamedTuple):
    """Accounting from one :func:`exchange_and_merge` (device scalars
    except the static ``quota``), already cross-shard reduced where
    noted by the caller's contract."""

    rows_sent: jax.Array  # valid rows this shard put on the wire
    send_dropped: jax.Array  # a send segment exceeded `quota` (retryable)
    max_fill: jax.Array  # fullest send segment observed on this shard
    merge_dropped: jax.Array  # fragment merge lost rows (statically ~impossible)
    quota: int  # the static per-peer quota the exchange ran at


def exchange_and_merge(st: AggState, axis: str, world: int, *,
                       backend: str = "auto", quota: int | None = None,
                       page_rows: int | None = None):
    """Key-range exchange + per-owner merge of a sorted, duplicate-free
    local state — the shared tail of the mesh-sharded pipelines: the
    one-shot finalize, the streamed finalize, AND the service's
    merge-on-read snapshot all run this same program over their
    per-shard merge output (the snapshot feeds it a fresh buffer, so
    exchanging never perturbs the live per-shard engine states).

    The per-peer quota is CAPACITY-BOUNDED (:func:`default_exchange_quota`
    unless overridden): expected rows per owner under the sampled cuts
    times a pow2 headroom, so the wire + merge footprint is
    O(quota_bound + merge_page) per shard instead of the old
    ``world * capacity``.  A segment over quota sets
    ``info.send_dropped`` — host entry points surface it as
    :class:`repro.core.types.ExchangeOverflowError` and retry once at
    the next pow2 quota.

    Returns ``(merged, info)``: the merged state at capacity
    ``world * quota`` and an :class:`ExchangeInfo`."""
    if quota is None:
        quota = default_exchange_quota(st.capacity, world)
    recv, rows_sent, send_dropped, max_fill = exchange_sorted_fragments(
        st, axis, world, quota=quota
    )
    merged, merge_dropped = merge_received_fragments(
        recv, world, quota, backend=backend, page_rows=page_rows
    )
    return merged, ExchangeInfo(rows_sent, send_dropped, max_fill,
                                merge_dropped, quota)


def merge_received_fragments(recv: AggState, world: int, quota: int, *,
                             backend: str = "auto",
                             page_rows: int | None = None):
    """Local PAGE-STREAMED wide merge (§4) of the ``world`` sorted
    fragments an :func:`exchange_sorted_fragments` shard received: the
    fragments are exactly §4 runs (sorted, duplicate-free,
    EMPTY-padded), so they stream page-wise through
    :func:`repro.core.merge.wide_merge_device` — resident working set
    ``(world + 2) * page`` rows instead of the former full-width
    ``world * quota`` merge tree.  The index bound is exact: the merge
    frontier is at least every read page's low key, so at most one page
    per fragment is ever resident (``index_rows = world * page``).

    Returns ``(merged, dropped)``: the merged state at capacity
    ``world * quota`` (trim + loud-overflow is the caller's policy, see
    :func:`repro.core.merge.trim_to_capacity`) and the wide merge's
    hard row-loss flag, statically impossible here because the output
    buffer holds every input row — surfaced defensively anyway."""
    p = exchange_page_rows(quota, page_rows)
    store, lens = merge_mod.fragments_to_store(recv, world, quota)
    merged, _out_cur, _pages, _max_occ, _overflow, dropped = (
        merge_mod.wide_merge_device(
            store, lens, page_rows=p, index_rows=world * p,
            out_capacity=world * quota, backend=backend,
        )
    )
    return merged, dropped


def sharded_merge_join_local(a: AggState, b: AggState, axis: str, world: int,
                             *, how: str = "inner", backend: str = "xla",
                             nsamp: int = 64, quota_a: int | None = None,
                             quota_b: int | None = None,
                             page_rows: int | None = None):
    """Per-shard body of the mesh-sharded merge join (call inside
    ``shard_map``; both inputs are this shard's sorted, duplicate-free,
    EMPTY-tailed slices of globally sorted relations).

    Sharded join = the existing key-range machinery, run twice under ONE
    shared cut vector: sample BOTH sides jointly
    (:func:`sample_range_cuts`), exchange each side by those cuts
    (:func:`exchange_sorted_fragments`), per-owner merge of each side's
    received fragments — and then the join is purely local, because
    owner ``i`` now holds *all* rows of *both* relations in key range
    ``i``.  No global sort anywhere: established order survives the
    shuffle, exactly as in the aggregation exchange.

    Both exchanges are capacity-bounded (:func:`default_exchange_quota`
    per side unless ``quota_a``/``quota_b`` override) and both fragment
    merges page-stream (:func:`merge_received_fragments`), so the join's
    shuffle footprint follows the same O(quota_bound + merge_page)
    discipline as the aggregation exchange.

    Returns ``(left, right_or_left, rows_sent, send_dropped, dropped,
    max_fill)``: the local join output trimmed back to this shard's
    slice of the global output capacity (``|a|`` rows — loud flag if a
    skewed owner's matches exceed its slice), the aligned right side
    (inner; the left state again for semi/anti so the shape structure is
    static), the global shuffle volume (both sides, psum'd), the pmax'd
    RETRYABLE quota-overflow flag (either side's send segment over its
    quota — the mesh join front door retries once at wider quotas), the
    pmax'd non-retryable row-loss flag (merge/trim), and the pmax'd
    fullest send segment across both sides.
    """
    from repro.core.merge_join import merge_join

    qa = default_exchange_quota(a.capacity, world) if quota_a is None else quota_a
    qb = default_exchange_quota(b.capacity, world) if quota_b is None else quota_b
    cuts = sample_range_cuts((a, b), axis, world, nsamp=nsamp)
    recv_a, sent_a, drop_a, fill_a = exchange_sorted_fragments(
        a, axis, world, quota=qa, inner_cuts=cuts)
    recv_b, sent_b, drop_b, fill_b = exchange_sorted_fragments(
        b, axis, world, quota=qb, inner_cuts=cuts)
    ma, mdrop_a = merge_received_fragments(
        recv_a, world, qa, backend=backend, page_rows=page_rows)
    mb, mdrop_b = merge_received_fragments(
        recv_b, world, qb, backend=backend, page_rows=page_rows)
    left, right = merge_join(ma, mb, how=how, backend=backend)
    left, trim_l = merge_mod.trim_to_capacity(left, a.capacity)
    if right is not None:
        right, trim_r = merge_mod.trim_to_capacity(right, a.capacity)
    else:
        right, trim_r = left, jnp.bool_(False)
    rows_sent = jax.lax.psum(sent_a + sent_b, axis)
    send_dropped = jax.lax.pmax(
        (drop_a | drop_b).astype(jnp.int32), axis) > 0
    dropped = jax.lax.pmax(
        (mdrop_a | mdrop_b | trim_l | trim_r).astype(jnp.int32), axis) > 0
    max_fill = jax.lax.pmax(jnp.maximum(fill_a, fill_b), axis)
    return left, right, rows_sent, send_dropped, dropped, max_fill


def make_distributed_groupby(mesh, axis: str = "data", *, capacity: int,
                             on_overflow: str = "raise",
                             exchange_quota: int | None = None,
                             page_rows: int | None = None):
    """Returns fn(keys (n_loc,), payload (n_loc, V)) → AggState per device,
    covering this device's key range (globally sorted across devices).

    The exchange runs at a capacity-bounded per-peer quota
    (:func:`default_exchange_quota` unless ``exchange_quota`` overrides)
    and the fragment merge page-streams, so per-shard memory is
    O(quota_bound + merge_page), not O(world × capacity).

    ``on_overflow`` controls what happens when fixed capacities would cut
    live rows: ``"raise"`` (default) reads the flags back after the
    exchange; a send segment over quota RETRIES ONCE at the next pow2
    quota with a loud log (the PR 8 retry-once pattern), then raises —
    any other loss site (local trim, post-merge trim) raises
    immediately; ``"flag"`` returns ``(state, dropped)`` with the
    combined device flag for callers embedding the exchange in a larger
    jitted program (NO retry: the flag read would cost the readback the
    mode exists to avoid).
    """
    if on_overflow not in ("raise", "flag"):
        raise ValueError(f"unknown on_overflow {on_overflow!r}: raise|flag")
    world = mesh.shape[axis]

    def local_fn(quota, keys, payload):
        keys = keys.reshape(-1)
        payload = payload.reshape(keys.shape[0], -1)
        # 1. local early aggregation — the paper's §3 on-device
        st, local_dropped = _local_group_sorted(keys, payload, capacity)
        # 2. capacity-bounded sampled key-range exchange (shared with the
        #    sharded pipeline)
        recv, _sent, send_dropped, _fill = exchange_sorted_fragments(
            st, axis, world, quota=quota
        )
        # 3. local page-streamed wide merge of the received fragments
        merged, merge_dropped = merge_received_fragments(
            recv, world, quota, page_rows=page_rows
        )
        merged, recv_dropped = merge_mod.trim_to_capacity(merged, capacity)
        pflag = lambda f: jax.lax.pmax(f.astype(jnp.int32), axis) > 0
        return merged, pflag(send_dropped), pflag(
            local_dropped | merge_dropped | recv_dropped
        )

    def sharded(quota):
        return shard_map(
            functools.partial(local_fn, quota), mesh=mesh,
            in_specs=(P(axis), P(axis, None)),
            out_specs=(
                AggState(
                    keys=P(axis), count=P(axis), sum=P(axis, None),
                    min=P(axis, None), max=P(axis, None),
                ),
                P(),
                P(),
            ),
        )

    q0 = (default_exchange_quota(capacity, world) if exchange_quota is None
          else exchange_quota)
    q_max = _pow2_ceil(capacity)

    def run(keys, payload):
        state, send_dropped, dropped = sharded(q0)(keys, payload)
        if on_overflow == "flag":
            return state, send_dropped | dropped
        # one replicated-scalar readback, eager callers only
        if bool(send_dropped) and q0 < q_max:
            quota2 = min(_pow2_ceil(q0 + 1), q_max)
            _log.warning(
                "distributed group-by exchange overflowed its per-peer "
                "quota=%d; retrying once at quota=%d", q0, quota2,
            )
            state, send_dropped, dropped = sharded(quota2)(keys, payload)
        if bool(send_dropped) or bool(dropped):
            raise RuntimeError(
                "distributed group-by dropped rows: a send segment "
                "exceeded the per-peer exchange quota even after one "
                "retry, or received fragments exceeded "
                f"capacity={capacity} on at least one shard — raise "
                "`capacity` (results would be missing keys/counts)"
            )
        return state

    return run


def sparse_embedding_grad(tokens, grads, vocab: int, mesh, axis="data",
                          capacity: int | None = None,
                          on_overflow: str = "raise"):
    """Aggregate (token, grad_row) pairs across devices sort-based, then
    scatter into the dense (V, D) gradient.  Wire volume: unique rows per
    range shard instead of the full dense table all-reduce.

    The default ``on_overflow="raise"`` reads one replicated flag back
    per call and raises on row loss — eager (host-driver) use only.
    Inside ``jit``/``grad`` pass ``on_overflow="flag"``: the result is
    ``(state, dropped)`` with the device flag for the caller to surface.
    """
    d = grads.shape[-1]
    capacity = capacity or tokens.size
    gb = make_distributed_groupby(mesh, axis, capacity=capacity,
                                  on_overflow=on_overflow)
    return gb(tokens.reshape(-1).astype(jnp.uint32), grads.reshape(-1, d))
