"""Distributed duplicate removal / grouping / aggregation (shard_map).

The cluster-scale form of the paper's operator, using its own §2.1
observation that *sorting and partitioning are the same physical
property*:

  1. local early aggregation (§3): each device absorbs its shard's
     duplicates with the in-memory ordered index — this is the paper's
     intro note that best-effort aggregation **before** re-partitioning
     reduces the shuffle volume;
  2. key-range exchange: the key space splits into `world` contiguous
     ranges; because local outputs are sorted, the send buffer is built
     with two searchsorted cuts, and the all_to_all is the paper's
     "partitioning enforced together with sorting";
  3. local wide merge (§4): each device merges the `world` sorted
     fragments it received — output is locally sorted, and globally
     sorted by (range owner, key): a distributed ORDER BY for free.

``sparse_embedding_grad`` applies the same pipeline to embedding-table
gradients: (token, grad) pairs dedup-aggregate locally, then only unique
rows travel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import sorted_ops
from repro.core.types import AggState, empty_key, rows_to_state
from repro.distributed._compat import shard_map


def _range_of(keys, world):
    """Owner of each key under contiguous range partitioning of the key
    dtype's domain (uint32 or uint64)."""
    bits = np.dtype(keys.dtype).itemsize * 8
    span = keys.dtype.type((1 << bits) // world)
    return jnp.minimum(keys // span, world - 1).astype(jnp.int32)


def _local_group_sorted(keys, payload, capacity):
    st = sorted_ops.sorted_groupby(keys, payload)
    return jax.tree.map(lambda x: x[:capacity], st)


def make_distributed_groupby(mesh, axis: str = "data", *, capacity: int):
    """Returns fn(keys (n_loc,), payload (n_loc, V)) → AggState per device,
    covering this device's key range (globally sorted across devices)."""
    world = mesh.shape[axis]

    def local_fn(keys, payload):
        keys = keys.reshape(-1)
        payload = payload.reshape(keys.shape[0], -1)
        # 1. local early aggregation — the paper's §3 on-device
        st = _local_group_sorted(keys, payload, capacity)
        # 2. key-range exchange with SAMPLED range boundaries (sample-sort
        #    style): fixed uniform ranges collapse under key skew, so each
        #    device contributes a sorted sample of its keys; the gathered
        #    sample's quantiles give identical, data-driven edges on every
        #    device.  Sorted local output ⇒ cuts are two searchsorted ops.
        nsamp = 64
        occ = jnp.maximum(st.occupancy(), 1)
        pos = jnp.minimum((jnp.arange(nsamp) * occ) // nsamp, capacity - 1)
        sample = jnp.take(st.keys, pos)
        all_samp = jnp.sort(jax.lax.all_gather(sample, axis).reshape(-1))
        eidx = (jnp.arange(1, world) * (world * nsamp)) // world
        inner = jnp.take(all_samp, eidx)
        cuts = jnp.searchsorted(st.keys, inner, side="left")
        starts = jnp.concatenate([jnp.zeros((1,), cuts.dtype), cuts])
        # fixed per-peer quota: capacity // world rows (overflow drops are
        # counted by callers via occupancy; tests size capacity generously)
        quota = capacity // world
        idx = starts[:, None] + jnp.arange(quota)[None, :]
        valid_send = idx < jnp.concatenate([cuts, jnp.array([capacity])])[:, None]
        idx = jnp.minimum(idx, capacity - 1)

        def gather_rows(x):
            g = jnp.take(x, idx.reshape(-1), axis=0)
            mask = valid_send.reshape(-1)
            return jnp.where(mask.reshape((-1,) + (1,) * (g.ndim - 1)),
                             g, _fill_like(x))

        send = jax.tree.map(gather_rows, st)
        recv = jax.tree.map(
            lambda x: jax.lax.all_to_all(
                x.reshape((world, quota) + x.shape[1:]), axis, 0, 0,
                tiled=False,
            ).reshape((world * quota,) + x.shape[1:]),
            send,
        )
        # 3. local wide merge of `world` sorted fragments: each peer's
        #    slice arrives sorted and EMPTY-padded, so a balanced tree of
        #    linear merge-absorbs (§3.4) replaces the former full re-sort.
        frags = [
            jax.tree.map(lambda x: x[i * quota : (i + 1) * quota], recv)
            for i in range(world)
        ]
        merged = sorted_ops.merge_absorb_many(frags, assume_unique=True)
        return jax.tree.map(lambda x: x[:capacity], merged)

    def _fill_like(x):
        if x.dtype in (jnp.uint32, jnp.uint64):
            return empty_key(x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.zeros((), x.dtype)
        return jnp.zeros((), x.dtype)

    def run(keys, payload):
        fn = shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(axis), P(axis, None)),
            out_specs=AggState(
                keys=P(axis), count=P(axis), sum=P(axis, None),
                min=P(axis, None), max=P(axis, None),
            ),
        )
        return fn(keys, payload)

    return run


def sparse_embedding_grad(tokens, grads, vocab: int, mesh, axis="data",
                          capacity: int | None = None):
    """Aggregate (token, grad_row) pairs across devices sort-based, then
    scatter into the dense (V, D) gradient.  Wire volume: unique rows per
    range shard instead of the full dense table all-reduce."""
    d = grads.shape[-1]
    capacity = capacity or tokens.size
    gb = make_distributed_groupby(mesh, axis, capacity=capacity)
    st = gb(tokens.reshape(-1).astype(jnp.uint32), grads.reshape(-1, d))
    return st
