"""Logical-axis → mesh-axis sharding rules (DP/FSDP + TP + EP + SP).

Parameters carry logical axis names from their initializers ("embed",
"heads", "vocab", "expert", …).  Rules map those to mesh axes; a conflict
pass guarantees a mesh axis appears at most once per spec (first logical
axis wins, later ones fall back to replication).

Default recipe (single pod (data=16, model=16), multi-pod adds "pod"):
  vocab / heads / kv_heads / mlp / expert / inner → "model"   (TP/EP)
  embed                                           → "data"    (FSDP/ZeRO-3)
  layers / lora / scalars                         → replicated
Batch dims of activations/inputs shard over ("pod","data").

GQA archs whose head counts don't divide 16 (qwen2*: 12 heads) shard the
flattened head*dh matrix dims evenly; activation head sharding is uneven
and GSPMD pads — documented waste, see EXPERIMENTS §Dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


DEFAULT_RULES: dict[str, Any] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "inner": "model",
    "embed": "data",  # FSDP / ZeRO-3
    "lora": None,
    "layers": None,
}


def rules_for_mesh(mesh) -> dict[str, Any]:
    """Multi-pod: FSDP spans both data-parallel axes (pod, data) so the
    671B-class models' parameter shards halve when pods double."""
    rules = dict(DEFAULT_RULES)
    if "pod" in mesh.axis_names:
        rules["embed"] = ("pod", "data")
    return rules


def spec_from_axes(axes, rules=None) -> P:
    """Tuple of logical names (possibly nested dict leaf) → PartitionSpec
    with duplicate-mesh-axis conflict resolution.  A rule value may be a
    tuple of mesh axes (sharded over their product)."""
    rules = rules or DEFAULT_RULES
    used: set[str] = set()
    out = []
    for ax in axes:
        mesh_ax = rules.get(ax) if ax is not None else None
        if isinstance(mesh_ax, tuple):
            free = tuple(a for a in mesh_ax if a not in used)
            if not free:
                out.append(None)
                continue
            out.append(free if len(free) > 1 else free[0])
            used.update(free)
        elif mesh_ax is None or mesh_ax in used:
            out.append(None)
        else:
            out.append(mesh_ax)
            used.add(mesh_ax)
    return P(*out)


def tree_specs(spec_tree, rules=None):
    """Map a pytree of logical-axis tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: spec_from_axes(axes, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def shardings_for(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_spec(mesh: Mesh, ndim: int, *, batch_axis: int = 0) -> P:
    """Inputs: shard the batch dim over every data-parallel mesh axis."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    parts = [None] * ndim
    parts[batch_axis] = dp if len(dp) > 1 else dp[0]
    return P(*parts)


def cache_specs(cfg, mesh: Mesh):
    """Decode-cache shardings: batch over data axes; kv heads over model
    when they divide the TP degree, otherwise the cache shards its
    SEQUENCE dim over model (flash-decoding style: per-shard partial
    attention + small softmax-stat collectives; the in-place cache update
    lowers to a masked per-shard dynamic-update-slice)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp if len(dp) > 1 else dp[0]
    tp = mesh.shape["model"]

    def attn():
        if cfg.mla is not None:
            return {
                "latent": P(None, dp, None, "model"),
                "k_rope": P(None, dp, "model", None),
                "index": P(None),
            }
        if (cfg.n_kv_heads * cfg.kv_dup) % tp == 0:
            kv = P(None, dp, None, "model", None)
        else:
            kv = P(None, dp, "model", None, None)  # sequence-sharded cache
        return {"k": kv, "v": kv, "index": P(None)}

    def mamba():
        return {
            "conv": P(None, dp, None, "model"),
            "ssm": P(None, dp, "model", None, None),
        }

    if cfg.family == "ssm":
        return mamba()
    if cfg.family == "hybrid":
        return (mamba(), attn())
    if cfg.moe is not None and cfg.moe.first_k_dense:
        return (attn(), attn())
    return attn()


def opt_state_specs(param_specs, opt_name: str):
    """Optimizer state inherits parameter shardings leaf-by-leaf.

    adamw: m/v same shape+sharding as the param.
    adafactor: factored rows/cols — drop the last (rows) / second-to-last
    (cols) axis of the param spec.
    """
    from jax.sharding import PartitionSpec as P

    def adam_like(s):
        return s

    def rows(s):
        parts = list(s)
        return P(*parts[:-1]) if len(parts) >= 2 else s

    def cols(s):
        parts = list(s)
        if len(parts) >= 2:
            return P(*(parts[:-2] + parts[-1:]))
        return P(None)

    step_spec = P()
    if opt_name == "adamw":
        m = jax.tree.map(adam_like, param_specs, is_leaf=lambda x: isinstance(x, P))
        v = jax.tree.map(adam_like, param_specs, is_leaf=lambda x: isinstance(x, P))
    else:
        m = jax.tree.map(rows, param_specs, is_leaf=lambda x: isinstance(x, P))
        v = jax.tree.map(cols, param_specs, is_leaf=lambda x: isinstance(x, P))
    return step_spec, m, v


# ---------------------------------------------------------------------------
# multi-host entry path (jax.distributed)
# ---------------------------------------------------------------------------
#
# The mesh-sharded aggregation pipeline is written entirely in
# shard_map-over-named-axis terms, so spanning hosts needs exactly two
# things: jax.distributed.initialize() before any backend touch, and a
# mesh over jax.devices() (GLOBAL devices once initialized).  Everything
# else — the capacity-bounded exchange, the page-streamed fragment
# merge, the psum/pmax stats reduce — is host-count agnostic.


def init_distributed(
    *,
    coordinator_address: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
    local_device_ids=None,
) -> bool:
    """Initialize :mod:`jax.distributed` for multi-host meshes.

    Arguments default from the environment (``REPRO_COORDINATOR``,
    ``REPRO_NUM_PROCESSES``, ``REPRO_PROCESS_ID``), matching the launch
    driver's recipe::

        REPRO_COORDINATOR=host0:1234 REPRO_NUM_PROCESSES=2 \
        REPRO_PROCESS_ID=0 python -m repro.launch.shard_agg ...

    Single-process runs (no coordinator configured, or one process) are
    a NO-OP returning False — the same code path then runs on whatever
    local devices exist, which is what the fake-device CI tests do.
    Idempotent: a second call after successful initialization returns
    True without re-initializing (jax raises otherwise)."""
    import os

    if coordinator_address is None:
        coordinator_address = os.environ.get("REPRO_COORDINATOR")
    if num_processes is None:
        env = os.environ.get("REPRO_NUM_PROCESSES")
        num_processes = int(env) if env else None
    if process_id is None:
        env = os.environ.get("REPRO_PROCESS_ID")
        process_id = int(env) if env else None
    if coordinator_address is None or not num_processes or num_processes == 1:
        return False
    state = getattr(jax.distributed, "global_state", None)
    if state is not None and getattr(state, "client", None) is not None:
        return True  # already initialized (idempotent entry)
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
        local_device_ids=local_device_ids,
    )
    return True


def data_mesh(axis: str = "shard"):
    """A 1-D mesh over ALL global devices (every process's, once
    :func:`init_distributed` ran) — the world the aggregation pipeline
    shards over."""
    return jax.make_mesh((jax.device_count(),), (axis,))


def host_local_array(x, mesh, spec):
    """Build a global sharded array from this process's LOCAL batch shard
    (``jax.make_array_from_process_local_data``): each host contributes
    its slice of the leading axis, no cross-host copy of input data.  On
    a single process this is an ordinary ``device_put`` under the
    sharding."""
    sharding = NamedSharding(mesh, spec)
    return jax.make_array_from_process_local_data(sharding, x)
