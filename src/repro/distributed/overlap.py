"""Compute/communication overlap: ring collective matmul (shard_map).

Sequence-parallel layers gather the sequence dim before their first
matmul: y = all_gather(x) @ W.  The naive plan serializes the gather
before any MXU work.  The ring form computes the output **row block** for
the x-chunk currently resident while the next chunk travels the ring —
hiding (P−1)/P of the communication behind compute.  XLA performs this
rewrite itself in favourable cases ("collective matmul"); expressing it
explicitly via shard_map + ppermute makes the overlap deterministic and
available as a §Perf lever.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed._compat import shard_map


def ring_allgather_matmul(mesh, axis: str = "model"):
    """fn(x (S, D) seq-sharded over `axis`, w (D, F) replicated) → (S, F).

    Per device: world steps; step t multiplies the chunk from device
    (me − t) mod world and writes its output row block, then forwards the
    chunk along the ring.  Output replicated (all devices hold all rows).
    """
    world = mesh.shape[axis]

    def local(x, w):  # x (S/P, D); w (D, F)
        me = jax.lax.axis_index(axis)
        s_loc = x.shape[0]
        perm = [(i, (i + 1) % world) for i in range(world)]

        def step(carry, t):
            y, xs = carry
            src = (me - t) % world
            blk = jnp.dot(xs, w, preferred_element_type=jnp.float32)
            y = jax.lax.dynamic_update_slice_in_dim(
                y, blk.astype(y.dtype)[None], src, axis=0
            )
            xs = jax.lax.ppermute(xs, axis, perm)
            return (y, xs), None

        y0 = jnp.zeros((world, s_loc, w.shape[-1]), x.dtype)
        if hasattr(jax.lax, "pcast"):  # mark the carry device-varying (VMA)
            y0 = jax.lax.pcast(y0, (axis,), to="varying")
        (y, _), _ = jax.lax.scan(step, (y0, x), jnp.arange(world))
        return y.reshape(world * s_loc, w.shape[-1])

    # output is replicated by construction, but VMA can't prove it
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(None, None),
    )


def reference_allgather_matmul(mesh, axis: str = "model"):
    """Unoverlapped baseline: all_gather(x) then one big matmul."""

    def local(x, w):
        xg = jax.lax.all_gather(x, axis, axis=0, tiled=True)
        return jnp.dot(xg, w, preferred_element_type=jnp.float32).astype(x.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(None, None)),
        out_specs=P(None, None),
    )
