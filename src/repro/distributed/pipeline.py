"""Pipeline parallelism (GPipe) over the inter-pod axis.

At 2+ pods the `pod` axis can act as pipeline stages instead of data
parallelism: inter-pod links are the slowest in the fleet, and PP crosses
them once per microbatch boundary instead of once per gradient
all-reduce.  Implementation: shard_map over `pod`; layers are split into
`stages` contiguous groups; microbatches stream through with
`ppermute`-rotated activations (1F1B-simplified: forward streaming,
backward handled by autodiff through the loop — checkpointed per stage).

The schedule executes stages*microbatches steps; at step t, stage s works
on microbatch (t − s), giving the classic (stages−1) bubble out of
(microbatches + stages − 1) slots — bubble fraction reported by
``bubble_fraction``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed._compat import shard_map


def bubble_fraction(stages: int, microbatches: int) -> float:
    return (stages - 1) / (microbatches + stages - 1)


def make_pipeline(mesh, apply_layer, n_layers: int, axis: str = "pod",
                  *, microbatches: int):
    """apply_layer(params_l, x) → x; params stacked (L, …).

    Returns fn(params, x (B, …)) → y computed as `stages` pipeline stages
    over `axis`, microbatching the leading batch dim.
    """
    stages = mesh.shape[axis]
    assert n_layers % stages == 0
    per_stage = n_layers // stages

    def local(params_stage, x_all):
        """params_stage: this stage's (L/stages, …) slice; x_all: full
        batch (every stage holds the input; only stage 0 uses it)."""
        me = jax.lax.axis_index(axis)
        b = x_all.shape[0]
        assert b % microbatches == 0
        mb = b // microbatches
        xmb = x_all.reshape((microbatches, mb) + x_all.shape[1:])
        perm = [(i, (i + 1) % stages) for i in range(stages)]
        n_steps = microbatches + stages - 1

        def stage_apply(x):
            def body(h, p_l):
                return apply_layer(p_l, h), None
            h, _ = jax.lax.scan(jax.checkpoint(body), x, params_stage)
            return h

        def step(carry, t):
            inflight, outputs = carry
            # stage 0 injects microbatch t; others take the rotated buffer
            mb_idx = jnp.clip(t, 0, microbatches - 1)
            inject = jax.lax.dynamic_index_in_dim(xmb, mb_idx, 0,
                                                  keepdims=False)
            h_in = jnp.where(me == 0, inject, inflight)
            h_out = stage_apply(h_in)
            # last stage writes its finished microbatch (t - stages + 1)
            out_idx = jnp.clip(t - stages + 1, 0, microbatches - 1)
            write = (me == stages - 1) & (t >= stages - 1)
            outputs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out, out_idx, 0),
                lambda o: o,
                outputs,
            )
            inflight = jax.lax.ppermute(h_out, axis, perm)
            return (inflight, outputs), None

        inflight0 = jnp.zeros_like(xmb[0])
        outputs0 = jnp.zeros_like(xmb)
        if hasattr(jax.lax, "pcast"):
            inflight0 = jax.lax.pcast(inflight0, (axis,), to="varying")
            outputs0 = jax.lax.pcast(outputs0, (axis,), to="varying")
        (_, outputs), _ = jax.lax.scan(step, (inflight0, outputs0),
                                       jnp.arange(n_steps))
        # only the last stage holds real outputs; broadcast via psum of
        # the masked buffer (ppermute needs unique destinations)
        outputs = jnp.where(me == stages - 1, outputs, 0)
        outputs = jax.lax.psum(outputs, axis)
        return outputs.reshape((b,) + x_all.shape[1:])

    def run(params, x):
        kw = dict(
            mesh=mesh,
            in_specs=(P(axis), P()),   # params layer-split across stages
            out_specs=P(),
        )
        fn = shard_map(local, **kw)
        return fn(params, x)

    return run
