from repro.optim.optimizers import (
    adamw,
    adafactor,
    OptState,
    make_optimizer,
    clip_by_global_norm,
    cosine_schedule,
)
from repro.optim import compression
