"""Gradient compression for cross-pod reduction — built on the paper's
aggregation engine.

Top-k sparsification with error feedback: each device keeps the top-k
magnitude entries of (grad + residual), exchanges sparse (index, value)
pairs, and aggregates them *by key* — duplicate-index aggregation across
devices is exactly the paper's grouping problem, solved with the same
sorted_groupby primitive.  The residual (error feedback) keeps the
compressed SGD convergent.

The paper's intro, applied to gradients: "best-effort in-memory duplicate
removal, grouping and aggregation can reduce the communication effort"
before re-partitioning.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.sorted_ops import sorted_groupby
from repro.core.types import EMPTY


class TopKState(NamedTuple):
    residual: jax.Array  # error-feedback accumulator, same shape as grad


def init_topk(grad_like) -> TopKState:
    return TopKState(jnp.zeros_like(grad_like, dtype=jnp.float32))


def compress_topk(grad: jax.Array, state: TopKState, k: int):
    """grad (N,) → (idx (k,), val (k,), new_state). Error feedback."""
    acc = grad.astype(jnp.float32) + state.residual
    val, idx = jax.lax.top_k(jnp.abs(acc), k)
    sel = acc[idx]
    residual = acc.at[idx].set(0.0)
    return idx.astype(jnp.uint32), sel, TopKState(residual)


def aggregate_sparse(idx: jax.Array, val: jax.Array, n: int):
    """Aggregate (index, value) pairs with duplicate indices — the paper's
    duplicate-key aggregation.  idx (M,) uint32, val (M,) → dense (n,)."""
    st = sorted_groupby(idx, val[:, None])
    dense = jnp.zeros((n,), jnp.float32)
    keys = jnp.where(st.keys == EMPTY, n, st.keys).astype(jnp.int32)
    return dense.at[keys].add(st.sum[:, 0], mode="drop")


def allreduce_topk(grad: jax.Array, state: TopKState, k: int, axis_name: str):
    """Sparse all-reduce inside shard_map: top-k + all_gather of the sparse
    pairs + sort-based aggregation.  Communication per device:
    2k·world words instead of N."""
    n = grad.shape[0]
    idx, val, new_state = compress_topk(grad, state, k)
    all_idx = jax.lax.all_gather(idx, axis_name).reshape(-1)
    all_val = jax.lax.all_gather(val, axis_name).reshape(-1)
    return aggregate_sparse(all_idx, all_val, n), new_state
