"""Optimizers built from scratch (no optax in this environment).

* ``adamw``     — the default.
* ``adafactor`` — factored second moment; the memory plan for the 671B
  model (params+grads+factored-V ≈ 10.5 GB/chip on a v5e-256, where Adam's
  fp32 moments alone would need 21 GB/chip).

Both are pytree-polymorphic and pjit-transparent: optimizer state inherits
parameter shardings leaf-by-leaf (fully sharded optimizer = ZeRO-style for
FSDP-sharded params).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    m: Any  # first moment (adamw) or factored rows (adafactor)
    v: Any  # second moment (adamw) or factored cols (adafactor)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          moment_dtype=jnp.float32):
    lr_fn = lr if callable(lr) else (lambda s: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(zeros, params), jax.tree.map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            m_new = b1 * m32 + (1 - b1) * g
            v_new = b2 * v32 + (1 - b2) * g * g
            mh, vh = m_new / bc1, v_new / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_t * delta).astype(p.dtype),
                    m_new.astype(moment_dtype), v_new.astype(moment_dtype))

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, OptState(step, new_m, new_v)

    return init, update


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment by default)
# ---------------------------------------------------------------------------


def adafactor(lr=1e-3, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0):
    lr_fn = lr if callable(lr) else (lambda s: lr)

    def init(params):
        def rows(p):
            if p.ndim < 2:
                return jnp.zeros(p.shape, jnp.float32)
            return jnp.zeros(p.shape[:-1], jnp.float32)

        def cols(p):
            if p.ndim < 2:
                return jnp.zeros((1,), jnp.float32)
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(rows, params), jax.tree.map(cols, params))

    def update(grads, state, params):
        step = state.step + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)
        lr_t = lr_fn(step)

        def upd(p, g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim < 2:
                vr_new = beta * vr + (1 - beta) * g2
                u = g * jax.lax.rsqrt(vr_new)
                vc_new = vc
            else:
                vr_new = beta * vr + (1 - beta) * g2.mean(-1)
                vc_new = beta * vc + (1 - beta) * g2.mean(-2)
                denom = vr_new[..., None] * vc_new[..., None, :]
                denom = denom / jnp.maximum(
                    vr_new.mean(-1)[..., None, None], eps
                )
                u = g * jax.lax.rsqrt(denom + eps)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            delta = u + weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr_t * delta).astype(p.dtype),
                    vr_new, vc_new)

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, OptState(step, new_m, new_v)

    return init, update


def make_optimizer(name: str, lr, **kw):
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(name)
