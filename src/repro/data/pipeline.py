"""Training data pipeline with checkpointable state, built on the paper's
engine for its grouping stages.

The paper's motivating workload is web-log scale duplicate removal
("billions of log records → millions of users").  The same problem shows
up in LM corpora: near-duplicate documents.  ``dedup_examples`` removes
duplicate documents by content fingerprint with the in-sort operator —
sorted output then makes ``pack_by_length`` (group docs into fixed-length
training sequences) a single in-stream pass, the interesting-orderings
payoff in data engineering form.

The loader is deterministic-resumable: its full state is (seed, step),
carried in the training checkpoint.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import ExecConfig, distinct
from repro.core.types import EMPTY


def iter_column_batches(columns, rows: int):
    """Split a column mapping into ``rows``-row batch mappings — the
    chunked source adapter for the streamed ``repro.aggregate`` front
    door (pass the resulting generator as ``columns``).

    The engine never sees the whole table at once: each yielded batch is
    packed, staged, and absorbed independently, so the device footprint
    is bounded by ``rows`` regardless of the table's size."""
    if rows <= 0:
        raise ValueError(f"rows must be positive, got {rows}")
    cols = {k: np.asarray(v) for k, v in columns.items()}
    if not cols:
        return
    n = len(next(iter(cols.values())))
    for k, v in cols.items():
        if len(v) != n:
            raise ValueError(
                f"column {k!r} has {len(v)} rows, expected {n}"
            )
    for s in range(0, n, rows):
        yield {k: v[s : s + rows] for k, v in cols.items()}


def rebatch_columns(batches, rows: int):
    """Re-chunk an iterable of column-batch mappings to ``rows``-row
    batches (host NumPy).  Producers emit whatever granularity is natural
    (log shards, parquet row groups, …); the engine wants super-batches
    big enough to amortize dispatch — this adapter sits between them.
    The final partial batch is yielded as-is."""
    if rows <= 0:
        raise ValueError(f"rows must be positive, got {rows}")
    buf: dict[str, list[np.ndarray]] = {}
    have = 0
    for batch in batches:
        batch = {k: np.asarray(v) for k, v in batch.items()}
        if not batch:
            continue
        n = len(next(iter(batch.values())))
        if n == 0:
            continue
        if buf and set(batch) != set(buf):
            raise ValueError(
                f"batch columns {sorted(batch)} != stream columns "
                f"{sorted(buf)}"
            )
        for k, v in batch.items():
            buf.setdefault(k, []).append(v)
        have += n
        while have >= rows:
            cat = {k: np.concatenate(v) if len(v) > 1 else v[0]
                   for k, v in buf.items()}
            yield {k: v[:rows] for k, v in cat.items()}
            buf = {k: [v[rows:]] for k, v in cat.items()}
            have -= rows
    if have:
        yield {k: np.concatenate(v) if len(v) > 1 else v[0]
               for k, v in buf.items()}


@dataclasses.dataclass
class SyntheticCorpus:
    """Deterministic synthetic corpus: duplicated zipf-ish documents."""

    vocab: int
    seed: int = 0
    dup_rate: float = 0.3
    n_docs: int = 4096
    max_len: int = 512

    def documents(self) -> list[np.ndarray]:
        rng = np.random.default_rng(self.seed)
        base: list[np.ndarray] = []
        docs: list[np.ndarray] = []
        for _ in range(self.n_docs):
            if base and rng.random() < self.dup_rate:
                docs.append(base[rng.integers(len(base))])  # duplicate
            else:
                ln = int(rng.integers(16, self.max_len))
                d = rng.integers(0, self.vocab, ln).astype(np.int32)
                base.append(d)
                docs.append(d)
        return docs


def fingerprint(doc: np.ndarray) -> np.uint32:
    """Order-sensitive 32-bit content hash (FNV-ish, vectorized)."""
    h = np.uint64(2166136261)
    mul = np.uint64(16777619)
    for chunk in np.array_split(doc.astype(np.uint64), max(1, len(doc) // 64)):
        h = (h * mul + np.uint64(chunk.sum() % (1 << 32))) % (1 << 32)
        h = (h * mul + np.uint64((chunk * np.arange(1, len(chunk) + 1,
             dtype=np.uint64)).sum() % (1 << 32))) % (1 << 32)
    return np.uint32(h % np.uint64(0xFFFFFFFE))


def dedup_examples(docs: list[np.ndarray], cfg: ExecConfig | None = None):
    """DISTINCT on document fingerprints via the paper's operator.

    Returns (unique docs, spill stats).  Output order is fingerprint-sorted
    (the operator's interesting ordering), keeping downstream grouping
    passes in-stream."""
    cfg = cfg or ExecConfig()
    prints = np.asarray([fingerprint(d) for d in docs], dtype=np.uint32)
    state, stats = distinct(prints, cfg, output_estimate=len(docs))
    keys = np.asarray(state.keys)
    keys = keys[keys != EMPTY]
    first_idx = {}
    for i, p in enumerate(prints):
        first_idx.setdefault(int(p), i)
    uniq = [docs[first_idx[int(k)]] for k in keys]
    return uniq, stats


def pack_by_length(docs: list[np.ndarray], seq_len: int) -> np.ndarray:
    """Greedy first-fit packing of docs into (N, seq_len) rows (-1 pad).

    Sorting docs by length first (one more sort!) raises packing density;
    the group boundaries double as the loss mask."""
    order = np.argsort([len(d) for d in docs])[::-1]
    rows: list[list[np.ndarray]] = []
    space: list[int] = []
    for i in order:
        d = docs[i][:seq_len]
        placed = False
        for r in range(len(rows)):
            if space[r] >= len(d):
                rows[r].append(d)
                space[r] -= len(d)
                placed = True
                break
        if not placed:
            rows.append([d])
            space.append(seq_len - len(d))
    out = np.full((len(rows), seq_len), -1, np.int32)
    for r, ds in enumerate(rows):
        cur = 0
        for d in ds:
            out[r, cur : cur + len(d)] = d
            cur += len(d)
    return out


@dataclasses.dataclass
class DataLoader:
    """Deterministic resumable batches of (tokens, labels)."""

    vocab: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, vocab, batch, seq, state):
        return cls(vocab, batch, seq, seed=state["seed"], step=state["step"])

    def next(self):
        rng = np.random.default_rng((self.seed, self.step))
        toks = rng.integers(0, self.vocab, (self.batch, self.seq + 1)).astype(np.int32)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
