from repro.data.pipeline import SyntheticCorpus, DataLoader, dedup_examples, pack_by_length
