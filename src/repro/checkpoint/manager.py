"""Fault-tolerant checkpointing: sharded save/restore, atomic manifests,
async writes, retention, and ELASTIC restore onto a different mesh.

Layout (one directory per step):

    <root>/step_000120/
        manifest.json        # tree structure, shapes, dtypes, step, extras
        shard_p0.npz         # this process's addressable leaf shards

Design points for 1000+ node fleets:
* every process writes only its addressable shards (here: one process);
* the manifest is written LAST and renamed atomically — a partially
  written checkpoint is never visible;
* restore is sharding-agnostic: leaves are placed with jax.device_put
  against the *target* sharding, so a job restarted on a different
  data-parallel width (elastic scaling) re-shards transparently;
* async: `save(..., blocking=False)` hands the host copy to a writer
  thread; training continues immediately (the step's arrays are already
  snapshotted to host numpy);
* data-pipeline state (step, rng, file cursor) rides in the manifest so
  resume is exactly-once w.r.t. the input stream.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


def save_pytree(tree, directory: str, *, step: int, extras: dict | None = None,
                process_index: int = 0):
    os.makedirs(directory, exist_ok=True)
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, f"shard_p{process_index}.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "extras": extras or {},
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "num_processes": jax.process_count(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)  # atomic publish


def restore_pytree(tree_like, directory: str, *, shardings=None):
    """Restore into the structure of ``tree_like``; if ``shardings`` is
    given, leaves are device_put against it (elastic re-shard)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, "shard_p0.npz"))
    flat_keys = _flatten(tree_like).keys()
    restored = {k: data[k] for k in flat_keys}
    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    flat_map = _flatten(tree_like)
    out_leaves = []
    if shardings is not None:
        sh_map = _flatten(shardings)
    for key in flat_map:
        arr = restored[key]
        if shardings is not None and key in sh_map:
            arr = jax.device_put(arr, sh_map[key])
        out_leaves.append(arr)
    # rebuild in treedef order: _flatten preserves flatten order
    return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest


class CheckpointManager:
    """Step-granular manager with retention and async saves."""

    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: threading.Thread | None = None

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.root, d, "manifest.json")
            ):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, tree, step: int, *, extras=None, blocking: bool = True):
        self.wait()
        # snapshot to host before returning control (donation-safe)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            save_pytree(host_tree, self._dir(step), step=step, extras=extras)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def restore(self, tree_like, *, step: int | None = None, shardings=None):
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        return restore_pytree(tree_like, self._dir(step), shardings=shardings)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)
