"""Aggregation-serving driver: sustained synthetic ingest with periodic
merge-on-read snapshot queries — the streaming-service twin of the
model-serving loop in :mod:`repro.launch.serve`.

    PYTHONPATH=src python -m repro.launch.serve_agg --smoke
    PYTHONPATH=src python -m repro.launch.serve_agg \
        --chunks 200 --chunk-rows 8192 --snapshot-every 25 --policy rs

Drives one :class:`repro.service.AggregationService` session: synthetic
keyed traffic (watermark-major composite keys, Zipf-ish duplication)
flows through the double-buffered ingest path while every
``--snapshot-every`` chunks a snapshot query runs against the live
engine.  Reports sustained ingest rows/sec and snapshot latency
p50/p99, plus the service metrics facade.  ``--ttl`` retires watermark
buckets older than that many snapshot periods at each snapshot
boundary (sessionization mode).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.types import ExecConfig
from repro.service import AggregationService


def synth_chunks(n_chunks: int, rows: int, *, keyspace: int, seed: int,
                 drift: float = 0.02):
    """Synthetic keyed traffic: a slowly drifting hot window over a large
    key space — duplicate-heavy inside a chunk (early aggregation has
    something to do), with keys trending upward so watermark eviction
    retires real data."""
    rng = np.random.default_rng(seed)
    for i in range(n_chunks):
        lo = int(i * drift * keyspace)
        keys = (lo + rng.integers(0, keyspace, rows)).astype(np.uint32)
        pay = rng.standard_normal((rows, 1)).astype(np.float32)
        yield keys, pay


def serve(*, chunks=100, chunk_rows=4096, snapshot_every=20, policy="rs",
          backend="auto", memory_rows=4096, batch_rows=512, ttl=0,
          overlap=True, warmup=True, seed=0, quiet=False):
    cfg = ExecConfig(memory_rows=memory_rows, page_rows=256, fanin=8,
                     batch_rows=batch_rows)
    keyspace = max(1024, chunk_rows)

    def make_service():
        return AggregationService(
            cfg, policy=policy, backend=backend, key_dtype=np.uint32,
            width=1,
            output_rows=1 << max(12, (chunks * chunk_rows - 1).bit_length()),
            # upper-bound the distinct-key estimate so the pre-merge
            # planner inserts enough levels for a session's worth of runs
            output_estimate=chunks * chunk_rows,
            overlap=overlap,
        )

    if warmup:
        # warm EVERY compiled-program bucket the measured session will
        # visit (absorb/grow/snapshot statics are pow2-bucketed, so a
        # twin session over the same schedule hits the same jit caches —
        # the measured loop then runs pure steady state)
        twin = make_service()
        for i, (k, p) in enumerate(synth_chunks(
                chunks, chunk_rows, keyspace=keyspace, seed=seed + 1)):
            twin.ingest(k, p)
            if snapshot_every and (i + 1) % snapshot_every == 0:
                if ttl:
                    lo = int((i + 1 - ttl * snapshot_every) * 0.02 * keyspace)
                    if lo > 0:
                        twin.retire_below(lo)
                twin.snapshot()
        twin.close()

    svc = make_service()
    drift = 0.02
    t_ingest = 0.0
    rows_done = 0
    t0 = time.perf_counter()
    for i, (keys, pay) in enumerate(
            synth_chunks(chunks, chunk_rows, keyspace=keyspace, seed=seed)):
        svc.ingest(keys, pay)
        rows_done += len(keys)
        if snapshot_every and (i + 1) % snapshot_every == 0:
            t_ingest += time.perf_counter() - t0
            if ttl:
                lo = int((i + 1 - ttl * snapshot_every) * drift * keyspace)
                if lo > 0:
                    svc.retire_below(lo)
            state, stats = svc.snapshot()
            if not quiet:
                print(f"  chunk {i + 1:5d}: snapshot groups="
                      f"{int(state.occupancy())} retired="
                      f"{stats.rows_retired} "
                      f"({svc.metrics.snapshot_latencies_s[-1] * 1e3:.1f} ms)")
            t0 = time.perf_counter()
    t_ingest += time.perf_counter() - t0
    state, stats = svc.close()
    m = svc.metrics
    report = {
        "rows_ingested": m.rows_ingested,
        "ingest_rows_per_s": rows_done / max(t_ingest, 1e-9),
        "snapshots": m.snapshots_taken,
        "snapshot_p50_ms": m.snapshot_latency_s(0.5) * 1e3,
        "snapshot_p99_ms": m.snapshot_latency_s(0.99) * 1e3,
        "final_groups": int(state.occupancy()),
        "rows_retired": int(stats.rows_retired),
        "duplicate_rate": m.duplicate_rate,
    }
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunks", type=int, default=100)
    ap.add_argument("--chunk-rows", type=int, default=4096)
    ap.add_argument("--snapshot-every", type=int, default=20)
    ap.add_argument("--policy", default="rs",
                    choices=("traditional", "inrun_dedup", "early_agg", "rs"))
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--memory-rows", type=int, default=4096)
    ap.add_argument("--batch-rows", type=int, default=512)
    ap.add_argument("--ttl", type=int, default=0,
                    help="retire watermarks older than TTL snapshot "
                         "periods (0 = keep everything)")
    ap.add_argument("--no-overlap", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    kw = dict(chunks=args.chunks, chunk_rows=args.chunk_rows,
              snapshot_every=args.snapshot_every, policy=args.policy,
              backend=args.backend, memory_rows=args.memory_rows,
              batch_rows=args.batch_rows, ttl=args.ttl,
              overlap=not args.no_overlap)
    if args.smoke:
        kw.update(chunks=12, chunk_rows=512, snapshot_every=4,
                  memory_rows=256, batch_rows=64)
    r = serve(**kw)
    print(f"ingested {r['rows_ingested']} rows at "
          f"{r['ingest_rows_per_s'] / 1e6:.2f} M rows/s sustained")
    print(f"{r['snapshots']} snapshots: p50 {r['snapshot_p50_ms']:.1f} ms, "
          f"p99 {r['snapshot_p99_ms']:.1f} ms")
    print(f"final groups {r['final_groups']}, rows retired "
          f"{r['rows_retired']}, duplicate rate {r['duplicate_rate']:.3f}")


if __name__ == "__main__":
    main()
