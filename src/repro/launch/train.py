"""End-to-end training driver: data pipeline → sharded train loop →
checkpoint/restart — runnable on 1 CPU device (smoke configs) and, with
the same code path, on the production meshes.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

Fault tolerance: checkpoints carry model+optimizer state AND the data
loader cursor; `--resume` restarts bit-exactly (tested).  On preemption
(SIGTERM) the loop saves and exits cleanly.
"""
from __future__ import annotations

import argparse
import dataclasses as dc
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataLoader
from repro.launch import steps as ST


def train(arch: str, *, smoke: bool = True, steps: int = 50, batch: int = 8,
          seq: int = 128, ckpt_dir: str | None = None, resume: bool = False,
          lr: float = 3e-4, log_every: int = 10, save_every: int = 25,
          mesh=None):
    cfg = get_config(arch, smoke=smoke)
    if mesh is not None:
        cfg = dc.replace(cfg, mesh_axes=tuple(mesh.axis_names))
    train_step, init_state, opt_name = ST.make_train_step(cfg, lr=lr)
    step_fn = jax.jit(train_step, donate_argnums=(0,))

    state = init_state(jax.random.PRNGKey(0))
    loader = DataLoader(cfg.vocab, batch, seq, seed=17)
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr and resume and mgr.latest_step() is not None:
        state, manifest = mgr.restore(state)
        loader = DataLoader.from_state(cfg.vocab, batch, seq,
                                       manifest["extras"]["loader"])
        start_step = manifest["step"]
        print(f"resumed from step {start_step}")

    stop = {"now": False}
    signal.signal(signal.SIGTERM, lambda *_: stop.update(now=True))

    losses = []
    t0 = time.time()
    for i in range(start_step, steps):
        batch_np = loader.next()
        b = {k: jnp.asarray(v) for k, v in batch_np.items()}
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                   (3, batch, seq))
            b["mrope_pos"] = pos
        if cfg.frontend_stub:
            # modality stub: embed tokens through a fixed projection stand-in
            rng = np.random.default_rng(0)
            # deterministic pseudo-embeddings keyed by token id
            emb = jnp.asarray(rng.normal(size=(cfg.vocab, cfg.d_model)) * 0.02,
                              jnp.float32)
            b["tokens"] = jnp.take(emb, b["tokens"], axis=0)
        state, metrics = step_fn(state, b)
        losses.append(float(metrics["loss"]))
        if (i + 1) % log_every == 0:
            dt = (time.time() - t0) / log_every
            print(f"step {i+1}: loss={losses[-1]:.4f} "
                  f"nll={float(metrics['nll']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f} ms/step")
            t0 = time.time()
        if mgr and ((i + 1) % save_every == 0 or stop["now"] or i + 1 == steps):
            mgr.save(state, i + 1, extras={"loader": loader.state()},
                     blocking=False)
        if stop["now"]:
            print("preemption signal: checkpoint saved, exiting")
            break
    if mgr:
        mgr.wait()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    losses = train(args.arch, smoke=not args.full, steps=args.steps,
                   batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt,
                   resume=args.resume, lr=args.lr)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
