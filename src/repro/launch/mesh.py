"""Production mesh construction (never touches jax device state at import).

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; "pod" is a pure
data-parallel (or pipeline) axis across the slower inter-pod links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device unit tests (8 host devices)."""
    return jax.make_mesh(shape, axes)
