import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, prove memory fits, and extract roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod] [--out results.json]

The XLA_FLAGS line above MUST run before any jax import: it provides 512
placeholder host devices for the 2×16×16 production mesh.
"""
import argparse
import dataclasses as dc
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import all_arch_ids, get_config
from repro.launch import roofline as RL
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh

HBM_PER_CHIP = 16e9  # v5e


def dryrun_cell(arch: str, shape: str, *, multi_pod: bool = False,
                verbose: bool = True, dispatch: str | None = None,
                extra=None):
    """Lower+compile one cell; returns a result dict (or skip record)."""
    cfg = get_config(arch)
    skip = ST.shape_skips(cfg, shape)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if skip:
        rec["status"] = "skip"
        rec["reason"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = dc.replace(cfg, mesh_axes=tuple(mesh.axis_names))
    from repro.distributed import moe_parallel as MP
    MP.set_current_mesh(mesh)
    chips = mesh.devices.size
    info = ST.SHAPES[shape]
    kind = info["kind"]
    t0 = time.time()
    try:
      with mesh:
          batch_sds = ST.input_specs(cfg, shape)
          batch_sh = ST.batch_shardings(cfg, mesh, shape)
          if kind == "train":
              step, _, opt_name = ST.make_train_step(cfg, dispatch=dispatch)
              state_sds = ST.abstract_train_state(cfg, opt_name)
              state_sh = ST.state_shardings(cfg, mesh, opt_name)
              jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                               out_shardings=(state_sh, None),
                               donate_argnums=(0,))
              lowered = jitted.lower(state_sds, batch_sds)
              rec["optimizer"] = opt_name
          else:
              pshapes, logical = ST.abstract_init(cfg)
              from repro.distributed import sharding as SH

              pspecs = SH.tree_specs(logical, SH.rules_for_mesh(mesh))
              psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
              if kind == "prefill":
                  fn = ST.make_prefill_step(cfg, max_len=info["seq"])
                  out_sh = (None, ST.batch_shardings(cfg, mesh, _decode_shape(shape))["caches"])
                  jitted = jax.jit(fn, in_shardings=(psh, batch_sh),
                                   out_shardings=out_sh)
              else:
                  fn = ST.make_serve_step(cfg)
                  out_sh = (None, batch_sh["caches"])
                  jitted = jax.jit(fn, in_shardings=(psh, batch_sh),
                                   out_shardings=out_sh,
                                   donate_argnums=(1,))
              lowered = jitted.lower(pshapes, batch_sds)
          compiled = lowered.compile()
          rec["lower_compile_s"] = round(time.time() - t0, 1)
          mem = compiled.memory_analysis()
          rec["memory"] = {
              "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
              "output_bytes": getattr(mem, "output_size_in_bytes", None),
              "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
              "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
          }
          arg_b = rec["memory"]["argument_bytes"] or 0
          tmp_b = rec["memory"]["temp_bytes"] or 0
          # memory_analysis reports per-device figures on SPMD modules.
          # CPU-backend caveat (verified on a minimal repro): XLA-CPU has no
          # native bf16 dot, so it converts bf16 operands to f32 and hoists
          # the converted copies out of loops — temp doubles vs TPU, where
          # bf16 dots are native.  Correct bf16 programs by 2× and report
          # both numbers.
          corrected = tmp_b / 2 if cfg.dtype == "bfloat16" else tmp_b
          rec["memory"]["temp_bytes_tpu_corrected"] = corrected
          per_dev = arg_b + corrected
          rec["memory"]["per_device_estimate"] = per_dev
          rec["memory"]["fits_16GB"] = bool(per_dev < HBM_PER_CHIP)
          rl = RL.from_compiled(compiled, chips)
          rec["roofline"] = rl.as_dict()
          rec["roofline"]["collective_breakdown"] = {
              k: v for k, v in (rl.coll_breakdown or {}).items()
              if not str(k).startswith("_")
          }
          rec["roofline"]["collective_counts"] = (rl.coll_breakdown or {}).get("_counts")
          mf = RL.model_flops(cfg, info, kind)
          rec["roofline"]["model_flops"] = mf
          rec["roofline"]["useful_flops_frac"] = (
              mf / rl.flops if rl.flops else None
          )
          rec["status"] = "ok"
          if verbose:
              print(f"[{rec['mesh']}] {arch} × {shape}: OK "
                    f"({rec['lower_compile_s']}s compile)")
              print("  memory:", rec["memory"])
              print("  roofline:", {k: v for k, v in rec["roofline"].items()
                                    if k != "collective_breakdown"})
    except Exception as e:  # sharding mismatch, OOM at compile, …
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=8)
        if verbose:
            print(f"[{rec['mesh']}] {arch} × {shape}: FAIL {rec['error']}")
    return rec


def _decode_shape(prefill_shape: str) -> str:
    return {"prefill_32k": "decode_32k"}.get(prefill_shape, prefill_shape)


# ---------------------------------------------------------------------------
# calibrated roofline: XLA cost_analysis counts a lax.scan body ONCE, so we
# measure two small-depth UNROLLED variants at full width/batch/mesh and
# linearly extrapolate:  total(L) = base + L·marginal.
# ---------------------------------------------------------------------------


def _measure_costs(cfg, shape: str, mesh, dispatch=None):
    """(flops, hbm_bytes, coll_bytes/device) of one compiled variant."""
    cfg = dc.replace(cfg, mesh_axes=tuple(mesh.axis_names))
    from repro.distributed import moe_parallel as MP
    MP.set_current_mesh(mesh)
    info = ST.SHAPES[shape]
    kind = info["kind"]
    batch_sds = ST.input_specs(cfg, shape)
    batch_sh = ST.batch_shardings(cfg, mesh, shape)
    with mesh:
      if kind == "train":
        step, _, opt_name = ST.make_train_step(cfg, dispatch=dispatch)
        state_sds = ST.abstract_train_state(cfg, opt_name)
        state_sh = ST.state_shardings(cfg, mesh, opt_name)
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None),
                          donate_argnums=(0,)).lower(state_sds, batch_sds)
      else:
        from repro.distributed import sharding as SH

        pshapes, logical = ST.abstract_init(cfg)
        pspecs = SH.tree_specs(logical, SH.rules_for_mesh(mesh))
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        if kind == "prefill":
            fn = ST.make_prefill_step(cfg, max_len=info["seq"])
            out_sh = (None, ST.batch_shardings(cfg, mesh, _decode_shape(shape))["caches"])
            lowered = jax.jit(fn, in_shardings=(psh, batch_sh),
                              out_shardings=out_sh).lower(pshapes, batch_sds)
        else:
            fn = ST.make_serve_step(cfg)
            lowered = jax.jit(fn, in_shardings=(psh, batch_sh),
                              out_shardings=(None, batch_sh["caches"]),
                              donate_argnums=(1,)).lower(pshapes, batch_sds)
      compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = RL.collective_bytes(compiled.as_text())
    total_coll = sum(v for k, v in coll.items() if not str(k).startswith("_"))
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            total_coll, coll.get("_counts"))


def _depth_variants(cfg):
    """Two reduced-depth configs + the depth multiplier to full scale.

    For hybrid archs the repeating unit is one (period mamba + shared
    attention) group; otherwise it's a single layer of the homogeneous
    (or MoE) stack."""
    import dataclasses as dc

    if cfg.family == "hybrid":
        per = cfg.hybrid_shared_period
        a = dc.replace(cfg, n_layers=per, scan_layers=False)
        b = dc.replace(cfg, n_layers=2 * per, scan_layers=False)
        units = cfg.n_layers // per
        return a, b, units
    if cfg.moe is not None and cfg.moe.first_k_dense:
        kd = cfg.moe.first_k_dense
        a = dc.replace(cfg, n_layers=kd + 1, scan_layers=False)
        b = dc.replace(cfg, n_layers=kd + 2, scan_layers=False)
        units = cfg.n_layers - kd
        return a, b, units
    a = dc.replace(cfg, n_layers=1, scan_layers=False)
    b = dc.replace(cfg, n_layers=2, scan_layers=False)
    units = cfg.n_layers
    return a, b, units


def apply_overrides(cfg, overrides):
    """dc.replace with dotted keys for nested configs (moe.capacity_factor)."""
    if not overrides:
        return cfg
    direct = {k: v for k, v in overrides.items() if "." not in k}
    nested = {k: v for k, v in overrides.items() if "." in k}
    if direct:
        cfg = dc.replace(cfg, **direct)
    for k, v in nested.items():
        sub, field = k.split(".", 1)
        cfg = dc.replace(cfg, **{sub: dc.replace(getattr(cfg, sub), **{field: v})})
    return cfg


def calibrated_roofline(arch: str, shape: str, *, multi_pod: bool = False,
                        dispatch: str | None = None, overrides=None):
    """Roofline terms with scan-trip-count-corrected totals.

    Known residual undercounts (documented): nested scans inside ONE layer
    (MoE token-chunk loop, attention q-chunk loop) are still counted once
    by XLA; totals are corrected for the layer scan and the grad-accum
    scan, which dominate.  Comparisons that vary inner chunk counts must
    use op-count/buffer metrics instead (see §Perf cell B)."""
    cfg = apply_overrides(get_config(arch), overrides)
    skip = ST.shape_skips(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape, "status": "skip", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    a, b, units = _depth_variants(cfg)
    fa, ba, ca_, cnt_a = _measure_costs(a, shape, mesh, dispatch)
    fb, bb, cb_, cnt_b = _measure_costs(b, shape, mesh, dispatch)
    info0 = ST.SHAPES[shape]
    accum = cfg.grad_accum if info0["kind"] == "train" else 1
    # the grad-accum scan body is also counted once: scale totals back
    fa, ba, ca_ = fa * accum, ba * accum, ca_ * accum
    fb, bb, cb_ = fb * accum, bb * accum, cb_ * accum
    mf = max(1.0, fb - fa)
    mbytes = max(0.0, bb - ba)
    mcoll = max(0.0, cb_ - ca_)
    base_f = max(0.0, fa - mf * (a.n_layers if cfg.family != "hybrid" else 1))
    # base = measurement at depth a minus a's worth of marginals
    units_a = (1 if cfg.family == "hybrid"
               else (a.n_layers - (cfg.moe.first_k_dense if cfg.moe else 0))
               if cfg.moe is not None and cfg.moe.first_k_dense else a.n_layers)
    base_f = max(0.0, fa - mf * units_a)
    base_b = max(0.0, ba - mbytes * units_a)
    base_c = max(0.0, ca_ - mcoll * units_a)
    flops = base_f + mf * units
    hbm = base_b + mbytes * units
    coll = base_c + mcoll * units
    info = ST.SHAPES[shape]
    rl = RL.Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll, chips=chips)
    rec = {"arch": arch, "shape": shape, "status": "ok",
           "mesh": "2x16x16" if multi_pod else "16x16",
           "roofline": rl.as_dict()}
    mfl = RL.model_flops(cfg, info, info["kind"])
    rec["roofline"]["model_flops"] = mfl
    # cost_analysis flops are per-device on SPMD modules: scale by chips
    rec["roofline"]["useful_flops_frac"] = mfl / (flops * chips) if flops else None
    rec["roofline"]["collective_counts"] = cnt_b
    rec["units"] = units
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--dispatch", default=None, choices=[None, "dense", "sorted"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in all_arch_ids():
            for shape in ST.SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multipod]
    if args.both_meshes:
        meshes = [False, True]

    results = []
    for mp in meshes:
        for arch, shape in cells:
            results.append(dryrun_cell(arch, shape, multi_pod=mp,
                                       dispatch=args.dispatch))
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skip" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run: {ok} ok, {skip} skip, {err} error ==")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
        print("wrote", args.out)
    return 0 if err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
