import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimbing driver: per cell, run the baseline and a list of
hypothesis-driven variants; record roofline terms + memory per iteration.

  PYTHONPATH=src python -m repro.launch.hillclimb --cell mistral
"""
import argparse
import json

from repro.launch.dryrun import calibrated_roofline, dryrun_cell, apply_overrides

CELLS = {
    # most collective-bound: FSDP gathers × grad-accum microbatches
    "mistral": {
        "arch": "mistral-large-123b", "shape": "train_4k",
        "iters": [
            ("baseline_accum4_sp", {}),
            ("accum2", {"grad_accum": 2}),
            ("accum1", {"grad_accum": 1}),
            ("accum2_nosp", {"grad_accum": 2, "sp": False}),
        ],
    },
    # the paper's technique cell: sorted EP dispatch knobs + dense baseline
    "qwen3": {
        "arch": "qwen3-moe-30b-a3b", "shape": "train_4k",
        "iters": [
            ("baseline_sorted_chunk8k_cf1.25", {}),
            ("chunk16k", {"moe_chunk": 16384}),
            ("cf1.0", {"moe.capacity_factor": 1.0}),
            ("chunk16k_cf1.0", {"moe_chunk": 16384, "moe.capacity_factor": 1.0}),
        ],
    },
    # collective-dominated decode: cache sharding layout
    "llama3_decode": {
        "arch": "llama3-8b", "shape": "decode_32k",
        "iters": [
            ("baseline_seqsharded_cache", {}),
            ("kv_dup2_headsharded", {"kv_dup": 2}),
            ("kv_dup2_chunk4k", {"kv_dup": 2, "attn_chunk_k": 4096}),
        ],
    },
}


def run_cell(name):
    spec = CELLS[name]
    out = []
    for tag, overrides in spec["iters"]:
        rec = calibrated_roofline(spec["arch"], spec["shape"],
                                  overrides=overrides)
        mem = memory_probe(spec["arch"], spec["shape"], overrides)
        rec["iter"] = tag
        rec["overrides"] = overrides
        rec["memory"] = mem
        rl = rec.get("roofline", {})
        print(f"[{name}] {tag}: compute={rl.get('t_compute_s', 0)*1e3:.0f}ms "
              f"memory={rl.get('t_memory_s', 0)*1e3:.0f}ms "
              f"collective={rl.get('t_collective_s', 0)*1e3:.0f}ms "
              f"bottleneck={rl.get('bottleneck')} mem/dev={mem:.1f}GB",
              flush=True)
        out.append(rec)
    return out


def memory_probe(arch, shape, overrides):
    """per-device (args + corrected temp) GB from the full scanned build."""
    import repro.launch.dryrun as DR
    from repro.configs import get_config

    orig = DR.get_config
    DR.get_config = lambda a: apply_overrides(get_config(a), overrides)
    try:
        rec = DR.dryrun_cell(arch, shape, verbose=False)
    finally:
        DR.get_config = orig
    if rec["status"] != "ok":
        return float("nan")
    return rec["memory"]["per_device_estimate"] / 1e9


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all")
    ap.add_argument("--out", default="hillclimb_results.json")
    args = ap.parse_args()
    cells = list(CELLS) if args.cell == "all" else [args.cell]
    results = {}
    for c in cells:
        results[c] = run_cell(c)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
