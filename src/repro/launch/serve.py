"""Batched serving driver: continuous-batching decode loop with prefill
admission — the serving-side example application.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import steps as ST
from repro.models import model as M


def serve(arch: str, *, smoke=True, batch=4, prompt_len=32, gen=16,
          max_len=None, seed=0):
    cfg = get_config(arch, smoke=smoke)
    max_len = max_len or (prompt_len + gen)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)

    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(ST.make_prefill_step(cfg, max_len))
    decode = jax.jit(ST.make_serve_step(cfg), donate_argnums=())

    t0 = time.time()
    batch_in = {"tokens": jnp.asarray(prompts)}
    if cfg.frontend_stub:
        emb = jnp.asarray(rng.normal(size=(cfg.vocab, cfg.d_model)) * 0.02,
                          jnp.float32)
        batch_in["tokens"] = jnp.take(emb, batch_in["tokens"], axis=0)
    if cfg.rope == "mrope":
        batch_in["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(prompt_len, dtype=jnp.int32), (3, batch, prompt_len))
    logits, caches = prefill(params, batch_in)
    t_prefill = time.time() - t0
    out_tokens = [np.asarray(jnp.argmax(logits[:, -1], -1))]
    t0 = time.time()
    for i in range(gen - 1):
        tok = jnp.asarray(out_tokens[-1][:, None])
        step_in = {"token": tok, "caches": caches}
        if cfg.rope == "mrope":
            step_in["mrope_pos"] = jnp.full((3, batch, 1), prompt_len + i,
                                            jnp.int32)
        logits, caches = decode(params, step_in)
        out_tokens.append(np.asarray(jnp.argmax(logits[:, -1], -1)))
    t_decode = (time.time() - t0) / max(1, gen - 1)
    gen_ids = np.stack(out_tokens, axis=1)
    return gen_ids, t_prefill, t_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    ids, tp, td = serve(args.arch, smoke=not args.full, batch=args.batch,
                        prompt_len=args.prompt_len, gen=args.gen)
    print(f"generated {ids.shape} tokens; prefill {tp*1e3:.1f} ms, "
          f"decode {td*1e3:.2f} ms/token")
    print("sample:", ids[0][:12])


if __name__ == "__main__":
    main()
