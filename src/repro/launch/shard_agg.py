"""Multi-host sharded aggregation driver — the launch recipe for the
capacity-bounded cross-shard exchange.

Single host (fake devices make a world without hardware):

    PYTHONPATH=src python -m repro.launch.shard_agg --smoke
    PYTHONPATH=src python -m repro.launch.shard_agg --fake-devices 8 \
        --rows 65536 --zipf 1.2

Multi-host (one process per host, same command everywhere but the id):

    REPRO_COORDINATOR=host0:1234 REPRO_NUM_PROCESSES=2 REPRO_PROCESS_ID=0 \
        PYTHONPATH=src python -m repro.launch.shard_agg --rows 1048576
    REPRO_COORDINATOR=host0:1234 REPRO_NUM_PROCESSES=2 REPRO_PROCESS_ID=1 \
        PYTHONPATH=src python -m repro.launch.shard_agg --rows 1048576

Each process calls :func:`repro.distributed.sharding.init_distributed`
(a no-op without the env vars), builds a 1-D mesh over the GLOBAL
device list, feeds its process-local slice of a synthetic Zipf-skewed
batch through :func:`repro.core.pipeline.insort_aggregate_device`, and
prints the exchange accounting that this PR's quota work added to
:class:`~repro.core.types.SpillStats`: the derived per-peer quota, the
fullest segment actually sent (``exchange_max_fill``), the fill
fraction, retry count, and the analytic per-shard exchange footprint.

``--fake-devices N`` must be handled BEFORE jax import (it sets
``XLA_FLAGS=--xla_force_host_platform_device_count=N``), which is why
argument parsing happens at module top level in :func:`main`.
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _zipf_keys(rng, n, domain, s, dtype):
    """Bounded-domain Zipf(s) draw: p(rank) ~ 1/rank**s over ``domain``
    distinct keys (s=0 is uniform).  numpy's rng.zipf is unsuitable here:
    it needs s>1 and has unbounded support."""
    import numpy as np

    ranks = np.arange(1, domain + 1, dtype=np.float64)
    p = ranks ** -float(s)
    p /= p.sum()
    return rng.choice(domain, size=n, p=p).astype(dtype)


def run(*, rows=65536, zipf=0.0, policy="rs", memory_rows=4096,
        batch_rows=512, width=1, seed=0, quiet=False):
    # jax imported here, after main() fixed XLA_FLAGS.  init_distributed
    # must run before ANY jax computation (jax raises otherwise), so it
    # goes before the pipeline imports — those trace code at import time.
    import jax
    import numpy as np

    from repro.distributed.sharding import (
        data_mesh,
        host_local_array,
        init_distributed,
    )

    multi = init_distributed()

    from repro.core.pipeline import insort_aggregate_device
    from repro.core.types import ExecConfig, empty_key
    from repro.distributed import groupby as gb
    from jax.sharding import PartitionSpec as P
    mesh = data_mesh("shard")
    world = jax.device_count()
    nproc = jax.process_count()
    if not quiet:
        print(f"world={world} devices across {nproc} process(es) "
              f"(jax.distributed {'ON' if multi else 'off'})")

    # Every process generates only ITS slice of the global batch: the
    # global row count is rows, each process holds rows // nproc.
    rows -= rows % world
    loc = rows // nproc
    rng = np.random.default_rng(seed + jax.process_index())
    domain = max(1024, rows // 4)
    keys = _zipf_keys(rng, loc, domain, zipf, np.uint32)
    payload = rng.standard_normal((loc, width)).astype(np.float32)
    spec = P("shard")
    gkeys = host_local_array(keys, mesh, spec)
    gpay = host_local_array(payload, mesh, P("shard", None))

    cfg = ExecConfig(memory_rows=memory_rows, page_rows=256, fanin=8,
                     batch_rows=batch_rows)
    t0 = time.perf_counter()
    st, stats = insort_aggregate_device(
        gkeys, gpay, cfg, policy=policy, mesh=mesh, mesh_axis="shard")
    jax.block_until_ready(st.keys)
    dt = time.perf_counter() - t0
    # group count as a jitted global reduction (works on multi-host
    # arrays, where np.asarray on the sharded output would not)
    groups = int(jax.jit(
        lambda k: (k != empty_key(k.dtype)).sum())(st.keys))

    quota = stats.exchange_quota
    fill = stats.exchange_max_fill
    foot = gb.exchange_footprint_rows(world, quota) if quota else 0
    report = {
        "world": world,
        "processes": nproc,
        "rows_global": rows,
        "zipf_s": zipf,
        "policy": policy,
        "groups": groups,
        "rows_exchanged": int(stats.rows_exchanged),
        "exchange_quota": int(quota),
        "exchange_max_fill": int(fill),
        "fill_frac": round(fill / quota, 4) if quota else 0.0,
        "exchange_retries": int(stats.exchange_retries),
        "exchange_footprint_rows": int(foot),
        "seconds": round(dt, 4),
    }
    if not quiet:
        for k, v in report.items():
            print(f"  {k:24s} {v}")
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--rows", type=int, default=65536,
                    help="GLOBAL row count (split across processes)")
    ap.add_argument("--zipf", type=float, default=0.0,
                    help="Zipf skew s (0 = uniform)")
    ap.add_argument("--policy", default="rs",
                    choices=["rs", "ms", "insort", "hash"])
    ap.add_argument("--memory-rows", type=int, default=4096)
    ap.add_argument("--batch-rows", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="force N host-platform devices (single process)")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    if args.fake_devices:
        if "jax" in sys.modules:
            raise RuntimeError("--fake-devices must be set before jax import")
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.fake_devices}").strip()

    if args.smoke:
        run(rows=4096, zipf=1.2, memory_rows=1024, batch_rows=256,
            seed=args.seed)
        return

    run(rows=args.rows, zipf=args.zipf, policy=args.policy,
        memory_rows=args.memory_rows, batch_rows=args.batch_rows,
        seed=args.seed)


if __name__ == "__main__":
    main()
