import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Assemble EXPERIMENTS.md §Dry-run and §Roofline from sweep artifacts.

  PYTHONPATH=src python -m repro.launch.make_report \
      --dryrun dryrun_results.json --calibrate --out experiments_tables.md
"""
import argparse
import json

from repro.configs import all_arch_ids
from repro.launch import steps as ST


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}G"


def dryrun_table(results):
    lines = [
        "| arch | shape | mesh | status | compile_s | args/dev | temp/dev (tpu-corr) | fits 16G | collectives (AG/AR/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r["status"] == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP: {r['reason']} | | | | | |")
            continue
        if r["status"] == "error":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL**: {r['error'][:60]} | | | | | |")
            continue
        m = r["memory"]
        cc = r["roofline"].get("collective_counts") or {}
        cstr = "/".join(str(cc.get(k, 0)) for k in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r.get('lower_compile_s','-')} | {fmt_bytes(m['argument_bytes'])} "
            f"| {fmt_bytes(m['temp_bytes_tpu_corrected'])} "
            f"| {'✓' if m['fits_16GB'] else '✗'} | {cstr} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck | MODEL_FLOPS | useful/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP: {r.get('reason','')} | | | | | | |")
            continue
        rl = r["roofline"]
        uf = rl.get("useful_flops_frac")
        # roofline fraction: useful model flops over the machine-time the
        # dominant term implies (how close the step is to the best term)
        t_dom = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        mf = rl.get("model_flops", 0.0)
        frac = (mf / (256 * 197e12)) / t_dom if t_dom else 0.0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['t_compute_s']*1e3:.1f}ms "
            f"| {rl['t_memory_s']*1e3:.1f}ms | {rl['t_collective_s']*1e3:.1f}ms "
            f"| **{rl['bottleneck']}** | {mf:.2e} | {uf:.3f} | {frac:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="dryrun_results.json")
    ap.add_argument("--calibrate", action="store_true")
    ap.add_argument("--calib-out", default="roofline_calibrated.json")
    ap.add_argument("--out", default="experiments_tables.md")
    args = ap.parse_args()

    out = []
    with open(args.dryrun) as f:
        results = json.load(f)
    out.append("## §Dry-run (raw sweep)\n")
    out.append(dryrun_table(results))

    if args.calibrate:
        from repro.launch.dryrun import calibrated_roofline

        recs = []
        for arch in all_arch_ids():
            for shape in ST.SHAPES:
                try:
                    rec = calibrated_roofline(arch, shape)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "status": "error",
                           "reason": f"{type(e).__name__}: {e}"}
                recs.append(rec)
                print(arch, shape, rec["status"],
                      rec.get("roofline", {}).get("bottleneck", rec.get("reason", "")))
        with open(args.calib_out, "w") as f:
            json.dump(recs, f, indent=1, default=str)
        out.append("\n\n## §Roofline (calibrated, single-pod 16×16)\n")
        out.append(roofline_table(recs))

    with open(args.out, "w") as f:
        f.write("\n".join(out) + "\n")
    print("wrote", args.out)


if __name__ == "__main__":
    main()
