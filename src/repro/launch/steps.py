"""Distributed train/serve step builders + input stand-ins for every
(architecture × shape) cell.

``train_step``  : fwd + loss + bwd + optimizer update (DP/FSDP/TP/EP).
``prefill_step``: forward over the full prompt, building the decode cache.
``serve_step``  : one-token decode against a seq_len KV/SSM cache.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStructs —
the dry-run lowers and compiles against these without allocating.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.optimizers import clip_by_global_norm, make_optimizer, cosine_schedule
from repro.distributed import sharding as SH


class TrainState(NamedTuple):
    params: Any
    opt_m: Any
    opt_v: Any
    step: jax.Array


# ---------------------------------------------------------------------------
# shapes (the four assigned input-shape sets)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def shape_skips(cfg: ModelConfig, shape: str) -> str | None:
    """Returns a skip reason or None (see DESIGN.md §Arch-applicability)."""
    if cfg.family == "encoder" and SHAPES[shape]["kind"] == "decode":
        return "encoder-only architecture has no decode step"
    if shape == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return "long_500k needs sub-quadratic attention; full-attention arch"
    return None


def input_specs(cfg: ModelConfig, shape: str, mesh=None):
    """ShapeDtypeStructs for every model input of this cell (no allocation)."""
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    kind = info["kind"]
    f32 = jnp.bfloat16
    out: dict[str, Any] = {}
    if kind == "train":
        if cfg.frontend_stub:
            out["tokens"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), f32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.rope == "mrope":
            out["mrope_pos"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    elif kind == "prefill":
        if cfg.frontend_stub:
            out["tokens"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), f32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.rope == "mrope":
            out["mrope_pos"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    else:  # decode
        out["token"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        out["caches"] = jax.eval_shape(
            lambda: M.init_cache(cfg, b, s, dtype=jnp.bfloat16)
        )
        if cfg.rope == "mrope":
            out["mrope_pos"] = jax.ShapeDtypeStruct((3, b, 1), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, *, optimizer: str | None = None,
                    lr: float = 3e-4, grad_clip: float = 1.0,
                    dispatch: str | None = None):
    opt_name = optimizer or default_optimizer(cfg)
    init_opt, update = make_optimizer(opt_name, cosine_schedule(lr, 200, 10_000))

    def loss_fn(params, batch):
        return M.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                         mrope_pos=batch.get("mrope_pos"), dispatch=dispatch)

    def train_step(state: TrainState, batch):
        accum = cfg.grad_accum
        if accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch
            )
        else:
            # scanned microbatches: activation live-set /= accum; gradients
            # accumulate in param dtype (bf16) to hold the memory plan of
            # the ≥100B models (documented trade-off).
            def _split(key, x):
                if key == "mrope_pos":  # (3, B, S): batch axis is 1
                    b = x.shape[1]
                    x = x.reshape((3, accum, b // accum) + x.shape[2:])
                    return jnp.moveaxis(x, 1, 0)
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

            mb = {k: _split(k, v) for k, v in batch.items()}

            def mb_step(acc, mbatch):
                (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mbatch
                )
                acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype), acc, g)
                return acc, (l, met)

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                state.params)
            grads, (losses, mets) = jax.lax.scan(mb_step, acc0, mb)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), mets)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        from repro.optim.optimizers import OptState

        new_params, opt = update(
            grads, OptState(state.step, state.opt_m, state.opt_v), state.params
        )
        new_state = TrainState(new_params, opt.m, opt.v, opt.step)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_state, metrics

    def init_state(key):
        params, _ = M.init(cfg, key)
        opt = init_opt(params)
        return TrainState(params, opt.m, opt.v, jnp.zeros((), jnp.int32))

    return train_step, init_state, opt_name


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill(params, batch):
        b = batch["tokens"].shape[0]
        caches = M.init_cache(cfg, b, max_len)
        logits, new_caches, _ = M.forward(
            params, cfg, batch["tokens"], caches=caches,
            mrope_pos=batch.get("mrope_pos"),
        )
        return logits[:, -1:], new_caches

    return prefill


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, batch):
        logits, new_caches = M.decode_step(
            params, cfg, batch["token"], batch["caches"],
            mrope_pos=batch.get("mrope_pos"),
        )
        return logits, new_caches

    return serve_step


def default_optimizer(cfg: ModelConfig) -> str:
    """Adafactor for the ≥100B models (fp32 Adam moments alone would
    exceed v5e HBM at 256 chips); AdamW otherwise."""
    return "adafactor" if cfg.param_count() > 60e9 else "adamw"


# ---------------------------------------------------------------------------
# sharding assembly for a cell
# ---------------------------------------------------------------------------


def abstract_init(cfg: ModelConfig):
    """(param ShapeDtypeStructs, logical spec tree) with zero allocation.

    The spec tree is plain python data built alongside the params, so we
    capture it through a side channel while eval_shape traces init.
    """
    box = {}

    def f(k):
        p, s = M.init(cfg, k)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["specs"]


def state_shardings(cfg: ModelConfig, mesh, opt_name: str):
    _, logical = abstract_init(cfg)
    pspecs = SH.tree_specs(logical, SH.rules_for_mesh(mesh))
    step_spec, m_specs, v_specs = SH.opt_state_specs(pspecs, opt_name)
    to_sh = lambda t: jax.tree.map(
        lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P)
    )
    return TrainState(
        params=to_sh(pspecs), opt_m=to_sh(m_specs), opt_v=to_sh(v_specs),
        step=NamedSharding(mesh, P()),
    )


def abstract_train_state(cfg: ModelConfig, opt_name: str):
    """TrainState of ShapeDtypeStructs (dry-run stand-in)."""
    pshapes, _ = abstract_init(cfg)
    init_opt, _ = make_optimizer(opt_name, 1e-3)
    opt_shapes = jax.eval_shape(init_opt, pshapes)
    return TrainState(pshapes, opt_shapes.m, opt_shapes.v,
                      jax.ShapeDtypeStruct((), jnp.int32))


def batch_shardings(cfg: ModelConfig, mesh, shape: str):
    specs = input_specs(cfg, shape)
    info = SHAPES[shape]
    dp_total = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp_total *= mesh.shape[a]
    shard_batch = info["batch"] % dp_total == 0
    out = {}
    for k, v in specs.items():
        if k == "caches":
            cspec = SH.cache_specs(cfg, mesh)
            if not shard_batch:  # e.g. long_500k global batch 1: replicate
                cspec = jax.tree.map(
                    lambda s: P(*(tuple(None if i == 1 else ax
                                        for i, ax in enumerate(s)))),
                    cspec, is_leaf=lambda x: isinstance(x, P))
            out[k] = jax.tree.map(lambda s: NamedSharding(mesh, s), cspec,
                                  is_leaf=lambda x: isinstance(x, P))
        elif k == "mrope_pos":
            sp = (SH.batch_spec(mesh, v.ndim, batch_axis=1) if shard_batch
                  else P())
            out[k] = NamedSharding(mesh, sp)
        else:
            sp = SH.batch_spec(mesh, v.ndim) if shard_batch else P()
            out[k] = NamedSharding(mesh, sp)
    return out
