"""Roofline-term derivation from compiled dry-run artifacts (§Roofline).

    compute    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
    memory     = HLO_bytes / (chips × 819 GB/s HBM)
    collective = wire_bytes / (chips × 2 links × 50 GB/s)

FLOPs/bytes come from ``compiled.cost_analysis()`` (whole-program, all
chips).  Collective bytes are parsed from the post-SPMD HLO text: we sum
the per-shard result sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, with wire factors
(all-reduce 2×: reduce-scatter + all-gather phases of a ring).  The "2
links" divisor models the two usable ICI directions per torus axis on a
v5e; stated here once and used consistently for baseline vs optimized
comparisons.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9       # bytes/s per chip
LINK_BW = 50e9       # bytes/s per ICI link
LINKS = 2            # usable links per chip per collective step

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-collective-kind wire bytes (per device) from post-SPMD HLO."""
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (\([^)]*\)|\S+) ([\w\-]+)", ls)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-start") or opname == c + "-done":
                kind = c
                break
        if kind is None:
            continue
        if opname.endswith("-done"):
            continue  # avoid double counting start/done pairs
        b = _shape_bytes(shape_str)
        out[kind] += b * _WIRE_FACTOR[kind]
        counts[kind] += 1
    out["_counts"] = counts  # type: ignore
    return out


@dataclasses.dataclass
class Roofline:
    """All inputs are PER-DEVICE quantities: XLA cost_analysis on an SPMD
    module reports the per-device program (verified empirically), and the
    collective parser reads per-shard shapes from the partitioned HLO."""

    flops: float        # per device
    hbm_bytes: float    # per device
    coll_bytes: float   # per device (wire)
    chips: int
    coll_breakdown: dict | None = None

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        # coll_bytes is per-device wire bytes already
        return self.coll_bytes / (LINKS * LINK_BW)

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self):
        return {
            "flops_per_dev": self.flops,
            "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def from_compiled(compiled, chips: int) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll = collective_bytes(txt)
    total_coll = sum(v for k, v in coll.items() if not k.startswith("_"))
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=total_coll,
                    chips=chips, coll_breakdown=coll)


def model_flops(cfg, shape_info, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (training) or 2·N·D (decode/prefill forward),
    with N = active params for MoE."""
    n = cfg.active_param_count()
    b, s = shape_info["batch"], shape_info["seq"]
    if kind == "train":
        tokens = b * s
        return 6.0 * n * tokens
    if kind == "prefill":
        return 2.0 * n * b * s
    return 2.0 * n * b  # decode: one token per sequence
