"""hubert-xlarge [audio]: 48L d_model=1280 16H d_ff=5120 vocab=504 —
encoder-only; the conv feature extractor is a STUB (input_specs provides
precomputed frame embeddings) [arXiv:2106.07447]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="encoder", n_layers=48, d_model=1280,
        n_heads=16, n_kv_heads=16, d_head=80, d_ff=5120, vocab=512,  # 504 targets padded to /16
        rope="rope", act="gelu", causal=False, frontend_stub=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", family="encoder", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab=64,
        rope="rope", act="gelu", causal=False, frontend_stub=True,
        attn_chunk_q=32, attn_chunk_k=32, dtype="float32",
    )
