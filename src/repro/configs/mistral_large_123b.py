"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768 [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", family="dense", n_layers=88,
        d_model=12288, n_heads=96, n_kv_heads=8, d_head=128, d_ff=28672,
        vocab=32768, grad_accum=2,  # §Perf: halves FSDP gather traffic, fits HBM
        # kv_dup left at 1: duplicating an 88-layer cache costs 2x12GB —
        # over budget (measured 29.5GB/dev); decode stays seq-sharded
        rope="rope", rope_theta=1_000_000.0, act="swiglu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b-smoke", family="dense", n_layers=3,
        d_model=64, n_heads=8, n_kv_heads=2, d_head=8, d_ff=160, vocab=256,
        rope="rope", act="swiglu", attn_chunk_q=32, attn_chunk_k=32,
        dtype="float32",
    )
