"""deepseek-v3-671b [moe]: 61L d_model=7168 128H vocab=129280 — MLA,
1 shared + 256 routed experts top-8 (expert d_ff=2048), first 3 layers
dense (d_ff=18432), MTP [arXiv:2412.19437]."""
from repro.models.config import ModelConfig, MoEConfig, MLAConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
        n_heads=128, n_kv_heads=128, d_head=128, d_ff=18432, vocab=129280,
        grad_accum=8,
        moe_chunk=4096,
        rope="rope", rope_theta=10_000.0, act="swiglu", mtp=True,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                      num_shared_experts=1, first_k_dense=3,
                      dispatch="sorted_ep"),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke", family="moe", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab=256,
        rope="rope", act="swiglu", mtp=True,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      num_shared_experts=1, first_k_dense=1,
                      dispatch="sorted"),
        attn_chunk_q=32, attn_chunk_k=32, dtype="float32",
    )
