"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA with QKV bias [arXiv:2407.10671]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense", n_layers=28, d_model=1536,
        n_heads=12, n_kv_heads=2, d_head=128, d_ff=8960, vocab=151936,
        qkv_bias=True, rope="rope", rope_theta=1_000_000.0, act="swiglu",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b-smoke", family="dense", n_layers=2, d_model=48,
        n_heads=6, n_kv_heads=2, d_head=8, d_ff=96, vocab=256,
        qkv_bias=True, rope="rope", act="swiglu", tie_embeddings=True,
        attn_chunk_q=32, attn_chunk_k=32, dtype="float32",
    )
