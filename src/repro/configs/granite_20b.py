"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — llama-arch, code [arXiv:2405.04324]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b", family="dense", n_layers=52, d_model=6144,
        n_heads=48, n_kv_heads=1, d_head=128, d_ff=24576, vocab=49152,
        rope="rope", rope_theta=10_000.0, act="gelu",  # 2-matrix MLP ⇒ 20B
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=8, n_kv_heads=1, d_head=8, d_ff=128, vocab=256,
        rope="rope", act="swiglu", attn_chunk_q=32, attn_chunk_k=32,
        dtype="float32",
    )
