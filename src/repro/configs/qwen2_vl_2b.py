"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution; the vision tower is a STUB
(input_specs provides patch embeddings + 3-component M-RoPE position ids)
[arXiv:2409.12191]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
        n_heads=12, n_kv_heads=2, d_head=128, d_ff=8960, vocab=151936,
        qkv_bias=True, rope="mrope", rope_theta=1_000_000.0, act="swiglu",
        tie_embeddings=True, frontend_stub=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-smoke", family="vlm", n_layers=2, d_model=48,
        n_heads=6, n_kv_heads=2, d_head=16, d_ff=96, vocab=256,
        qkv_bias=True, rope="mrope", act="swiglu", tie_embeddings=True,
        frontend_stub=True, attn_chunk_q=32, attn_chunk_k=32, dtype="float32",
    )
