"""Architecture registry: one module per assigned architecture, each
exporting ``config()`` (the exact published configuration) and
``smoke_config()`` (a reduced same-family configuration for CPU tests).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "hubert_xlarge",
    "mamba2_2p7b",
    "deepseek_v3_671b",
    "qwen3_moe_30b_a3b",
    "llama3_8b",
    "qwen2_1p5b",
    "mistral_large_123b",
    "granite_20b",
    "zamba2_2p7b",
    "qwen2_vl_2b",
]

_ALIASES = {
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-2.7b": "mamba2_2p7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama3-8b": "llama3_8b",
    "qwen2-1.5b": "qwen2_1p5b",
    "mistral-large-123b": "mistral_large_123b",
    "granite-20b": "granite_20b",
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen2-vl-2b": "qwen2_vl_2b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name)


def get_config(name: str, *, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.smoke_config() if smoke else mod.config()


def all_arch_ids() -> list[str]:
    return list(_ALIASES.keys())
