"""mamba2-2.7b [ssm]: 64L d_model=2560, attention-free, ssm_state=128 —
SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
        n_heads=0, n_kv_heads=0, d_head=0, d_ff=0, vocab=50288,  # 50280→pad16
        rope="none",
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_head=0, d_ff=0, vocab=256, rope="none",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=32),
        dtype="float32",
    )
