"""zamba2-2.7b [hybrid]: 54 Mamba2 layers d_model=2560 + shared attention
block (32H, d_ff=10240) re-used every 6 layers with per-invocation LoRA,
ssm_state=64 [arXiv:2411.15242]."""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_head=80, d_ff=10240, vocab=32000,
        rope="rope",
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk=256),
        hybrid_shared_period=6, hybrid_lora_rank=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab=256, rope="rope",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk=32),
        hybrid_shared_period=2, hybrid_lora_rank=8,
        attn_chunk_q=32, attn_chunk_k=32, dtype="float32",
    )
