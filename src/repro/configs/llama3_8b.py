"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — GQA, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_head=128, d_ff=14336, vocab=128256,
        rope="rope", rope_theta=500_000.0, act="swiglu",
        kv_dup=2,  # §Perf: head-sharded decode cache (−97% decode collectives)
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
        rope="rope", rope_theta=500_000.0, act="swiglu",
        attn_chunk_q=32, attn_chunk_k=32, dtype="float32",
    )
