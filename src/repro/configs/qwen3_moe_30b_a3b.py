"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, expert d_ff=768 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=4, d_head=128, d_ff=6144, vocab=151936,
        rope="rope", rope_theta=1_000_000.0, act="swiglu",
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768,
                      dispatch="sorted_ep", capacity_factor=1.0),  # §Perf
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
        rope="rope", act="swiglu",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      dispatch="sorted"),
        attn_chunk_q=32, attn_chunk_k=32, dtype="float32",
    )
