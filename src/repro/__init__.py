"""repro — sort-based duplicate removal, grouping, and aggregation.

The schema front door lives at the package root:

    import repro
    result = repro.aggregate(
        {"country": c, "hour": h}, by=repro.KeySpec.of(country=8, hour=5),
        values=latency, aggs=repro.AggSpec("count", "avg"),
    )

Exports resolve lazily so importing :mod:`repro` stays cheap for
subsystems (models, launch, …) that never touch the engine.
"""
from __future__ import annotations

_SCHEMA_EXPORTS = (
    "aggregate",
    "pipeline",
    "rollup",
    "serve_aggregate",
    "AggResult",
    "AggSpec",
    "JoinResult",
    "KeyColumn",
    "KeySpec",
)

__all__ = list(_SCHEMA_EXPORTS)


def __getattr__(name):
    if name in _SCHEMA_EXPORTS:
        from repro.core import schema

        return getattr(schema, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
