"""Mixture-of-experts block with the paper's technique as the dispatch
engine.

Routing tokens to experts IS duplicate-removal-free grouping: group rows
(tokens) by key (expert id), process each group, and aggregate the top-k
results per token.  Two dispatch strategies:

* ``dense``  — one-hot dispatch/combine einsums (the "hash aggregation"
  analogue: no ordering exploited; great for small E, wasteful at E=256).
  GSPMD-friendly; default for dry-runs.
* ``sorted`` — the paper's sort-based grouping: tokens are key-sorted by
  expert id (bitonic kernel on TPU), giving per-expert *contiguous*
  segments that feed the grouped matmul kernel; the combine is a
  segmented weighted reduction keyed by original token position.  This is
  run-generation + in-sort aggregation applied to routing, and it's the
  layout that expert-parallel all_to_all wants (contiguous per-expert
  blocks per device).

Both produce identical outputs up to capacity drops (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import make_dense, make_mlp, mlp, dense, hint


def make_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d, e, eff = cfg.d_model, m.num_experts, m.d_ff_expert
    ks = jax.random.split(key, 5)
    p, s = {}, {}
    p["router"], s["router"] = make_dense(ks[0], d, e, dtype, axes=("embed", "expert"))
    scale = 1.0 / (d ** 0.5)
    p["wi"] = (jax.random.normal(ks[1], (e, d, eff)) * scale).astype(dtype)
    p["wg"] = (jax.random.normal(ks[2], (e, d, eff)) * scale).astype(dtype)
    p["wo"] = (jax.random.normal(ks[3], (e, eff, d)) * (eff ** -0.5)).astype(dtype)
    s["wi"] = ("expert", "embed", "mlp")
    s["wg"] = ("expert", "embed", "mlp")
    s["wo"] = ("expert", "mlp", "embed")
    if m.num_shared_experts:
        p["shared"], s["shared"] = make_mlp(
            ks[4], d, eff * m.num_shared_experts, "swiglu", dtype
        )
    return p, s


def _router(p, cfg, x):
    """(B,S,D) → top-k expert ids (B,S,K) and weights (B,S,K)."""
    m = cfg.moe
    logits = dense(p["router"], x).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    if m.router_scale:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return idx, w.astype(x.dtype), probs


def _expert_ffn(p, xs):
    """xs: (E, C, D) per-expert token blocks → (E, C, D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", xs, p["wi"]
    )
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def _moe_dense_dispatch(p, cfg, x, idx, w):
    """One-hot einsum dispatch/combine (baseline)."""
    m = cfg.moe
    b, s, d = x.shape
    e = m.num_experts
    onehot = jax.nn.one_hot(idx, e, dtype=x.dtype)  # (B,S,K,E)
    comb = onehot * w[..., None]  # (B,S,K,E)
    disp = comb.sum(2)  # (B,S,E) combined weights per expert
    xs = jnp.einsum("bsd,bse->ebsd", x, (disp > 0).astype(x.dtype))
    xs = hint(xs.reshape(e, b * s, d), cfg, "model", "dp", None)
    ys = hint(_expert_ffn(p, xs), cfg, "model", "dp", None).reshape(e, b, s, d)
    return jnp.einsum("ebsd,bse->bsd", ys, disp)


def _moe_sorted_dispatch(p, cfg, x, idx, w):
    """The paper's sort-based grouping applied to MoE routing.

    1. run generation: key-sort the (token, expert) pairs by expert id —
       per-expert segments become contiguous;
    2. capacity-pad each segment to C rows (fixed shapes; the padded
       layout is what the grouped-matmul kernel and EP all_to_all want);
    3. grouped FFN on (E, C, D);
    4. combine: scatter-add the weighted results back by original token
       position — a segmented aggregation keyed by token id.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.num_experts, m.top_k
    t = b * s * k
    cap = int(m.capacity_factor * b * s * k / e)
    cap = max(8, -(-cap // 8) * 8)  # multiple of 8 (128 on real TPU tiles)
    flat_x = x.reshape(b * s, d)
    flat_e = idx.reshape(t)  # expert key per (token, k) row
    flat_w = w.reshape(t)
    tok = jnp.arange(t, dtype=jnp.int32) // k  # original token per row

    # --- sort rows by expert key (stable: key*T + position) ---
    order = jnp.argsort(flat_e * t + jnp.arange(t, dtype=flat_e.dtype))
    se, stok, sw = flat_e[order], tok[order], flat_w[order]
    # position of each row within its expert segment (rank via running count)
    ones = jnp.ones_like(se)
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")
    rank = jnp.arange(t) - seg_start[se]
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e * cap)  # drop overflow
    # gather tokens into the capacity-padded (E*C, D) layout
    xs = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(flat_x[stok], mode="drop")
    xs = xs[:-1].reshape(e, cap, d)
    ys = _expert_ffn(p, xs).reshape(e * cap, d)
    # combine: weighted scatter-add back to token positions
    contrib = ys[jnp.minimum(slot, e * cap - 1)] * sw[:, None]
    contrib = jnp.where(keep[:, None], contrib, 0)
    out = jnp.zeros((b * s, d), x.dtype).at[stok].add(contrib)
    return out.reshape(b, s, d)


def moe_block(p, cfg: ModelConfig, x, *, dispatch: str | None = None):
    m = cfg.moe
    mode = dispatch or m.dispatch
    if mode == "sorted_ep":
        from repro.distributed import moe_parallel as MP

        if cfg.mesh_axes is None or MP._CURRENT_MESH[0] is None:
            mode = "sorted"  # single-device fallback (same math, no EP)
        else:
            return MP.ep_moe_block(p, cfg, x)
    idx, w, probs = _router(p, cfg, x)
    if mode == "sorted":
        y = _moe_sorted_dispatch(p, cfg, x, idx, w)
    else:
        y = _moe_dense_dispatch(p, cfg, x, idx, w)
    if m.num_shared_experts:
        y = y + mlp(p["shared"], x, "swiglu")
    # load-balance auxiliary loss (returned via aux, wired by the caller)
    me = probs.mean(axis=(0, 1))  # (E,)
    frac = jax.nn.one_hot(idx, m.num_experts).mean(axis=(0, 1, 2))
    aux = m.num_experts * jnp.sum(me * frac)
    return y, aux
