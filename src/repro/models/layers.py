"""Model substrate: norms, projections, RoPE/M-RoPE, GQA and MLA attention
(with flash-style chunked softmax), and MLPs.  Pure JAX — distribution
comes from pjit shardings on the parameter/activation pytrees.

Parameters are plain nested dicts; initializers return (params, specs)
where specs mirror the structure with logical-axis tuples consumed by
repro.distributed.sharding.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

Params = dict
Specs = dict

_INIT_SCALE = 1.0


def hint(x, cfg, *axes):
    """Activation-sharding constraint ("dp" → all data axes, "model",
    "sp" → "model" on a sequence dim when cfg.sp, None).

    No-op when cfg.mesh_axes is unset (single-device paths).  These pins
    keep GSPMD from flipping batch sharding around FSDP-sharded weights
    (observed: replicated-batch f32 logits = 40 GB/device without them).
    "sp" additionally sequence-shards the residual stream between blocks
    (Megatron-SP): saved remat carries shrink by the TP degree.
    """
    if not cfg.mesh_axes:
        return x
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in cfg.mesh_axes)
    parts = []
    for i, a in enumerate(axes):
        if a == "dp":
            if not dp:
                parts.append(None)
            else:
                parts.append(dp if len(dp) > 1 else dp[0])
        elif a == "sp":
            parts.append("model" if (cfg.sp and x.shape[i] > 1) else None)
        else:
            parts.append(a)
    try:
        return jax.lax.with_sharding_constraint(x, P(*parts))
    except Exception:
        return x


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def make_dense(key, d_in, d_out, dtype, *, bias=False, axes=("embed", "mlp")):
    p = {"kernel": _dense_init(key, (d_in, d_out), d_in, dtype)}
    s = {"kernel": axes}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
        s["bias"] = (axes[-1],)
    return p, s


def dense(p, x):
    y = x @ p["kernel"]
    if "bias" in p:
        y = y + p["bias"]
    return y


def make_norm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def rmsnorm(p, x, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta):
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,d/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    xdt = x.dtype
    x1, x2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(xdt)


def apply_mrope(x, positions3, theta, sections=(16, 24, 24)):
    """Qwen2-VL M-RoPE: positions3 (3, B, S) = (t, h, w) ids; the frequency
    spectrum is split into three sections, each rotated by its own id."""
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)  # (half,)
    # build a (B, S, half) angle with per-section position ids
    parts, start = [], 0
    for i, sec in enumerate(sections):
        pos = positions3[i]  # (B, S)
        ang = pos[..., None].astype(jnp.float32) * freqs[start : start + sec]
        parts.append(ang)
        start += sec
    ang = jnp.concatenate(parts, axis=-1)  # (B,S,half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xdt = x.dtype
    x1, x2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(xdt)


# ---------------------------------------------------------------------------
# flash-style attention (pure JAX, chunked online softmax)
# ---------------------------------------------------------------------------


def _attn_chunked(q, k, v, *, causal: bool, q_offset, chunk_q: int, chunk_k: int):
    """q (B,Sq,H,D); k,v (B,Sk,KH,D) already head-repeated to H.
    Online-softmax over KV chunks; scanned over Q chunks.  Memory is
    O(chunk_q × chunk_k) per head instead of O(Sq × Sk)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    cq = min(chunk_q, sq)
    ck = min(chunk_k, sk)
    nq, nk = sq // cq, sk // ck
    assert sq % cq == 0 and sk % ck == 0
    qc = q.reshape(b, nq, cq, h, d)
    kc = k.reshape(b, nk, ck, h, d)
    vc = v.reshape(b, nk, ck, h, d)

    def q_step(_, qi):
        qblk, iq = qi  # (B,cq,H,D), scalar chunk index
        q_pos = q_offset + iq * cq + jnp.arange(cq)

        def kv_step(carry, kvi):
            m, l, acc = carry
            kblk, vblk, ik = kvi
            k_pos = ik * ck + jnp.arange(ck)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)),
        )
        l = jnp.maximum(l, 1e-20)
        out = (acc / l[..., None]).transpose(0, 2, 1, 3)  # (B,cq,H,D)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(
        jax.checkpoint(q_step) if nq > 1 else q_step,
        None, (qc.transpose(1, 0, 2, 3, 4), jnp.arange(nq))
    )
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)


def repeat_kv(x, n_rep: int):
    if n_rep == 1:
        return x
    b, s, kh, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kh, n_rep, d)).reshape(
        b, s, kh * n_rep, d
    )


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def make_attention(key, cfg: ModelConfig, dtype):
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = make_dense(ks[0], d, h * dh, dtype, bias=cfg.qkv_bias,
                                  axes=("embed", "heads"))
    p["wk"], s["wk"] = make_dense(ks[1], d, kh * dh, dtype, bias=cfg.qkv_bias,
                                  axes=("embed", "kv_heads"))
    p["wv"], s["wv"] = make_dense(ks[2], d, kh * dh, dtype, bias=cfg.qkv_bias,
                                  axes=("embed", "kv_heads"))
    p["wo"], s["wo"] = make_dense(ks[3], h * dh, d, dtype, axes=("heads", "embed"))
    return p, s


def attention(p, cfg: ModelConfig, x, positions, *, cache=None, mrope_pos=None):
    """x (B,S,D). cache: None (training/prefill w/o cache) or dict with
    k/v (B,Smax,KH,Dh) and index for decode; returns (out, new_cache)."""
    b, sq, _ = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = hint(dense(p["wq"], x).reshape(b, sq, h, dh), cfg, "dp", None, "model", None)
    k = hint(dense(p["wk"], x).reshape(b, sq, kh, dh), cfg, "dp", None, None, None)
    v = hint(dense(p["wv"], x).reshape(b, sq, kh, dh), cfg, "dp", None, None, None)
    if cfg.rope == "mrope" and mrope_pos is not None:
        half = dh // 2
        sec = (half - 2 * (half * 3 // 8), half * 3 // 8, half * 3 // 8)
        q = apply_mrope(q, mrope_pos, cfg.rope_theta, sections=sec)
        k = apply_mrope(k, mrope_pos, cfg.rope_theta, sections=sec)
    elif cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        idx = cache["index"]  # tokens already in cache
        if cfg.kv_dup > 1:  # store duplicated kv heads (clean TP sharding)
            k = repeat_kv(k, cfg.kv_dup)
            v = repeat_kv(v, cfg.kv_dup)
        kh_eff = kh * cfg.kv_dup
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        new_cache = {"k": ck, "v": cv, "index": idx + sq}
        kk, vv = ck, cv
        # decode: mask out beyond idx+sq via causal offset
        q_offset = idx
        kfull = repeat_kv(kk.astype(q.dtype), h // kh_eff)
        vfull = repeat_kv(vv.astype(q.dtype), h // kh_eff)
        out = _attn_chunked(
            q, kfull, vfull, causal=True, q_offset=q_offset,
            chunk_q=min(cfg.attn_chunk_q, sq), chunk_k=cfg.attn_chunk_k,
        )
    else:
        kfull = repeat_kv(k, h // kh)
        vfull = repeat_kv(v, h // kh)
        out = _attn_chunked(
            q, kfull, vfull, causal=cfg.causal, q_offset=0,
            chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k,
        )
    out = hint(out, cfg, "dp", None, "model", None).reshape(b, sq, h * dh)
    return dense(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------


def make_mla(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_nope, qk_rope, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["wq_a"], s["wq_a"] = make_dense(ks[0], d, m.q_lora_rank, dtype,
                                      axes=("embed", "lora"))
    p["q_norm"], s["q_norm"] = make_norm(m.q_lora_rank, dtype)
    s["q_norm"] = {"scale": ("lora",)}
    p["wq_b"], s["wq_b"] = make_dense(
        ks[1], m.q_lora_rank, h * (qk_nope + qk_rope), dtype, axes=("lora", "heads")
    )
    p["wkv_a"], s["wkv_a"] = make_dense(
        ks[2], d, m.kv_lora_rank + qk_rope, dtype, axes=("embed", "lora")
    )
    p["kv_norm"], s["kv_norm"] = make_norm(m.kv_lora_rank, dtype)
    s["kv_norm"] = {"scale": ("lora",)}
    p["wkv_b"], s["wkv_b"] = make_dense(
        ks[3], m.kv_lora_rank, h * (qk_nope + dv), dtype, axes=("lora", "heads")
    )
    p["wo"], s["wo"] = make_dense(ks[4], h * dv, d, dtype, axes=("heads", "embed"))
    return p, s


def mla_attention(p, cfg: ModelConfig, x, positions, *, cache=None):
    """DeepSeek-V3 MLA.  The decode cache stores the *compressed* latent
    (kv_lora_rank + rope dims per token) — the memory win of MLA."""
    m = cfg.mla
    b, sq, _ = x.shape
    h = cfg.n_heads
    qk_nope, qk_rope, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x), cfg.norm_eps))
    q = hint(q.reshape(b, sq, h, qk_nope + qk_rope), cfg, "dp", None, "model", None)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = dense(p["wkv_a"], x)  # (B,S,r+rope)
    latent, k_rope = kv_a[..., : m.kv_lora_rank], kv_a[..., m.kv_lora_rank :]
    latent = rmsnorm(p["kv_norm"], latent, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,rope)

    new_cache = None
    if cache is not None:
        idx = cache["index"]
        lat = jax.lax.dynamic_update_slice_in_dim(
            cache["latent"], latent.astype(cache["latent"].dtype), idx, axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype), idx, axis=1)
        new_cache = {"latent": lat, "k_rope": kr, "index": idx + sq}
        latent_full, k_rope_full = lat.astype(x.dtype), kr[:, :, None].astype(x.dtype)
        q_offset = idx
    else:
        latent_full, k_rope_full = latent, k_rope
        q_offset = 0

    kv = dense(p["wkv_b"], latent_full).reshape(b, -1, h, qk_nope + dv)
    k_nope, v = kv[..., :qk_nope], kv[..., qk_nope:]
    sk = k_nope.shape[1]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_full, (b, sk, h, qk_rope))], -1
    )
    k = hint(k, cfg, "dp", None, "model", None)
    qfull = jnp.concatenate([q_nope, q_rope], -1)
    # pad v to qk dim for the shared chunked kernel, slice after
    pad = (qk_nope + qk_rope) - dv
    vpad = hint(jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad))),
                cfg, "dp", None, "model", None)
    out = _attn_chunked(
        qfull, k, vpad, causal=cfg.causal, q_offset=q_offset,
        chunk_q=min(cfg.attn_chunk_q, sq), chunk_k=cfg.attn_chunk_k,
    )[..., :dv]
    out = hint(out, cfg, "dp", None, "model", None).reshape(b, sq, h * dv)
    return dense(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def make_mlp(key, d, d_ff, act, dtype):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    if act == "swiglu":
        p["wi"], s["wi"] = make_dense(ks[0], d, d_ff, dtype, axes=("embed", "mlp"))
        p["wg"], s["wg"] = make_dense(ks[1], d, d_ff, dtype, axes=("embed", "mlp"))
        p["wo"], s["wo"] = make_dense(ks[2], d_ff, d, dtype, axes=("mlp", "embed"))
    else:
        p["wi"], s["wi"] = make_dense(ks[0], d, d_ff, dtype, axes=("embed", "mlp"))
        p["wo"], s["wo"] = make_dense(ks[2], d_ff, d, dtype, axes=("mlp", "embed"))
    return p, s


def mlp(p, x, act):
    if act == "swiglu":
        return dense(p["wo"], jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x))
    return dense(p["wo"], jax.nn.gelu(dense(p["wi"], x)))
