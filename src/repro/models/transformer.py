"""Layer blocks and the scanned stacks composing all ten architectures.

Homogeneous layer runs are stacked (L, …) and driven by ``lax.scan`` —
compile time stays flat in depth (61–88 layer models) and remat applies
per layer.  Heterogeneous structure (deepseek's first-k-dense, zamba2's
shared attention block) becomes a short python-level composition of
scanned segments.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM


def _stacked_init(fn, key, n: int):
    """vmap an initializer over layer keys → params with leading (n,)."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: fn(k)[0])(keys)
    _, specs = fn(key)  # structure only
    specs = jax.tree.map(lambda s: ("layers",) + tuple(s), specs,
                         is_leaf=lambda s: isinstance(s, tuple))
    return params, specs


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def make_block(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    if kind == "mamba":
        p["norm"], s["norm"] = L.make_norm(cfg.d_model, dtype)
        p["mixer"], s["mixer"] = SSM.make_mamba2(ks[0], cfg, dtype)
        return p, s
    p["ln1"], s["ln1"] = L.make_norm(cfg.d_model, dtype)
    p["ln2"], s["ln2"] = L.make_norm(cfg.d_model, dtype)
    if cfg.mla is not None:
        p["attn"], s["attn"] = L.make_mla(ks[0], cfg, dtype)
    else:
        p["attn"], s["attn"] = L.make_attention(ks[0], cfg, dtype)
    if kind == "attn_moe":
        p["moe"], s["moe"] = MOE.make_moe(ks[1], cfg, dtype)
    else:
        p["mlp"], s["mlp"] = L.make_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p, s


def apply_block(p, cfg: ModelConfig, kind: str, x, positions, *, cache=None,
                mrope_pos=None, dispatch=None):
    aux = jnp.float32(0.0)
    if kind == "mamba":
        h, new_cache = SSM.mamba2_block(
            p["mixer"], cfg, L.rmsnorm(p["norm"], x, cfg.norm_eps), cache=cache
        )
        return L.hint(x + h, cfg, "dp", "sp", None), new_cache, aux
    if cfg.mla is not None:
        h, new_cache = L.mla_attention(
            p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps), positions,
            cache=cache,
        )
    else:
        h, new_cache = L.attention(
            p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps), positions,
            cache=cache, mrope_pos=mrope_pos,
        )
    x = L.hint(x + h, cfg, "dp", "sp", None)
    hn = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "attn_moe":
        h, aux = MOE.moe_block(p["moe"], cfg, hn, dispatch=dispatch)
    else:
        h = L.mlp(p["mlp"], hn, cfg.act)
    return L.hint(x + h, cfg, "dp", "sp", None), new_cache, aux


# ---------------------------------------------------------------------------
# scanned stack
# ---------------------------------------------------------------------------


def make_stack(key, cfg: ModelConfig, kind: str, n_layers: int, dtype):
    return _stacked_init(lambda k: make_block(k, cfg, kind, dtype), key, n_layers)


def apply_stack(params, cfg: ModelConfig, kind: str, x, positions, *,
                caches=None, mrope_pos=None, dispatch=None):
    """Apply a homogeneous stack: lax.scan over stacked (L, …) params by
    default (flat compile time in depth), or an unrolled python loop when
    ``cfg.scan_layers=False`` (used by the roofline calibration, where XLA
    cost_analysis must see every layer)."""

    def body(carry, xs):
        h, aux = carry
        p_l, cache_l = xs
        h, new_cache, a = apply_block(
            p_l, cfg, kind, h, positions, cache=cache_l, mrope_pos=mrope_pos,
            dispatch=dispatch,
        )
        return (h, aux + a), new_cache

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    if not cfg.scan_layers:
        n = jax.tree.leaves(params)[0].shape[0]
        aux = jnp.float32(0.0)
        outs = []
        for i in range(n):
            p_l = jax.tree.map(lambda a: a[i], params)
            c_l = None if caches is None else jax.tree.map(lambda a: a[i], caches)
            (x, aux), nc = body((x, aux), (p_l, c_l))
            outs.append(nc)
        new_caches = (None if outs[0] is None
                      else jax.tree.map(lambda *xs: jnp.stack(xs, 0), *outs))
        return x, new_caches, aux

    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                        (params, caches))
    return x, new_caches, aux
