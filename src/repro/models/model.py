"""Unified model wrapper: embeddings → (hetero)stacks → head, with init /
forward / decode-step / cache-init / loss, for every assigned family.

Composition per family
  dense / vlm       : scan(attn+mlp × L)
  moe               : scan(attn+mlp × k_dense) ∘ scan(attn+moe × (L−k))
  ssm               : scan(mamba × L)
  hybrid (zamba2)   : [scan(mamba × period) ∘ shared-attn]* with one shared
                      transformer block reused between groups (per-slot
                      LoRA on its qkv input projection)
  encoder (hubert)  : scan(bidir attn+mlp × L), frame-class head
`frontend_stub` families (audio/vlm) accept precomputed (B,S,D) embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T

Params = dict


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(cfg: ModelConfig, key) -> tuple[Params, dict]:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    p: Params = {}
    s: dict = {}
    p["embed"] = (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) *
                  0.01).astype(dtype)
    s["embed"] = ("vocab", "embed")
    if cfg.family == "ssm":
        p["layers"], s["layers"] = T.make_stack(keys[1], cfg, "mamba",
                                                cfg.n_layers, dtype)
    elif cfg.family == "hybrid":
        period = cfg.hybrid_shared_period or cfg.n_layers
        n_groups = cfg.n_layers // period
        p["layers"], s["layers"] = T.make_stack(
            keys[1], cfg, "mamba", cfg.n_layers, dtype
        )
        p["shared"], s["shared"] = T.make_block(keys[2], cfg, "attn_mlp", dtype)
        # per-invocation LoRA on the shared block's input (zamba2)
        r = cfg.hybrid_lora_rank or 16
        p["shared_in"], s["shared_in"] = L.make_dense(
            keys[3], 2 * cfg.d_model, cfg.d_model, dtype, axes=("mlp", "embed")
        )
        p["lora_a"] = (jax.random.normal(keys[4],
                       (n_groups, 2 * cfg.d_model, r)) * 0.01).astype(dtype)
        p["lora_b"] = jnp.zeros((n_groups, r, cfg.d_model), dtype)
        s["lora_a"] = ("layers", "mlp", None)
        s["lora_b"] = ("layers", None, "embed")
    elif cfg.moe is not None:
        kd = cfg.moe.first_k_dense
        if kd:
            p["dense_layers"], s["dense_layers"] = T.make_stack(
                keys[1], cfg, "attn_mlp", kd, dtype
            )
        p["layers"], s["layers"] = T.make_stack(
            keys[2], cfg, "attn_moe", cfg.n_layers - kd, dtype
        )
    else:
        p["layers"], s["layers"] = T.make_stack(
            keys[1], cfg, "attn_mlp", cfg.n_layers, dtype
        )
    p["ln_f"], s["ln_f"] = L.make_norm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(keys[5],
                        (cfg.d_model, cfg.vocab)) * 0.01).astype(dtype)
        s["unembed"] = ("embed", "vocab")
    if cfg.mtp:  # deepseek multi-token prediction: one extra block + proj
        p["mtp_block"], s["mtp_block"] = T.make_block(keys[6], cfg, "attn_mlp", dtype)
        p["mtp_proj"], s["mtp_proj"] = L.make_dense(
            keys[7], 2 * cfg.d_model, cfg.d_model, dtype, axes=("mlp", "embed")
        )
    return p, s


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _embed_in(p, cfg, tokens_or_embeds):
    if cfg.frontend_stub and tokens_or_embeds.ndim == 3:
        return tokens_or_embeds.astype(_dtype(cfg))  # precomputed embeddings
    return jnp.take(p["embed"], tokens_or_embeds, axis=0)


def _head(p, cfg, x):
    x = L.rmsnorm(p["ln_f"], x, cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    return L.hint(x @ w, cfg, "dp", None, "model")  # (B,S,V) vocab-sharded


def _hybrid_stacks(p, cfg, x, positions, caches, dispatch):
    period = cfg.hybrid_shared_period or cfg.n_layers
    n_groups = cfg.n_layers // period
    x0 = x
    aux = jnp.float32(0.0)
    new_m_caches, new_a_caches = [], []
    m_caches, a_caches = (caches or (None, None))
    for gidx in range(n_groups):
        sl = (lambda t: jax.tree.map(
            lambda a: a[gidx * period:(gidx + 1) * period], t))
        grp_cache = None if m_caches is None else sl(m_caches)
        x, nc, a = T.apply_stack(sl(p["layers"]), cfg, "mamba", x, positions,
                                 caches=grp_cache, dispatch=dispatch)
        aux += a
        new_m_caches.append(nc)
        # shared attention block on concat(hidden, initial embedding);
        # rematerialized — 9 unremat'd full-attention blocks would
        # otherwise dominate activation memory (observed +13 GB/device)
        a_cache = None if a_caches is None else jax.tree.map(
            lambda a: a[gidx], a_caches)

        def shared_fn(xx, x00, pp, cache):
            cat = jnp.concatenate([xx, x00], axis=-1)
            lora = (cat @ pp["lora_a"]) @ pp["lora_b"]
            h = L.dense(pp["shared_in"], cat) + lora
            return T.apply_block(pp["shared"], cfg, "attn_mlp", h, positions,
                                 cache=cache)

        if cfg.remat:
            shared_fn = jax.checkpoint(shared_fn)
        h, na, a2 = shared_fn(
            x, x0,
            {"shared": p["shared"], "shared_in": p["shared_in"],
             "lora_a": p["lora_a"][gidx], "lora_b": p["lora_b"][gidx]},
            a_cache)
        x = x + h
        new_a_caches.append(na)
        aux += a2
    cat_m = (jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_m_caches)
             if new_m_caches[0] is not None else None)
    cat_a = (jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_a_caches)
             if new_a_caches[0] is not None else None)
    return x, (cat_m, cat_a), aux


def forward(p, cfg: ModelConfig, tokens, *, positions=None, caches=None,
            mrope_pos=None, dispatch=None):
    """tokens (B,S) int32 or (B,S,D) embeddings (frontend_stub).
    Returns (logits, new_caches, aux_loss)."""
    x = L.hint(_embed_in(p, cfg, tokens), cfg, "dp", "sp", None)
    b, sq = x.shape[:2]
    if positions is None:
        start = 0 if caches is None else _cache_index(cfg, caches)
        positions = start + jnp.arange(sq, dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(positions, (b, sq))
    if cfg.family == "hybrid":
        x, new_caches, aux = _hybrid_stacks(p, cfg, x, positions, caches, dispatch)
    elif cfg.moe is not None and cfg.moe.first_k_dense:
        kd = cfg.moe.first_k_dense
        dc, mc = (None, None) if caches is None else caches
        x, ndc, a1 = T.apply_stack(p["dense_layers"], cfg, "attn_mlp", x,
                                   positions, caches=dc, mrope_pos=mrope_pos)
        x, nmc, a2 = T.apply_stack(p["layers"], cfg, "attn_moe", x, positions,
                                   caches=mc, dispatch=dispatch)
        new_caches, aux = (ndc, nmc), a1 + a2
    else:
        kind = ("mamba" if cfg.family == "ssm"
                else "attn_moe" if cfg.moe is not None else "attn_mlp")
        x, new_caches, aux = T.apply_stack(
            p["layers"], cfg, kind, x, positions, caches=caches,
            mrope_pos=mrope_pos, dispatch=dispatch,
        )
    logits = _head(p, cfg, x)
    return logits, new_caches, aux


def _cache_index(cfg, caches):
    leaves = [x for x in jax.tree.leaves(caches) if x.ndim == 1]
    # index leaves are stacked (L,) int32; take layer 0
    idxs = [x for x in jax.tree.leaves(caches)
            if jnp.issubdtype(x.dtype, jnp.integer) and x.ndim <= 1]
    if idxs:
        v = idxs[0]
        return v[0] if v.ndim else v
    return 0


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Decode-state pytree per family, stacked over layers."""
    def attn_cache(n):
        if cfg.mla is not None:
            m = cfg.mla
            return {
                "latent": jnp.zeros((n, batch, max_len, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((n, batch, max_len, m.qk_rope_head_dim), dtype),
                "index": jnp.zeros((n,), jnp.int32),
            }
        kh = cfg.n_kv_heads * cfg.kv_dup
        return {
            "k": jnp.zeros((n, batch, max_len, kh, cfg.d_head), dtype),
            "v": jnp.zeros((n, batch, max_len, kh, cfg.d_head), dtype),
            "index": jnp.zeros((n,), jnp.int32),
        }

    def mamba_cache(n):
        s = cfg.ssm
        conv_dim = cfg.d_inner_ssm + 2 * s.n_groups * s.d_state
        return {
            "conv": jnp.zeros((n, batch, s.d_conv - 1, conv_dim), dtype),
            "ssm": jnp.zeros(
                (n, batch, cfg.n_ssm_heads, s.head_dim, s.d_state), jnp.float32
            ),
        }

    if cfg.family == "ssm":
        return mamba_cache(cfg.n_layers)
    if cfg.family == "hybrid":
        period = cfg.hybrid_shared_period or cfg.n_layers
        n_groups = cfg.n_layers // period
        return (mamba_cache(cfg.n_layers), attn_cache(n_groups))
    if cfg.moe is not None and cfg.moe.first_k_dense:
        return (attn_cache(cfg.moe.first_k_dense),
                attn_cache(cfg.n_layers - cfg.moe.first_k_dense))
    return attn_cache(cfg.n_layers)


# ---------------------------------------------------------------------------
# losses / steps (model-level; the distributed wrappers live in launch/)
# ---------------------------------------------------------------------------


def _masked_ce(logits, labels):
    """Shard-friendly masked cross-entropy.

    Uses a one-hot contraction instead of take_along_axis so vocab-sharded
    logits stay sharded (no (B,S,V) all-gather — 40 GB/device for a 152k
    vocab at 64k tokens/device); logsumexp reduces with a tiny all-reduce.
    """
    v = logits.shape[-1]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(safe, v, dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def lm_loss(p, cfg: ModelConfig, tokens, labels, *, mrope_pos=None,
            dispatch=None, aux_weight=0.01, mtp_weight=0.3):
    logits, _, aux = forward(p, cfg, tokens, mrope_pos=mrope_pos,
                             dispatch=dispatch)
    loss = _masked_ce(logits, labels)
    total = loss + aux_weight * aux
    if cfg.mtp:
        total = total + mtp_weight * _mtp_loss(p, cfg, tokens, labels)
    return total, {"nll": loss, "aux": aux}


def _mtp_loss(p, cfg, tokens, labels):
    """DeepSeek-V3 MTP: predict t+2 from (h_t, emb(t+1)) through one extra
    block.  Approximated with the embedding stream as h (cheap but wired
    end-to-end so the head trains and shards)."""
    emb = jnp.take(p["embed"], tokens, axis=0)
    nxt = jnp.roll(emb, -1, axis=1)
    h = L.dense(p["mtp_proj"], jnp.concatenate([emb, nxt], axis=-1))
    b, sq = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32)[None], (b, sq))
    h, _, _ = T.apply_block(p["mtp_block"], cfg, "attn_mlp", h, positions)
    logits = _head(p, cfg, h)
    lab2 = jnp.roll(labels, -1, axis=1)
    lab2 = lab2.at[:, -2:].set(-1)  # no target beyond the sequence end
    return _masked_ce(logits, lab2)


def decode_step(p, cfg: ModelConfig, token, caches, *, mrope_pos=None):
    """One-token decode: token (B,1) → (logits (B,1,V), new caches)."""
    logits, new_caches, _ = forward(p, cfg, token, caches=caches,
                                    mrope_pos=mrope_pos)
    return logits, new_caches
