"""Mamba2 — state-space duality (SSD) blocks, chunked scan + decode step.

The SSD algorithm is itself a *segmented* computation: the sequence is cut
into chunks; within a chunk the output is a (masked, decay-weighted)
matmul; across chunks a small recurrent state is scanned.  Structurally it
is the same blocked scan-with-carry the paper's run generation uses — one
more place the framework's segmented primitives pay off.

Shapes follow the Mamba2 reference: d_inner = expand·d_model, heads of
size head_dim, state size N per head, grouped B/C (n_groups).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import make_dense, dense, rmsnorm, make_norm, hint


def make_mamba2(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = cfg.d_inner_ssm
    nh = cfg.n_ssm_heads
    g, n = s.n_groups, s.d_state
    ks = jax.random.split(key, 6)
    p, sp = {}, {}
    d_in_proj = 2 * di + 2 * g * n + nh  # z, x, B, C, dt
    p["in_proj"], sp["in_proj"] = make_dense(ks[0], d, d_in_proj, dtype,
                                             axes=("embed", "inner"))
    p["out_proj"], sp["out_proj"] = make_dense(ks[1], di, d, dtype,
                                               axes=("inner", "embed"))
    conv_dim = di + 2 * g * n
    p["conv_w"] = (jax.random.normal(ks[2], (s.d_conv, conv_dim)) /
                   math.sqrt(s.d_conv)).astype(dtype)
    sp["conv_w"] = (None, "inner")
    p["conv_b"] = jnp.zeros((conv_dim,), dtype)
    sp["conv_b"] = ("inner",)
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32)
    sp["A_log"] = ("inner",)
    p["D"] = jnp.ones((nh,), jnp.float32)
    sp["D"] = ("inner",)
    p["dt_bias"] = jnp.zeros((nh,), jnp.float32)
    sp["dt_bias"] = ("inner",)
    p["norm"], sp["norm"] = make_norm(di, dtype)
    sp["norm"] = {"scale": ("inner",)}
    return p, sp


def _causal_conv(x, w, b):
    """x (B,S,C), w (K,C): depthwise causal conv via shifted adds."""
    k = w.shape[0]
    y = x * w[-1]
    for i in range(1, k):
        y = y + jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]] * w[-1 - i]
    return y + b


def _ssd_chunked(x, dt, A, B, C, chunk: int, ssm_state=None):
    """SSD (Mamba2 alg. via block decomposition).

    x (b,l,h,p); dt (b,l,h) (already softplus'd); A (h,) (negative);
    B, C (b,l,g,n).  Returns y (b,l,h,p) and final state (b,h,p,n).
    """
    b, l, h, pdim = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0
    nc = l // chunk
    rep = h // g
    # broadcast groups to heads
    Bh = jnp.repeat(B, rep, axis=2)  # (b,l,h,n)
    Ch = jnp.repeat(C, rep, axis=2)
    xc = x.reshape(b, nc, chunk, h, pdim).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, h).transpose(1, 0, 2, 3)
    Bc = Bh.reshape(b, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)
    Cc = Ch.reshape(b, nc, chunk, h, n).transpose(1, 0, 2, 3, 4)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    # scan over chunks with the (b,h,p,n) state as carry: peak memory is
    # ONE chunk's (c×c) decay matrix, not all nc of them — the same
    # carry-and-emit structure as the paper's run generation.
    def chunk_step(state, inp):
        xz, dz, Bz, Cz = inp  # (b,c,h,p) (b,c,h) (b,c,h,n) (b,c,h,n)
        dA = dz * A[None, None, :]
        dA_cum = jnp.cumsum(dA, axis=1)  # (b,c,h)
        # intra-chunk: L[i,j] = exp(cum_i − cum_j) for i ≥ j.  Mask BEFORE
        # exp: masking after produces inf·0 = NaN in the backward pass.
        seg = dA_cum[:, :, None, :] - dA_cum[:, None, :, :]  # (b,c,c,h)
        seg = jnp.where(causal[None, :, :, None], seg, -jnp.inf)
        Lmat = jnp.exp(seg)
        scores = jnp.einsum("bihn,bjhn->bijh", Cz, Bz)
        y_diag = jnp.einsum("bijh,bijh,bjh,bjhp->bihp", scores, Lmat, dz, xz)
        # entering-state contribution
        state_decay = jnp.exp(dA_cum)  # (b,c,h)
        y_off = jnp.einsum("bchn,bch,bhpn->bchp", Cz, state_decay, state)
        # carry update
        decay_to_end = jnp.exp(dA_cum[:, -1:, :] - dA_cum)
        contrib = jnp.einsum("bchn,bch,bch,bchp->bhpn", Bz, decay_to_end, dz, xz)
        chunk_decay = jnp.exp(dA_cum[:, -1, :])  # (b,h)
        new_state = state * chunk_decay[:, :, None, None] + contrib
        return new_state, (y_diag + y_off).astype(x.dtype)

    init = (jnp.zeros((b, h, pdim, n), jnp.float32) if ssm_state is None
            else ssm_state.astype(jnp.float32))
    final, yc = jax.lax.scan(
        chunk_step, init,
        (xc.astype(jnp.float32), dtc, Bc.astype(jnp.float32),
         Cc.astype(jnp.float32)),
    )
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, l, h, pdim)
    return y, final


def mamba2_block(p, cfg: ModelConfig, x, *, cache=None):
    """x (B,S,D) → (B,S,D); cache = {'conv': (B,K-1,convdim), 'ssm':
    (B,h,p,n)} for single-token decode."""
    s = cfg.ssm
    b, l, d = x.shape
    di, nh, g, n = cfg.d_inner_ssm, cfg.n_ssm_heads, s.n_groups, s.d_state
    pdim = s.head_dim
    zxbcdt = hint(dense(p["in_proj"], x), cfg, "dp", None, "model")
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * g * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,l,nh)
    A = -jnp.exp(p["A_log"])  # (nh,)

    new_cache = None
    prefill = cache is not None and l > 1
    if cache is None or prefill:
        raw_xbc = xbc
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    else:
        # decode: l == 1; maintain a rolling conv window
        window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (b,K,cd)
        xbc = (window * p["conv_w"][None]).sum(1, keepdims=True) + p["conv_b"]
        new_conv = window[:, 1:]
    xbc = jax.nn.silu(xbc)
    xin, B, C = jnp.split(xbc, [di, di + g * n], axis=-1)
    xin = xin.reshape(b, l, nh, pdim)
    B = B.reshape(b, l, g, n)
    C = C.reshape(b, l, g, n)

    if cache is None or prefill:
        chunk = min(s.chunk, l)
        assert l % chunk == 0
        y, final = _ssd_chunked(xin, dt, A, B, C, chunk,
                                ssm_state=cache["ssm"] if prefill else None)
        if prefill:  # cache the conv tail (raw pre-activation inputs)
            new_cache = {
                "conv": raw_xbc[:, -(s.d_conv - 1):].astype(cache["conv"].dtype),
                "ssm": final.astype(cache["ssm"].dtype),
            }
    else:
        # single-step recurrence: state ← state·exp(A·dt) + dt·B⊗x
        st = cache["ssm"].astype(jnp.float32)  # (b,nh,p,n)
        dt1 = dt[:, 0]  # (b,nh)
        dA = jnp.exp(dt1 * A[None, :])  # (b,nh)
        rep = nh // g
        B1 = jnp.repeat(B[:, 0], rep, axis=1)  # (b,nh,n)
        C1 = jnp.repeat(C[:, 0], rep, axis=1)
        x1 = xin[:, 0].astype(jnp.float32)  # (b,nh,p)
        st = st * dA[:, :, None, None] + (
            dt1[:, :, None, None] * x1[..., None] * B1[:, :, None, :]
        )
        y = jnp.einsum("bhpn,bhn->bhp", st, C1)[:, None].astype(x.dtype)
        new_cache = {"conv": new_conv, "ssm": st.astype(cache["ssm"].dtype)}
    y = y.reshape(b, l, di) + (p["D"][None, None, :, None] *
                               xin.astype(jnp.float32)).astype(x.dtype).reshape(b, l, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return dense(p["out_proj"], y), new_cache
