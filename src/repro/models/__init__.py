from repro.models.config import ModelConfig, MoEConfig, MLAConfig, SSMConfig
from repro.models import model
