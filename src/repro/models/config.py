"""Unified model configuration covering all ten assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    num_shared_experts: int = 0  # deepseek: 1 shared expert
    first_k_dense: int = 0       # deepseek: first 3 layers are dense
    router_scale: bool = True    # normalize top-k router weights
    dispatch: str = "dense"      # "dense" (one-hot einsum) | "sorted" (paper)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    qkv_bias: bool = False
    rope: str = "rope"  # rope | mrope | none (learned/none for encoder)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    act: str = "swiglu"  # swiglu | gelu
    causal: bool = True
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block re-used every k ssm layers
    hybrid_shared_period: int = 0
    hybrid_lora_rank: int = 0
    # deepseek multi-token prediction: one extra MTP head/layer
    mtp: bool = False
    # modality frontend stub: model consumes precomputed (B,S,D) embeddings
    frontend_stub: bool = False
    # training-time knobs
    remat: bool = True
    attn_chunk_q: int = 1024
    attn_chunk_k: int = 2048
    scan_layers: bool = True
    dtype: str = "bfloat16"
    # mesh axis names for activation-sharding hints (None = no constraints,
    # e.g. single-device smoke tests); set by the launcher/dry-run.
    mesh_axes: tuple | None = None
    # token-chunk size for EP MoE dispatch (bounds all_to_all buffers)
    moe_chunk: int = 8192
    # sequence parallelism: shard the residual stream's sequence dim over
    # "model" between blocks (Megatron-SP) — divides saved-activation
    # memory by the TP degree; attention/mlp gather on entry.
    sp: bool = True
    # gradient accumulation microbatches (1 = none); activation memory
    # scales down by this factor at the cost of re-running the backward.
    grad_accum: int = 1
    # decode-cache kv-head duplication factor: store each kv head `kv_dup`
    # times so kv_heads·kv_dup divides the TP degree — trades cache memory
    # for clean head-sharded decode attention (vs seq-sharded cache).
    kv_dup: int = 1

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm.head_dim if self.ssm else 0

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        total = V * d  # embed
        if not self.tie_embeddings:
            total += V * d
        per_layer_attn = 0
        if self.family not in ("ssm",):
            if self.mla:
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                per_layer_attn = (
                    d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d
                )
            else:
                per_layer_attn = (
                    d * self.n_heads * self.d_head
                    + 2 * d * self.n_kv_heads * self.d_head
                    + self.n_heads * self.d_head * d
                )
        ssm_per_layer = 0
        if self.ssm:
            di, ns, g = self.d_inner_ssm, self.ssm.d_state, self.ssm.n_groups
            ssm_per_layer = (
                d * (2 * di + 2 * g * ns + self.n_ssm_heads)  # in_proj
                + di * d  # out_proj
                + (di + 2 * g * ns) * self.ssm.d_conv
                + 2 * self.n_ssm_heads
            )
        mlp_per_layer = 3 * d * self.d_ff if self.act == "swiglu" else 2 * d * self.d_ff
        total_layers = 0
        for layer in range(L):
            if self.family == "ssm":
                total_layers += ssm_per_layer
            elif self.family == "hybrid":
                total_layers += ssm_per_layer
            elif self.moe and layer >= self.moe.first_k_dense:
                e_ff = self.moe.d_ff_expert
                total_layers += per_layer_attn + 3 * d * e_ff * (
                    self.moe.num_experts + self.moe.num_shared_experts
                ) + d * self.moe.num_experts
            else:
                total_layers += per_layer_attn + mlp_per_layer
        if self.family == "hybrid" and self.hybrid_shared_period:
            shared_attn = 4 * d * self.n_heads * self.d_head + 3 * d * self.d_ff
            total_layers += shared_attn  # one shared block
        return total + total_layers

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer_attn = 0
        if self.mla:
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer_attn = (
                d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + self.n_heads * m.v_head_dim * d
            )
        else:
            per_layer_attn = (
                d * self.n_heads * self.d_head
                + 2 * d * self.n_kv_heads * self.d_head
                + self.n_heads * self.d_head * d
            )
        for layer in range(L):
            if layer < self.moe.first_k_dense:
                total += per_layer_attn + 3 * d * self.d_ff
            else:
                active_e = self.moe.top_k + self.moe.num_shared_experts
                total += per_layer_attn + 3 * d * self.moe.d_ff_expert * active_e
                total += d * self.moe.num_experts  # router
        return total
