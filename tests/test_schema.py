"""Schema front door: KeySpec packing, AggSpec planes, aggregate().

Acceptance bar of the api_redesign PR: a 3-column composite key wider
than 32 bits flows through ``repro.aggregate`` and matches the NumPy
oracle on both backends, and the merge-absorb path stays sort-free at
64 bits (the jaxpr check lives in tests/test_ordered_index.py,
parameterized over key dtypes).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _hypothesis_shim import given, settings, st
from _jaxpr_checks import assert_no_sort_no_scatter

import repro
from repro.core import ExecConfig, sorted_ops
from repro.core.operators import validate_against_oracle
from repro.core.schema import AggSpec, KeyColumn, KeySpec
from repro.core.types import (
    EMPTY,
    EMPTY64,
    empty_key,
    key_dtype_context,
    rows_to_state,
)

RNG = np.random.default_rng(17)

CFG_SMALL = ExecConfig(memory_rows=128, page_rows=32, fanin=4, batch_rows=32)


# ---------------------------------------------------------------------------
# KeySpec packing
# ---------------------------------------------------------------------------


def _roundtrip(bit_widths, n=300, rng=RNG):
    spec = KeySpec(tuple(KeyColumn(f"c{i}", b) for i, b in enumerate(bit_widths)))
    cols = {
        c.name: rng.integers(0, c.max_value, n, dtype=np.uint64, endpoint=True)
        for c in spec.columns
    }
    # avoid the reserved all-ones combination
    cols[spec.columns[0].name][cols[spec.columns[0].name] == spec.columns[0].max_value] = 0
    packed = spec.pack(cols)
    assert packed.dtype == spec.key_dtype
    unpacked = spec.unpack(packed)
    for name in spec.names:
        np.testing.assert_array_equal(
            unpacked[name].astype(np.uint64), cols[name].astype(np.uint64), err_msg=name
        )
    # packed order is the lexicographic order of the column list
    order = np.lexsort(tuple(cols[n] for n in reversed(spec.names)))
    np.testing.assert_array_equal(np.argsort(packed, kind="stable"), order)
    return spec, packed


def test_pack_roundtrip_32bit():
    spec, packed = _roundtrip([12, 10, 10])  # exactly 32 bits
    assert spec.key_dtype == np.uint32


def test_pack_roundtrip_64bit():
    spec, packed = _roundtrip([24, 24, 16])  # exactly 64 bits
    assert spec.key_dtype == np.uint64


def test_pack_roundtrip_odd_widths():
    for widths in ([1, 1, 1], [5, 9, 4], [31, 1], [33], [20, 20, 20], [64]):
        _roundtrip(widths)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 16), min_size=1, max_size=6), st.integers(0, 2**31))
def test_pack_roundtrip_property(widths, seed):
    """Hypothesis: n-column pack/unpack roundtrips at any total ≤ 64 bits."""
    if sum(widths) > 64:
        widths = widths[:2]
    rng = np.random.default_rng(seed)
    _roundtrip(widths, n=64, rng=rng)


def test_sentinel_preserved_and_reserved():
    """MAX_KEY-adjacent packings survive; the EMPTY pattern is rejected."""
    spec = KeySpec.of(hi=40, lo=24)
    # the largest legal packing: all-ones except the last bit == MAX_KEY64
    packed = spec.pack({"hi": [(1 << 40) - 1], "lo": [(1 << 24) - 2]})
    assert int(packed[0]) == int(np.uint64(0xFFFFFFFFFFFFFFFE))
    with pytest.raises(ValueError, match="EMPTY"):
        spec.pack({"hi": [(1 << 40) - 1], "lo": [(1 << 24) - 1]})
    # EMPTY rows in an engine state survive a 64-bit groupby untouched
    with key_dtype_context(np.uint64):
        keys = np.array([5, EMPTY64, 5, 9], np.uint64)
        st_ = sorted_ops.sorted_groupby(keys)
        got = np.asarray(st_.keys)
    assert (got == EMPTY64).sum() == 2  # sentinel never aggregates
    assert set(got[got != EMPTY64].tolist()) == {5, 9}


def test_keyspec_validation():
    with pytest.raises(ValueError, match="at most 64"):
        KeySpec.of(a=40, b=40)
    with pytest.raises(ValueError, match="duplicate"):
        KeySpec((KeyColumn("x", 4), KeyColumn("x", 4)))
    with pytest.raises(ValueError, match="budget"):
        KeySpec.of(a=4).pack({"a": [16]})
    spec = KeySpec.of(a=8, b=8)
    assert spec.prefix(1).names == ("a",)
    assert spec.shift_of("a") == 8 and spec.shift_of("b") == 0


# ---------------------------------------------------------------------------
# AggSpec
# ---------------------------------------------------------------------------


def test_aggspec_planes():
    assert AggSpec("count").plane_widths(3) == (0, 0, 0)
    assert AggSpec("sum").plane_widths(3) == (3, 0, 0)
    assert AggSpec("avg").plane_widths(2) == (2, 0, 0)  # avg ⇒ sum+count
    assert AggSpec("min", "max").plane_widths(1) == (0, 1, 1)
    assert AggSpec("count", "sum", "min", "max").plane_widths(2) == (2, 2, 2)
    with pytest.raises(ValueError, match="unknown"):
        AggSpec("median")


def test_aggspec_finalize_avg():
    keys = np.array([1, 1, 2, 2, 2], np.uint32)
    vals = np.array([[2.0], [4.0], [3.0], [6.0], [9.0]], np.float32)
    res = repro.aggregate(
        {"k": keys}, by=KeySpec.of(k=8), values=vals, aggs=AggSpec("count", "avg")
    )
    rel = res.relation()
    np.testing.assert_array_equal(rel["k"], [1, 2])
    np.testing.assert_array_equal(rel["count"], [2, 3])
    np.testing.assert_allclose(rel["avg"][:, 0], [3.0, 6.0], rtol=1e-6)
    assert res.state.min.shape[1] == 0 and res.state.max.shape[1] == 0


# ---------------------------------------------------------------------------
# aggregate() oracle parity — the acceptance criterion
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_aggregate_3col_over_32bits_matches_oracle(backend):
    """3-column composite key exceeding 32 total bits vs the NumPy oracle
    on both backends, through the external-memory path."""
    n = 1000
    spec = KeySpec.of(store=20, sku=20, region=10)  # 50 bits
    cols = {
        "store": RNG.integers(0, 50, n),
        "sku": RNG.integers(0, 20, n),
        "region": RNG.integers(0, 4, n),
    }
    vals = RNG.normal(size=(n, 1)).astype(np.float32)
    res = repro.aggregate(
        cols, by=spec, values=vals, aggs=("count", "sum"),
        cfg=CFG_SMALL, output_estimate=800, backend=backend,
    )
    assert res.state.keys.dtype == jnp.uint64
    validate_against_oracle(res.state, spec.pack(cols), vals)
    assert res.stats.total_spill_rows > 0  # genuinely took the spill path
    # result is sorted by the composite key: order_by any prefix is free
    k = np.asarray(res.state.keys)
    k = k[k != EMPTY64]
    assert np.all(k[:-1] < k[1:])


@pytest.mark.parametrize("algorithm", ["auto", "hash", "inmemory"])
def test_aggregate_in_memory_64bit_all_algorithms(algorithm):
    n = 400
    spec = KeySpec.of(a=30, b=20)  # 50 bits
    cols = {"a": RNG.integers(0, 100, n), "b": RNG.integers(0, 10, n)}
    vals = RNG.normal(size=(n, 2)).astype(np.float32)
    res = repro.aggregate(
        cols, by=spec, values=vals, aggs=("count", "sum"),
        algorithm=algorithm, order_by=True,
    )
    validate_against_oracle(res.state, spec.pack(cols), vals)
    k = np.asarray(res.state.keys)
    k = k[k != empty_key(k.dtype)]
    assert np.all(k[:-1] < k[1:])  # sorted (order_by honored for every alg)


def test_aggregate_order_by_must_be_prefix():
    spec = KeySpec.of(a=8, b=8)
    cols = {"a": [1, 2], "b": [3, 4]}
    with pytest.raises(ValueError, match="prefix"):
        repro.aggregate(cols, by=spec, order_by=("b",))
    # a legal prefix passes
    repro.aggregate(cols, by=spec, order_by=("a",))


def test_aggregate_count_only_drops_payload_planes():
    """AggSpec("count") carries no float plane anywhere — including spill."""
    n = 600
    keys = RNG.integers(0, 300, n).astype(np.uint32)
    res = repro.aggregate(
        {"k": keys}, by=KeySpec.of(k=16), values=np.ones((n, 4), np.float32),
        aggs=("count",), cfg=CFG_SMALL, output_estimate=300,
    )
    assert res.state.widths == (0, 0, 0)
    validate_against_oracle(res.state, keys)


# ---------------------------------------------------------------------------
# generic rollup
# ---------------------------------------------------------------------------


def test_generic_rollup_any_hierarchy_64bit():
    """Rollup over a 3-level hierarchy wider than 32 bits: every level's
    per-key sums match the NumPy oracle, all levels from one sort."""
    n = 2000
    spec = KeySpec.of(region=24, store=20, sku=10)  # 54 bits
    cols = {
        "region": RNG.integers(0, 3, n).astype(np.uint64),
        "store": RNG.integers(0, 10, n).astype(np.uint64),
        "sku": RNG.integers(0, 40, n).astype(np.uint64),
    }
    vals = np.ones((n, 1), np.float32)
    levels, stats = repro.rollup(
        cols, by=spec, values=vals, aggs=("count", "sum"),
        cfg=CFG_SMALL, output_estimate=1200,
    )
    assert set(levels) == {
        ("region", "store", "sku"), ("region", "store"), ("region",), ()
    }
    for names, res in levels.items():
        # row conservation at every level
        assert float(np.asarray(res.state.sum).sum()) == n
        if names:
            want = len({tuple(int(cols[c][i]) for c in names) for i in range(n)})
        else:
            want = 1
        assert res.occupancy() == want, names
    # per-key check at the middle level
    mid = levels[("region", "store")]
    rel = mid.relation()
    oracle = {}
    for i in range(n):
        oracle.setdefault((int(cols["region"][i]), int(cols["store"][i])), 0)
        oracle[(int(cols["region"][i]), int(cols["store"][i]))] += 1
    got = {
        (int(r), int(s)): int(c)
        for r, s, c in zip(rel["region"], rel["store"], rel["count"])
    }
    assert got == oracle


def test_rollup_narrow_prefix_relation_of_wide_key():
    """Regression: a ≤32-bit prefix level of a uint64 rollup must not leak
    EMPTY64 padding rows through relation() (the prefix KeySpec's uint32
    sentinel differs from the engine state's)."""
    n = 400
    spec = KeySpec.of(region=24, store=20, sku=10)  # 54 bits
    cols = {
        "region": RNG.integers(0, 3, n),
        "store": RNG.integers(0, 7, n),
        "sku": RNG.integers(0, 11, n),
    }
    levels, _ = repro.rollup(cols, by=spec, values=np.ones((n, 1), np.float32))
    top = levels[("region",)]  # 24-bit prefix spec over a uint64 state
    rel = top.relation()
    assert len(rel["region"]) == top.occupancy() == len(np.unique(cols["region"]))
    assert rel["count"].sum() == n
    total = levels[()]
    rel0 = total.relation()
    assert len(rel0["count"]) == 1 and rel0["count"][0] == n


def test_hash_rejects_sentinel_colliding_key():
    """Regression: the one key whose multiplicative hash IS the EMPTY
    sentinel must fail loudly in the hash baselines (it would silently
    vanish), at both key widths; the sort-based operator handles it."""
    from repro.core.hash_agg import _KNUTH_INV, _KNUTH64_INV, hash_aggregate
    from repro.core.types import EMPTY, EMPTY64

    bad32 = np.uint32((int(EMPTY) * int(_KNUTH_INV)) % (1 << 32))
    bad64 = np.uint64((int(EMPTY64) * int(_KNUTH64_INV)) % (1 << 64))
    for bad in (bad32, bad64):
        keys = np.array([1, 2, bad], dtype=bad.dtype)
        with pytest.raises(ValueError, match="sentinel"):
            hash_aggregate(keys)
        st, _ = repro.core.group_by(keys)  # in-sort path: no restriction
        validate_against_oracle(st, keys)


def test_legacy_rollup_wrapper_unchanged():
    """operators.rollup keeps its signature and its level names."""
    from repro.core import rollup as legacy_rollup

    n = 500
    day = RNG.integers(1, 29, n).astype(np.uint32)
    month = RNG.integers(1, 13, n).astype(np.uint32)
    year = RNG.integers(0, 3, n).astype(np.uint32)
    pay = np.ones((n, 1), np.float32)
    levels, _ = legacy_rollup(day, month, year, pay, CFG_SMALL, output_estimate=1200)
    assert set(levels) == {"day", "month", "year", "all"}
    for name in levels:
        assert float(np.asarray(levels[name].sum).sum()) == n
        # regression: every level keeps full (N, V) value planes so legacy
        # consumers can still read min/max columns
        assert levels[name].sum.shape[1] == 1
        assert levels[name].min.shape[1] == 1
        assert levels[name].max.shape[1] == 1
    assert int(levels["all"].occupancy()) == 1
    assert int(levels["year"].occupancy()) == len(np.unique(year))


# ---------------------------------------------------------------------------
# intersect_distinct: merge probe instead of O(N·M) isin
# ---------------------------------------------------------------------------


def test_intersect_merge_probe_no_sort_no_isin():
    from repro.core.operators import _merge_probe_intersect

    ka = np.sort(RNG.choice(500, 80, replace=False)).astype(np.uint32)
    kb = np.sort(RNG.choice(500, 120, replace=False)).astype(np.uint32)
    assert_no_sort_no_scatter(
        _merge_probe_intersect, jnp.asarray(ka), jnp.asarray(kb),
        context="in _merge_probe_intersect",
    )
    got = np.asarray(_merge_probe_intersect(jnp.asarray(ka), jnp.asarray(kb)))
    got = got[got != EMPTY]
    np.testing.assert_array_equal(got, np.intersect1d(ka, kb))


# ---------------------------------------------------------------------------
# backend default unification (satellite): "auto" everywhere
# ---------------------------------------------------------------------------


def test_operator_backend_defaults_are_auto():
    import inspect

    from repro.core import hash_agg, insort, operators, sorted_ops as so

    for fn in (
        operators.group_by,
        insort.insort_aggregate,
        insort.sort_then_stream_aggregate,
        hash_agg.hash_aggregate,
        hash_agg.f1_hash_aggregate,
        so.sorted_groupby,
        so.sort_state,
        so.segmented_combine,
        so.absorb,
        so.merge_absorb,
        so.merge_absorb_many,
    ):
        sig = inspect.signature(fn)
        assert sig.parameters["backend"].default == "auto", fn.__name__
