"""Aggregation-service tests: merge-on-read snapshots, TTL eviction.

The service contract on top of the streamed engine:

* **Snapshot parity** — at unit-aligned chunk boundaries, a snapshot
  after k chunks is bit-identical (keys, counts, stats) to the one-shot
  pipeline over those k chunks, for every policy and both key dtypes.
* **Non-destructive** — the live engine state is byte-for-byte
  unchanged by a snapshot, and ingest-after-snapshot produces exactly
  the ingest-without-snapshot result.
* **Zero-readback ingest** — interleaving snapshot queries keeps the
  ingest path free of implicit transfers (transfer-guard enforced), and
  repeated same-bucket snapshots are jit-cache hits.
* **Eviction accounting** — ``retire_below`` removes exactly the
  resident rows below the watermark, and every later snapshot reports
  the cumulative count in ``stats.rows_retired`` (nothing silently
  dropped).  Empty and all-evicted sessions answer valid EMPTY
  relations, not errors.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import merge, pipeline
from repro.core.types import (
    DeviceSpillStats,
    ExecConfig,
    empty_key,
    empty_state,
    max_key,
)
from repro.core.operators import validate_against_oracle
from repro.service import AggregationService, AggregationSession, ServiceMetrics

RNG = np.random.default_rng(11)
CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
N = 4000
DOMAIN = 1200
POLICIES = ("traditional", "inrun_dedup", "early_agg", "rs")

ENV = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_py(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def _mkinput(n=N, domain=DOMAIN, width=1, key_dtype=np.uint32, rng=RNG):
    keys = rng.integers(0, domain, n).astype(key_dtype)
    if key_dtype == np.uint64:
        keys = keys << np.uint64(30)
    pay = None if width == 0 else rng.normal(size=(n, width)).astype(np.float32)
    return keys, pay


def _unit(policy):
    return (CFG.memory_rows if policy in ("traditional", "inrun_dedup")
            else CFG.batch_rows)


def _chunks(keys, pay, sizes):
    s = 0
    for c in sizes:
        yield keys[s:s + c], None if pay is None else pay[s:s + c]
        s += c


def _unit_sizes(policy, n):
    u = _unit(policy)
    sizes = [u] * (n // u)
    if n % u:
        sizes.append(n % u)
    return sizes


def _strip(st):
    k = np.asarray(st.keys)
    v = k != empty_key(k.dtype)
    return k[v], np.asarray(st.count)[v], np.asarray(st.sum)[v]


def _service(policy="rs", key_dtype=np.uint32, width=1, **kw):
    kw.setdefault("output_rows", 4096)
    return AggregationService(CFG, policy=policy, key_dtype=key_dtype,
                              width=width, **kw)


def _engine_leaves(svc):
    return [np.asarray(x).copy() for x in jax.tree.leaves(svc._agg._es)]


# ---------------------------------------------------------------------------
# snapshot parity + non-destructiveness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("key_dtype", (np.uint32, np.uint64))
@pytest.mark.parametrize("policy", POLICIES)
def test_snapshot_parity_and_nondestructive(policy, key_dtype):
    """Snapshot after k unit-aligned chunks == one-shot over those k
    chunks (keys, counts, sums AND SpillStats); the engine is
    byte-unchanged by the snapshot; continued ingest then close ==
    one-shot over ALL chunks (ingest-after-snapshot is indistinguishable
    from ingest-without-snapshot)."""
    keys, pay = _mkinput(key_dtype=key_dtype)
    u = _unit(policy)
    cut = 8 * u

    st1, s1 = pipeline.insort_aggregate_device(
        keys[:cut], pay[:cut], CFG, policy=policy)
    k1, c1, v1 = _strip(st1)

    svc = _service(policy=policy, key_dtype=key_dtype)
    for ck, cp in _chunks(keys[:cut], pay[:cut], [u] * 8):
        svc.ingest(ck, cp)
    svc.flush()  # drain the double buffer so `before` is the queried state
    before = _engine_leaves(svc)

    state, stats = svc.snapshot()
    assert stats.as_dict() == s1.as_dict()
    k2, c2, v2 = _strip(state)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_allclose(v1, v2, rtol=1e-6)
    validate_against_oracle(state, keys[:cut], pay[:cut])

    # non-destructive: every engine leaf is byte-identical post-snapshot
    after = [np.asarray(x) for x in jax.tree.leaves(svc._agg._es)]
    assert len(before) == len(after)
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)

    # continued ingest + close matches the one-shot over all chunks
    for ck, cp in _chunks(keys[cut:], pay[cut:],
                          _unit_sizes(policy, N - cut)):
        svc.ingest(ck, cp)
    st3, s3 = svc.close()
    stF, sF = pipeline.insort_aggregate_device(keys, pay, CFG, policy=policy)
    assert s3.as_dict() == sF.as_dict()
    kF, cF, vF = _strip(stF)
    k3, c3, v3 = _strip(st3)
    np.testing.assert_array_equal(kF, k3)
    np.testing.assert_array_equal(cF, c3)
    np.testing.assert_allclose(vF, v3, rtol=1e-6)
    with pytest.raises(RuntimeError, match="closed"):
        svc.snapshot()


def test_repeated_snapshots_are_stable_and_cached():
    """Back-to-back snapshots of the same engine state return identical
    results and hit the jit cache (zero new traces — merge-on-read is a
    pow2-bucketed compiled program, not a per-query compile)."""
    keys, pay = _mkinput()
    u = _unit("rs")
    svc = _service("rs")
    for ck, cp in _chunks(keys[:8 * u], pay[:8 * u], [u] * 8):
        svc.ingest(ck, cp)
    state1, stats1 = svc.snapshot()
    before = len(pipeline.TRACE_LOG)
    state2, stats2 = svc.snapshot()
    assert pipeline.TRACE_LOG[before:] == []
    assert stats1.as_dict() == stats2.as_dict()
    for a, b in zip(jax.tree.leaves(state1), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert svc.metrics.snapshots_taken == 2


def test_ingest_with_snapshots_stays_zero_readback():
    """The serving loop — staged ingest with snapshot queries
    interleaved — performs no implicit transfers: staging is an explicit
    ``device_put`` and the merge-on-read answer stays on device until
    the caller reads it back."""
    keys, pay = _mkinput()
    sizes = _unit_sizes("rs", N)

    def loop(svc):
        for i, (ck, cp) in enumerate(_chunks(keys, pay, sizes)):
            svc.ingest(ck, cp)
            if (i + 1) % 16 == 0:
                svc.snapshot_device()  # mid-stream queries, answers on device
        return svc.snapshot_device()  # final query covers every chunk

    loop(_service("rs"))  # compile every bucket outside the guard
    svc = _service("rs")
    with jax.transfer_guard("disallow"):
        state, dstats = loop(svc)
        jax.block_until_ready((state.keys, dstats.rows_emitted))
    assert isinstance(dstats, DeviceSpillStats)
    stats = dstats.finalize(entry_point="snapshot")  # readback outside guard
    validate_against_oracle(state, keys, pay)
    assert stats.rows_retired == 0


# ---------------------------------------------------------------------------
# empty / all-evicted sessions answer valid EMPTY relations
# ---------------------------------------------------------------------------


def test_empty_service_snapshot_is_valid():
    svc = _service("rs", widths=(1, 0, 0))
    state, stats = svc.snapshot()
    assert int(state.occupancy()) == 0
    assert state.widths == (1, 0, 0)  # declared planes survive emptiness
    assert stats.rows_retired == 0 and stats.total_spill_rows == 0
    # the empty session is still live: ingest then snapshot sees the data
    keys, pay = _mkinput(n=512)
    svc.ingest(keys, pay)
    state, _ = svc.snapshot()
    validate_against_oracle(state, keys, pay)
    assert svc.metrics.snapshots_taken == 2


def test_all_evicted_session_snapshot_and_reingest():
    keys, pay = _mkinput()
    svc = _service("rs")
    for ck, cp in _chunks(keys, pay, _unit_sizes("rs", N)):
        svc.ingest(ck, cp)
    retired = svc.retire_below(int(max_key(np.uint32)))
    assert retired > 0
    state, stats = svc.snapshot()
    assert int(state.occupancy()) == 0  # valid EMPTY answer, not a raise
    assert stats.rows_retired == retired
    # the engine keeps serving after a full retirement
    late_keys, late_pay = _mkinput(n=1024)
    for ck, cp in _chunks(late_keys, late_pay, _unit_sizes("rs", 1024)):
        svc.ingest(ck, cp)
    state, stats = svc.close()
    validate_against_oracle(state, late_keys, late_pay)
    assert stats.rows_retired == retired


# ---------------------------------------------------------------------------
# TTL eviction semantics + accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
def test_eviction_retires_exactly_below_watermark(policy):
    keys, pay = _mkinput()
    thr = 600
    svc = _service(policy=policy)
    for ck, cp in _chunks(keys, pay, _unit_sizes(policy, N)):
        svc.ingest(ck, cp)
    retired = svc.retire_below(thr)
    assert retired > 0
    state, stats = svc.snapshot()
    assert stats.rows_retired == retired  # accounting: nothing silent
    k, c, v = _strip(state)

    live = keys >= thr
    exp_keys = np.unique(keys[live])
    exp_count = np.bincount(keys[live], minlength=DOMAIN)[exp_keys]
    exp_sum = np.bincount(keys[live], weights=pay[live, 0],
                          minlength=DOMAIN)[exp_keys]
    np.testing.assert_array_equal(k, exp_keys)
    np.testing.assert_array_equal(c, exp_count)
    np.testing.assert_allclose(v[:, 0], exp_sum, rtol=1e-4, atol=1e-3)

    # retirement is point-in-time: keys below the old watermark ingested
    # AFTER the eviction are live again
    svc.ingest(np.full(64, 3, np.uint32),
               np.ones((64, 1), np.float32))
    state, stats = svc.close()
    k2, c2, _ = _strip(state)
    assert k2[0] == 3 and c2[0] == 64
    assert stats.rows_retired == retired


def test_evict_threshold_validation():
    svc = _service("rs")
    svc.ingest(*_mkinput(n=256))
    with pytest.raises(ValueError, match="threshold"):
        svc.retire_below(-1)
    with pytest.raises(ValueError, match="EMPTY"):
        svc.retire_below(int(empty_key(np.uint32)))  # the sentinel itself
    assert svc.retire_below(0) == 0  # vacuous eviction is legal


# ---------------------------------------------------------------------------
# overflow errors name their entry point
# ---------------------------------------------------------------------------


def test_output_overrun_names_entry_point():
    keys = np.arange(512, dtype=np.uint32)  # 512 distinct groups
    svc = _service("rs", width=0, output_rows=16)
    svc.ingest(keys)
    with pytest.raises(RuntimeError, match="snapshot"):
        svc.snapshot()
    svc2 = _service("rs", width=0, output_rows=16)
    svc2.ingest(keys)
    with pytest.raises(RuntimeError, match="finalize"):
        svc2.close()


def test_wide_merge_rejects_mismatched_out_buffer():
    store = jax.tree.map(lambda x: x[None],
                         empty_state(64, 1, key_dtype=np.uint32))
    lens = jnp.zeros((1,), jnp.int32)
    with pytest.raises(ValueError, match="does not match the run store"):
        merge.wide_merge_device(store, lens, page_rows=32, index_rows=64,
                                out=empty_state(16, 2, key_dtype=np.uint32))
    with pytest.raises(ValueError, match="out_capacity"):
        merge.wide_merge_device(store, lens, page_rows=32, index_rows=64)


# ---------------------------------------------------------------------------
# metrics facade
# ---------------------------------------------------------------------------


def test_metrics_facade():
    keys, pay = _mkinput()
    svc = _service("rs")
    sizes = _unit_sizes("rs", N)
    for i, (ck, cp) in enumerate(_chunks(keys, pay, sizes)):
        svc.ingest(ck, cp)
        if (i + 1) % 20 == 0:
            svc.snapshot()
    m = svc.metrics
    assert m.rows_ingested == N and m.chunks_ingested == len(sizes)
    assert m.snapshots_taken == len(m.snapshot_latencies_s) > 0
    assert 0.0 < m.duplicate_rate < 1.0  # domain << N: heavy duplication
    assert m.groups_last_snapshot > 0 and m.runs_generated > 0
    assert m.snapshot_latency_s(0.5) <= m.snapshot_latency_s(0.99)
    s = m.summary()
    for key in ("rows_ingested", "snapshots_taken", "duplicate_rate",
                "snapshot_p50_s", "snapshot_p99_s", "rows_retired"):
        assert key in s
    assert ServiceMetrics().snapshot_latency_s(0.99) == 0.0


# ---------------------------------------------------------------------------
# schema sessions: composite keys, declarative aggs, watermark TTL
# ---------------------------------------------------------------------------


def test_session_schema_end_to_end():
    rng = np.random.default_rng(23)
    minutes = rng.integers(0, 8, N).astype(np.uint32)
    users = rng.integers(0, 400, N).astype(np.uint32)
    amount = rng.random(N).astype(np.float32)
    by = repro.KeySpec.of(minute=12, user=10)

    ref = repro.aggregate(
        {"minute": minutes, "user": users}, by=by, values=amount,
        aggs=("count", "sum", "avg"), cfg=CFG)

    sess = repro.serve_aggregate(
        by=by, values="amount", aggs=("count", "sum", "avg"),
        watermark="minute", cfg=CFG, output_rows=4096)
    for s in range(0, N, 1000):
        sess.ingest({"minute": minutes[s:s + 1000],
                     "user": users[s:s + 1000],
                     "amount": amount[s:s + 1000]})
    res = sess.snapshot()
    assert res.plan["service"] and res.plan["streamed"]
    r1, r2 = ref.relation(), res.relation()
    for col in ("minute", "user", "count"):
        np.testing.assert_array_equal(r1[col], r2[col])
    for col in ("sum", "avg"):
        np.testing.assert_allclose(r1[col], r2[col], rtol=1e-4, atol=1e-4)

    # watermark TTL: expire minutes < 4, by column name
    retired = sess.expire_below(minute=4)
    assert retired > 0
    res2 = sess.snapshot()
    rel = res2.relation()
    assert rel["minute"].min() >= 4
    assert res2.stats.rows_retired == retired
    np.testing.assert_array_equal(
        rel["count"], r1["count"][r1["minute"] >= 4])

    final = sess.close()
    assert final.stats.rows_retired == retired
    assert sess.closed
    with pytest.raises(RuntimeError, match="closed"):
        sess.snapshot()
    with pytest.raises(RuntimeError, match="closed"):
        sess.ingest({"minute": minutes, "user": users, "amount": amount})


def test_session_validation_and_empty():
    by = repro.KeySpec.of(minute=12, user=10)
    # watermark must be the major (first) key column
    with pytest.raises(ValueError, match="major"):
        repro.serve_aggregate(by=by, watermark="user")
    # payload-needing aggs demand a values column name
    with pytest.raises(ValueError, match="payload"):
        repro.serve_aggregate(by=by, aggs=("sum",))
    with pytest.raises(TypeError, match="column"):
        repro.serve_aggregate(by=by, values=np.zeros(4), aggs=("sum",))

    # a session that never ingested answers valid EMPTY relations
    sess = repro.serve_aggregate(by=by, watermark="minute", cfg=CFG)
    assert sess.expire_below(minute=3) == 0
    res = sess.snapshot()
    rel = res.relation()
    assert len(rel["count"]) == 0 and set(rel) >= {"minute", "user", "count"}
    final = sess.close()
    assert len(final.relation()["count"]) == 0
    # cutoff range is validated against the watermark column's bit width
    sess2 = repro.serve_aggregate(by=by, watermark="minute", cfg=CFG)
    with pytest.raises(ValueError, match="range"):
        sess2.expire_below(minute=1 << 13)


# ---------------------------------------------------------------------------
# mesh-sharded service (8 fake CPU devices via subprocess)
# ---------------------------------------------------------------------------


def test_service_mesh_snapshot_evict_close():
    run_py("""
        import jax, numpy as np
        from repro.core import pipeline
        from repro.core.types import ExecConfig, empty_key
        from repro.service import AggregationService

        CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4,
                         batch_rows=64)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(9)
        keys = rng.integers(0, 1200, 8192).astype(np.uint32)
        pay = rng.normal(size=(8192, 1)).astype(np.float32)

        svc = AggregationService(CFG, policy="rs", key_dtype=np.uint32,
                                 width=1, output_rows=8192, mesh=mesh)
        for s in range(0, 8192, 2048):
            svc.ingest(keys[s:s+2048], pay[s:s+2048])

        def strip(st):
            k = np.asarray(st.keys)
            v = k != empty_key(k.dtype)
            return k[v], np.asarray(st.count)[v], np.asarray(st.sum)[v]

        # sharded snapshot == single-device one-shot over the same rows
        state, stats = svc.snapshot()
        assert stats.rows_exchanged > 0 and stats.rows_retired == 0
        gk, gc, gs = strip(state)
        st1, _ = pipeline.insort_aggregate_device(keys, pay, CFG,
                                                  policy="rs")
        rk, rc, rs_ = strip(st1)
        np.testing.assert_array_equal(gk, rk)
        np.testing.assert_array_equal(gc, rc)
        np.testing.assert_allclose(gs, rs_, rtol=2e-4, atol=2e-3)

        # per-shard eviction with global accounting
        ret = svc.retire_below(600)
        assert ret > 0
        state2, stats2 = svc.snapshot()
        assert stats2.rows_retired == ret
        k2, c2, _ = strip(state2)
        assert np.all(k2 >= 600)
        np.testing.assert_array_equal(k2, rk[rk >= 600])
        np.testing.assert_array_equal(c2, rc[rk >= 600])

        # ingest continues post-snapshot/evict; close carries the account
        svc.ingest(keys[:2048], pay[:2048])
        state3, stats3 = svc.close()
        assert stats3.rows_retired == ret
        k3, _, _ = strip(state3)
        assert len(k3) > 0
        print("service mesh OK")
    """)
