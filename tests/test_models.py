"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting output shapes and no NaNs; plus
decode-path equivalence (prefill+decode ≡ full forward) per family.
"""
import dataclasses as dc

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_arch_ids, get_config
from repro.launch import steps as ST
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b=2, s=64):
    rng = np.random.default_rng(0)
    out = {}
    if cfg.frontend_stub:
        out["tokens"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)).astype(np.float32))
    else:
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                    dtype=jnp.int32)
    out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                dtype=jnp.int32)
    if cfg.rope == "mrope":
        out["mrope_pos"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32), (3, b, s)).copy()
    return out


@pytest.mark.parametrize("arch", all_arch_ids())
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params, specs = M.init(cfg, KEY)
    batch = _batch_for(cfg)
    logits, _, aux = M.forward(params, cfg, batch["tokens"],
                               mrope_pos=batch.get("mrope_pos"))
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))
    # spec tree mirrors the param tree
    assert set(specs.keys()) == set(params.keys())


@pytest.mark.parametrize("arch", all_arch_ids())
def test_train_step_decreases_loss(arch):
    cfg = get_config(arch, smoke=True)
    step, init_state, _ = ST.make_train_step(cfg, lr=5e-3)
    step = jax.jit(step)
    state = init_state(KEY)
    batch = _batch_for(cfg)
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["loss"])), arch
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], f"{arch}: no learning signal {losses}"


@pytest.mark.parametrize("arch", ["llama3-8b", "deepseek-v3-671b",
                                  "mamba2-2.7b", "zamba2-2.7b", "qwen2-vl-2b"])
def test_decode_matches_forward(arch):
    """prefill + single-token decode must reproduce the full forward."""
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:  # deterministic dispatch for comparison
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0,
                                             dispatch="dense"))
    params, _ = M.init(cfg, KEY)
    b, s = 2, 32
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), dtype=jnp.int32)
    mrope = (jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s)).copy()
             if cfg.rope == "mrope" else None)
    full_logits, _, _ = M.forward(params, cfg, toks, mrope_pos=mrope)

    caches = M.init_cache(cfg, b, s, dtype=jnp.float32)
    logits_steps = []
    for t in range(s):
        mr = (jnp.full((3, b, 1), t, jnp.int32) if cfg.rope == "mrope" else None)
        lg, caches = M.decode_step(params, cfg, toks[:, t : t + 1], caches,
                                   mrope_pos=mr)
        logits_steps.append(lg[:, 0])
    dec = jnp.stack(logits_steps, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


def test_moe_sorted_vs_dense_dispatch():
    from repro.models import moe as MOE

    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0))
    params, _ = M.init(cfg, KEY)
    moe_p = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    x = jax.random.normal(KEY, (2, 64, cfg.d_model), jnp.float32)
    yd, _ = MOE.moe_block(moe_p, cfg, x, dispatch="dense")
    ys, _ = MOE.moe_block(moe_p, cfg, x, dispatch="sorted")
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ys),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_ssd_chunked_equals_step_recurrence():
    """The SSD chunked scan must agree with the token-by-token recurrence."""
    from repro.models import ssm as SSM

    cfg = get_config("mamba2-2.7b", smoke=True)
    params, _ = M.init(cfg, KEY)
    p = jax.tree.map(lambda a: a[0], params["layers"])["mixer"]
    b, l = 2, 32
    x = jax.random.normal(KEY, (b, l, cfg.d_model), jnp.float32) * 0.5
    y_full, _ = SSM.mamba2_block(p, cfg, x)
    # decode path
    s = cfg.ssm
    conv_dim = cfg.d_inner_ssm + 2 * s.n_groups * s.d_state
    cache = {
        "conv": jnp.zeros((b, s.d_conv - 1, conv_dim), jnp.float32),
        "ssm": jnp.zeros((b, cfg.n_ssm_heads, s.head_dim, s.d_state),
                         jnp.float32),
    }
    outs = []
    for t in range(l):
        y, cache = SSM.mamba2_block(p, cfg, x[:, t : t + 1], cache=cache)
        outs.append(y[:, 0])
    y_step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)


def test_param_count_sanity():
    """Full configs must land near their published parameter counts."""
    expect = {
        "llama3-8b": (8.0e9, 0.10),
        "mistral-large-123b": (123e9, 0.10),
        "deepseek-v3-671b": (671e9, 0.10),
        "qwen3-moe-30b-a3b": (30.5e9, 0.15),
        "mamba2-2.7b": (2.7e9, 0.15),
        "qwen2-1.5b": (1.5e9, 0.25),
        "granite-20b": (20e9, 0.15),
        "zamba2-2.7b": (2.7e9, 0.30),
    }
    for arch, (n, tol) in expect.items():
        cfg = get_config(arch)
        got = cfg.param_count()
        assert abs(got - n) / n < tol, f"{arch}: {got/1e9:.2f}B vs {n/1e9}B"


def test_active_params_moe():
    cfg = get_config("deepseek-v3-671b")
    active = cfg.active_param_count()
    assert 25e9 < active < 55e9  # published ~37B active
    cfg = get_config("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    assert 1.5e9 < active < 6e9  # published ~3B active
