"""The analytic cost model must reproduce the paper's worked examples and
match the executable implementation's exact accounting (property-based)."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import ExecConfig, hash_aggregate, insort_aggregate
from repro.core import cost_model as cm


# ---------------------------------------------------------------------------
# paper worked examples (§4.1, §4.2, §4.5)
# ---------------------------------------------------------------------------


def test_example3_hash():
    """Ex 3: I=750k, M=1k, F=6, O=32k → hash spill 1,500,000 (2 levels)."""
    b = cm.simulate_hash(750_000, 32_000, 1_000, 6, hybrid=False)
    assert b.total_spill == 1_500_000
    assert b.merge_levels == 2


def test_example3_traditional_sort():
    """Ex 3 traditional: paper computes 1,884,000 (with I≈run-gen spill)."""
    b = cm.simulate_insort(
        750_000, 32_000, 1_000, 6,
        early_aggregation=True, wide_merge=False, replacement_selection=True,
    )
    assert b.total_spill == pytest.approx(1_884_000, rel=0.03)
    # the paper's level structure: full level, full level, one partial step
    assert b.merge_steps[-1] == 32_000  # penultimate step writes one run of O


def test_example3_wide_merge():
    """Ex 3 wide merging: spill 1,500,000 — perfectly competitive (§4.1)."""
    b = cm.simulate_insort(
        750_000, 32_000, 1_000, 6,
        early_aggregation=True, wide_merge=True, replacement_selection=True,
    )
    assert b.total_spill == pytest.approx(1_500_000, rel=0.03)
    assert b.merge_levels == cm.merge_levels_insort(32_000, 1_000, 6) == 2


def test_example4():
    """Ex 4: I=100M, M=100k, F=100, O=8M."""
    hash_ = cm.simulate_hash(100e6, 8e6, 100e3, 100)
    assert hash_.total_spill == pytest.approx(100e6, rel=0.02)
    assert hash_.merge_levels == 1
    trad = cm.simulate_insort(
        100e6, 8e6, 100e3, 100,
        early_aggregation=True, wide_merge=False, replacement_selection=True,
    )
    assert trad.total_spill == pytest.approx(133e6, rel=0.03)
    wide = cm.simulate_insort(
        100e6, 8e6, 100e3, 100,
        early_aggregation=True, wide_merge=True, replacement_selection=True,
    )
    assert wide.total_spill == pytest.approx(100e6, rel=0.02)
    assert wide.merge_levels == 1  # single wide merge of ~500 runs


def test_example5_parity():
    """Ex 5 (O=1.5·M): early agg + wide merge ⇒ parity with hybrid hash.

    (The paper's prose says "about half" absorbed; its own §3.5 model gives
    M/O = 2/3 absorbed.  Both algorithms match either way — the parity is
    the claim, and parity is exact here.)"""
    ins = cm.simulate_insort(
        100e6, 150e3, 100e3, 100,
        early_aggregation=True, wide_merge=True, replacement_selection=True,
    )
    hsh = cm.simulate_hash(100e6, 150e3, 100e3, 100)
    assert ins.total_spill == pytest.approx(hsh.total_spill, rel=0.01)
    assert ins.merge_levels == 1


def test_fig7_spill_model():
    """Fig 7: I=1M, M=100k; O=M ⇒ no spill; O≫M ⇒ nearly all spill."""
    none = cm.early_agg_run_gen(1_000_000, 100_000, 100_000)[0]
    assert none == 0.0
    lots = cm.early_agg_run_gen(1_000_000, 3_200_000, 100_000)[0]
    assert lots > 0.96 * 1_000_000 * (1 - 100_000 / 3_200_000)


def test_merge_depth_is_output_driven():
    """§4.3: depth ceil(log_F(O/M)) versus traditional ceil(log_F(I/M))."""
    assert cm.merge_levels_insort(32_000, 1_000, 6) == 2
    assert cm.merge_levels_insort(8e6, 1e5, 100) == 1
    assert cm.merge_levels_traditional(750_000, 1_000, 6) == 4
    assert cm.merge_levels_insort(100, 1_000, 6) == 0


def test_fig24_gap_disappears():
    """Fig 23 → 24: the sort-vs-hash gap practically disappears."""
    red, early3, hash_, insort = cm.fig24_curves()
    early3, hash_, insort = map(np.asarray, (early3, hash_, insort))
    # new algorithm within 15% of hash everywhere …
    assert np.all(insort <= 1.15 * hash_ + 2 * 100e3)
    # … while the old sort-based algorithm is far worse somewhere
    assert np.any(early3 > 1.5 * hash_)


# ---------------------------------------------------------------------------
# property: executable accounting obeys the analytic model
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(4_000, 24_000),
    o=st.integers(10, 6_000),
    seed=st.integers(0, 2**31 - 1),
)
def test_accounting_matches_model(n, o, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, o, n).astype(np.uint32)
    o_true = len(np.unique(keys))
    cfg = ExecConfig(memory_rows=512, page_rows=64, fanin=4, batch_rows=128)
    _, meas = insort_aggregate(keys, None, cfg, output_estimate=o_true)
    model = cm.simulate_insort(
        n, o_true, cfg.memory_rows, cfg.fanin,
        early_aggregation=True, wide_merge=True,
    )
    if o_true <= cfg.memory_rows:
        assert meas.total_spill_rows == 0
        return
    # run generation can spill at most the input (+ one memory load);
    # each pre-wide merge level rewrites at most its own input (merging
    # with aggregation never grows data), and the input of level 1 is the
    # run-generation spill.
    assert meas.rows_spilled_run_generation <= n + cfg.memory_rows
    assert meas.rows_spilled_merge <= max(0, meas.merge_levels - 1) * (
        meas.rows_spilled_run_generation
    )
    assert meas.total_spill_rows >= 0.5 * model.total_spill
    assert meas.total_spill_rows <= 2.0 * model.total_spill + cfg.memory_rows
    # wide merge adds no merge spill; depth is output-driven
    assert meas.rows_spilled_merge == 0 or meas.merge_levels > 1


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(4_000, 20_000),
    o=st.integers(600, 5_000),
    seed=st.integers(0, 2**31 - 1),
)
def test_insort_vs_hash_parity_property(n, o, seed):
    """The headline claim, property-tested: spill parity within RSW slack."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, o, n).astype(np.uint32)
    o_true = len(np.unique(keys))
    cfg = ExecConfig(memory_rows=512, page_rows=64, fanin=4, batch_rows=128)
    _, si = insort_aggregate(keys, None, cfg, output_estimate=o_true)
    _, sh = hash_aggregate(keys, None, cfg, output_estimate=o_true)
    # replacement-selection keeps in-sort within ~2× of hybrid hashing
    # everywhere (paper Fig 3: "slightly worse for small outputs"), versus
    # the ≥(log_F(I/M))× of traditional sorting.
    assert si.total_spill_rows <= 2.0 * sh.total_spill_rows + 2 * cfg.memory_rows
