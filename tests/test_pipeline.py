"""Device-resident pipeline tests: oracle/reference parity for every
run-generation policy at both key widths, plus the sync-count regression
tests — the scan-based pipeline performs O(1) host transfers per input
while the host-loop reference blocks once per batch (O(N/B)).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import pipeline
from repro.core import run_generation as rg
from repro.core.insort import insort_aggregate
from repro.core.operators import validate_against_oracle
from repro.core.types import DeviceSpillStats, ExecConfig, empty_key

RNG = np.random.default_rng(7)

# one shared config so every parametrization reuses the same compiled
# programs (the fused jit specializes on (T, M, B, P, policy, dtype))
CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
N = 4000
KEY_DTYPES = (np.uint32, np.uint64)
POLICIES = ("traditional", "inrun_dedup", "early_agg", "rs")


def _mkinput(n=N, domain=1200, width=1, key_dtype=np.uint32, rng=RNG):
    keys = rng.integers(0, domain, n).astype(key_dtype)
    if key_dtype == np.uint64:
        keys = keys << np.uint64(30)  # spread past 32 bits
    pay = None if width == 0 else rng.normal(size=(n, width)).astype(np.float32)
    return keys, pay


def _host_reference(keys, pay, policy):
    if policy == "rs":
        return insort_aggregate(keys, pay, CFG, run_policy="rs", pipeline="host")
    if policy == "early_agg":
        return insort_aggregate(keys, pay, CFG, run_policy="batch", pipeline="host")
    # inrun_dedup / traditional: the host generate_runs path with the
    # matching policy (merged through the host wide merge)
    return insort_aggregate(
        keys, pay, CFG, early_aggregation=False, pipeline="host"
    )


# ---------------------------------------------------------------------------
# oracle + host-reference parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("key_dtype", KEY_DTYPES)
@pytest.mark.parametrize("policy", POLICIES)
def test_device_pipeline_oracle_parity(policy, key_dtype):
    keys, pay = _mkinput(key_dtype=key_dtype)
    st, stats = pipeline.insort_aggregate_device(keys, pay, CFG, policy=policy)
    validate_against_oracle(st, keys, pay)
    assert stats.rows_spilled_merge == 0  # the wide merge never spills
    assert stats.total_spill_rows > 0  # sized to genuinely take the spill path
    k = np.asarray(st.keys)
    k = k[k != empty_key(k.dtype)]
    assert np.all(k[:-1] < k[1:])  # sorted, duplicate-free output


@pytest.mark.parametrize("key_dtype", KEY_DTYPES)
@pytest.mark.parametrize("policy", ("early_agg", "rs"))
def test_device_pipeline_matches_host_reference_exactly(policy, key_dtype):
    """Same per-batch state machine ⇒ identical runs, spill accounting,
    and key/count output as the host loop (random input: the device
    buffer's close-early rule never triggers)."""
    keys, pay = _mkinput(key_dtype=key_dtype)
    st_h, s_h = _host_reference(keys, pay, policy)
    st_d, s_d = pipeline.insort_aggregate_device(keys, pay, CFG, policy=policy)
    assert s_d.as_dict() == s_h.as_dict()
    kh = np.asarray(st_h.keys)
    kd = np.asarray(st_d.keys)
    kh = kh[kh != empty_key(kh.dtype)]
    kd = kd[kd != empty_key(kd.dtype)]
    np.testing.assert_array_equal(kh, kd)
    ch = np.asarray(st_h.count)[: len(kh)]
    cd = np.asarray(st_d.count)[: len(kd)]
    np.testing.assert_array_equal(ch, cd)


@pytest.mark.parametrize("policy", ("traditional", "inrun_dedup"))
def test_device_sortwrite_matches_host_run_accounting(policy):
    """Read-sort-write policies: run generation accounting (runs, spilled
    rows) is identical to the host generate_runs; merge accounting
    differs by design (the fused path always finishes with one wide
    merge instead of spilling pre-levels)."""
    keys, pay = _mkinput()
    runs, _, s_h = rg.generate_runs(keys, pay, CFG, policy=policy)
    _, s_d = pipeline.insort_aggregate_device(keys, pay, CFG, policy=policy)
    assert s_d.runs_generated == s_h.runs_generated == len(runs)
    assert s_d.rows_spilled_run_generation == s_h.rows_spilled_run_generation


def test_device_pipeline_in_memory_and_edges():
    # in-memory: zero spill accounting, table streamed through the merge
    keys = RNG.integers(0, 50, 800).astype(np.uint32)
    st, stats = pipeline.insort_aggregate_device(keys, None, CFG, policy="rs")
    validate_against_oracle(st, keys)
    assert stats.as_dict() == pipeline.SpillStats().as_dict()
    # empty input
    st, stats = pipeline.insort_aggregate_device(
        np.zeros((0,), np.uint32), None, CFG
    )
    assert int(st.occupancy()) == 0 and stats.total_spill_rows == 0
    # one hot key: a single group always fits memory
    hot = np.full(3 * N, 7, np.uint32)
    st, stats = pipeline.insort_aggregate_device(hot, None, CFG, policy="rs")
    assert int(st.occupancy()) == 1 and int(st.count[0]) == 3 * N
    assert stats.total_spill_rows == 0


def test_device_rs_adversarial_orders():
    """Pre-sorted input makes host replacement selection build one giant
    run; the device buffer legally closes runs early at slot capacity —
    output must be identical either way.  Reverse-sorted input exercises
    the close/promote path every eviction."""
    base = RNG.integers(0, 3000, N).astype(np.uint32)
    for keys in (np.sort(base), np.sort(base)[::-1].copy()):
        st, stats = pipeline.insort_aggregate_device(keys, None, CFG, policy="rs")
        validate_against_oracle(st, keys)
        assert stats.rows_spilled_merge == 0


def test_device_premerge_levels_deep_merge_regime():
    """O/M ≫ F: the statically planned device pre-merge levels (§4.3)
    keep the wide-merge index within memory where a single wide merge
    over all runs would overflow it; merge depth matches the paper's
    output-driven formula."""
    from repro.core.cost_model import merge_levels_insort

    keys = RNG.integers(0, 3200, 16_000).astype(np.uint32)
    o = len(np.unique(keys))  # O/M ≈ 12 ≫ F = 4
    st, stats = pipeline.insort_aggregate_device(
        keys, None, CFG, policy="rs", output_estimate=o
    )
    validate_against_oracle(st, keys)
    assert stats.rows_spilled_merge > 0  # pre-levels rewrite runs
    assert stats.merge_levels == merge_levels_insort(o, CFG.memory_rows, CFG.fanin)
    assert not stats.index_overflowed


def test_device_merge_drop_fails_loudly():
    """If the wide-merge index would drop live rows (severe estimate
    error / tiny index), the pipeline raises instead of returning a
    silently incomplete result."""
    keys = RNG.permutation(np.arange(4000, dtype=np.uint32))  # all distinct
    with pytest.raises(RuntimeError, match="dropped rows"):
        pipeline.insort_aggregate_device(
            keys, None, CFG, policy="early_agg", index_rows=8
        )


def test_host_wide_merge_drop_fails_loudly():
    from repro.core import merge as merge_mod

    keys = RNG.permutation(np.arange(4000, dtype=np.uint32))
    runs, _, stats = rg.generate_runs(keys, None, CFG, policy="early_agg")
    with pytest.raises(RuntimeError, match="dropped rows"):
        merge_mod.wide_merge(runs, CFG, stats=stats, index_rows=8)


@pytest.mark.parametrize("policy", ("early_agg", "rs"))
def test_device_pipeline_pallas_backend_smoke(policy):
    """The fused program also compiles with the Pallas kernel backend
    (interpret mode off-TPU) — tiny size, it is one big program."""
    cfg = ExecConfig(memory_rows=64, page_rows=16, fanin=4, batch_rows=16)
    keys, pay = _mkinput(n=400, domain=120)
    st, _ = pipeline.insort_aggregate_device(
        keys, pay, cfg, policy=policy, backend="pallas"
    )
    validate_against_oracle(st, keys, pay)


def test_device_plane_widths_travel_through_pipeline():
    """An AggSpec-style width restriction (count+sum only) keeps zero-width
    min/max planes across run buffers, eviction, and the merge."""
    keys, pay = _mkinput()
    st, _ = pipeline.insort_aggregate_device(
        keys, pay, CFG, policy="rs", widths=(1, 0, 0)
    )
    assert st.widths == (1, 0, 0)
    validate_against_oracle(st, keys, pay)


# ---------------------------------------------------------------------------
# sync-count regression: O(1) device syncs vs O(N/B) host syncs
# ---------------------------------------------------------------------------


def test_device_pipeline_is_sync_free_under_transfer_guard():
    """The full generate_runs + wide_merge program performs ZERO implicit
    transfers: with device-resident inputs it runs to completion under
    ``jax.transfer_guard("disallow")``; only the explicit stats finalize
    reads anything back (O(1) scalars per input)."""
    keys, pay = _mkinput()
    dk, dp = jax.device_put(keys), jax.device_put(pay)
    # compile outside the guard; the guard then proves steady-state runs
    state, _ = pipeline.aggregate_device(dk, dp, CFG, policy="rs")
    jax.block_until_ready(state)
    with jax.transfer_guard("disallow"):
        state, dstats = pipeline.aggregate_device(dk, dp, CFG, policy="rs")
        jax.block_until_ready((state, dstats))
    assert isinstance(dstats, DeviceSpillStats)
    stats = dstats.finalize()  # the single readback, outside the guard
    assert stats.total_spill_rows > 0
    validate_against_oracle(state, keys, pay)


def test_host_loop_syncs_once_per_batch():
    """The host reference blocks on an occupancy readback after EVERY
    batch: counting device-scalar ``int(...)`` conversions inside the
    run-generation module shows O(N/B) syncs, and the loop cannot even
    start under a transfer guard."""
    keys, pay = _mkinput()
    n_batches = -(-len(keys) // CFG.batch_rows)
    counts = {"sync": 0}
    real_int = int

    def counting_int(x, *a, **kw):
        if isinstance(x, jax.Array):
            counts["sync"] += 1
        return real_int(x, *a, **kw)

    # module-level name shadows the builtin inside run_generation only
    rg.int = counting_int
    try:
        rg.generate_runs(keys, pay, CFG, policy="early_agg")
    finally:
        del rg.int
    assert counts["sync"] >= n_batches  # one occupancy readback per batch

    with jax.transfer_guard("disallow"):
        with pytest.raises(Exception):
            rg.generate_runs(keys, pay, CFG, policy="early_agg")


# ---------------------------------------------------------------------------
# the schema front door compiles end-to-end by default
# ---------------------------------------------------------------------------


def test_schema_aggregate_routes_through_device_pipeline():
    import repro
    from repro.core.schema import KeySpec

    keys, pay = _mkinput()
    res = repro.aggregate(
        {"k": keys}, by=KeySpec.of(k=12), values=pay, aggs=("count", "sum"),
        cfg=CFG, order_by=True,
    )
    assert res.plan["pipeline"] == "device"
    validate_against_oracle(res.state, keys, pay)
    # the reference host plan produces the same relation
    res_h = repro.aggregate(
        {"k": keys}, by=KeySpec.of(k=12), values=pay, aggs=("count", "sum"),
        cfg=CFG, order_by=True, pipeline="host",
    )
    rel_d, rel_h = res.relation(), res_h.relation()
    np.testing.assert_array_equal(rel_d["k"], rel_h["k"])
    np.testing.assert_array_equal(rel_d["count"], rel_h["count"])
    np.testing.assert_allclose(rel_d["sum"], rel_h["sum"], rtol=2e-4, atol=2e-3)
