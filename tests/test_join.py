"""Order-consuming merge join (PR 9): oracle parity for inner/semi/anti
over u32 AND u64 keys, structural no-sort/no-scatter jaxpr invariants,
Pallas probe parity, KeySpec-packed ``join_aggregate``, and exact parity
of the composed ``aggregate → merge_join → rollup`` pipeline against the
same operators run independently (stats included).

Capacities are kept small (≤ 512) on purpose: segmented-combine /
merge-join compiles scale badly on the CPU backend and tier-1 must stay
fast."""
import functools
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from _jaxpr_checks import assert_no_sort_no_scatter

import repro
from repro.core import merge_join as mj
from repro.core.join import join_aggregate, resolve_join_keys
from repro.core.schema import KeySpec, _check_join_compat
from repro.core.types import AggState, empty_key, key_dtype_context

RNG = np.random.default_rng(29)

CAP = 64  # one shared capacity ⇒ one jit cache entry per (how, dtype)


def make_state(uniq, counts=None, sums=None, capacity=CAP, dtype=np.uint32):
    """A sorted, duplicate-free, EMPTY-tailed AggState from unique keys."""
    uniq = np.asarray(uniq, dtype)
    assert len(np.unique(uniq)) == len(uniq)
    n = len(uniq)
    kd = np.dtype(dtype)
    keys = np.full(capacity, empty_key(kd), kd)
    keys[:n] = np.sort(uniq)
    order = np.argsort(uniq, kind="stable")
    count = np.zeros(capacity, np.int32)
    count[:n] = 1 if counts is None else np.asarray(counts, np.int32)[order]
    s = np.zeros((capacity, 1), np.float32)
    s[:n, 0] = (
        (keys[:n] % 97).astype(np.float32) if sums is None
        else np.asarray(sums, np.float32)[order]
    )
    inf = np.float32(np.inf)
    mn = np.full((capacity, 1), inf, np.float32)
    mx = np.full((capacity, 1), -inf, np.float32)
    mn[:n] = s[:n]
    mx[:n] = s[:n]
    return AggState(keys=jnp.asarray(keys), count=jnp.asarray(count),
                    sum=jnp.asarray(s), min=jnp.asarray(mn),
                    max=jnp.asarray(mx))


def _u64ify(keys):
    """Push u32-range keys above 2**32 so the hi lane actually varies."""
    return (np.asarray(keys, np.uint64) << np.uint64(33)) | np.uint64(5)


# ---------------------------------------------------------------------------
# merge_join oracle parity: how × dtype × edge scenarios
# ---------------------------------------------------------------------------

SCENARIOS = {
    "overlap": (np.array([1, 4, 7, 9, 12, 30]), np.array([2, 4, 9, 13, 30])),
    "disjoint": (np.array([1, 3, 5]), np.array([2, 4, 6])),
    "empty_left": (np.array([], np.int64), np.array([2, 4, 6])),
    "empty_right": (np.array([1, 3, 5]), np.array([], np.int64)),
    "both_empty": (np.array([], np.int64), np.array([], np.int64)),
    "all_equal": (np.array([17]), np.array([17])),
    "identical": (np.arange(40), np.arange(40)),
}


def _expected_keys(ka, kb, how):
    sa, sb = set(ka.tolist()), set(kb.tolist())
    keep = sorted(sa & sb) if how in ("inner", "semi") else sorted(sa - sb)
    return np.asarray(keep, np.uint64)


@pytest.mark.parametrize("dtype", [np.uint32, np.uint64], ids=["u32", "u64"])
@pytest.mark.parametrize("how", mj.JOIN_HOWS)
def test_merge_join_matches_oracle(how, dtype):
    for name, (ka, kb) in SCENARIOS.items():
        ka = _u64ify(ka) if dtype is np.uint64 else np.asarray(ka, np.uint32)
        kb = _u64ify(kb) if dtype is np.uint64 else np.asarray(kb, np.uint32)
        with key_dtype_context(dtype):
            a, b = make_state(ka, dtype=dtype), make_state(kb, dtype=dtype)
            left, right = mj.merge_join(a, b, how=how, backend="xla")
        got = np.asarray(left.keys)
        got = got[got != empty_key(got.dtype)]
        exp = _expected_keys(ka, kb, how)
        np.testing.assert_array_equal(
            got.astype(np.uint64), exp, err_msg=f"{how}/{name}")
        # matched tail stays EMPTY-padded (OrderedIndex invariant)
        tail = np.asarray(left.keys)[len(exp):]
        assert (tail == empty_key(tail.dtype)).all(), f"{how}/{name}"
        if how == "inner":
            # right rows aligned on the SAME key vector, carrying b's planes
            np.testing.assert_array_equal(
                np.asarray(right.keys)[: len(exp)].astype(np.uint64), exp,
                err_msg=f"{how}/{name}")
            exp32 = exp.astype(dtype)
            np.testing.assert_allclose(
                np.asarray(right.sum)[: len(exp), 0],
                (exp32 % 97).astype(np.float32), err_msg=f"{how}/{name}")
        else:
            assert right is None


def test_merge_join_hot_key_products_fp32():
    """Hot groups: per-side counts up to 10^6 — |L|·|R| = 10^12 overflows
    int32, so the group-join product plane must be float."""
    ka = np.array([3, 8, 11], np.uint32)
    kb = np.array([8, 11, 20], np.uint32)
    a = make_state(ka, counts=[1_000_000, 1_000_000, 2])
    b = make_state(kb, counts=[1_000_000, 5, 9])
    left, right = mj.merge_join(a, b, how="inner")
    prods = mj.group_join_products(left, right)
    jc = np.asarray(prods["join_count"])[:2]
    np.testing.assert_allclose(jc, [1e12, 10.0])
    assert prods["join_count"].dtype == jnp.float32


def test_merge_join_zero_capacity():
    empty = make_state(np.array([], np.int64), capacity=0)
    some = make_state(np.array([1, 2], np.int64), capacity=4)
    for how in mj.JOIN_HOWS:
        left, right = mj.merge_join(empty, some, how=how)
        assert left.capacity == 0
        left, right = mj.merge_join(some, empty, how=how)
        got = np.asarray(left.keys)
        n_live = int((got != empty_key(got.dtype)).sum())
        assert n_live == (2 if how == "anti" else 0)


# ---------------------------------------------------------------------------
# structural invariant: the jaxpr has NO sort and NO scatter (u32 AND u64)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.uint32, np.uint64], ids=["u32", "u64"])
@pytest.mark.parametrize("how", mj.JOIN_HOWS)
def test_merge_join_jaxpr_sort_and_scatter_free(how, dtype):
    ka = np.array([1, 4, 9], np.uint64)
    kb = np.array([4, 9, 13], np.uint64)
    if dtype is np.uint64:
        ka, kb = _u64ify(ka), _u64ify(kb)
    with key_dtype_context(dtype):
        a, b = make_state(ka, dtype=dtype), make_state(kb, dtype=dtype)
        fn = functools.partial(mj.merge_join, how=how, backend="xla")
        assert_no_sort_no_scatter(
            fn, a, b, context=f"in merge_join[{how}] over {np.dtype(dtype)}")


def test_compact_state_jaxpr_sort_and_scatter_free():
    st = make_state(np.array([2, 5, 9], np.int64))
    # punch interior EMPTY gaps like a mesh shard boundary would
    keys = np.asarray(st.keys).copy()
    keys[1] = empty_key(keys.dtype)
    st = AggState(keys=jnp.asarray(keys), count=st.count, sum=st.sum,
                  min=st.min, max=st.max)
    assert_no_sort_no_scatter(mj.compact_state, st, context="in compact_state")
    out = mj.compact_state(st)
    got = np.asarray(out.keys)
    np.testing.assert_array_equal(got[:2], [2, 9])
    assert (got[2:] == empty_key(got.dtype)).all()


# ---------------------------------------------------------------------------
# Pallas probe kernel parity (interpret mode off-TPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [np.uint32, np.uint64], ids=["u32", "u64"])
def test_pallas_probe_matches_xla(dtype):
    from repro.kernels import ops as kops

    base_a = np.sort(RNG.choice(4000, 120, replace=False))
    base_b = np.sort(RNG.choice(4000, 90, replace=False))
    ka = _u64ify(base_a) if dtype is np.uint64 else base_a.astype(np.uint32)
    kb = _u64ify(base_b) if dtype is np.uint64 else base_b.astype(np.uint32)
    with key_dtype_context(dtype):
        # EMPTY tails as merge_join would pass them
        a = np.asarray(make_state(ka, capacity=128, dtype=dtype).keys)
        b = np.asarray(make_state(kb, capacity=128, dtype=dtype).keys)
        pos_p, hit_p = kops.join_probe(jnp.asarray(a), jnp.asarray(b))
        pos_x, hit_x = mj.join_probe_xla(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(hit_p), np.asarray(hit_x))
    hp, px, pp = np.asarray(hit_p), np.asarray(pos_x), np.asarray(pos_p)
    np.testing.assert_array_equal(pp[hp], px[hp])
    exp_hit = np.isin(a, b) & (a != empty_key(np.dtype(dtype)))
    np.testing.assert_array_equal(hp, exp_hit)


# ---------------------------------------------------------------------------
# join.py: KeySpec packing, dtype-mismatch guards (satellite #1)
# ---------------------------------------------------------------------------


def test_resolve_join_keys_dtype_mismatch_raises():
    with pytest.raises(TypeError, match="dtype mismatch"):
        resolve_join_keys(np.array([1], np.uint32), np.array([1], np.uint64))
    with pytest.raises(TypeError, match="integers"):
        resolve_join_keys(np.array([1.0]), np.array([1.0]))
    with pytest.raises(ValueError, match="non-negative"):
        resolve_join_keys(np.array([-1]), np.array([1]))


def test_resolve_join_keys_widens_not_truncates():
    """Seed regression: >32-bit keys must infer uint64, never truncate."""
    big = np.array([2**40, 2**40 + 1], np.uint64)
    lk, rk, kd = resolve_join_keys(big, big)
    assert kd == np.dtype(np.uint64)
    np.testing.assert_array_equal(lk, big)
    lk, rk, kd = resolve_join_keys(
        np.array([3, 7], np.uint32), np.array([7], np.uint32))
    assert kd == np.dtype(np.uint32)


def test_join_aggregate_u64_keyspec_matches_oracle():
    spec = KeySpec.of(store=30, sku=20)  # 50 bits → uint64 packing
    assert spec.key_dtype == np.uint64
    r = np.random.default_rng(11)
    n = 300
    left = {"store": r.integers(0, 6, n) + 2**28, "sku": r.integers(0, 5, n)}
    right = {"store": r.integers(0, 6, n) + 2**28, "sku": r.integers(0, 5, n)}
    lpay = r.normal(size=n).astype(np.float32)
    res, stats = join_aggregate(
        left, right, left_payload=lpay, by=spec, output_estimate=128)
    keys = np.asarray(res["keys"])
    live = keys != empty_key(keys.dtype)
    lk, rk = spec.pack(left), spec.pack(right)
    # oracle: per shared key, |L|·|R| and Σ_L payload·|R|
    exp = {}
    for k in np.unique(np.concatenate([lk, rk])):
        nl, nr = int((lk == k).sum()), int((rk == k).sum())
        exp[int(k)] = (nl * nr, lpay[lk == k].sum() * nr)
    got_k = keys[live]
    np.testing.assert_array_equal(np.sort(got_k), np.unique(np.concatenate([lk, rk])))
    for k, jc, sl in zip(got_k, np.asarray(res["join_count"])[live],
                         np.asarray(res["sum_left_pay"])[live, 0]):
        e_jc, e_sl = exp[int(k)]
        assert jc == e_jc, int(k)
        np.testing.assert_allclose(sl, e_sl, rtol=1e-5)
    spilled = stats.rows_spilled_run_generation + stats.rows_spilled_merge
    assert spilled <= 2 * n  # each mixed-stream row spills at most once


# ---------------------------------------------------------------------------
# schema composition: AggResult.merge_join / rollup / pipeline
# ---------------------------------------------------------------------------

SPEC = KeySpec.of(region=6, store=8)
N = 600


def _rel(seed, lo=0, hi=12):
    r = np.random.default_rng(seed)
    cols = {"region": r.integers(0, 4, N), "store": r.integers(lo, hi, N)}
    vals = r.normal(size=N).astype(np.float32)
    return cols, vals


def _aggregate(cols, vals):
    return repro.aggregate(cols, by=SPEC, values=vals, aggs=("count", "sum"),
                           output_estimate=256)


@pytest.fixture(scope="module")
def two_relations():
    (lc, lv), (rc, rv) = _rel(1), _rel(2, lo=6, hi=18)
    return _aggregate(lc, lv), _aggregate(rc, rv), (lc, lv), (rc, rv)


def _np_groupby(cols, vals):
    k = SPEC.pack(cols)
    out = {}
    for kk in np.unique(k):
        m = k == kk
        out[int(kk)] = (int(m.sum()), float(vals[m].sum()))
    return out


def test_schema_merge_join_matches_oracle(two_relations):
    L, R, (lc, lv), (rc, rv) = two_relations
    gl, gr = _np_groupby(lc, lv), _np_groupby(rc, rv)
    shared = sorted(set(gl) & set(gr))
    J = L.merge_join(R)
    rel = J.relation()
    packed = SPEC.pack({"region": rel["region"], "store": rel["store"]})
    np.testing.assert_array_equal(packed.astype(np.int64), shared)
    for i, k in enumerate(shared):
        assert rel["count_left"][i] == gl[k][0]
        assert rel["count_right"][i] == gr[k][0]
        np.testing.assert_allclose(rel["sum_left"][i], gl[k][1], rtol=1e-4)
        np.testing.assert_allclose(
            rel["join_count"][i], gl[k][0] * gr[k][0], rtol=1e-6)
        np.testing.assert_allclose(
            rel["sum_left_x_count_right"][i, 0], gl[k][1] * gr[k][0],
            rtol=1e-4)
    # cost model: consuming the established order means a ZERO sort term
    cm = J.plan["cost_model"]
    assert cm["inputs_sorted"] and cm["sort_rows"] == 0.0
    base = J.plan["cost_model_resort_baseline"]
    assert base["sort_rows"] > 0 and base["merge_join_ns"] > cm["merge_join_ns"]
    # stats combine BOTH sides' accounting
    assert J.stats.runs_generated == L.stats.runs_generated + R.stats.runs_generated
    assert J.stats.rows_emitted == L.stats.rows_emitted + R.stats.rows_emitted


def test_schema_semi_anti_partition(two_relations):
    L, R, (lc, lv), (rc, rv) = two_relations
    gl, gr = _np_groupby(lc, lv), _np_groupby(rc, rv)
    semi = L.merge_join(R, how="semi")
    anti = L.merge_join(R, how="anti")
    ks = SPEC.pack({k: v for k, v in semi.relation().items()
                    if k in ("region", "store")})
    ka = SPEC.pack({k: v for k, v in anti.relation().items()
                    if k in ("region", "store")})
    assert set(ks.tolist()) == set(gl) & set(gr)
    assert set(ka.tolist()) == set(gl) - set(gr)
    # semi + anti partition the left key set exactly
    assert semi.occupancy() + anti.occupancy() == L.occupancy()
    assert semi.right is None and semi.products is None


def test_join_key_layout_mismatch_raises(two_relations):
    L, R, _, _ = two_relations
    other_spec = KeySpec.of(region=6, store=30)  # 36 bits → uint64
    with pytest.raises(TypeError, match="dtype mismatch"):
        _check_join_compat(SPEC, other_spec)
    with pytest.raises(TypeError, match="layout mismatch"):
        _check_join_compat(KeySpec.of(a=6, b=8), KeySpec.of(a=8, b=6))
    with pytest.raises(ValueError, match="unknown join how"):
        L.merge_join(R, how="outer")


def test_join_rollup_exact(two_relations):
    """Rollup OF the join = the fine join's aggregates grouped by prefix
    (the products are sums over join pairs, hence additive)."""
    L, R, (lc, lv), (rc, rv) = two_relations
    gl, gr = _np_groupby(lc, lv), _np_groupby(rc, rv)
    shared = sorted(set(gl) & set(gr))
    J = L.merge_join(R)
    tiers = J.rollup()
    assert set(tiers) == {("region", "store"), ("region",), ()}
    # per-region: Σ over fine matched keys of |L|·|R|
    shift = SPEC.shift_of("region")
    exp_by_region = {}
    for k in shared:
        r = k >> shift
        exp_by_region[r] = exp_by_region.get(r, 0.0) + gl[k][0] * gr[k][0]
    rel = tiers[("region",)].relation()
    got = dict(zip(rel["region"].tolist(), rel["join_count"].tolist()))
    assert got == pytest.approx(exp_by_region)
    # grand total joins the full cardinality
    total = tiers[()].relation()
    np.testing.assert_allclose(
        total["join_count"], [sum(exp_by_region.values())])
    # left/right packets roll up alongside
    np.testing.assert_allclose(
        total["count_left"], [sum(gl[k][0] for k in shared)])
    for t in tiers.values():
        assert t.plan["rollup"]["sorts"] == 0


def test_pipeline_composes_without_resort(two_relations):
    L, R, (lc, lv), _ = two_relations
    out = repro.pipeline([
        ("aggregate", dict(columns=lc, by=SPEC, values=lv,
                           aggs=("count", "sum"), output_estimate=256)),
        ("merge_join", {"right": R}),
        ("rollup", {}),
    ])
    assert isinstance(out, dict)
    manual = L.merge_join(R).rollup()
    for names, tier in out.items():
        pipe_block = tier.plan["pipeline"]
        assert pipe_block == {
            "stages": ["aggregate", "merge_join[inner]", "rollup"],
            "source_sorts": 2,
            "re_sorts": 0,
        }
        # exact parity with the independently composed operators
        got, exp = tier.relation(), manual[names].relation()
        assert set(got) == set(exp)
        for col in got:
            np.testing.assert_allclose(got[col], exp[col], rtol=1e-6,
                                       err_msg=f"{names}/{col}")
        assert tier.stats == manual[names].stats


def test_sorted_by_threads_through(two_relations):
    L, R, _, _ = two_relations
    assert L.sorted_by == {"columns": ("region", "store"), "prefix_len": 2,
                           "key_dtype": "uint32"}
    J = L.merge_join(R)
    assert J.sorted_by == L.sorted_by
    assert J.plan["sorted_by"] == [L.sorted_by, R.sorted_by]


# ---------------------------------------------------------------------------
# mesh-sharded merge join (8 fake devices, subprocess per dry-run contract)
# ---------------------------------------------------------------------------

ENV = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_py(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_mesh_merge_join_matches_local():
    run_py("""
        import jax, numpy as np
        import repro

        mesh = jax.make_mesh((8,), ("data",))
        spec = repro.KeySpec.of(region=6, store=8)
        n = 600

        def rel(seed, lo, hi):
            r = np.random.default_rng(seed)
            cols = {"region": r.integers(0, 4, n),
                    "store": r.integers(lo, hi, n)}
            return repro.aggregate(cols, by=spec,
                                   values=r.normal(size=n).astype(np.float32),
                                   aggs=("count", "sum"), output_estimate=256)

        L, R = rel(1, 0, 12), rel(2, 6, 18)
        ref = L.merge_join(R).relation()
        with mesh:
            J = L.merge_join(R, mesh=mesh, mesh_axis="data")
        assert J.plan["mesh"] == {"axis": "data", "world": 8}
        assert J.stats.rows_exchanged > 0
        got = J.relation()
        o = np.lexsort((got["store"], got["region"]))
        assert set(got) == set(ref)
        for col in ref:
            np.testing.assert_allclose(
                np.asarray(got[col])[o], ref[col], rtol=1e-5, err_msg=col)
        # rollup off the mesh-sharded join still matches the local one
        tier = J.rollup(levels=[0])[()].relation()
        ref_tier = L.merge_join(R).rollup(levels=[0])[()].relation()
        np.testing.assert_allclose(tier["join_count"], ref_tier["join_count"])
        # anti join: mesh and local agree on the surviving key set
        with mesh:
            A = L.merge_join(R, how="anti", mesh=mesh, mesh_axis="data")
        ra = L.merge_join(R, how="anti").relation()
        ga = A.relation()
        oa = np.lexsort((ga["store"], ga["region"]))
        np.testing.assert_array_equal(np.asarray(ga["region"])[oa], ra["region"])
        np.testing.assert_array_equal(np.asarray(ga["store"])[oa], ra["store"])
        print("mesh merge join OK", len(got["region"]))
    """)
