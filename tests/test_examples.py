"""The examples must actually run — each is executed as a subprocess in
smoke size (env-var scaled) so the README's entry points cannot rot.
"""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_example(name: str, env_extra: dict, timeout: int = 420) -> str:
    env = dict(os.environ, PYTHONPATH="src", **env_extra)
    r = subprocess.run(
        [sys.executable, os.path.join("examples", name)],
        env=env, capture_output=True, text=True, timeout=timeout, cwd=ROOT,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_streaming_service_smoke():
    out = run_example("streaming_service.py",
                      {"SERVICE_MINUTES": "16", "SERVICE_ROWS": "512"})
    assert "mid-stream queries:" in out       # snapshots answered mid-ingest
    assert "rows retired" in out              # TTL expiry actually fired
    assert "surviving events" in out          # window accounting closed
    assert "sessionized service OK" in out


def test_quickstart_smoke_including_streamed_ingest():
    out = run_example("quickstart.py", {"QUICKSTART_N": "8000"})
    assert "distinct users:" in out
    assert "output arrives sorted" in out
    assert "front door:" in out
    # the streamed-ingest snippet ran and matched the resident relation
    assert "streamed ingest" in out
    assert "identical relation" in out


def test_intersect_warehouse_smoke():
    out = run_example("intersect_warehouse.py", {"INTERSECT_N": "20000"})
    assert "sort-based plan spill:" in out
    # the composed pipeline consumed the sources' order: no re-sorts, and
    # the join side's recorded cost model has a zero sort term
    assert "'re_sorts': 0" in out
    assert "join-side sort term: 0 rows" in out
    assert "order-preserving pipeline OK" in out
