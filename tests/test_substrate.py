"""Substrate tests: optimizers, checkpoint/resume, data pipeline."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, st

from repro.checkpoint import CheckpointManager
from repro.core import ExecConfig
from repro.data import DataLoader, SyntheticCorpus, dedup_examples, pack_by_length
from repro.optim import adafactor, adamw, clip_by_global_norm, cosine_schedule


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def _quadratic_losses(opt_init, opt_update, steps=60):
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)))
    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}
    state = opt_init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)

    out = []
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state = opt_update(g, state, params)
        out.append(float(loss(params)))
    return out


def test_adamw_converges():
    init, update = adamw(lr=0.05, weight_decay=0.0)
    losses = _quadratic_losses(init, update)
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_converges():
    init, update = adafactor(lr=0.3)
    losses = _quadratic_losses(init, update)
    assert losses[-1] < 0.2 * losses[0]


def test_adafactor_state_is_factored():
    init, _ = adafactor()
    params = {"w": jnp.zeros((64, 128))}
    st_ = init(params)
    n_state = sum(x.size for x in jax.tree.leaves((st_.m, st_.v)))
    assert n_state == 64 + 128  # rows + cols, not 64×128


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0 * np.sqrt(10), rel=1e-5)
    n2 = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(n2) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.0, abs=1e-9)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_retention():
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "nested": {"b": jnp.ones(3)}}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for step in (1, 2, 3):
            mgr.save(jax.tree.map(lambda x: x * step, tree), step,
                     extras={"loader": {"seed": 0, "step": step}})
        assert mgr.all_steps() == [2, 3]  # retention
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            tree)
        restored, manifest = mgr.restore(like)
        assert manifest["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]) * 3)


def test_checkpoint_async_save():
    tree = {"w": jnp.ones((128, 128))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(tree, 5, blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 5


def test_checkpoint_atomicity_no_partial_dirs():
    tree = {"w": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(tree, 1)
        for sub in os.listdir(d):
            assert not sub.endswith(".tmp")


def test_train_resume_bit_exact():
    """Fault tolerance end-to-end: interrupt + resume ≡ uninterrupted."""
    from repro.launch.train import train

    with tempfile.TemporaryDirectory() as d:
        losses_full = train("qwen2-1.5b", smoke=True, steps=6, batch=2,
                            seq=32, ckpt_dir=None, log_every=100)
        train("qwen2-1.5b", smoke=True, steps=3, batch=2, seq=32,
              ckpt_dir=d, save_every=3, log_every=100)
        losses_resumed = train("qwen2-1.5b", smoke=True, steps=6, batch=2,
                               seq=32, ckpt_dir=d, resume=True,
                               save_every=100, log_every=100)
        assert losses_resumed[-1] == pytest.approx(losses_full[-1], rel=1e-4)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_dedup_examples_removes_duplicates():
    corpus = SyntheticCorpus(vocab=500, n_docs=600, dup_rate=0.5, seed=3)
    docs = corpus.documents()
    uniq, stats = dedup_examples(
        docs, ExecConfig(memory_rows=256, page_rows=32, fanin=4,
                         batch_rows=128))
    keys = {tuple(d.tolist()) for d in docs}
    assert len(uniq) <= len(keys) and len(uniq) >= 0.95 * len(keys)
    assert len({tuple(d.tolist()) for d in uniq}) == len(uniq)


@settings(max_examples=10, deadline=None)
@given(seq_len=st.integers(32, 256), n=st.integers(1, 200),
       seed=st.integers(0, 1000))
def test_pack_by_length_invariants(seq_len, n, seed):
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, 100, rng.integers(1, seq_len + 1)).astype(np.int32)
            for _ in range(n)]
    packed = pack_by_length(docs, seq_len)
    # every token preserved; rows are seq_len wide; padding is -1
    assert packed.shape[1] == seq_len
    n_tokens = sum(len(d) for d in docs)
    assert int((packed >= 0).sum()) == n_tokens
    # density of first-fit-decreasing ≥ naive one-doc-per-row
    assert packed.shape[0] <= len(docs)


def test_loader_deterministic_resume():
    a = DataLoader(1000, 4, 16, seed=7)
    b1 = [a.next() for _ in range(3)]
    b = DataLoader.from_state(1000, 4, 16, {"seed": 7, "step": 2})
    np.testing.assert_array_equal(b.next()["tokens"], b1[2]["tokens"])
