"""Behaviour tests for the paper's operator and its baselines.

Every algorithm must produce the identical multiset of (key, count, sum)
groups as the NumPy oracle, for any input — the paper's correctness bar.
Spill accounting must obey the paper's structural claims.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    EMPTY,
    AggState,
    ExecConfig,
    distinct,
    f1_hash_aggregate,
    finalize,
    group_by,
    hash_aggregate,
    insort_aggregate,
    instream_aggregate,
    sort_then_stream_aggregate,
    sorted_groupby,
)
from repro.core.operators import validate_against_oracle

RNG = np.random.default_rng(42)


def mkinput(n, o, width=2, skew=False):
    if skew:
        # zipf-ish skew: a few very hot keys
        z = RNG.zipf(1.5, size=n).astype(np.uint64)
        keys = (z % o).astype(np.uint32)
    else:
        keys = RNG.integers(0, o, n).astype(np.uint32)
    pay = RNG.normal(size=(n, width)).astype(np.float32) if width else None
    return keys, pay


CFG = ExecConfig(memory_rows=512, page_rows=64, fanin=4, batch_rows=128)

ALGOS = ["insort", "hash", "f1_hash", "sort_then_stream", "inmemory"]


@pytest.mark.parametrize("algorithm", ALGOS)
@pytest.mark.parametrize("o", [1, 37, 700, 5000])
def test_groupby_matches_oracle(algorithm, o):
    keys, pay = mkinput(12_000, o)
    st, stats = group_by(keys, pay, CFG, algorithm=algorithm, output_estimate=o)
    validate_against_oracle(st, keys, pay)
    assert stats.total_spill_rows >= 0


@pytest.mark.parametrize("algorithm", ["insort", "hash"])
def test_groupby_skewed_keys(algorithm):
    keys, pay = mkinput(20_000, 3_000, skew=True)
    st, _ = group_by(keys, pay, CFG, algorithm=algorithm, output_estimate=3_000)
    validate_against_oracle(st, keys, pay)


def test_inmemory_case_never_spills():
    """Paper Fig 6 / Example 1 (TPC-H Q1): O ≤ M ⇒ zero spill."""
    keys, pay = mkinput(50_000, 100)
    st, stats = insort_aggregate(keys, pay, CFG, output_estimate=100)
    assert stats.total_spill_rows == 0
    assert stats.runs_generated == 0
    validate_against_oracle(st, keys, pay)


def test_insort_output_is_sorted():
    """Interesting orderings: in-sort output is sorted as a byproduct."""
    keys, pay = mkinput(30_000, 2_000)
    st, _ = insort_aggregate(keys, pay, CFG, output_estimate=2_000)
    k = np.asarray(st.keys)
    k = k[k != EMPTY]
    assert np.all(np.diff(k.astype(np.int64)) > 0)  # sorted and duplicate-free


def test_hash_output_is_not_key_sorted():
    """The deficit the paper removes: hash output is in hash order."""
    keys, pay = mkinput(30_000, 2_000)
    st, _ = hash_aggregate(keys, pay, CFG, output_estimate=2_000)
    k = np.asarray(st.keys)
    k = k[k != EMPTY].astype(np.int64)
    assert not np.all(np.diff(k) > 0)


def test_early_aggregation_beats_traditional_spill():
    """§3: early aggregation spills less than input-driven sorting."""
    keys, _ = mkinput(40_000, 1_000)
    _, s_insort = insort_aggregate(keys, None, CFG, output_estimate=1_000)
    _, s_trad = sort_then_stream_aggregate(keys, None, CFG)
    assert s_insort.total_spill_rows < s_trad.total_spill_rows
    # traditional spill ≥ input at run generation alone
    assert s_trad.rows_spilled_run_generation == 40_000


def test_insort_competitive_with_hash_spill():
    """The paper's headline: in-sort spill ≈ hash spill for O ≫ M."""
    keys, _ = mkinput(60_000, 4_000)
    _, si = insort_aggregate(keys, None, CFG, output_estimate=4_000)
    _, sh = hash_aggregate(keys, None, CFG, output_estimate=4_000)
    # read-sort-write cycles spill a bit more than hybrid hashing (Fig 12);
    # parity bound: within 35% and far below the traditional sort.
    assert si.total_spill_rows <= 1.35 * sh.total_spill_rows + CFG.memory_rows
    _, st = sort_then_stream_aggregate(keys, None, CFG)
    assert si.total_spill_rows < 0.5 * st.total_spill_rows


def test_wide_merge_single_level():
    """§4: when O/M ≤ F one wide merge finishes with zero merge spill,
    where a traditional merge needs multiple spilling levels (Fig 14)."""
    keys, _ = mkinput(60_000, 4_000)
    cfg = ExecConfig(memory_rows=1024, page_rows=64, fanin=4, batch_rows=128)
    _, s_wide = insort_aggregate(keys, None, cfg, output_estimate=4_000)
    _, s_trad = insort_aggregate(
        keys, None, cfg, output_estimate=4_000, use_wide_merge=False
    )
    assert s_wide.merge_levels == 1  # ceil(log_F(O/M)) = 1
    assert s_wide.rows_spilled_merge == 0  # wide merge never spills
    assert s_wide.merge_levels < s_trad.merge_levels
    assert s_trad.rows_spilled_merge > 0


def test_wide_merge_depth_output_driven():
    """§4.3: merge depth is ceil(log_F(O/M)) even when O/M > F — the
    pre-levels spill, the final wide merge does not."""
    keys, _ = mkinput(60_000, 4_000)
    _, s = insort_aggregate(keys, None, CFG, output_estimate=4_000)
    from repro.core.cost_model import merge_levels_insort

    assert s.merge_levels == merge_levels_insort(4_000, CFG.memory_rows, CFG.fanin)
    assert not s.index_overflowed


def test_wide_merge_index_stays_within_memory():
    """§4.2: the wide-merge index needs well under the memory allocation."""
    keys, _ = mkinput(60_000, 4_000)
    _, s = insort_aggregate(keys, None, CFG, output_estimate=4_000)
    assert not s.index_overflowed
    assert s.max_index_occupancy <= CFG.memory_rows


def test_wrong_output_estimate_is_still_correct():
    """Optimizer mis-estimates change the plan, never the answer."""
    keys, pay = mkinput(30_000, 2_500)
    for est in (1, 100, 2_500, 10**6):
        st, _ = insort_aggregate(keys, pay, CFG, output_estimate=est)
        validate_against_oracle(st, keys, pay)


def test_instream_streaming_and_correct():
    keys, pay = mkinput(17_000, 900)
    sk = np.sort(keys)
    order = np.argsort(keys, kind="stable")
    # payload must follow its key when pre-sorting the stream
    spay = pay[order]
    st, n = instream_aggregate(jnp.asarray(sk), jnp.asarray(spay), chunk=256)
    assert int(n) == len(np.unique(keys))
    validate_against_oracle(st, sk, spay)


def test_instream_tiny_and_degenerate():
    st, n = instream_aggregate(jnp.asarray(np.zeros(5, np.uint32)), None, chunk=4)
    assert int(n) == 1
    k = np.full(7, EMPTY, np.uint32)
    st, n = instream_aggregate(jnp.asarray(k), None, chunk=4)
    assert int(n) == 0


def test_finalize_avg():
    keys = np.array([3, 3, 5], np.uint32)
    pay = np.array([[1.0], [3.0], [10.0]], np.float32)
    st = sorted_groupby(jnp.asarray(keys), jnp.asarray(pay))
    out = finalize(st)
    assert out["avg"][0, 0] == pytest.approx(2.0)
    assert out["avg"][1, 0] == pytest.approx(10.0)
    assert out["count"][0] == 2 and out["count"][1] == 1
    assert out["min"][0, 0] == pytest.approx(1.0)
    assert out["max"][0, 0] == pytest.approx(3.0)


def test_distinct_no_payload():
    keys, _ = mkinput(25_000, 1_500, width=0)
    st, _ = distinct(keys, CFG, output_estimate=1_500)
    k = np.asarray(st.keys)
    k = k[k != EMPTY]
    assert np.array_equal(np.sort(k), np.unique(keys))


def test_empty_input():
    st, stats = insort_aggregate(np.zeros((0,), np.uint32), None, CFG)
    assert int(st.occupancy()) == 0
    assert stats.total_spill_rows == 0


def test_single_key_all_duplicates():
    keys = np.full(30_000, 7, np.uint32)
    st, stats = insort_aggregate(keys, None, CFG, output_estimate=1)
    assert stats.total_spill_rows == 0  # one group always fits memory
    assert int(st.occupancy()) == 1
    assert int(st.count[0]) == 30_000
