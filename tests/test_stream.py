"""Streamed (double-buffered super-batch) pipeline tests.

Parity contract: for every run-generation policy and both key dtypes,
any chunking whose chunk sizes are multiples of the engine's input batch
(``memory_rows`` for the read-sort-write policies, ``batch_rows`` for
early-agg/RS; the final chunk may be a ragged tail) produces EXACTLY the
one-shot pipeline's result state AND SpillStats — EMPTY-padded batches
are no-ops in every policy.  Plus: the streamed loop performs zero
implicit transfers (explicit ``device_put`` staging only) with ONE stats
readback at finalize; absorbing a second same-geometry super-batch hits
the jit cache (no retrace); and the one-shot front door no longer
retraces when N changes within a pow2-bucketed geometry.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import pipeline
from repro.core.types import DeviceSpillStats, ExecConfig, empty_key
from repro.core.operators import group_by, validate_against_oracle

RNG = np.random.default_rng(7)
CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
N = 4000
KEY_DTYPES = (np.uint32, np.uint64)
POLICIES = ("traditional", "inrun_dedup", "early_agg", "rs")

ENV = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_py(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def _mkinput(n=N, domain=1200, width=1, key_dtype=np.uint32, rng=RNG):
    keys = rng.integers(0, domain, n).astype(key_dtype)
    if key_dtype == np.uint64:
        keys = keys << np.uint64(30)  # spread past 32 bits
    pay = None if width == 0 else rng.normal(size=(n, width)).astype(np.float32)
    return keys, pay


def _unit(policy):
    """The engine's input batch: chunk boundaries at multiples of this
    keep the absorbed batch sequence identical to the one-shot path."""
    return (CFG.memory_rows if policy in ("traditional", "inrun_dedup")
            else CFG.batch_rows)


def _chunk_sizes(policy, chunking):
    u = _unit(policy)
    if chunking == "one":
        return [N]  # degenerate streaming: one super-batch
    if chunking == "three":
        return [6 * u, 3 * u, N - 9 * u]  # uneven, unit-aligned
    # "tail": many equal super-batches + a ragged tail chunk whose batch
    # count gets pow2-bucketed with trailing EMPTY batches
    sizes = [5 * u] * ((N - 1) // (5 * u))
    sizes.append(N - sum(sizes))
    return sizes


def _chunks(keys, pay, sizes):
    s = 0
    for c in sizes:
        yield keys[s:s + c], None if pay is None else pay[s:s + c]
        s += c


def _strip(st):
    k = np.asarray(st.keys)
    v = k != empty_key(k.dtype)
    return k[v], np.asarray(st.count)[v], np.asarray(st.sum)[v]


# ---------------------------------------------------------------------------
# streamed vs one-shot: exact result AND stats parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("key_dtype", KEY_DTYPES)
@pytest.mark.parametrize("policy", POLICIES)
def test_streamed_matches_one_shot_exactly(policy, key_dtype):
    keys, pay = _mkinput(key_dtype=key_dtype)
    st1, s1 = pipeline.insort_aggregate_device(keys, pay, CFG, policy=policy)
    k1, c1, v1 = _strip(st1)
    for chunking in ("one", "three", "tail"):
        sizes = _chunk_sizes(policy, chunking)
        assert sum(sizes) == N
        # output_rows pinned to the one-shot's padded capacity: identical
        # result shapes AND one finalize compile shared by all chunkings
        st2, s2 = pipeline.insort_aggregate_device_stream(
            _chunks(keys, pay, sizes), CFG, policy=policy, output_rows=4096
        )
        assert s2.as_dict() == s1.as_dict(), chunking
        k2, c2, v2 = _strip(st2)
        np.testing.assert_array_equal(k1, k2, err_msg=chunking)
        np.testing.assert_array_equal(c1, c2, err_msg=chunking)
        np.testing.assert_allclose(v1, v2, rtol=1e-6, err_msg=chunking)
        validate_against_oracle(st2, keys, pay)


def test_streamed_unaligned_chunks_still_match_oracle():
    """Chunk sizes that are NOT unit multiples interleave EMPTY padding
    mid-stream — run composition (and thus spill accounting) may legally
    differ, but the aggregate relation must not."""
    keys, pay = _mkinput()
    st1, _ = pipeline.insort_aggregate_device(keys, pay, CFG, policy="rs")
    st2, s2 = pipeline.insort_aggregate_device_stream(
        _chunks(keys, pay, [700] * 5 + [500]), CFG, policy="rs"
    )
    k1, c1, v1 = _strip(st1)
    k2, c2, v2 = _strip(st2)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-4)
    assert s2.total_spill_rows > 0
    validate_against_oracle(st2, keys, pay)


def test_streamed_edges():
    # empty stream
    st, s = pipeline.insort_aggregate_device_stream(iter(()), CFG)
    assert int(st.occupancy()) == 0 and s.total_spill_rows == 0
    # empty chunks interleaved with real ones
    keys, _ = _mkinput(width=0)
    e = np.zeros(0, np.uint32)
    st, _ = pipeline.insort_aggregate_device_stream(
        iter([e, keys[:1000], e, keys[1000:], e]), CFG, policy="rs"
    )
    validate_against_oracle(st, keys)
    # one hot key across many chunks collapses to one group
    hot = np.full(3 * N, 7, np.uint32)
    st, s = pipeline.insort_aggregate_device_stream(
        _chunks(hot, None, [N, N, N]), CFG, policy="rs"
    )
    assert int(st.occupancy()) == 1 and int(st.count[0]) == 3 * N
    # plane-width restriction travels through the streamed path
    keys, pay = _mkinput()
    st, _ = pipeline.insort_aggregate_device_stream(
        _chunks(keys, pay, [2000, 2000]), CFG, policy="rs", widths=(1, 0, 0)
    )
    assert st.widths == (1, 0, 0)
    validate_against_oracle(st, keys, pay)


def test_rebatch_chunks_and_super_batch_rows():
    keys, pay = _mkinput()
    # rebatch: ragged producer chunks → fixed super-batches
    out = list(pipeline.rebatch_chunks(
        _chunks(keys, pay, [700] * 5 + [500]), 1024))
    assert [len(k) for k, _ in out] == [1024, 1024, 1024, 928]
    np.testing.assert_array_equal(np.concatenate([k for k, _ in out]), keys)
    np.testing.assert_array_equal(np.concatenate([p for _, p in out]), pay)
    # the same re-chunking inline via super_batch_rows=
    st1, s1 = pipeline.insort_aggregate_device_stream(
        _chunks(keys, pay, [700] * 5 + [500]), CFG, policy="rs",
        super_batch_rows=1024,
    )
    st2, s2 = pipeline.insort_aggregate_device_stream(
        iter(out), CFG, policy="rs"
    )
    assert s1.as_dict() == s2.as_dict()
    np.testing.assert_array_equal(*map(lambda s: _strip(s)[0], (st1, st2)))


# ---------------------------------------------------------------------------
# transfer discipline: explicit staging only, ONE readback at finalize
# ---------------------------------------------------------------------------


def test_streamed_single_readback_under_transfer_guard():
    """The absorb loop performs zero implicit transfers: staging is an
    explicit ``jax.device_put``, the engine state lives on device across
    super-batches, and only ``DeviceSpillStats.finalize()`` reads
    anything back — O(1) scalars for the whole stream."""
    keys, pay = _mkinput()
    sizes = _chunk_sizes("rs", "three")
    # compile outside the guard; the guard then proves steady state
    st, _ = pipeline.aggregate_device_stream(
        _chunks(keys, pay, sizes), CFG, policy="rs")
    jax.block_until_ready(st)
    with jax.transfer_guard("disallow"):
        st, dstats = pipeline.aggregate_device_stream(
            _chunks(keys, pay, sizes), CFG, policy="rs")
        jax.block_until_ready((st, dstats))
    assert isinstance(dstats, DeviceSpillStats)
    stats = dstats.finalize()  # the single readback, outside the guard
    assert stats.total_spill_rows > 0
    validate_against_oracle(st, keys, pay)


def test_streamed_loop_performs_no_host_syncs():
    """Counting device-scalar ``int(...)`` conversions inside the
    pipeline module during the absorb loop: zero — the run-slot bound is
    computed on the host from row counts alone (no occupancy readbacks,
    unlike the host reference loop's O(N/B))."""
    keys, pay = _mkinput()
    counts = {"sync": 0}
    real_int = int

    def counting_int(x, *a, **kw):
        if isinstance(x, jax.Array):
            counts["sync"] += 1
        return real_int(x, *a, **kw)

    pipeline.int = counting_int
    try:
        st, dstats = pipeline.aggregate_device_stream(
            _chunks(keys, pay, _chunk_sizes("rs", "tail")), CFG, policy="rs")
    finally:
        del pipeline.int
    assert counts["sync"] == 0


# ---------------------------------------------------------------------------
# compile discipline: geometry-keyed caches, no per-chunk retraces
# ---------------------------------------------------------------------------


def test_one_shot_does_not_retrace_within_geometry_bucket():
    """The front door pads on the HOST to the pow2-bucketed batch
    geometry before entering the jit, so a second call with a different N
    in the same bucket reuses the compiled program (the recompile-churn
    fix: the jit specializes on geometry, not on N)."""
    keys, pay = _mkinput(n=4000)
    pipeline.insort_aggregate_device(keys, pay, CFG, policy="rs")
    before = len(pipeline.TRACE_LOG)
    keys2, pay2 = _mkinput(n=3900)  # same bucket: 64 batches of 64
    st, _ = pipeline.insort_aggregate_device(keys2, pay2, CFG, policy="rs")
    assert pipeline.TRACE_LOG[before:] == []
    validate_against_oracle(st, keys2, pay2)
    # a genuinely different geometry (smaller bucket) does retrace
    keys3, pay3 = _mkinput(n=900)
    pipeline.insort_aggregate_device(keys3, pay3, CFG, policy="rs")
    assert any(t[0] == "pipeline" for t in pipeline.TRACE_LOG[before:])


def test_streamed_absorb_reuses_compilation_across_super_batches():
    """Absorbing super-batch k+1 with the same geometry is a jit-cache
    hit; new compiles happen only at the (log-many, pow2-spaced) run-slot
    growth events — chunk COUNT never enters trace shapes."""
    keys, _ = _mkinput(n=3 * 320, width=0)
    agg = pipeline.StreamingAggregator(
        CFG, policy="rs", key_dtype=np.uint32, width=0)
    agg.absorb(keys[:320])  # init + absorb compile here
    before = len(pipeline.TRACE_LOG)
    agg.absorb(keys[320:640])  # same geometry: zero new traces
    assert pipeline.TRACE_LOG[before:] == []
    agg.absorb(keys[640:])  # crosses the slot bound: grow (+ the absorb
    # re-specialized on the grown store shape), nothing else
    new = [t[0] for t in pipeline.TRACE_LOG[before:]]
    assert new in ([], ["grow"], ["grow", "absorb"])
    st, _ = agg.finalize()
    validate_against_oracle(st, keys)


# ---------------------------------------------------------------------------
# mesh-sharded streaming (8 fake CPU devices via subprocess)
# ---------------------------------------------------------------------------


def test_streamed_mesh_matches_single_device():
    run_py("""
        import jax, numpy as np
        from repro.core import pipeline
        from repro.core.types import ExecConfig, empty_key
        from repro.core.operators import validate_against_oracle

        CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4,
                         batch_rows=64)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(9)
        keys = rng.integers(0, 1200, 8192).astype(np.uint32)
        pay = rng.normal(size=(8192, 1)).astype(np.float32)

        def chunks():
            for s in range(0, 8192, 2048):
                yield keys[s:s+2048], pay[s:s+2048]

        st, stats = pipeline.insort_aggregate_device_stream(
            chunks(), CFG, policy="rs", mesh=mesh)
        validate_against_oracle(st, keys, pay)
        assert stats.rows_exchanged > 0

        def strip(st):
            k = np.asarray(st.keys)
            v = k != empty_key(k.dtype)
            return k[v], np.asarray(st.count)[v], np.asarray(st.sum)[v]

        gk, gc, gs = strip(st)
        assert np.all(gk[:-1] < gk[1:])  # globally sorted, unique
        st1, _ = pipeline.insort_aggregate_device(keys, pay, CFG,
                                                  policy="rs")
        rk, rc, rs_ = strip(st1)
        np.testing.assert_array_equal(gk, rk)
        np.testing.assert_array_equal(gc, rc)
        np.testing.assert_allclose(gs, rs_, rtol=2e-4, atol=2e-3)
        print("streamed mesh parity OK")
    """)


# ---------------------------------------------------------------------------
# front doors: schema aggregate / group_by over iterators, data adapters
# ---------------------------------------------------------------------------


def test_schema_aggregate_streams_column_batches():
    import repro
    from repro.data.pipeline import iter_column_batches

    rng = np.random.default_rng(3)
    cols = {
        "u": rng.integers(0, 50, N).astype(np.uint32),
        "i": rng.integers(0, 20, N).astype(np.uint32),
        "x": rng.random(N).astype(np.float32),
    }
    by = repro.KeySpec.of(u=16, i=16)
    res = repro.aggregate(
        {k: cols[k] for k in ("u", "i")}, by=by, values=cols["x"],
        aggs=("count", "sum", "avg"), cfg=CFG, output_estimate=1024,
    )
    stream = repro.aggregate(
        iter_column_batches(cols, 640), by=by, values="x",
        aggs=("count", "sum", "avg"), cfg=CFG, output_estimate=1024,
    )
    assert stream.plan["streamed"] and stream.plan["pipeline"] == "device"
    assert stream.plan["input_rows"] == N
    r1, r2 = res.relation(), stream.relation()
    for k in ("u", "i", "count"):
        np.testing.assert_array_equal(r1[k], r2[k])
    for k in ("sum", "avg"):
        np.testing.assert_allclose(r1[k], r2[k], rtol=1e-5, atol=1e-5)

    # count-only drops the value column entirely (no payload staged)
    res_c = repro.aggregate(
        iter_column_batches(cols, 640), by=by, values="x", aggs=("count",),
        cfg=CFG, output_estimate=1024,
    )
    np.testing.assert_array_equal(res_c.relation()["count"], r1["count"])

    # empty stream
    empty = repro.aggregate(iter(()), by=by, aggs=("count",), cfg=CFG)
    assert empty.occupancy() == 0 and empty.plan["streamed"]


def test_streamed_front_door_input_validation():
    import repro
    from repro.data.pipeline import iter_column_batches

    by = repro.KeySpec.of(k=12)
    batches = lambda: iter([{"k": np.arange(100, dtype=np.uint32)}])
    with pytest.raises(ValueError, match="in-sort"):
        repro.aggregate(batches(), by=by, algorithm="hash", cfg=CFG)
    with pytest.raises(ValueError, match="device"):
        repro.aggregate(batches(), by=by, pipeline="host", cfg=CFG)
    with pytest.raises(TypeError, match="column"):
        repro.aggregate(batches(), by=by, values=np.zeros(100), cfg=CFG,
                        aggs=("sum",))
    with pytest.raises(KeyError, match="missing"):
        repro.aggregate(batches(), by=by, values="x", aggs=("sum",), cfg=CFG)
    # adapters validate their inputs too
    with pytest.raises(ValueError, match="rows"):
        list(iter_column_batches({"k": np.arange(4)}, 0))
    with pytest.raises(ValueError, match="expected"):
        list(iter_column_batches(
            {"a": np.arange(4), "b": np.arange(5)}, 2))


def test_group_by_accepts_chunk_iterator():
    keys, pay = _mkinput()
    st1, s1 = group_by(keys, pay, CFG)
    st2, s2 = group_by(
        _chunks(keys, pay, _chunk_sizes("rs", "three")), None, CFG)
    k1, c1, v1 = _strip(st1)
    k2, c2, v2 = _strip(st2)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_allclose(v1, v2, rtol=1e-6)
    assert s1.as_dict() == s2.as_dict()
    with pytest.raises(ValueError, match="in-sort"):
        group_by(_chunks(keys, pay, [N]), None, CFG, algorithm="hash")
    with pytest.raises(ValueError, match="pairs"):
        group_by(_chunks(keys, None, [N]), pay, CFG)


def test_rebatch_columns_adapter():
    from repro.data.pipeline import rebatch_columns

    rng = np.random.default_rng(5)
    shards = [
        {"a": rng.integers(0, 9, n).astype(np.uint32),
         "x": rng.random(n).astype(np.float32)}
        for n in (300, 50, 700, 10)
    ]
    out = list(rebatch_columns(iter(shards), 256))
    assert [len(b["a"]) for b in out] == [256, 256, 256, 256, 36]
    np.testing.assert_array_equal(
        np.concatenate([b["a"] for b in out]),
        np.concatenate([s["a"] for s in shards]))
    np.testing.assert_array_equal(
        np.concatenate([b["x"] for b in out]),
        np.concatenate([s["x"] for s in shards]))
    with pytest.raises(ValueError, match="columns"):
        list(rebatch_columns(iter([{"a": np.arange(4)},
                                   {"b": np.arange(4)}]), 2))
