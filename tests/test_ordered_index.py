"""OrderedIndex engine + backend registry tests.

The acceptance bar of the merge-path refactor: absorbing one sorted state
into another is a *linear merge* — no full argsort on either backend —
and the rank computation that realizes it is exactly the stable-merge
permutation.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dispatch, sorted_ops
from repro.core.ordered_index import (
    OrderedIndex,
    merge_absorb_xla,
    merge_gather_indices,
    merge_ranks,
    pair_combine_xla,
)
from repro.core.operators import validate_against_oracle
from repro.core.types import (
    EMPTY,
    AggState,
    empty_state,
    key_dtype_context,
    rows_to_state,
)

RNG = np.random.default_rng(99)

BACKENDS = ("xla", "pallas")
KEY_DTYPES = (np.uint32, np.uint64)


def _sorted_state(n, domain, width, rng=RNG, key_dtype=np.uint32):
    keys = rng.integers(0, domain, n).astype(key_dtype)
    if key_dtype == np.uint64:
        keys = keys << np.uint64(30)  # spread past 32 bits
    pay = None if width == 0 else rng.normal(size=(n, width)).astype(np.float32)
    st = rows_to_state(keys, None if pay is None else jnp.asarray(pay))
    return sorted_ops.absorb(st), keys, pay


# ---------------------------------------------------------------------------
# rank computation (the heart of the linear merge)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("na,nb,domain", [(100, 100, 50), (257, 33, 10),
                                          (64, 512, 1 << 30), (1, 1, 2)])
def test_merge_ranks_is_stable_merge_permutation(na, nb, domain):
    a = np.sort(RNG.integers(0, domain, na).astype(np.uint32))
    b = np.sort(RNG.integers(0, domain, nb).astype(np.uint32))
    pos_a, pos_b = merge_ranks(jnp.asarray(a), jnp.asarray(b))
    pos_a, pos_b = np.asarray(pos_a), np.asarray(pos_b)
    # a permutation of range(na+nb) …
    assert sorted(pos_a.tolist() + pos_b.tolist()) == list(range(na + nb))
    # … that realizes the sorted merge …
    out = np.empty(na + nb, np.uint32)
    out[pos_a] = a
    out[pos_b] = b
    np.testing.assert_array_equal(out, np.sort(np.concatenate([a, b])))
    # … stably: on ties, every a-row precedes every b-row
    for k in np.intersect1d(a, b):
        assert pos_a[a == k].max() < pos_b[b == k].min()


def test_merge_gather_indices_inverts_ranks():
    a = np.sort(RNG.integers(0, 40, 300).astype(np.uint32))
    b = np.sort(RNG.integers(0, 40, 200).astype(np.uint32))
    src = np.asarray(merge_gather_indices(jnp.asarray(a), jnp.asarray(b)))
    cat = np.concatenate([a, b])
    np.testing.assert_array_equal(cat[src], np.sort(cat))
    assert sorted(src.tolist()) == list(range(500))  # a permutation


from _jaxpr_checks import assert_no_scatter, assert_no_sort, collect_primitives


@pytest.mark.parametrize("key_dtype", KEY_DTYPES)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("assume_unique", [False, True])
def test_merge_absorb_performs_no_sort(backend, assume_unique, key_dtype):
    """merge_absorb of two sorted states must not contain a sort primitive
    anywhere in its jaxpr (including inside the Pallas kernel body) — at
    32 AND 64-bit key width (64-bit keys run as (hi, lo) uint32 lanes on
    Pallas and native uint64 under x64 on XLA)."""
    with key_dtype_context(key_dtype):
        a, _, _ = _sorted_state(256, 100, 2, key_dtype=key_dtype)
        b, _, _ = _sorted_state(128, 100, 2, key_dtype=key_dtype)
        jx = jax.make_jaxpr(
            lambda x, y: sorted_ops.merge_absorb(
                x, y, backend=backend, assume_unique=assume_unique
            )
        )(a, b)
    prims = collect_primitives(jx.jaxpr)
    assert_no_sort(prims, context=f"via backend={backend}")
    if backend == "xla":
        # the XLA engine is also scatter-free end to end: rank-gather
        # interleave + segmented-scan combine + compaction gather
        assert_no_scatter(prims, context="on xla path")


@pytest.mark.parametrize("key_dtype", KEY_DTYPES)
def test_segmented_combine_xla_scatter_free_and_correct(key_dtype):
    """The general segmented combine (≥3 duplicates per group) on XLA is a
    segmented associative scan + compaction gather: its jaxpr must contain
    neither a sort nor any scatter primitive, and it must match the oracle
    on groups with ≥3 duplicates."""
    rng = np.random.default_rng(5)
    keys = np.sort(
        np.repeat(rng.choice(200, 60, replace=False), rng.integers(3, 7, 60))
    ).astype(key_dtype)
    if key_dtype == np.uint64:
        keys = keys << np.uint64(34)
    pay = rng.normal(size=(len(keys), 2)).astype(np.float32)
    with key_dtype_context(key_dtype):
        st = rows_to_state(jnp.asarray(keys), jnp.asarray(pay))
        jx = jax.make_jaxpr(
            lambda s: sorted_ops.segmented_combine(s, backend="xla")
        )(st)
        out = sorted_ops.segmented_combine(st, backend="xla")
    prims = collect_primitives(jx.jaxpr)
    assert_no_scatter(prims, context="in segmented_combine_xla")
    assert_no_sort(prims)
    validate_against_oracle(out, keys, pay)
    # per-group min/max survive the scan rewrite
    got_valid = np.asarray(out.valid())
    got_keys = np.asarray(out.keys)[got_valid]
    for name, red in (("min", np.minimum.reduceat), ("max", np.maximum.reduceat)):
        col = np.asarray(getattr(out, name))[got_valid]
        uk, starts = np.unique(keys, return_index=True)
        want = red(pay, starts, axis=0)
        np.testing.assert_array_equal(got_keys, uk)
        np.testing.assert_allclose(col, want, rtol=1e-6)


def test_absorb_of_unsorted_does_sort():
    """Sanity check on the detector: the full-argsort path IS a sort."""
    st = rows_to_state(jnp.asarray(RNG.integers(0, 9, 64).astype(np.uint32)), None)
    jx = jax.make_jaxpr(lambda x: sorted_ops.absorb(x))(st)
    assert "sort" in collect_primitives(jx.jaxpr)


# ---------------------------------------------------------------------------
# merge_absorb correctness across backends / shapes / uniqueness promises
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("na,nb,domain,width", [
    (700, 500, 300, 2), (128, 128, 10, 0), (64, 1, 5, 1), (300, 900, 1 << 30, 2),
])
def test_merge_absorb_matches_oracle(backend, na, nb, domain, width):
    a, ka, pa = _sorted_state(na, domain, width)
    b, kb, pb = _sorted_state(nb, domain, width)
    for uniq in (False, True):
        got = sorted_ops.merge_absorb(a, b, backend=backend, assume_unique=uniq)
        assert got.capacity == na + nb
        validate_against_oracle(
            got, np.concatenate([ka, kb]),
            None if width == 0 else np.concatenate([pa, pb]),
        )
        k = np.asarray(got.keys)
        k = k[k != EMPTY]
        assert np.all(np.diff(k.astype(np.int64)) > 0)  # sorted, duplicate-free


@pytest.mark.parametrize("backend", BACKENDS)
def test_merge_absorb_duplicates_within_inputs(backend):
    """Sorted-but-not-deduped inputs (e.g. run pages) combine correctly on
    the general path."""
    ka = np.sort(RNG.integers(0, 50, 200).astype(np.uint32))
    kb = np.sort(RNG.integers(0, 50, 100).astype(np.uint32))
    a = rows_to_state(jnp.asarray(ka), None)
    b = rows_to_state(jnp.asarray(kb), None)
    got = sorted_ops.merge_absorb(a, b, backend=backend)
    validate_against_oracle(got, np.concatenate([ka, kb]))


def test_merge_absorb_empty_capacity_side():
    a, ka, pa = _sorted_state(100, 30, 2)
    b = empty_state(0, 2)
    for uniq in (False, True):
        got = sorted_ops.merge_absorb(a, b, assume_unique=uniq)
        validate_against_oracle(got, ka, pa)


def test_pair_combine_matches_segmented_combine():
    """On ≤2-rows-per-key sorted input the pair-combine must agree with
    the general segmented combine bit for bit (modulo float assoc)."""
    keys = np.repeat(RNG.choice(1000, 300, replace=False).astype(np.uint32),
                     RNG.integers(1, 3, 300))
    keys = np.sort(keys)
    pay = RNG.normal(size=(len(keys), 2)).astype(np.float32)
    st = rows_to_state(jnp.asarray(keys), jnp.asarray(pay))
    got = pair_combine_xla(st)
    want = sorted_ops.segmented_combine(st)
    np.testing.assert_array_equal(np.asarray(got.keys), np.asarray(want.keys))
    np.testing.assert_array_equal(np.asarray(got.count), np.asarray(want.count))
    np.testing.assert_allclose(np.asarray(got.sum), np.asarray(want.sum),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got.min), np.asarray(want.min))
    np.testing.assert_allclose(np.asarray(got.max), np.asarray(want.max))


# ---------------------------------------------------------------------------
# OrderedIndex type
# ---------------------------------------------------------------------------


def test_ordered_index_roundtrip_and_trim():
    keys = RNG.integers(0, 64, 500).astype(np.uint32)
    pay = RNG.normal(size=(500, 1)).astype(np.float32)
    oi = OrderedIndex.from_unsorted(rows_to_state(jnp.asarray(keys), jnp.asarray(pay)))
    validate_against_oracle(oi.state, keys, pay)
    occ = int(oi.occupancy())
    trimmed = oi.trim(occ)
    assert trimmed.capacity == occ
    validate_against_oracle(trimmed.state, keys, pay)


def test_ordered_index_merge_absorb():
    a = OrderedIndex.from_unsorted(
        rows_to_state(jnp.asarray(RNG.integers(0, 99, 400).astype(np.uint32)), None)
    )
    b = OrderedIndex.from_unsorted(
        rows_to_state(jnp.asarray(RNG.integers(50, 150, 300).astype(np.uint32)), None)
    )
    m = a.merge_absorb(b)
    assert isinstance(m, OrderedIndex)
    assert m.capacity == 700
    k = np.asarray(m.keys)
    k = k[k != EMPTY]
    assert np.all(np.diff(k.astype(np.int64)) > 0)


def test_ordered_index_is_pytree():
    oi = OrderedIndex.empty(16, 2)
    out = jax.jit(lambda x: x.merge_absorb(OrderedIndex.empty(16, 2)))(oi)
    assert out.capacity == 32


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------


def test_registry_builtin_backends():
    assert set(dispatch.registered_backends()) >= {"xla", "pallas"}
    assert dispatch.backend_available("xla")
    be = dispatch.get_backend("xla")
    assert be.name == "xla"
    assert dispatch.get_backend("xla") is be  # cached


def test_registry_auto_resolution():
    name = dispatch.resolve_backend_name("auto")
    assert name in dispatch.registered_backends()
    # off-TPU, auto must prefer the XLA engine
    if jax.default_backend() != "tpu":
        assert name == "xla"


def test_registry_unknown_backend_raises():
    with pytest.raises(KeyError):
        dispatch.get_backend("cuda-classic")


def test_registry_custom_backend_and_probe():
    calls = []

    def loader():
        calls.append(1)
        xla = dispatch.get_backend("xla")
        return dispatch.Backend(
            name="custom", argsort=xla.argsort,
            segmented_combine=xla.segmented_combine, merge_sorted=xla.merge_sorted,
        )

    dispatch.register_backend("custom-test", loader)
    try:
        assert dispatch.backend_available("custom-test")
        be = dispatch.get_backend("custom-test")
        assert be.name == "custom" and calls == [1]
        dispatch.get_backend("custom-test")
        assert calls == [1]  # loader ran once
        with pytest.raises(ValueError):
            dispatch.register_backend("custom-test", loader)
    finally:
        dispatch._loaders.pop("custom-test", None)
        dispatch._cache.pop("custom-test", None)


def test_registry_unavailable_backend_probes_false():
    def loader():
        raise dispatch.BackendUnavailable("no such accelerator")

    dispatch.register_backend("broken-test", loader)
    try:
        assert not dispatch.backend_available("broken-test")
        with pytest.raises(dispatch.BackendUnavailable):
            dispatch.get_backend("broken-test")
    finally:
        dispatch._loaders.pop("broken-test", None)


# ---------------------------------------------------------------------------
# the full operator on the pallas engine (acceptance: every policy + wide
# merge, both backends) — sizes kept small: interpret mode is slow
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["traditional", "inrun_dedup", "early_agg", "rs"])
def test_policies_oracle_pallas_backend(policy):
    from repro.core import insort_aggregate
    from repro.core.types import ExecConfig

    cfg = ExecConfig(memory_rows=128, page_rows=32, fanin=4, batch_rows=32)
    keys = RNG.integers(0, 300, 1500).astype(np.uint32)
    pay = RNG.normal(size=(1500, 1)).astype(np.float32)
    if policy == "rs":
        st, _ = insort_aggregate(keys, pay, cfg, output_estimate=300,
                                 run_policy="rs", backend="pallas")
    elif policy == "traditional":
        from repro.core.insort import sort_then_stream_aggregate

        st, _ = sort_then_stream_aggregate(keys, pay, cfg, backend="pallas")
    else:
        st, _ = insort_aggregate(
            keys, pay, cfg, output_estimate=300,
            early_aggregation=(policy == "early_agg"), run_policy="batch",
            backend="pallas",
        )
    validate_against_oracle(st, keys, pay)


@pytest.mark.parametrize("backend", BACKENDS)
def test_wide_merge_oracle_both_backends(backend):
    from repro.core import insort_aggregate
    from repro.core.types import ExecConfig

    cfg = ExecConfig(memory_rows=128, page_rows=32, fanin=4, batch_rows=32)
    keys = RNG.integers(0, 400, 2000).astype(np.uint32)
    st, stats = insort_aggregate(keys, None, cfg, output_estimate=400,
                                 backend=backend)
    validate_against_oracle(st, keys)
    assert stats.rows_spilled_merge == 0  # the wide merge never spills
    assert stats.rows_emitted == len(np.unique(keys))
