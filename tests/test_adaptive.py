"""Calibrated cost model + mid-flight adaptive policy switching tests.

Three layers:

* cost-model unit tests — the constants schema gate (CI fails if the
  checked-in ``core/_cost_constants.py`` drifts from the generator
  schema), the linear crossover solve, and the sorted-input credit;
* governor unit tests — every decision path (``start``, ``hold``,
  ``small_window``, ``hysteresis``, ``switch``) forced deterministically
  with injected constants, no device involved;
* engine integration — Zipf and phase-change key streams through
  ``policy="adaptive"`` with EXACT keys/counts parity vs the one-shot
  oracle on every decision path, the O(stream/k) readback contract
  counted, the transfer-guard discipline (the governor's readback is an
  explicit ``device_get``), and the snapshot/finalize out-capacity
  retry-at-next-pow2.
"""
import logging

import jax
import numpy as np
import pytest

from repro.core import cost_model, pipeline
from repro.core.adaptive import ARMS, GovernorConfig, Observation, PolicyGovernor
from repro.core.operators import validate_against_oracle
from repro.core.types import ExecConfig, MergeOverflowError

RNG = np.random.default_rng(11)
CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
N = 4096


def make_constants(
    *,
    traditional=100.0,
    early=150.0,
    early_dup=None,
    rs=400.0,
    sort=30.0,
    merge=50.0,
    spill=10.0,
) -> dict:
    """A schema-complete constants entry with injected per-policy costs
    (``early_dup`` defaults to ``early`` — duplicate-independent)."""
    absorb = {"traditional": traditional, "inrun_dedup": traditional + 20,
              "early_agg": early, "rs": rs}
    absorb_dup = dict(absorb)
    if early_dup is not None:
        absorb_dup["early_agg"] = early_dup
    return {
        "schema_version": cost_model.COST_SCHEMA_VERSION,
        "absorb_row_ns": absorb,
        "absorb_dup_row_ns": absorb_dup,
        "sort_row_ns": sort,
        "merge_row_ns": merge,
        "hash_probe_row_ns": 80.0,
        "spill_write_row_ns": spill,
        "meta": {"backend": "test", "generated_by": "tests"},
    }


# traditional wins at every duplicate rate (big absorb gap, small spill)
FAVOR_TRAD = make_constants(traditional=100.0, early=400.0, rs=900.0)
# early_agg wins at every duplicate rate
FAVOR_EARLY = make_constants(traditional=400.0, early=100.0, rs=900.0)
# crossover at d = 0.3125: traditional below, early_agg above
CROSSOVER = make_constants(traditional=100.0, early=150.0, early_dup=50.0,
                           rs=900.0, merge=50.0, spill=10.0)


# ---------------------------------------------------------------------------
# constants schema gate (the CI staleness check)
# ---------------------------------------------------------------------------


def test_checked_in_constants_match_generator_schema():
    from repro.core import _cost_constants as cc

    cost_model.validate_constants(cc.COST_CONSTANTS)
    assert cc.COST_SCHEMA_VERSION == cost_model.COST_SCHEMA_VERSION
    assert "cpu" in cc.COST_CONSTANTS, "CPU defaults must stay committed"


def test_stale_constants_fail_loudly():
    bad = {"cpu": dict(make_constants())}
    del bad["cpu"]["merge_row_ns"]
    with pytest.raises(cost_model.StaleConstantsError, match="merge_row_ns"):
        cost_model.validate_constants(bad)
    stale = {"cpu": dict(make_constants(), schema_version=0)}
    with pytest.raises(cost_model.StaleConstantsError, match="schema_version"):
        cost_model.validate_constants(stale)
    partial = {"cpu": dict(make_constants())}
    partial["cpu"]["absorb_row_ns"] = {"traditional": 1.0}
    with pytest.raises(cost_model.StaleConstantsError, match="early_agg"):
        cost_model.validate_constants(partial)


# ---------------------------------------------------------------------------
# calibrated cost model
# ---------------------------------------------------------------------------


def test_crossover_exact_linear_solve():
    d = cost_model.crossover_dup_rate("traditional", "early_agg",
                                      constants=CROSSOVER, merge_levels=1)
    assert d == pytest.approx(0.3125)
    lo = cost_model.choose_policy(d - 0.05, constants=CROSSOVER,
                                  arms=("traditional", "early_agg"))
    hi = cost_model.choose_policy(d + 0.05, constants=CROSSOVER,
                                  arms=("traditional", "early_agg"))
    assert (lo, hi) == ("traditional", "early_agg")
    # degenerate: one policy dominating puts the crossover at the clamp
    assert cost_model.crossover_dup_rate(
        "traditional", "early_agg", constants=FAVOR_TRAD) == 1.0
    assert cost_model.crossover_dup_rate(
        "traditional", "early_agg", constants=FAVOR_EARLY) == 0.0


def test_sorted_input_credit_zeroes_sort_term():
    base = cost_model.policy_cost_per_row("traditional", 0.0,
                                          constants=CROSSOVER)
    credited = cost_model.policy_cost_per_row("traditional", 0.0,
                                              constants=CROSSOVER,
                                              input_sorted=True)
    assert base - credited == pytest.approx(CROSSOVER["sort_row_ns"])
    # the merging policies never re-sort a batch from scratch: no credit
    for p in ("early_agg", "rs"):
        assert cost_model.policy_cost_per_row(
            p, 0.0, constants=CROSSOVER
        ) == cost_model.policy_cost_per_row(
            p, 0.0, constants=CROSSOVER, input_sorted=True)


def test_plan_surfaces_cost_model_and_sorted_credit():
    import repro

    keys = RNG.integers(0, 64, 2048)
    res = repro.aggregate({"k": keys}, by=repro.KeySpec.of(k=10))
    cm = res.plan["cost_model"]
    assert set(cm) >= {"crossover_dup_rate", "policy_cost_ns_per_row",
                       "chosen_policy", "estimated_dup_rate",
                       "calibrated_backend", "input_sorted"}
    assert res.plan["input_sorted"] is False
    res2 = repro.aggregate({"k": np.sort(keys)}, by=repro.KeySpec.of(k=10),
                           input_sorted=True)
    cm2 = res2.plan["cost_model"]
    assert cm2["input_sorted"] is True
    constants = cost_model.load_cost_constants()
    assert (cm["policy_cost_ns_per_row"]["traditional"]
            - cm2["policy_cost_ns_per_row"]["traditional"]
            ) == pytest.approx(constants["sort_row_ns"])


# ---------------------------------------------------------------------------
# governor decision paths (unit, injected constants, no device)
# ---------------------------------------------------------------------------


def _gov(constants, **kw):
    return PolicyGovernor(CFG, config=GovernorConfig(constants=constants, **kw))


def _obs(rows, dups, **kw):
    return Observation(rows_absorbed=rows, dup_rows=dups, rows_spilled=0,
                       table_rows=0, run_slots_used=kw.get("slots", 1))


def test_governor_start_paths():
    g = _gov(FAVOR_TRAD)
    assert g.start_arm() == "traditional"
    assert g.events[-1]["path"] == "start"
    assert _gov(FAVOR_EARLY).start_arm() == "early_agg"
    forced = _gov(FAVOR_TRAD, start="rs")
    assert forced.start_arm() == "rs"
    # the output-estimate prior feeds the same chooser
    assert _gov(CROSSOVER).start_arm(output_estimate=10_000) in ARMS


def test_governor_hold_and_switch_paths():
    g = _gov(FAVOR_TRAD, min_window_rows=64)
    assert g.decide(_obs(1024, 0), current="traditional") == "traditional"
    assert g.events[-1]["path"] == "hold"
    # rs is badly wrong under these constants: switch fires
    g2 = _gov(FAVOR_TRAD, min_window_rows=64)
    assert g2.decide(_obs(1024, 0), current="rs") == "traditional"
    ev = g2.events[-1]
    assert ev["path"] == "switch" and ev["from"] == "rs"
    assert ev["advantage"] > 0.5


def test_governor_small_window_path():
    g = _gov(FAVOR_TRAD, min_window_rows=10_000)
    assert g.decide(_obs(1024, 0), current="rs") == "rs"
    assert g.events[-1]["path"] == "small_window"
    # window is measured since the LAST decision, not since stream start
    g2 = _gov(FAVOR_TRAD, min_window_rows=512)
    g2.decide(_obs(1024, 0), current="rs")
    assert g2.decide(_obs(1100, 0), current="rs") == "rs"
    assert g2.events[-1]["path"] == "small_window"


def test_governor_hysteresis_path():
    # challenger (traditional) is better, but not by the demanded margin
    close = make_constants(traditional=95.0, early=100.0, rs=900.0,
                           merge=0.0, spill=0.0, sort=0.0)
    g = _gov(close, min_window_rows=64, hysteresis=0.5)
    assert g.decide(_obs(1024, 0), current="early_agg") == "early_agg"
    ev = g.events[-1]
    assert ev["path"] == "hysteresis" and ev["challenger"] == "traditional"
    assert 0.0 < ev["advantage"] < 0.5


def test_governor_windowed_dup_rate_crosses():
    g = _gov(CROSSOVER, min_window_rows=64, hysteresis=0.05)
    # first window: unique-ish -> below crossover, stay traditional
    assert g.decide(_obs(1000, 100), current="traditional") == "traditional"
    # second window: heavy duplicates (window rate (900-100)/1000=0.8)
    nxt = g.decide(_obs(2000, 900), current="traditional")
    assert nxt == "early_agg"
    assert g.events[-1]["path"] == "switch"


def test_governor_config_validation():
    with pytest.raises(ValueError, match="interval_chunks"):
        GovernorConfig(interval_chunks=0)
    with pytest.raises(ValueError, match="arms"):
        GovernorConfig(arms=("early_agg", "hash"))
    with pytest.raises(ValueError, match="start"):
        GovernorConfig(start="traditional", arms=("early_agg", "rs"))


def test_governor_refused_at_construction_when_it_cannot_steer():
    """Satellite contract: a governor that would silently never steer is
    refused AT CONSTRUCTION, not discovered via a bench that lies — a
    fixed-policy stream ignores it, and mesh= streams have no
    cross-shard observation reduce yet."""
    gov = PolicyGovernor(CFG)
    with pytest.raises(ValueError, match="fixed policy 'rs'"):
        pipeline.StreamingAggregator(CFG, policy="rs", key_dtype=np.uint32,
                                     governor=gov)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="mesh"):
        pipeline.StreamingAggregator(CFG, policy="rs", key_dtype=np.uint32,
                                     governor=gov, mesh=mesh)
    # adaptive + mesh refuses too (pre-existing contract, now symmetric)
    with pytest.raises(ValueError, match="adaptive"):
        pipeline.StreamingAggregator(CFG, policy="adaptive",
                                     key_dtype=np.uint32, mesh=mesh)


# ---------------------------------------------------------------------------
# engine integration: parity on every decision path
# ---------------------------------------------------------------------------


def _phase_keys(order="uniq->dup", n=N):
    h = n // 2
    uniq = RNG.integers(1, 2**31, h).astype(np.uint32)
    dup = RNG.integers(1, 24, h).astype(np.uint32)
    parts = {"uniq": uniq, "dup": dup}
    names = order.split("->")
    return np.concatenate([parts[names[0]], parts[names[1]]])


def _zipf_keys(n=N, a=1.4, domain=4096):
    return ((RNG.zipf(a, n) - 1) % domain + 1).astype(np.uint32)


def _stream(keys, pay, chunk=256):
    for i in range(0, len(keys), chunk):
        yield keys[i:i + chunk], None if pay is None else pay[i:i + chunk]


def _run_adaptive(keys, pay, governor, *, chunk=256, cfg=CFG):
    gov = PolicyGovernor(cfg, config=governor) \
        if isinstance(governor, GovernorConfig) else governor
    agg = pipeline.StreamingAggregator(
        cfg, policy="adaptive", key_dtype=np.uint32,
        width=0 if pay is None else pay.shape[1], governor=gov)
    for k, p in _stream(keys, pay, chunk):
        agg.absorb(k, p)
    state, stats = agg.finalize()
    return state, stats, gov, agg


DECISION_SCENARIOS = [
    # (label, constants, governor kwargs, key order, expected event path)
    ("wrong_start_recovers", FAVOR_TRAD, dict(start="rs"),
     "uniq->dup", "switch"),
    ("hold_steady", FAVOR_TRAD, dict(start="traditional"),
     "uniq->dup", "hold"),
    ("crossover_switch", CROSSOVER, dict(start="traditional",
                                         hysteresis=0.05),
     "uniq->dup", "switch"),
    ("reverse_crossover", CROSSOVER, dict(hysteresis=0.05),
     "dup->uniq", "switch"),
    ("hysteresis_blocks_flap", make_constants(
        traditional=95.0, early=100.0, rs=900.0, merge=0.0, spill=0.0,
        sort=0.0), dict(start="early_agg", hysteresis=0.5),
     "uniq->dup", "hysteresis"),
    ("small_window_holds", FAVOR_TRAD, dict(start="rs", interval_chunks=1,
                                            min_window_rows=10**6),
     "uniq->dup", "small_window"),
]


@pytest.mark.parametrize(
    "label,constants,gkw,order,expect_path",
    DECISION_SCENARIOS, ids=[s[0] for s in DECISION_SCENARIOS])
def test_adaptive_decision_paths_exact_parity(label, constants, gkw, order,
                                              expect_path):
    keys = _phase_keys(order)
    pay = RNG.normal(size=(len(keys), 1)).astype(np.float32)
    cfgkw = dict(constants=constants, min_window_rows=64)
    cfgkw.update(gkw)
    state, stats, gov, agg = _run_adaptive(
        keys, pay, GovernorConfig(**cfgkw))
    validate_against_oracle(state, keys, pay)
    paths = {e["path"] for e in gov.events}
    assert expect_path in paths, (label, gov.events)
    d = stats.as_dict()
    assert d["readbacks_paid"] == stats.readbacks_paid > 0
    assert d["policy_switches"] == len(agg.policy_events)
    if expect_path == "switch":
        assert stats.policy_switches >= 1
        ev = agg.policy_events[0]
        assert set(ev) >= {"rows_seen", "from", "to", "duplicate_rate"}
    else:
        assert stats.policy_switches == 0


def test_adaptive_zipf_parity_and_default_governor():
    keys = _zipf_keys()
    pay = RNG.normal(size=(N, 2)).astype(np.float32)
    # calibrated (checked-in) constants drive the real default governor
    state, stats, gov, _agg = _run_adaptive(keys, pay, None)
    validate_against_oracle(state, keys, pay)
    assert gov is None  # StreamingAggregator built its own
    assert stats.readbacks_paid > 0
    assert 0.0 <= stats.duplicate_rate <= 1.0


def test_adaptive_switch_mid_stream_changes_arm():
    keys = _phase_keys("uniq->dup")
    gov = PolicyGovernor(CFG, config=GovernorConfig(
        constants=CROSSOVER, hysteresis=0.05, min_window_rows=64,
        start="traditional"))
    agg = pipeline.StreamingAggregator(CFG, policy="adaptive",
                                       key_dtype=np.uint32, width=0,
                                       governor=gov)
    arms_seen = []
    for k, p in _stream(keys, None):
        agg.absorb(k, p)
        arms_seen.append(agg.arm)
    state, stats = agg.finalize()
    validate_against_oracle(state, keys)
    assert arms_seen[0] == "traditional"
    assert "early_agg" in arms_seen, "the dup phase must flip the arm"
    assert stats.duplicate_rate > 0.2


# ---------------------------------------------------------------------------
# the O(stream/k) readback contract
# ---------------------------------------------------------------------------


def test_readback_count_is_stream_over_k():
    keys = _zipf_keys(n=16 * 256)
    for k_interval in (2, 4, 8):
        _st, stats, _g, agg = _run_adaptive(
            keys, None, GovernorConfig(constants=FAVOR_TRAD,
                                       interval_chunks=k_interval))
        # the readback is pipelined one boundary behind its dispatch, so
        # a no-switch stream of C chunks harvests exactly C//k - 1 times
        chunks = 16
        assert agg.readbacks_paid == chunks // k_interval - 1
        assert stats.readbacks_paid == chunks // k_interval - 1
        assert stats.policy_switches == 0
    # fixed policies stay at ZERO governor readbacks
    agg = pipeline.StreamingAggregator(CFG, policy="rs",
                                       key_dtype=np.uint32, width=0)
    for k, p in _stream(keys, None):
        agg.absorb(k, p)
    _st, stats = agg.finalize()
    assert stats.readbacks_paid == 0 and stats.policy_switches == 0


def test_adaptive_observation_is_explicit_under_transfer_guard():
    """The governor's observation readback is an EXPLICIT device_get —
    the ingest path stays legal under ``transfer_guard("disallow")``
    (which bans implicit transfers only)."""
    keys = _phase_keys("uniq->dup")
    gov = GovernorConfig(constants=FAVOR_TRAD, start="rs")
    with jax.transfer_guard("disallow"):
        state, stats, g, _agg = _run_adaptive(keys, None, gov)
    validate_against_oracle(state, keys)
    assert stats.readbacks_paid > 0
    assert stats.policy_switches >= 1  # the switch flush is also guarded


# ---------------------------------------------------------------------------
# snapshot/finalize out_capacity retry at the next pow2
# ---------------------------------------------------------------------------


def _overflow_agg(n_unique, output_rows=16):
    keys = (np.arange(n_unique, dtype=np.uint32) + 1)
    keys = np.repeat(keys, 4)
    RNG.shuffle(keys)
    agg = pipeline.StreamingAggregator(CFG, policy="rs",
                                       key_dtype=np.uint32, width=0,
                                       output_rows=output_rows)
    for k, p in _stream(keys, None, chunk=256):
        agg.absorb(k, p)
    return agg, keys


def test_finalize_retries_once_at_next_pow2(caplog):
    agg, keys = _overflow_agg(24)  # 24 uniques > 16, <= 32: retry lands
    with caplog.at_level(logging.WARNING, logger="repro.core.pipeline"):
        state, stats = agg.finalize()
    validate_against_oracle(state, keys)
    assert any("retrying once" in r.message for r in caplog.records)


def test_snapshot_retries_once_and_engine_survives(caplog):
    agg, keys = _overflow_agg(24)
    with caplog.at_level(logging.WARNING, logger="repro.core.pipeline"):
        state, stats = agg.snapshot()
    validate_against_oracle(state, keys)
    assert any("retrying once" in r.message for r in caplog.records)
    # the live engine is untouched by the snapshot retry: keep ingesting,
    # then finalize (which must also retry) and still match the oracle
    more = RNG.integers(1, 25, 256).astype(np.uint32)
    agg.absorb(more, None)
    state2, _stats2 = agg.finalize()
    validate_against_oracle(state2, np.concatenate([keys, more]))


def test_retry_that_still_overflows_raises():
    agg, _keys = _overflow_agg(512)  # 512 uniques >> 32: retry can't save it
    with pytest.raises(MergeOverflowError, match="finalize"):
        agg.finalize()


# ---------------------------------------------------------------------------
# schema front door
# ---------------------------------------------------------------------------


def _batches(keys, chunk=256):
    for i in range(0, len(keys), chunk):
        yield {"k": keys[i:i + chunk]}


def test_streamed_default_is_adaptive():
    import repro

    keys = _zipf_keys(n=2048) % 1000
    res = repro.aggregate(_batches(keys), by=repro.KeySpec.of(k=10), cfg=CFG)
    assert res.plan["algorithm"] == "adaptive"
    assert res.plan["policy"] == "adaptive"
    assert res.plan["streamed"] is True
    assert "policy_switches" in res.plan and "readbacks_paid" in res.plan
    validate_against_oracle(res.state, keys)
    # a geometry adaptive can't run (M not divisible by B) falls back
    odd = ExecConfig(memory_rows=192, page_rows=32, fanin=4, batch_rows=128)
    res2 = repro.aggregate(_batches(keys), by=repro.KeySpec.of(k=10), cfg=odd)
    assert res2.plan["algorithm"] == "insort"
    validate_against_oracle(res2.state, keys)


def test_adaptive_algorithm_validation():
    import repro

    keys = np.arange(64, dtype=np.uint32)
    with pytest.raises(ValueError, match="streamed"):
        repro.aggregate({"k": keys}, by=repro.KeySpec.of(k=10),
                        algorithm="adaptive")
    odd = ExecConfig(memory_rows=192, page_rows=32, fanin=4, batch_rows=128)
    with pytest.raises(ValueError, match="divisible"):
        repro.aggregate(_batches(keys, 32), by=repro.KeySpec.of(k=10),
                        algorithm="adaptive", cfg=odd)


# ---------------------------------------------------------------------------
# service surfaces policy telemetry
# ---------------------------------------------------------------------------


def test_service_reports_policy_switch_events():
    from repro.service import AggregationService

    svc = AggregationService(
        CFG, policy="adaptive", key_dtype=np.uint32,
        governor=GovernorConfig(constants=FAVOR_TRAD, start="rs"))
    keys = _phase_keys("uniq->dup")
    for k, _p in _stream(keys, None):
        svc.ingest(k)
    state, stats = svc.snapshot()
    m = svc.metrics.summary()
    assert m["policy_switches"] >= 1
    assert m["readbacks_paid"] >= 1
    assert m["current_policy"] == "traditional"
    assert svc.current_policy == "traditional"
    validate_against_oracle(state, keys)
    state2, _ = svc.close()
    validate_against_oracle(state2, keys)
