"""Multi-device tests (8 fake CPU devices via subprocess — the main test
process must keep seeing 1 device, per the dry-run contract)."""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_py(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_distributed_groupby_matches_oracle():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.groupby import make_distributed_groupby
        from repro.core.types import EMPTY
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        n, o = 8 * 4096, 700
        keys = rng.integers(0, o, n).astype(np.uint32)
        pay = rng.normal(size=(n, 2)).astype(np.float32)
        gb = make_distributed_groupby(mesh, "data", capacity=4096)
        with mesh:
            st = gb(jnp.asarray(keys), jnp.asarray(pay))
        got_k = np.asarray(st.keys); valid = got_k != EMPTY
        got_k = got_k[valid]
        # global result: all unique keys exactly once, counts exact
        uk, cnt = np.unique(keys, return_counts=True)
        assert np.array_equal(np.sort(got_k), uk), (len(got_k), len(uk))
        got_c = np.asarray(st.count)[valid]
        order = np.argsort(got_k)
        assert np.array_equal(got_c[order], cnt)
        # each device's shard is sorted (distributed interesting ordering)
        print("distributed groupby OK", len(uk))
    """)


def test_distributed_groupby_overflow_fails_loudly():
    """Regression: the gather/fill path used to drop rows silently when a
    shard's received fragments exceeded ``capacity`` (or a send segment
    its per-peer quota).  It must fail loudly like the PR 3 wide merge —
    or hand back the device flag for jit-embedded callers."""
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.groupby import make_distributed_groupby
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        n = 8 * 4096
        keys = rng.integers(0, 700, n).astype(np.uint32)
        pay = rng.normal(size=(n, 2)).astype(np.float32)
        # capacity 256 < unique keys per range: fragments must overflow
        gb = make_distributed_groupby(mesh, "data", capacity=256)
        try:
            with mesh:
                gb(jnp.asarray(keys), jnp.asarray(pay))
            raise SystemExit("overflow did not raise")
        except RuntimeError as e:
            assert "dropped rows" in str(e), e
        # flag mode: same condition surfaces as a device scalar instead
        gb = make_distributed_groupby(mesh, "data", capacity=256,
                                      on_overflow="flag")
        with mesh:
            st, dropped = gb(jnp.asarray(keys), jnp.asarray(pay))
        assert bool(dropped)
        # generous capacity: no flag, exact oracle (unchanged behavior)
        gb = make_distributed_groupby(mesh, "data", capacity=4096,
                                      on_overflow="flag")
        with mesh:
            st, dropped = gb(jnp.asarray(keys), jnp.asarray(pay))
        assert not bool(dropped)
        # all-unique keys: the LOCAL aggregation trim (before any
        # exchange) is the loss site — must flag too
        uniq = np.arange(n, dtype=np.uint32)
        gb = make_distributed_groupby(mesh, "data", capacity=1024,
                                      on_overflow="flag")
        with mesh:
            st, dropped = gb(jnp.asarray(uniq), jnp.asarray(pay))
        assert bool(dropped)
        print("groupby loud overflow OK")
    """)


def test_ep_moe_grad_and_parity():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses as dc
        from repro.configs import get_config
        from repro.models import model as M, moe as MOE
        from repro.distributed import moe_parallel as MP
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        MP.set_current_mesh(mesh)
        # moe_chunk=64 exercises the chunked dispatch; the EP path computes
        # expert capacity PER CHUNK, so exact parity with the whole-batch
        # reference holds only when no chunk overflows its capacity ("equal
        # up to capacity drops").  cf=4 gives every 64-token chunk enough
        # headroom that nothing drops under this routing draw.
        cfg = dc.replace(get_config("qwen3-moe-30b-a3b", smoke=True),
                         mesh_axes=("data", "model"), moe_chunk=64)
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=4.0))
        p, _ = M.init(cfg, jax.random.PRNGKey(0))
        moe_p = jax.tree.map(lambda a: a[0], p["layers"])["moe"]
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, cfg.d_model),
                              jnp.float32)
        with mesh:
            y_ref, _ = MOE.moe_block(moe_p, cfg, x, dispatch="sorted")
            y_ep, _ = jax.jit(lambda pp, xx: MOE.moe_block(pp, cfg, xx,
                              dispatch="sorted_ep"))(moe_p, x)
            g = jax.jit(jax.grad(lambda pp, xx: MOE.moe_block(
                pp, cfg, xx, dispatch="sorted_ep")[0].sum()))(moe_p, x)
        assert float(jnp.abs(y_ref - y_ep).max()) < 1e-5
        assert all(bool(jnp.isfinite(a).all()) for a in jax.tree.leaves(g))
        print("EP MoE parity + grads OK")
    """)


def test_ring_collective_matmul():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.overlap import (ring_allgather_matmul,
                                               reference_allgather_matmul)
        mesh = jax.make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)
        x = rng.normal(size=(64, 128)).astype(np.float32)
        w = rng.normal(size=(128, 96)).astype(np.float32)
        with mesh:
            ring = jax.jit(ring_allgather_matmul(mesh))
            ref = jax.jit(reference_allgather_matmul(mesh))
            yr = ring(jnp.asarray(x), jnp.asarray(w))
            yref = ref(jnp.asarray(x), jnp.asarray(w))
        np.testing.assert_allclose(np.asarray(yr), x @ w, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(yref), x @ w, rtol=1e-4, atol=1e-4)
        # the ring version contains collective-permute, not all-gather
        txt = jax.jit(ring_allgather_matmul(mesh)).lower(
            jax.ShapeDtypeStruct((64, 128), jnp.float32),
            jax.ShapeDtypeStruct((128, 96), jnp.float32)).compile().as_text()
        assert "collective-permute" in txt and "all-gather" not in txt
        print("ring collective matmul OK")
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses as dc
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.launch import steps as ST
        from repro.distributed import sharding as SH
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg0 = get_config("llama3-8b", smoke=True)
        cfg = dc.replace(cfg0, mesh_axes=("data", "model"))
        step0, init0, opt = ST.make_train_step(cfg0, lr=1e-3)
        step1, init1, _ = ST.make_train_step(cfg, lr=1e-3)
        state0 = init0(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)),
                                       dtype=jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)),
                                       dtype=jnp.int32)}
        # single device
        s0, m0 = jax.jit(step0)(state0, batch)
        # sharded
        psh = ST.state_shardings(cfg, mesh, opt)
        bsh = {k: NamedSharding(mesh, SH.batch_spec(mesh, v.ndim))
               for k, v in batch.items()}
        with mesh:
            state1 = jax.device_put(init1(jax.random.PRNGKey(0)), psh)
            sb = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}
            s1, m1 = jax.jit(step1, in_shardings=(psh, bsh),
                             out_shardings=(psh, None))(state1, sb)
        np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                                   rtol=2e-3)
        # parameters after one step agree
        l0 = jax.tree.leaves(s0.params)[0]
        l1 = jax.tree.leaves(s1.params)[0]
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   rtol=3e-2, atol=3e-3)
        print("sharded step parity OK", float(m0["loss"]), float(m1["loss"]))
    """)


def test_sparse_grad_compression_allreduce():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.optim import compression as C
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        grads = rng.normal(size=(8, 1024)).astype(np.float32)

        def local(g):
            st = C.init_topk(g[0])
            out, _ = C.allreduce_topk(g[0], st, k=256, axis_name="data")
            return out[None]

        from repro.distributed._compat import shard_map
        fn = shard_map(local, mesh=mesh, in_specs=(P("data", None),),
                       out_specs=P("data", None))
        with mesh:
            out = fn(jnp.asarray(grads))
        got = np.asarray(out)[0]
        # oracle: each index receives exactly the contributions of shards
        # where it made that shard's top-k (error feedback keeps the rest)
        want = np.zeros(1024, np.float32)
        for srow in grads:
            top = np.argsort(-np.abs(srow))[:256]
            want[top] += srow[top]
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        print("topk sparse allreduce OK")
    """)


def test_elastic_checkpoint_restore():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        mesh8 = jax.make_mesh((8,), ("data",))
        mesh4 = jax.make_mesh((4, 2), ("data", "model"))
        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones((8,), jnp.float32)}
        sh8 = {"w": NamedSharding(mesh8, P("data", None)),
               "b": NamedSharding(mesh8, P(None))}
        tree8 = jax.device_put(tree, sh8)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2)
            mgr.save(tree8, 10, extras={"loader": {"seed": 1, "step": 10}})
            # elastic: restore onto a DIFFERENT mesh/sharding
            sh4 = {"w": NamedSharding(mesh4, P("data", "model")),
                   "b": NamedSharding(mesh4, P("model"))}
            like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
            restored, manifest = mgr.restore(like, shardings=sh4)
            assert manifest["step"] == 10
            assert manifest["extras"]["loader"]["step"] == 10
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.asarray(tree["w"]))
            assert restored["w"].sharding == sh4["w"]
        print("elastic checkpoint OK")
    """)


def test_pipeline_parallel_matches_sequential():
    run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import make_pipeline, bubble_fraction
        mesh = jax.make_mesh((4,), ("pod",))
        L, B, D = 8, 16, 32
        rng = np.random.default_rng(0)
        params = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32)
                             / np.sqrt(D))
        x = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32))

        def apply_layer(w, h):
            return jnp.tanh(h @ w)

        pipe = make_pipeline(mesh, apply_layer, L, microbatches=4)
        with mesh:
            y = jax.jit(pipe)(params, x)
            g = jax.jit(jax.grad(lambda p, xx: pipe(p, xx).sum()))(params, x)
        ref = np.asarray(x)
        for l in range(L):
            ref = np.tanh(ref @ np.asarray(params[l]))
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-5)
        assert bool(jnp.isfinite(g).all()) and float(jnp.abs(g).sum()) > 0
        assert abs(bubble_fraction(4, 4) - 3/7) < 1e-9
        print("pipeline parallel OK")
    """)
