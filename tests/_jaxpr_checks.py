"""Shared jaxpr-primitive assertions for the "no sort / no scatter"
invariants.

The engine's central claim is *structural*: operators over already-sorted
inputs (merge-absorb, segmented combine, intersect probe, merge join)
must compile to programs containing NO sort and — on the XLA path — NO
scatter primitive, because the established order lets rank-gather +
compaction-gather do all the work.  These helpers walk a jaxpr
recursively (through pjit/scan/cond/pallas_call sub-jaxprs) so the
assertion also covers kernel bodies, and are shared by
test_ordered_index.py, test_schema.py, and test_join.py.
"""
from __future__ import annotations

import jax


def collect_primitives(jaxpr, acc: set | None = None) -> set:
    """Every primitive name reachable from ``jaxpr``, including nested
    sub-jaxprs inside call/control-flow/pallas params."""
    acc = set() if acc is None else acc
    for eqn in jaxpr.eqns:
        acc.add(eqn.primitive.name)
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for vv in vs:
                if hasattr(vv, "eqns"):
                    collect_primitives(vv, acc)
                elif hasattr(vv, "jaxpr"):
                    collect_primitives(vv.jaxpr, acc)
    return acc


def primitives_of(fn, *args, **kwargs) -> set:
    """Trace ``fn(*args, **kwargs)`` and return its full primitive set."""
    return collect_primitives(jax.make_jaxpr(fn)(*args, **kwargs).jaxpr)


def assert_no_sort(prims: set, *, context: str = ""):
    assert "sort" not in prims, (
        f"found sort primitive {context}: {sorted(prims)}"
    )


def assert_no_scatter(prims: set, *, context: str = ""):
    scatters = {p for p in prims if "scatter" in p}
    assert not scatters, (
        f"found scatter primitives {context}: {sorted(scatters)}"
    )


def assert_no_sort_no_scatter(fn, *args, context: str = "", **kwargs) -> set:
    """The combined invariant: trace ``fn`` and require a sort-free,
    scatter-free program.  Returns the primitive set for further checks."""
    prims = primitives_of(fn, *args, **kwargs)
    assert_no_sort(prims, context=context)
    assert_no_scatter(prims, context=context)
    return prims
