"""Mesh-sharded pipeline tests (8 fake CPU devices via subprocess — the
main test process must keep seeing 1 device, per the dry-run contract).

Parity contract: at every world size (now through 32), for every
run-generation policy and both key dtypes, the sharded program's relation
(keys, counts, sums) is EXACTLY the single-device pipeline's, and its
reduced SpillStats equal the shard-wise reduction of per-shard
single-device references (``SpillStats.reduce_shards``) — the exchange
itself adds only its own accounting (``rows_exchanged`` plus the
capacity-bounded quota fields ``exchange_quota`` / ``exchange_max_fill``
/ ``exchange_retries``).  Plus: Zipf-skewed key draws at world 32, edge
inputs (empty / one hot key / skewed key band), exchange edge geometry
(quota=1 with an empty shard; every row aimed at one peer), the
retry-once ladder firing exactly once under a deliberately small quota,
and a transfer-guard proof that the whole mesh program still performs
exactly one stats readback.
"""
import os
import subprocess
import sys
import textwrap

import pytest


def run_py(code: str, devices: int = 8):
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=env, capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


_PARITY = """
    import jax, numpy as np
    from repro.core import pipeline
    from repro.core.types import ExecConfig, SpillStats, empty_key
    from repro.core.operators import validate_against_oracle

    WORLD = {world}
    CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
    N = 4096  # divisible by every world size
    rng = np.random.default_rng(7)
    mesh = jax.make_mesh((WORLD,), ("data",))

    def strip(st):
        k = np.asarray(st.keys)
        v = k != empty_key(k.dtype)
        return k[v], np.asarray(st.count)[v], np.asarray(st.sum)[v]

    for kd in (np.uint32, np.uint64):
        for policy in ("traditional", "inrun_dedup", "early_agg", "rs"):
            keys = rng.integers(0, 1200, N).astype(kd)
            if kd == np.uint64:
                keys = keys << np.uint64(30)  # spread past 32 bits
            pay = rng.normal(size=(N, 1)).astype(np.float32)
            st, stats = pipeline.insort_aggregate_device(
                keys, pay, CFG, policy=policy, mesh=mesh)
            validate_against_oracle(st, keys, pay)
            gk, gc, gs = strip(st)
            assert np.all(gk[:-1] < gk[1:])  # globally sorted, unique
            # exact relation parity with the single-device program
            st1, _ = pipeline.insort_aggregate_device(
                keys, pay, CFG, policy=policy)
            rk, rc, rs_ = strip(st1)
            np.testing.assert_array_equal(gk, rk)
            np.testing.assert_array_equal(gc, rc)
            np.testing.assert_allclose(gs, rs_, rtol=2e-4, atol=2e-3)
            # exact stats parity: the sharded accounting is the reduction
            # of per-shard single-device references; the exchange adds
            # only rows_exchanged
            n_loc = N // WORLD
            refs = [pipeline.insort_aggregate_device(
                        keys[i*n_loc:(i+1)*n_loc], pay[i*n_loc:(i+1)*n_loc],
                        CFG, policy=policy)[1] for i in range(WORLD)]
            want = SpillStats.reduce_shards(refs).as_dict()
            got = stats.as_dict()
            assert got.pop("rows_exchanged") > 0
            want.pop("rows_exchanged")
            # exchange accounting exists only on the sharded side: the
            # quota is capacity-bounded and the sampled cuts never
            # overfilled it (no retry)
            assert got.pop("exchange_retries") == 0 == want.pop("exchange_retries")
            quota, fill = got.pop("exchange_quota"), got.pop("exchange_max_fill")
            assert 0 < fill <= quota
            want.pop("exchange_quota"); want.pop("exchange_max_fill")
            assert got == want, (policy, np.dtype(kd).name, got, want)
            print("OK", np.dtype(kd).name, policy)
    print("sharded parity OK at world", WORLD)
"""


@pytest.mark.parametrize("world,devices", ((1, 8), (2, 8), (8, 8), (32, 32)))
def test_sharded_pipeline_matches_single_device(world, devices):
    run_py(_PARITY.format(world=world), devices=devices)


_ZIPF = """
    import jax, numpy as np
    from repro.core import pipeline
    from repro.core.types import ExecConfig, SpillStats, empty_key
    from repro.core.operators import validate_against_oracle

    WORLD = 32
    CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
    N = 8192
    kd = np.{dtype}
    mesh = jax.make_mesh((WORLD,), ("data",))
    rng = np.random.default_rng(23)

    def zipf(n, domain, s):
        ranks = np.arange(1, domain + 1, dtype=np.float64)
        p = ranks ** -float(s)
        return rng.choice(domain, size=n, p=p / p.sum())

    def strip(st):
        k = np.asarray(st.keys)
        v = k != empty_key(k.dtype)
        return k[v], np.asarray(st.count)[v], np.asarray(st.sum)[v]

    for s in (0.0, 1.2):
        for policy in ("traditional", "inrun_dedup", "early_agg", "rs"):
            keys = zipf(N, 2048, s).astype(kd)
            if kd == np.uint64:
                keys = keys << np.uint64(30)
            pay = rng.normal(size=(N, 1)).astype(np.float32)
            st, stats = pipeline.insort_aggregate_device(
                keys, pay, CFG, policy=policy, mesh=mesh)
            validate_against_oracle(st, keys, pay)
            gk, gc, gs = strip(st)
            # the single-device reference needs a wider merge index at
            # s=1.2: merging duplicate-laden traditional runs keeps every
            # copy of the frontier key resident, and the hottest key has
            # ~0.2*N rows.  (The sharded program doesn't: per-shard
            # hot-key copies are ~N/world * 0.2, and the exchange merges
            # per-shard DEDUPED fragments.)
            st1, _ = pipeline.insort_aggregate_device(
                keys, pay, CFG, policy=policy, index_rows=2048)
            rk, rc, rs_ = strip(st1)
            np.testing.assert_array_equal(gk, rk)
            np.testing.assert_array_equal(gc, rc)
            np.testing.assert_allclose(gs, rs_, rtol=2e-4, atol=2e-3)
            # shuffle-volume oracle: every shard fully dedups its slice
            # locally, then puts each surviving row on the wire exactly
            # once — so rows_exchanged is the sum of per-slice distinct
            # key counts
            n_loc = N // WORLD
            want_sent = sum(
                len(np.unique(keys[i * n_loc:(i + 1) * n_loc]))
                for i in range(WORLD))
            assert stats.rows_exchanged == want_sent
            # even at s=1.2 the sampled+strictified cuts keep every send
            # segment inside the capacity-derived quota: no retry fired
            assert stats.exchange_retries == 0
            assert 0 < stats.exchange_max_fill <= stats.exchange_quota
            print("OK", np.dtype(kd).name, policy, "s=", s)
    print("zipf parity OK")
"""


@pytest.mark.parametrize("dtype", ("uint32", "uint64"))
def test_sharded_zipf_skew_world32(dtype):
    run_py(_ZIPF.format(dtype=dtype), devices=32)


def test_exchange_footprint_capacity_bounded():
    """The §4 discipline for the exchange: per-shard footprint is
    O(world·quota + world·page) with quota ≈ 2·capacity/world, so at a
    FIXED per-shard capacity the footprint is ~flat in world — growing
    the world 8 → 32 must cost ≤ 1.3×.  (Under the old quota=capacity
    scheme the same ratio was exactly 4×.)  Pure accounting: the numbers
    come from the same helpers the mesh pipeline derives its buffer
    shapes from, so this is the shipped geometry, not a model of it."""
    from repro.distributed import groupby as gb

    n_loc = 2048  # rows per shard, fixed as the world grows
    cap = n_loc  # worst case: every local row a distinct key
    foot = {}
    for world in (8, 32):
        quota = gb.default_exchange_quota(cap, world)
        page = gb.exchange_page_rows(quota, 32)
        assert quota * world >= cap  # lossless when cuts are balanced
        assert quota % page == 0
        foot[world] = gb.exchange_footprint_rows(world, quota, 32)
    ratio = foot[32] / foot[8]
    assert ratio <= 1.3, (foot, ratio)
    # and the old scheme really was the world-proportional one
    old = {w: 2 * w * cap + (w + 2) * 32 for w in (8, 32)}
    assert old[32] / old[8] > 3.5


def test_exchange_edge_geometry():
    """quota=1 at world=2 with an empty shard, and an all-rows-to-one-
    peer split: the exchange must pad honestly, flag overfill instead of
    corrupting, and keep parity."""
    run_py("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed import groupby as gb
        from repro.distributed._compat import shard_map
        from repro.core.types import EMPTY, empty_state

        mesh = jax.make_mesh((2,), ("data",))

        def mk(keys_np, capacity):
            # a sorted, duplicate-free, EMPTY-padded local state
            keys = np.full(capacity, EMPTY, np.uint32)
            keys[:len(keys_np)] = np.sort(np.asarray(keys_np, np.uint32))
            cnt = (keys != EMPTY).astype(np.int32)
            return dataclasses.replace(empty_state(capacity, 1),
                                       keys=jnp.asarray(keys),
                                       count=jnp.asarray(cnt))

        def run_exchange(local_a, local_b, quota, inner=None):
            cap = local_a.capacity

            def f(st):
                recv, sent, dropped, fill = gb.exchange_sorted_fragments(
                    st, "data", 2, quota=quota, inner_cuts=inner)
                return (recv,
                        jax.lax.psum(sent, "data"),
                        jax.lax.pmax(dropped, "data"),
                        jax.lax.pmax(fill, "data"))

            spec = P("data")
            stacked = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), local_a, local_b)
            fn = shard_map(f, mesh=mesh,
                           in_specs=(spec,),
                           out_specs=(spec, P(), P(), P()))
            return fn(stacked)

        # --- quota=1, world=2, shard B empty: one row per peer range ---
        a = mk([3, 900000], 4)     # one key below the cut, one above
        b = mk([], 4)
        inner = jnp.asarray([1000], jnp.uint32)  # cut: [0,1000) | [1000,top]
        recv, sent, dropped, fill = run_exchange(a, b, 1, inner)
        assert not bool(dropped) and int(sent) == 2 and int(fill) == 1
        rk = np.asarray(recv.keys).reshape(2, 2)  # (shard, world*quota=2)
        # owner 0 got key 3 from shard A and EMPTY padding from B;
        # owner 1 got 900000 from A and EMPTY from B
        assert rk[0, 0] == 3 and rk[0, 1] == EMPTY
        assert rk[1, 0] == 900000 and rk[1, 1] == EMPTY

        # --- every row aimed at one peer: fill == occupancy, and a
        # quota below it trips send_dropped (the retryable signal) ---
        a = mk([10, 11, 12], 4)
        b = mk([13, 14, 15], 4)
        inner = jnp.asarray([1 << 20], jnp.uint32)  # everything -> owner 0
        recv, sent, dropped, fill = run_exchange(a, b, 2, inner)
        assert bool(dropped) and int(fill) == 3
        recv, sent, dropped, fill = run_exchange(a, b, 4, inner)
        assert not bool(dropped) and int(sent) == 6 and int(fill) == 3
        rk = np.asarray(recv.keys).reshape(2, 2, 4)  # (shard, peer, quota)
        np.testing.assert_array_equal(rk[0, 0, :3], [10, 11, 12])
        np.testing.assert_array_equal(rk[0, 1, :3], [13, 14, 15])
        assert np.all(rk[1] == EMPTY)  # owner 1's range is empty
        print("edge geometry OK")
    """)


def test_exchange_retry_fires_exactly_once():
    """A deliberately undersized explicit quota makes the first dispatch
    overflow; the host entry point must retry ONCE at the next pow2 and
    land exact parity with exchange_retries == 1."""
    run_py("""
        import jax, numpy as np
        from repro.core import pipeline
        from repro.core.types import ExecConfig, empty_key
        from repro.core.operators import validate_against_oracle

        CFG = ExecConfig(memory_rows=512, page_rows=32, fanin=4,
                         batch_rows=64)
        mesh = jax.make_mesh((2,), ("data",))
        # shard 0 holds keys 0..255, shard 1 holds 256..511: the sampled
        # cut sends each shard's 256 distinct keys to one owner apiece,
        # so quota=128 overflows (fill=256) and the pow2 retry at 256
        # succeeds
        keys = np.arange(512, dtype=np.uint32)
        pay = np.ones((512, 1), np.float32)
        st, stats = pipeline.insort_aggregate_device(
            keys, pay, CFG, policy="rs", mesh=mesh, exchange_quota=128)
        assert stats.exchange_retries == 1, stats
        assert stats.exchange_quota == 256
        assert stats.exchange_max_fill == 256
        validate_against_oracle(st, keys, pay)
        k = np.asarray(st.keys)
        assert (k != empty_key(k.dtype)).sum() == 512
        print("retry-once OK")
    """)


def test_strictify_cuts_dedupes_and_clamps():
    """Duplicate sampled cut values (heavy skew) must come out strictly
    increasing wherever the key domain allows, saturating at the top of
    the domain — on both key widths."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.types import key_dtype_context, max_key
    from repro.distributed.groupby import strictify_cuts

    for kd in (np.uint32, np.uint64):
        ctx = key_dtype_context(kd)
        top = max_key(kd)
        with ctx:  # uint64 keys need the scoped x64 context (as in-engine)
            cuts = jnp.asarray(np.array([7, 7, 7, 9, 9, 3], dtype=kd))
            out = np.asarray(strictify_cuts(cuts))
            np.testing.assert_array_equal(out, np.array(
                [7, 8, 9, 10, 11, 12], dtype=kd))
            # already-strict cuts pass through untouched
            cuts = jnp.asarray(np.array([5, 100, 2000], dtype=kd))
            np.testing.assert_array_equal(np.asarray(strictify_cuts(cuts)),
                                          np.array([5, 100, 2000], dtype=kd))
            # saturation at the domain top (EMPTY stays reserved)
            cuts = jnp.asarray(np.array([top, top, top], dtype=kd))
            out = np.asarray(strictify_cuts(cuts))
            np.testing.assert_array_equal(out, np.array([top] * 3, dtype=kd))
            assert out.max() == top  # never into the EMPTY sentinel


def test_hot_key_majority_regression():
    """Satellite regression: >50% of all rows carry ONE key.  Raw sample
    quantiles then repeat that key across most cut positions; without
    dedup/clamp several owners' ranges collapse and the exchange piles
    everything on one peer.  Parity + no retry proves the strictified
    cuts keep the quota bound honest under majority skew."""
    run_py("""
        import jax, numpy as np
        from repro.core import pipeline
        from repro.core.types import ExecConfig, empty_key
        from repro.core.operators import validate_against_oracle

        CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4,
                         batch_rows=64)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(17)
        N = 4096
        keys = rng.integers(0, 700, N).astype(np.uint32)
        keys[rng.permutation(N)[: int(N * 0.6)]] = 350  # >=60% one key
        hot_rows = int((keys == 350).sum())
        assert hot_rows >= N * 0.6
        pay = rng.normal(size=(N, 1)).astype(np.float32)
        for policy in ("rs", "early_agg"):
            st, stats = pipeline.insort_aggregate_device(
                keys, pay, CFG, policy=policy, mesh=mesh)
            validate_against_oracle(st, keys, pay)
            assert stats.exchange_retries == 0
            assert stats.exchange_max_fill <= stats.exchange_quota
            k = np.asarray(st.keys)
            v = k != empty_key(k.dtype)
            assert int(np.asarray(st.count)[v][k[v] == 350][0]) == hot_rows
        print("hot key OK")
    """)


def test_non_shardable_backend_refused_at_front_door():
    """The mesh path guards on Backend.shardable before building any
    program (in-process: a world-1 mesh needs no fake devices)."""
    import jax
    import numpy as np

    from repro.core import dispatch, pipeline
    from repro.core.types import ExecConfig

    be = dispatch.get_backend("xla")
    dispatch.register_backend(
        "nosharding",
        lambda: dispatch.Backend(
            name="nosharding", argsort=be.argsort,
            segmented_combine=be.segmented_combine,
            merge_sorted=be.merge_sorted, shardable=False,
        ),
    )
    try:
        mesh = jax.make_mesh((1,), ("data",))
        keys = np.arange(64, dtype=np.uint32)
        with pytest.raises(dispatch.BackendUnavailable, match="shard_map"):
            pipeline.aggregate_device(keys, None, ExecConfig(),
                                      backend="nosharding", mesh=mesh)
        # single-device plans are untouched by the capability flag
        st, _ = pipeline.insort_aggregate_device(keys, None, ExecConfig(),
                                                 backend="nosharding")
        assert int(st.occupancy()) == 64
    finally:
        dispatch._loaders.pop("nosharding", None)
        dispatch._cache.pop("nosharding", None)


def test_sharded_pipeline_edges():
    run_py("""
        import jax, numpy as np
        from repro.core import pipeline
        from repro.core.types import ExecConfig, EMPTY
        from repro.core.operators import validate_against_oracle

        CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(3)

        # empty input
        st, stats = pipeline.insort_aggregate_device(
            np.zeros((0,), np.uint32), None, CFG, mesh=mesh)
        assert int(st.occupancy()) == 0 and stats.total_spill_rows == 0

        # input not divisible by world (EMPTY padding path)
        keys = rng.integers(0, 900, 4001).astype(np.uint32)
        st, _ = pipeline.insort_aggregate_device(
            keys, None, CFG, policy="early_agg", mesh=mesh)
        validate_against_oracle(st, keys)

        # one hot key: a single group, every shard sends one row to the
        # same range owner
        hot = np.full(12000, 7, np.uint32)
        st, stats = pipeline.insort_aggregate_device(
            hot, None, CFG, policy="rs", mesh=mesh)
        k = np.asarray(st.keys)
        assert int(st.occupancy()) == 1
        assert int(np.asarray(st.count)[k == 7][0]) == 12000
        assert stats.rows_exchanged == 8  # one surviving row per shard

        # skewed key range: every key inside a narrow band high in the
        # key space — fixed uniform ranges would send everything to one
        # owner; the sampled cuts adapt
        keys = (rng.integers(0, 500, 4096) + (1 << 31)).astype(np.uint32)
        pay = rng.normal(size=(4096, 2)).astype(np.float32)
        st, stats = pipeline.insort_aggregate_device(
            keys, pay, CFG, policy="rs", mesh=mesh)
        validate_against_oracle(st, keys, pay)
        # rows landed on several owners, not one
        kk = np.asarray(st.keys).reshape(8, -1)
        owners = (kk != EMPTY).any(axis=1).sum()
        assert owners >= 4, owners

        # plane-width restriction travels through the exchange
        st, _ = pipeline.insort_aggregate_device(
            keys, pay, CFG, policy="rs", widths=(2, 0, 0), mesh=mesh)
        assert st.widths == (2, 0, 0)
        validate_against_oracle(st, keys, pay)
        print("sharded edges OK")
    """)


def test_sharded_pipeline_single_readback_under_transfer_guard():
    run_py("""
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import pipeline
        from repro.core.types import DeviceSpillStats, ExecConfig
        from repro.core.operators import validate_against_oracle

        CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(5)
        keys = rng.integers(0, 1200, 4096).astype(np.uint32)
        pay = rng.normal(size=(4096, 1)).astype(np.float32)
        dk = jax.device_put(keys, NamedSharding(mesh, P("data")))
        dp = jax.device_put(pay, NamedSharding(mesh, P("data", None)))
        # compile outside the guard; the guard then proves steady state
        state, _ = pipeline.aggregate_device(dk, dp, CFG, policy="rs",
                                             mesh=mesh)
        jax.block_until_ready(state)
        with jax.transfer_guard("disallow"):
            state, dstats = pipeline.aggregate_device(dk, dp, CFG,
                                                      policy="rs", mesh=mesh)
            jax.block_until_ready((state, dstats))
        assert isinstance(dstats, DeviceSpillStats)
        stats = dstats.finalize()  # the single readback, outside the guard
        assert stats.total_spill_rows > 0
        assert 0 < stats.rows_exchanged < len(keys)
        validate_against_oracle(state, keys, pay)
        print("sharded transfer guard OK")
    """)


def test_sharded_schema_front_door_and_pallas_smoke():
    run_py("""
        import jax, numpy as np
        import repro
        from repro.core import pipeline
        from repro.core.schema import KeySpec
        from repro.core.types import ExecConfig
        from repro.core.operators import validate_against_oracle, group_by

        CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(11)
        keys = rng.integers(0, 1200, 4096).astype(np.uint32)
        pay = rng.normal(size=(4096, 1)).astype(np.float32)
        res = repro.aggregate({"k": keys}, by=KeySpec.of(k=12), values=pay,
                              aggs=("count", "sum"), cfg=CFG, order_by=True,
                              mesh=mesh)
        assert res.plan["mesh"] == {"axis": "data", "world": 8}
        assert res.plan["pipeline"] == "device"
        validate_against_oracle(res.state, keys, pay)
        rel = res.relation()
        assert np.all(np.diff(rel["k"].astype(np.int64)) > 0)

        st, _ = group_by(keys, pay, CFG, mesh=mesh)
        validate_against_oracle(st, keys, pay)

        # mesh + non-device plans must refuse, not silently single-device
        try:
            repro.aggregate({"k": keys}, by=KeySpec.of(k=12), cfg=CFG,
                            algorithm="hash", mesh=mesh)
            raise SystemExit("hash+mesh did not raise")
        except ValueError:
            pass
        try:
            group_by(keys, pay, CFG, pipeline="host", mesh=mesh)
            raise SystemExit("host+mesh did not raise")
        except ValueError:
            pass

        # the fused sharded program also compiles with the Pallas kernel
        # backend (interpret mode off-TPU) — tiny size, one program
        cfg = ExecConfig(memory_rows=64, page_rows=16, fanin=4, batch_rows=16)
        mesh2 = jax.make_mesh((2,), ("data",))
        k2 = rng.integers(0, 120, 400).astype(np.uint32)
        p2 = rng.normal(size=(400, 1)).astype(np.float32)
        st, _ = pipeline.insort_aggregate_device(
            k2, p2, cfg, policy="early_agg", backend="pallas", mesh=mesh2)
        validate_against_oracle(st, k2, p2)
        print("sharded front door + pallas smoke OK")
    """)
