"""Mesh-sharded pipeline tests (8 fake CPU devices via subprocess — the
main test process must keep seeing 1 device, per the dry-run contract).

Parity contract: at every world size, for every run-generation policy and
both key dtypes, the sharded program's relation (keys, counts, sums) is
EXACTLY the single-device pipeline's, and its reduced SpillStats equal
the shard-wise reduction of per-shard single-device references
(``SpillStats.reduce_shards``) — the exchange itself adds only
``rows_exchanged``.  Plus: edge inputs (empty / one hot key / skewed key
band), and a transfer-guard proof that the whole mesh program still
performs exactly one stats readback.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = dict(os.environ, XLA_FLAGS="--xla_force_host_platform_device_count=8",
           PYTHONPATH="src")


def run_py(code: str):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=ENV, capture_output=True, text=True, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


_PARITY = """
    import jax, numpy as np
    from repro.core import pipeline
    from repro.core.types import ExecConfig, SpillStats, empty_key
    from repro.core.operators import validate_against_oracle

    WORLD = {world}
    CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
    N = 4096  # divisible by every world size
    rng = np.random.default_rng(7)
    mesh = jax.make_mesh((WORLD,), ("data",))

    def strip(st):
        k = np.asarray(st.keys)
        v = k != empty_key(k.dtype)
        return k[v], np.asarray(st.count)[v], np.asarray(st.sum)[v]

    for kd in (np.uint32, np.uint64):
        for policy in ("traditional", "inrun_dedup", "early_agg", "rs"):
            keys = rng.integers(0, 1200, N).astype(kd)
            if kd == np.uint64:
                keys = keys << np.uint64(30)  # spread past 32 bits
            pay = rng.normal(size=(N, 1)).astype(np.float32)
            st, stats = pipeline.insort_aggregate_device(
                keys, pay, CFG, policy=policy, mesh=mesh)
            validate_against_oracle(st, keys, pay)
            gk, gc, gs = strip(st)
            assert np.all(gk[:-1] < gk[1:])  # globally sorted, unique
            # exact relation parity with the single-device program
            st1, _ = pipeline.insort_aggregate_device(
                keys, pay, CFG, policy=policy)
            rk, rc, rs_ = strip(st1)
            np.testing.assert_array_equal(gk, rk)
            np.testing.assert_array_equal(gc, rc)
            np.testing.assert_allclose(gs, rs_, rtol=2e-4, atol=2e-3)
            # exact stats parity: the sharded accounting is the reduction
            # of per-shard single-device references; the exchange adds
            # only rows_exchanged
            n_loc = N // WORLD
            refs = [pipeline.insort_aggregate_device(
                        keys[i*n_loc:(i+1)*n_loc], pay[i*n_loc:(i+1)*n_loc],
                        CFG, policy=policy)[1] for i in range(WORLD)]
            want = SpillStats.reduce_shards(refs).as_dict()
            got = stats.as_dict()
            assert got.pop("rows_exchanged") > 0
            want.pop("rows_exchanged")
            assert got == want, (policy, np.dtype(kd).name, got, want)
            print("OK", np.dtype(kd).name, policy)
    print("sharded parity OK at world", WORLD)
"""


@pytest.mark.parametrize("world", (1, 2, 8))
def test_sharded_pipeline_matches_single_device(world):
    run_py(_PARITY.format(world=world))


def test_non_shardable_backend_refused_at_front_door():
    """The mesh path guards on Backend.shardable before building any
    program (in-process: a world-1 mesh needs no fake devices)."""
    import jax
    import numpy as np

    from repro.core import dispatch, pipeline
    from repro.core.types import ExecConfig

    be = dispatch.get_backend("xla")
    dispatch.register_backend(
        "nosharding",
        lambda: dispatch.Backend(
            name="nosharding", argsort=be.argsort,
            segmented_combine=be.segmented_combine,
            merge_sorted=be.merge_sorted, shardable=False,
        ),
    )
    try:
        mesh = jax.make_mesh((1,), ("data",))
        keys = np.arange(64, dtype=np.uint32)
        with pytest.raises(dispatch.BackendUnavailable, match="shard_map"):
            pipeline.aggregate_device(keys, None, ExecConfig(),
                                      backend="nosharding", mesh=mesh)
        # single-device plans are untouched by the capability flag
        st, _ = pipeline.insort_aggregate_device(keys, None, ExecConfig(),
                                                 backend="nosharding")
        assert int(st.occupancy()) == 64
    finally:
        dispatch._loaders.pop("nosharding", None)
        dispatch._cache.pop("nosharding", None)


def test_sharded_pipeline_edges():
    run_py("""
        import jax, numpy as np
        from repro.core import pipeline
        from repro.core.types import ExecConfig, EMPTY
        from repro.core.operators import validate_against_oracle

        CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(3)

        # empty input
        st, stats = pipeline.insort_aggregate_device(
            np.zeros((0,), np.uint32), None, CFG, mesh=mesh)
        assert int(st.occupancy()) == 0 and stats.total_spill_rows == 0

        # input not divisible by world (EMPTY padding path)
        keys = rng.integers(0, 900, 4001).astype(np.uint32)
        st, _ = pipeline.insort_aggregate_device(
            keys, None, CFG, policy="early_agg", mesh=mesh)
        validate_against_oracle(st, keys)

        # one hot key: a single group, every shard sends one row to the
        # same range owner
        hot = np.full(12000, 7, np.uint32)
        st, stats = pipeline.insort_aggregate_device(
            hot, None, CFG, policy="rs", mesh=mesh)
        k = np.asarray(st.keys)
        assert int(st.occupancy()) == 1
        assert int(np.asarray(st.count)[k == 7][0]) == 12000
        assert stats.rows_exchanged == 8  # one surviving row per shard

        # skewed key range: every key inside a narrow band high in the
        # key space — fixed uniform ranges would send everything to one
        # owner; the sampled cuts adapt
        keys = (rng.integers(0, 500, 4096) + (1 << 31)).astype(np.uint32)
        pay = rng.normal(size=(4096, 2)).astype(np.float32)
        st, stats = pipeline.insort_aggregate_device(
            keys, pay, CFG, policy="rs", mesh=mesh)
        validate_against_oracle(st, keys, pay)
        # rows landed on several owners, not one
        kk = np.asarray(st.keys).reshape(8, -1)
        owners = (kk != EMPTY).any(axis=1).sum()
        assert owners >= 4, owners

        # plane-width restriction travels through the exchange
        st, _ = pipeline.insort_aggregate_device(
            keys, pay, CFG, policy="rs", widths=(2, 0, 0), mesh=mesh)
        assert st.widths == (2, 0, 0)
        validate_against_oracle(st, keys, pay)
        print("sharded edges OK")
    """)


def test_sharded_pipeline_single_readback_under_transfer_guard():
    run_py("""
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import pipeline
        from repro.core.types import DeviceSpillStats, ExecConfig
        from repro.core.operators import validate_against_oracle

        CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(5)
        keys = rng.integers(0, 1200, 4096).astype(np.uint32)
        pay = rng.normal(size=(4096, 1)).astype(np.float32)
        dk = jax.device_put(keys, NamedSharding(mesh, P("data")))
        dp = jax.device_put(pay, NamedSharding(mesh, P("data", None)))
        # compile outside the guard; the guard then proves steady state
        state, _ = pipeline.aggregate_device(dk, dp, CFG, policy="rs",
                                             mesh=mesh)
        jax.block_until_ready(state)
        with jax.transfer_guard("disallow"):
            state, dstats = pipeline.aggregate_device(dk, dp, CFG,
                                                      policy="rs", mesh=mesh)
            jax.block_until_ready((state, dstats))
        assert isinstance(dstats, DeviceSpillStats)
        stats = dstats.finalize()  # the single readback, outside the guard
        assert stats.total_spill_rows > 0
        assert 0 < stats.rows_exchanged < len(keys)
        validate_against_oracle(state, keys, pay)
        print("sharded transfer guard OK")
    """)


def test_sharded_schema_front_door_and_pallas_smoke():
    run_py("""
        import jax, numpy as np
        import repro
        from repro.core import pipeline
        from repro.core.schema import KeySpec
        from repro.core.types import ExecConfig
        from repro.core.operators import validate_against_oracle, group_by

        CFG = ExecConfig(memory_rows=256, page_rows=32, fanin=4, batch_rows=64)
        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(11)
        keys = rng.integers(0, 1200, 4096).astype(np.uint32)
        pay = rng.normal(size=(4096, 1)).astype(np.float32)
        res = repro.aggregate({"k": keys}, by=KeySpec.of(k=12), values=pay,
                              aggs=("count", "sum"), cfg=CFG, order_by=True,
                              mesh=mesh)
        assert res.plan["mesh"] == {"axis": "data", "world": 8}
        assert res.plan["pipeline"] == "device"
        validate_against_oracle(res.state, keys, pay)
        rel = res.relation()
        assert np.all(np.diff(rel["k"].astype(np.int64)) > 0)

        st, _ = group_by(keys, pay, CFG, mesh=mesh)
        validate_against_oracle(st, keys, pay)

        # mesh + non-device plans must refuse, not silently single-device
        try:
            repro.aggregate({"k": keys}, by=KeySpec.of(k=12), cfg=CFG,
                            algorithm="hash", mesh=mesh)
            raise SystemExit("hash+mesh did not raise")
        except ValueError:
            pass
        try:
            group_by(keys, pay, CFG, pipeline="host", mesh=mesh)
            raise SystemExit("host+mesh did not raise")
        except ValueError:
            pass

        # the fused sharded program also compiles with the Pallas kernel
        # backend (interpret mode off-TPU) — tiny size, one program
        cfg = ExecConfig(memory_rows=64, page_rows=16, fanin=4, batch_rows=16)
        mesh2 = jax.make_mesh((2,), ("data",))
        k2 = rng.integers(0, 120, 400).astype(np.uint32)
        p2 = rng.normal(size=(400, 1)).astype(np.float32)
        st, _ = pipeline.insort_aggregate_device(
            k2, p2, cfg, policy="early_agg", backend="pallas", mesh=mesh2)
        validate_against_oracle(st, k2, p2)
        print("sharded front door + pallas smoke OK")
    """)
