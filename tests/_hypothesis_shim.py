"""Optional-import shim for hypothesis.

Property-based tests use hypothesis when it is installed; in a bare
environment (no ``pip install`` possible) they must *skip cleanly* rather
than fail the whole module at collection.  Import ``given/settings/st``
from here instead of from ``hypothesis``:

    from _hypothesis_shim import given, settings, st

When hypothesis is absent, ``given(...)`` replaces the test with a
zero-argument function that calls ``pytest.skip`` (zero-argument so pytest
does not mistake the strategy parameters for fixtures), and ``st`` yields
inert placeholder strategies so decoration-time expressions like
``st.integers(0, 10)`` still evaluate.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _InertStrategies:
        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _InertStrategies()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        def deco(fn):
            def _skip():
                pytest.skip("hypothesis not installed")

            _skip.__name__ = fn.__name__
            _skip.__doc__ = fn.__doc__
            return _skip

        return deco
