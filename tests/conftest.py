"""Test-session hygiene.

The full suite compiles many hundreds of XLA:CPU executables in one
process; the CPU JIT's dylib cache eventually fails with
"Failed to materialize symbols" once too many live executables
accumulate.  Dropping JAX's compilation caches between test modules keeps
the live-executable set bounded (each module re-compiles what it needs).

NOTE: deliberately no XLA_FLAGS here — tests must see 1 device; the
dry-run module and the multi-device subprocess tests set their own.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
