"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs the
pure-jnp oracles in repro/kernels/ref.py.  Kernels run in interpret mode
(CPU container); the pallas_call/BlockSpec structure is the TPU target.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.types import EMPTY, AggState
from repro.core import sorted_ops
from repro.core.types import rows_to_state
from repro.kernels import ops, ref
from repro.kernels.bitonic_sort import bitonic_sort, bitonic_sort_kv
from repro.kernels.grouped_matmul import grouped_matmul
from repro.kernels.merge_aggregate import merge_absorb_tiles
from repro.kernels.segmented_reduce import segmented_scan_tiles

RNG = np.random.default_rng(123)


# ---------------------------------------------------------------------------
# bitonic sort
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t", [1, 3])
@pytest.mark.parametrize("n", [2, 8, 128, 1024, 4096])
def test_bitonic_sort_shapes(t, n):
    k = RNG.integers(0, 2**32 - 1, size=(t, n)).astype(np.uint32)
    got = bitonic_sort(jnp.asarray(k))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.ref_sort(k)))


@pytest.mark.parametrize("domain", [2, 100, 2**31])
def test_bitonic_sort_duplicates(domain):
    k = RNG.integers(0, domain, size=(2, 512)).astype(np.uint32)
    got = bitonic_sort(jnp.asarray(k))
    np.testing.assert_array_equal(np.asarray(got), np.sort(k, axis=-1))


def test_bitonic_sort_with_empty_sentinels():
    k = RNG.integers(0, 1000, size=(1, 256)).astype(np.uint32)
    k[0, 17:93] = EMPTY
    got = np.asarray(bitonic_sort(jnp.asarray(k)))[0]
    np.testing.assert_array_equal(got, np.sort(k[0]))
    assert np.all(got[-76:] == EMPTY)  # sentinels sink to the tail


def test_bitonic_kv_payload_follows_key():
    n = 2048
    k = RNG.integers(0, 2**32 - 1, size=(1, n)).astype(np.uint32)
    v = np.arange(n, dtype=np.uint32)[None]
    sk, sv = bitonic_sort_kv(jnp.asarray(k), jnp.asarray(v))
    sk, sv = np.asarray(sk)[0], np.asarray(sv)[0]
    np.testing.assert_array_equal(k[0][sv], sk)  # payload is a permutation


@settings(max_examples=10, deadline=None)
@given(
    logn=st.integers(1, 11),
    domain=st.sampled_from([1, 7, 1000, 2**31]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitonic_sort_property(logn, domain, seed):
    rng = np.random.default_rng(seed)
    k = rng.integers(0, domain, size=(1, 2**logn)).astype(np.uint32)
    got = bitonic_sort(jnp.asarray(k))
    np.testing.assert_array_equal(np.asarray(got)[0], np.sort(k[0]))


def test_ops_argsort_u32_non_pow2():
    for n in (5, 100, 1000, 1537):
        k = RNG.integers(0, 500, size=(n,)).astype(np.uint32)
        perm = np.asarray(ops.argsort_u32(jnp.asarray(k)))
        np.testing.assert_array_equal(k[perm], np.sort(k))


def test_ops_argsort_interior_empty_non_pow2_is_permutation():
    """Regression: interior EMPTY rows tie with the pow2 padding; without
    the index tie-break lane the unstable network could emit a pad slot
    inside the first n outputs, and clamping duplicated a real row.  The
    perm must be exactly a permutation of range(n) for every shape."""
    from repro.core.types import EMPTY as E

    for n in (5, 48, 100, 731):
        k = RNG.integers(0, 40, size=(n,)).astype(np.uint32)
        k[RNG.random(n) < 0.4] = E
        perm = np.asarray(ops.argsort_u32(jnp.asarray(k)))
        assert sorted(perm.tolist()) == list(range(n)), n
        np.testing.assert_array_equal(k[perm], np.sort(k))


# ---------------------------------------------------------------------------
# segmented reduce
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [8, 128, 512, 2048])
@pytest.mark.parametrize("v", [1, 3])
def test_segmented_scan_vs_ref(n, v):
    keys = np.sort(RNG.integers(0, max(2, n // 8), size=(2, n)).astype(np.uint32), -1)
    cnt = RNG.integers(1, 5, size=(2, n)).astype(np.int32)
    val = RNG.normal(size=(2, v, n)).astype(np.float32)
    got = segmented_scan_tiles(
        jnp.asarray(keys), jnp.asarray(cnt), jnp.asarray(val),
        jnp.asarray(val), jnp.asarray(val),
    )
    want = ref.ref_segmented_scan(
        jnp.asarray(keys), jnp.asarray(cnt), jnp.asarray(val),
        jnp.asarray(val), jnp.asarray(val),
    )
    names = ["count", "sum", "min", "max", "tails"]
    for g, w, name in zip(got, want, names):
        if name in ("count", "tails"):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w), err_msg=name)
        else:
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-5, err_msg=name
            )


def test_segmented_scan_with_empty_tail():
    n = 256
    keys = np.sort(RNG.integers(0, 30, size=(1, n)).astype(np.uint32), -1)
    keys[0, 200:] = EMPTY
    cnt = np.ones((1, n), np.int32)
    val = np.ones((1, 1, n), np.float32)
    c, s, mn, mx, tails = segmented_scan_tiles(
        jnp.asarray(keys), jnp.asarray(cnt), jnp.asarray(val),
        jnp.asarray(val), jnp.asarray(val),
    )
    tails = np.asarray(tails)[0]
    assert not tails[200:].any()  # EMPTY rows are never segment tails
    # group total at each tail equals true group size
    for i in np.where(tails)[0]:
        assert int(np.asarray(c)[0, i]) == int((keys[0] == keys[0, i]).sum())


def test_ops_segmented_combine_matches_xla_backend():
    """The pallas path must agree with core.sorted_ops (the XLA oracle)."""
    for n, width in [(100, 0), (500, 2), (1024, 1)]:
        keys = np.sort(RNG.integers(0, 64, size=(n,)).astype(np.uint32))
        pay = None if width == 0 else RNG.normal(size=(n, width)).astype(np.float32)
        state = rows_to_state(jnp.asarray(keys), None if pay is None else jnp.asarray(pay))
        want = sorted_ops.segmented_combine(state)
        got = ops.segmented_combine(state)
        np.testing.assert_array_equal(np.asarray(got.keys), np.asarray(want.keys))
        np.testing.assert_array_equal(np.asarray(got.count), np.asarray(want.count))
        np.testing.assert_allclose(
            np.asarray(got.sum), np.asarray(want.sum), rtol=1e-4, atol=1e-5
        )


# ---------------------------------------------------------------------------
# fused merge-aggregate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [64, 256, 1024])
def test_merge_aggregate_vs_ref(n):
    def mk(nn):
        k = np.sort(RNG.integers(0, nn // 2, size=(1, nn)).astype(np.uint32), -1)
        c = np.ones((1, nn), np.int32)
        v = RNG.normal(size=(1, 2, nn)).astype(np.float32)
        return k, c, v

    ka, ca, va = mk(n)
    kb, cb, vb = mk(n)
    args = [jnp.asarray(x) for x in (ka, ca, va, va, va, kb, cb, vb, vb, vb)]
    got = merge_absorb_tiles(*args)
    want = ref.ref_merge_absorb(*args)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))  # keys
    np.testing.assert_array_equal(np.asarray(got[5]), np.asarray(want[5]))  # tails
    tails = np.asarray(got[5])
    for g, w in zip(got[1:5], want[1:5]):
        np.testing.assert_allclose(  # compare where it matters: at tails
            np.asarray(g)[..., tails[0]], np.asarray(w)[..., tails[0]],
            rtol=1e-4, atol=1e-5,
        )


def test_ops_merge_absorb_sorted_end_to_end():
    ka = np.sort(RNG.integers(0, 300, 700).astype(np.uint32))
    kb = np.sort(RNG.integers(100, 400, 500).astype(np.uint32))
    pa = RNG.normal(size=(700, 2)).astype(np.float32)
    pb = RNG.normal(size=(500, 2)).astype(np.float32)
    a = sorted_ops.absorb(rows_to_state(jnp.asarray(ka), jnp.asarray(pa)))
    b = sorted_ops.absorb(rows_to_state(jnp.asarray(kb), jnp.asarray(pb)))
    got = ops.merge_absorb_sorted(a, b)
    want = sorted_ops.merge_absorb(a, b)
    gk = np.asarray(got.keys); gk = gk[gk != EMPTY]
    wk = np.asarray(want.keys); wk = wk[wk != EMPTY]
    np.testing.assert_array_equal(gk, wk)
    gv, wv = np.asarray(got.count), np.asarray(want.count)
    np.testing.assert_array_equal(gv[: len(gk)], wv[: len(wk)])
    np.testing.assert_allclose(
        np.asarray(got.sum)[: len(gk)], np.asarray(want.sum)[: len(wk)],
        rtol=1e-4, atol=1e-4,
    )


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("e,c,d,f", [(2, 128, 128, 128), (4, 256, 256, 384),
                                     (8, 128, 512, 256)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_grouped_matmul_vs_ref(e, c, d, f, dtype):
    x = RNG.normal(size=(e * c, d)).astype(np.float32)
    w = RNG.normal(size=(e, d, f)).astype(np.float32) / np.sqrt(d)
    xj = jnp.asarray(x, dtype=dtype)
    wj = jnp.asarray(w, dtype=dtype)
    got = grouped_matmul(xj, wj, capacity=c)
    want = ref.ref_grouped_matmul(xj, wj, capacity=c)
    tol = 1e-4 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_grouped_matmul_block_shape_sweep():
    e, c, d, f = 2, 256, 256, 256
    x = RNG.normal(size=(e * c, d)).astype(np.float32)
    w = RNG.normal(size=(e, d, f)).astype(np.float32)
    want = np.asarray(ref.ref_grouped_matmul(jnp.asarray(x), jnp.asarray(w), capacity=c))
    for bm, bn, bk in [(128, 128, 128), (256, 128, 128), (128, 256, 256)]:
        got = grouped_matmul(
            jnp.asarray(x), jnp.asarray(w), capacity=c,
            block_m=bm, block_n=bn, block_k=bk,
        )
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# pallas backend plumbed through the paper operator
# ---------------------------------------------------------------------------


def test_sorted_groupby_pallas_backend():
    keys = RNG.integers(0, 200, 1000).astype(np.uint32)
    pay = RNG.normal(size=(1000, 2)).astype(np.float32)
    want = sorted_ops.sorted_groupby(jnp.asarray(keys), jnp.asarray(pay))
    got = sorted_ops.sorted_groupby(
        jnp.asarray(keys), jnp.asarray(pay), backend="pallas"
    )
    np.testing.assert_array_equal(np.asarray(got.keys), np.asarray(want.keys))
    np.testing.assert_array_equal(np.asarray(got.count), np.asarray(want.count))
    np.testing.assert_allclose(
        np.asarray(got.sum), np.asarray(want.sum), rtol=1e-4, atol=1e-4
    )
