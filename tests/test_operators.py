"""Interesting-orderings operators (§2.2, §6.3, §6.4)."""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    EMPTY,
    ExecConfig,
    count_and_count_distinct,
    group_by_order_by,
    intersect_distinct,
    pack_keys,
    rollup,
    unpack_keys,
)

RNG = np.random.default_rng(7)
CFG = ExecConfig(memory_rows=512, page_rows=64, fanin=4, batch_rows=128)


def test_pack_unpack_roundtrip():
    hi = jnp.asarray(RNG.integers(0, 1 << 12, 100).astype(np.uint32))
    lo = jnp.asarray(RNG.integers(0, 1 << 10, 100).astype(np.uint32))
    packed = pack_keys(hi, lo, 10)
    h2, l2 = unpack_keys(packed, 10)
    assert np.array_equal(np.asarray(h2), np.asarray(hi))
    assert np.array_equal(np.asarray(l2), np.asarray(lo))
    # packed order is (hi, lo) lexicographic
    order = np.lexsort((np.asarray(lo), np.asarray(hi)))
    assert np.array_equal(np.argsort(np.asarray(packed), kind="stable"), order)


def test_group_by_order_by_free_for_insort():
    """Fig 19: sorted grouping satisfies an equal ORDER BY at no extra cost."""
    keys = RNG.integers(0, 3_000, 20_000).astype(np.uint32)
    st_i, _, extra_i = group_by_order_by(keys, None, CFG, algorithm="insort",
                                         output_estimate=3_000)
    st_h, _, extra_h = group_by_order_by(keys, None, CFG, algorithm="hash",
                                         output_estimate=3_000)
    assert extra_i == 0
    assert extra_h > 0  # hash pays a full post-sort of the result
    ki = np.asarray(st_i.keys); ki = ki[ki != EMPTY]
    kh = np.asarray(st_h.keys); kh = kh[kh != EMPTY]
    assert np.array_equal(ki, kh)  # same result, sorted either way in the end


def test_count_and_count_distinct_single_sort():
    """Fig 20: one sort produces count and count-distinct per group."""
    g = RNG.integers(0, 50, 30_000).astype(np.uint32)
    a = RNG.integers(0, 200, 30_000).astype(np.uint32)
    st, stats = count_and_count_distinct(g, a, lo_bits=10, cfg=CFG,
                                         output_estimate=50 * 200)
    k = np.asarray(st.keys)
    valid = k != EMPTY
    got = {int(kk): (int(c), float(s0), float(s1))
           for kk, c, (s0, s1) in zip(k[valid], np.asarray(st.count)[valid],
                                      np.asarray(st.sum)[valid])}
    for gg in np.unique(g):
        m = g == gg
        n_count = int(m.sum())
        n_distinct = len(np.unique(a[m]))
        _, s0, s1 = got[int(gg)]
        assert int(s0) == n_count, f"count(a) wrong for g={gg}"
        assert int(s1) == n_distinct, f"count(distinct a) wrong for g={gg}"

    # hash plan spills more: two hash tables
    _, stats_h = count_and_count_distinct(g, a, lo_bits=10, cfg=CFG,
                                          algorithm="hash",
                                          output_estimate=50 * 200)
    assert stats.total_spill_rows <= stats_h.total_spill_rows * 1.5 + CFG.memory_rows


def test_rollup_levels_consistent():
    n = 8_000
    day = RNG.integers(1, 29, n).astype(np.uint32)
    month = RNG.integers(1, 13, n).astype(np.uint32)
    year = RNG.integers(0, 4, n).astype(np.uint32)
    pay = np.ones((n, 1), np.float32)
    levels, _ = rollup(day, month, year, pay, CFG, output_estimate=4 * 12 * 28)
    # total row count is conserved at every rollup level
    for name in ("day", "month", "year", "all"):
        s = np.asarray(levels[name].sum)[:, 0].sum()
        assert s == n, f"level {name} lost rows"
    assert int(levels["all"].occupancy()) == 1
    assert int(levels["year"].occupancy()) == len(np.unique(year))


def test_intersect_distinct_sort_vs_hash():
    """Figs 21/22: identical result; sort-based plan spills ≤ half of hash."""
    a = RNG.integers(0, 4_000, 30_000).astype(np.uint32)
    b = RNG.integers(2_000, 6_000, 30_000).astype(np.uint32)
    # single-merge-level regime (O ≤ M·F), as in the paper's Fig 22 setup
    cfg = ExecConfig(memory_rows=2048, page_rows=128, fanin=4, batch_rows=256)
    out_s, st_s = intersect_distinct(a, b, cfg, algorithm="insort",
                                     output_estimate=4_000)
    out_h, st_h = intersect_distinct(a, b, cfg, algorithm="hash",
                                     output_estimate=4_000)
    expect = np.intersect1d(np.unique(a), np.unique(b))
    ks = np.asarray(out_s); ks = ks[ks != EMPTY]
    kh = np.asarray(out_h); kh = kh[kh != EMPTY]
    assert np.array_equal(np.sort(ks), expect)
    assert np.array_equal(np.sort(kh), expect)
    # each input row spills once (sort plan) vs twice (hash plan + join)
    assert st_s.total_spill_rows < st_h.total_spill_rows


def test_join_by_grouping_matches_oracle():
    """Paper §2.5 / Fig 4: inner-join cardinalities and fused aggregates
    from ONE mixed sort; each input row spills at most once."""
    from repro.core.join import join_aggregate, semi_join, anti_semi_join

    lk = RNG.integers(0, 500, 6_000).astype(np.uint32)
    rk = RNG.integers(250, 750, 4_000).astype(np.uint32)
    lp = RNG.normal(size=(6_000, 1)).astype(np.float32)
    res, stats = join_aggregate(lk, rk, lp, None, CFG, output_estimate=750)
    k = np.asarray(res["keys"]); valid = k != EMPTY
    jc = np.asarray(res["join_count"])[valid]
    slp = np.asarray(res["sum_left_pay"])[valid]
    got = dict(zip(k[valid].tolist(), zip(jc.tolist(), slp[:, 0].tolist())))
    # oracle via numpy
    import collections
    lcnt = collections.Counter(lk.tolist())
    rcnt = collections.Counter(rk.tolist())
    lsum = collections.defaultdict(float)
    for key, v in zip(lk.tolist(), lp[:, 0].tolist()):
        lsum[key] += v
    for key in set(lcnt) | set(rcnt):
        want_jc = lcnt.get(key, 0) * rcnt.get(key, 0)
        gjc, gslp = got.get(key, (0.0, 0.0))
        assert int(gjc) == want_jc, key
        if want_jc:
            assert abs(gslp - lsum[key] * rcnt[key]) < 1e-2 * max(1, abs(gslp))
    # Fig 4 invariant at the I/O level: one mixed sort, inputs spill ≤ once
    assert stats.total_spill_rows <= len(lk) + len(rk) + CFG.memory_rows
    # semi/anti joins from the same machinery
    s, _ = semi_join(lk, rk, CFG, output_estimate=750)
    a, _ = anti_semi_join(lk, rk, CFG, output_estimate=750)
    want_semi = np.intersect1d(np.unique(lk), np.unique(rk))
    want_anti = np.setdiff1d(np.unique(lk), np.unique(rk))
    assert np.array_equal(np.sort(s), want_semi)
    assert np.array_equal(np.sort(a), want_anti)


# ---------------------------------------------------------------------------
# NumPy-oracle coverage for rollup / count_and_count_distinct, including
# bit-packing edge cases (max day/month values, keys near the EMPTY
# sentinel)
# ---------------------------------------------------------------------------


def test_rollup_matches_numpy_oracle_per_level():
    """Every rollup level's (key → sum) mapping must equal the NumPy
    oracle, at the extreme ends of the packed bit ranges: day uses 5 bits
    (max 31), month 4 bits (max 15)."""
    n = 5_000
    day = RNG.integers(1, 32, n).astype(np.uint32)      # includes day=31
    month = RNG.integers(1, 16, n).astype(np.uint32)    # includes month=15
    year = RNG.integers(0, 3, n).astype(np.uint32)
    pay = RNG.normal(size=(n, 1)).astype(np.float32).astype(np.float64)
    levels, _ = rollup(day, month, year, pay.astype(np.float32), CFG,
                       output_estimate=3 * 15 * 31)

    def oracle(keys_np):
        out = {}
        for k, v in zip(keys_np.tolist(), pay[:, 0].tolist()):
            out[k] = out.get(k, 0.0) + v
        return out

    packed = {
        "day": (year << 9) | (month << 5) | day,
        "month": (year << 4) | month,
        "year": year,
        "all": np.zeros(n, np.uint32),
    }
    for name, keys_np in packed.items():
        st = levels[name]
        k = np.asarray(st.keys)
        valid = k != EMPTY
        got = dict(zip(k[valid].tolist(), np.asarray(st.sum)[valid, 0].tolist()))
        want = oracle(keys_np.astype(np.uint32))
        assert set(got) == set(want), f"level {name}: key sets differ"
        for kk, vv in want.items():
            assert abs(got[kk] - vv) < 1e-2 * max(1.0, abs(vv)), (name, kk)


def test_rollup_bitpacking_no_collisions_at_max_values():
    """day=31/month=15 must not bleed into neighbouring fields: two dates
    that differ only in (day, month) map to distinct fine keys and to the
    same year key."""
    day = np.array([31, 1], np.uint32)
    month = np.array([1, 15], np.uint32)   # (31, 1) vs (1, 15): same year
    year = np.array([2, 2], np.uint32)
    pay = np.array([[1.0], [10.0]], np.float32)
    levels, _ = rollup(day, month, year, pay, CFG, output_estimate=4)
    assert int(levels["day"].occupancy()) == 2     # no fine-key collision
    assert int(levels["month"].occupancy()) == 2   # distinct months
    assert int(levels["year"].occupancy()) == 1    # one year bucket
    assert float(np.asarray(levels["year"].sum)[0, 0]) == 11.0


def test_count_distinct_keys_near_empty_sentinel():
    """Packed (g, a) keys that reach MAX_KEY = EMPTY-1 must survive; the
    EMPTY bit pattern itself is reserved and must never be produced by
    valid (g, a) pairs below the packing limit."""
    from repro.core import MAX_KEY

    lo_bits = 8
    g_max = (1 << (32 - lo_bits)) - 1   # top of the g range
    # (g_max, 254) packs to 0xFFFFFFFE == MAX_KEY; (g_max, 255) would be
    # EMPTY and is excluded by construction of the input
    g = np.array([g_max, g_max, g_max, 7, 7], np.uint32)
    a = np.array([254, 254, 253, 254, 1], np.uint32)
    assert int((g[0].astype(np.uint64) << lo_bits) | a[0]) == int(MAX_KEY)
    st, _ = count_and_count_distinct(g, a, lo_bits=lo_bits, cfg=CFG,
                                     output_estimate=4)
    k = np.asarray(st.keys)
    valid = k != EMPTY
    # oracle: g_max has 3 rows over 2 distinct a; 7 has 2 rows, 2 distinct
    sums = {int(kk): tuple(s) for kk, s in zip(
        k[valid], np.asarray(st.sum)[valid].astype(np.int64).tolist())}
    assert sums[g_max] == (3, 2), sums   # count(a)=3, count(distinct a)=2
    assert sums[7] == (2, 2), sums


def test_count_and_count_distinct_matches_numpy_oracle_dense():
    """Dense random sweep of the fused plan against the NumPy oracle."""
    g = RNG.integers(0, 40, 10_000).astype(np.uint32)
    a = RNG.integers(0, 64, 10_000).astype(np.uint32)
    st, _ = count_and_count_distinct(g, a, lo_bits=6, cfg=CFG,
                                     output_estimate=40 * 64)
    k = np.asarray(st.keys)
    valid = k != EMPTY
    sums = np.asarray(st.sum)[valid].astype(np.int64)
    got = {int(kk): (int(s0), int(s1)) for kk, (s0, s1) in zip(k[valid], sums)}
    for gg in np.unique(g):
        m = g == gg
        want = (int(m.sum()), len(np.unique(a[m])))
        assert got[int(gg)] == want, (gg, got[int(gg)], want)
