PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench bench-absorb bench-keywidth bench-shard bench-stream bench-service bench-figures

test:           ## tier-1 suite (property tests skip if hypothesis absent)
	python -m pytest -x -q

bench:          ## smoke-mode absorb + key-width + pipeline + shard + stream + service benches (CI sanity)
	python benchmarks/bench_absorb.py --smoke
	python benchmarks/bench_keywidth.py --smoke
	python benchmarks/bench_pipeline.py --smoke
	python benchmarks/bench_shard.py --smoke
	python benchmarks/bench_stream.py --smoke
	python benchmarks/bench_service.py --smoke

bench-absorb:   ## sort-absorb vs merge-absorb microbenchmark
	python benchmarks/bench_absorb.py

bench-keywidth: ## uint32 vs uint64 absorb/merge throughput
	python benchmarks/bench_keywidth.py

bench-pipeline: ## host-loop vs device-resident end-to-end aggregate
	python benchmarks/bench_pipeline.py

bench-shard:    ## mesh-sharded pipeline: per-world wall time + shuffle volume
	python benchmarks/bench_shard.py

bench-stream:   ## streamed vs resident pipeline: overlap + peak footprint
	python benchmarks/bench_stream.py

bench-service:  ## aggregation service: sustained ingest + snapshot latency
	python benchmarks/bench_service.py

bench-figures:  ## paper-figure benchmark driver
	python benchmarks/run.py
