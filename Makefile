PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench bench-absorb

test:           ## tier-1 suite (property tests skip if hypothesis absent)
	python -m pytest -x -q

bench-absorb:   ## sort-absorb vs merge-absorb microbenchmark
	python benchmarks/bench_absorb.py

bench:          ## paper-figure benchmark driver
	python benchmarks/run.py
