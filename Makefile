PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench bench-absorb bench-keywidth bench-shard bench-stream bench-service bench-adaptive bench-join bench-figures calibrate calibrate-check

test:           ## tier-1 suite (property tests skip if hypothesis absent)
	python -m pytest -x -q

bench:          ## smoke-mode benches + calibration code path (CI sanity)
	python benchmarks/bench_absorb.py --smoke
	python benchmarks/bench_keywidth.py --smoke
	python benchmarks/bench_pipeline.py --smoke
	python benchmarks/bench_shard.py --smoke
	python benchmarks/bench_stream.py --smoke
	python benchmarks/bench_service.py --smoke
	python benchmarks/bench_adaptive.py --smoke
	python benchmarks/bench_join.py --smoke
	python benchmarks/calibrate.py --smoke

bench-absorb:   ## sort-absorb vs merge-absorb microbenchmark
	python benchmarks/bench_absorb.py

bench-keywidth: ## uint32 vs uint64 absorb/merge throughput
	python benchmarks/bench_keywidth.py

bench-pipeline: ## host-loop vs device-resident end-to-end aggregate
	python benchmarks/bench_pipeline.py

bench-shard:    ## mesh-sharded pipeline: per-world wall time + shuffle volume
	python benchmarks/bench_shard.py

bench-stream:   ## streamed vs resident pipeline: overlap + peak footprint
	python benchmarks/bench_stream.py

bench-service:  ## aggregation service: sustained ingest + snapshot latency
	python benchmarks/bench_service.py

bench-adaptive: ## adaptive vs fixed policies on phase-change key streams
	python benchmarks/bench_adaptive.py

bench-join:     ## order-consuming merge join vs re-sort baseline
	python benchmarks/bench_join.py

calibrate:      ## measure per-row cost constants, regenerate core/_cost_constants.py
	python benchmarks/calibrate.py

calibrate-check: ## validate the checked-in constants against the generator schema
	python benchmarks/calibrate.py --check

bench-figures:  ## paper-figure benchmark driver
	python benchmarks/run.py
