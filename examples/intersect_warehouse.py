"""Interesting orderings end-to-end (paper §6.4): set operations and an
order-preserving query pipeline over one warehouse dataset.

Part 1 — INTERSECT DISTINCT via sort-based vs hash-based plans, with
exact spill accounting (the §6.4 race: the sort-based plan spills each
input row at most once and its merge join reads sorted streams).

Part 2 — the composition payoff: aggregate each fact table ONCE, then
chain ``merge_join`` and ``rollup`` off the established key order —
zero sorts after the sources', which the recorded plan proves
(``cost_model.sort_rows == 0``, ``pipeline.re_sorts == 0``).

Run:  PYTHONPATH=src python examples/intersect_warehouse.py
      (INTERSECT_N scales the input for smoke runs)
"""
import os

import numpy as np

import repro
from repro.core import ExecConfig, intersect_distinct

rng = np.random.default_rng(1)
I = int(os.environ.get("INTERSECT_N", 500_000))
a = rng.integers(0, max(60_000, I // 8), I).astype(np.uint32)
b = rng.integers(30_000, max(90_000, I // 4), I).astype(np.uint32)
est = min(60_000, max(256, I // 8))
cfg = ExecConfig(memory_rows=32_768, page_rows=2_048, fanin=16,
                 batch_rows=8_192)

out_s, st_s = intersect_distinct(a, b, cfg, algorithm="insort",
                                 output_estimate=est)
out_h, st_h = intersect_distinct(a, b, cfg, algorithm="hash",
                                 output_estimate=est)
ks = np.asarray(out_s); ks = ks[ks != np.uint32(0xFFFFFFFF)]
print(f"|A ∩ B| = {len(ks):,}")
print(f"sort-based plan spill: {st_s.total_spill_rows:,} rows "
      f"(each input row spills ≤ once; merge join reads sorted streams)")
print(f"hash-based plan spill: {st_h.total_spill_rows:,} rows "
      f"(DISTINCT twice + join build/probe spill)")
print(f"ratio: {st_h.total_spill_rows / max(1, st_s.total_spill_rows):.2f}×")

# --- Part 2: order-preserving pipeline over the same warehouse -------------
#
# Two fact tables share a (region, store) dimension.  Each side pays ONE
# sort inside its aggregation; everything after — the join aligning the
# two sides' groups, the group-join products, the per-region and grand
# total rollups — only CONSUMES that order.
n = max(4_000, I // 25)
spec = repro.KeySpec.of(region=6, store=10)
sales_cols = {"region": rng.integers(0, 8, n),
              "store": rng.integers(0, 64, n)}
sales_amount = rng.gamma(2.0, 10.0, n).astype(np.float32)
returns_cols = {"region": rng.integers(0, 8, n),
                "store": rng.integers(32, 96, n)}
returns_amount = rng.gamma(2.0, 3.0, n).astype(np.float32)

returns = repro.aggregate(returns_cols, by=spec, values=returns_amount,
                          aggs=("count", "sum"), output_estimate=1024)
tiers = repro.pipeline([
    ("aggregate", dict(columns=sales_cols, by=spec, values=sales_amount,
                       aggs=("count", "sum"), output_estimate=1024)),
    ("merge_join", {"right": returns}),          # stores seen on BOTH sides
    ("rollup", {}),                              # …grouped by every prefix
])
fine = tiers[("region", "store")]
rel = fine.relation()
print(f"stores with sales AND returns: {len(rel['store']):,} "
      f"(join consumed both sides' sort order)")
print(f"pipeline plan: {fine.plan['pipeline']}")
cm = fine.plan["cost_model"]
print(f"join-side sort term: {cm['sort_rows']:.0f} rows "
      f"(re-sort baseline would sort "
      f"{fine.plan['cost_model_resort_baseline']['sort_rows']:.0f})")
total = tiers[()].relation()
print(f"grand total join pairs: {float(total['join_count'][0]):,.0f}; "
      f"sales in joined stores: {float(np.ravel(total['sum_left'])[0]):,.0f}")

# the anti join answers the complementary question from the SAME inputs,
# still without sorting anything
anti = repro.pipeline([
    ("aggregate", dict(columns=sales_cols, by=spec, values=sales_amount,
                       aggs=("count", "sum"), output_estimate=1024)),
    ("merge_join", {"right": returns, "how": "anti"}),
])
print(f"stores with sales and NO returns: {anti.occupancy():,} "
      f"(re_sorts={anti.plan['pipeline']['re_sorts']})")
print("order-preserving pipeline OK")
