"""Interesting orderings end-to-end (paper §6.4): INTERSECT DISTINCT via
sort-based vs hash-based plans, with exact spill accounting.

Run:  PYTHONPATH=src python examples/intersect_warehouse.py
"""
import numpy as np

from repro.core import ExecConfig, intersect_distinct

rng = np.random.default_rng(1)
I = 500_000
a = rng.integers(0, 60_000, I).astype(np.uint32)
b = rng.integers(30_000, 90_000, I).astype(np.uint32)
cfg = ExecConfig(memory_rows=32_768, page_rows=2_048, fanin=16,
                 batch_rows=8_192)

out_s, st_s = intersect_distinct(a, b, cfg, algorithm="insort",
                                 output_estimate=60_000)
out_h, st_h = intersect_distinct(a, b, cfg, algorithm="hash",
                                 output_estimate=60_000)
ks = np.asarray(out_s); ks = ks[ks != np.uint32(0xFFFFFFFF)]
print(f"|A ∩ B| = {len(ks):,}")
print(f"sort-based plan spill: {st_s.total_spill_rows:,} rows "
      f"(each input row spills ≤ once; merge join reads sorted streams)")
print(f"hash-based plan spill: {st_h.total_spill_rows:,} rows "
      f"(DISTINCT twice + join build/probe spill)")
print(f"ratio: {st_h.total_spill_rows / max(1, st_s.total_spill_rows):.2f}×")
