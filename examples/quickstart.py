"""Quickstart: the paper's operator on a web-log-style workload.

Counts distinct users and per-(country, hour) events from 2M unsorted log
records under a 64k-row memory budget — the paper's §2.2 motivating
example — and shows the algorithm-choice problem dissolving: one in-sort
operator covers the in-memory, small-output, and large-output regimes
while matching hash aggregation's spill and producing sorted output.

Run:  PYTHONPATH=src python examples/quickstart.py
      (QUICKSTART_N=... scales the log size; CI smoke uses a small one)
"""
import os

import numpy as np
import jax.numpy as jnp

from repro.core import (
    ExecConfig, group_by, finalize, pack_keys, EMPTY,
    insort_aggregate, hash_aggregate, sort_then_stream_aggregate,
)

rng = np.random.default_rng(0)
N = int(os.environ.get("QUICKSTART_N", 2_000_000))
n_users = max(16, N // 13)

print(f"== web log: {N:,} records, ~{n_users:,} distinct users ==")
users = (rng.zipf(1.3, N) % n_users).astype(np.uint32)
country = rng.integers(0, 50, N).astype(np.uint32)
hour = rng.integers(0, 24, N).astype(np.uint32)
latency = rng.gamma(2.0, 30.0, N).astype(np.float32)

# memory budget ~N/32 (the paper's external regime), capped at the 64k
# rows of the full-size demo — the smoke run compiles small programs
M = max(1 << 10, min(1 << 16, 1 << (N.bit_length() - 5)))
cfg = ExecConfig(memory_rows=M, page_rows=max(64, M // 16), fanin=16,
                 batch_rows=max(256, M // 4))

# 1) SELECT COUNT(DISTINCT user) — large input, medium output
state, stats = insort_aggregate(users, None, cfg,
                                output_estimate=n_users)
uniq = int(state.occupancy())
print(f"distinct users: {uniq:,}")
print(f"  spill: {stats.total_spill_rows:,} rows "
      f"({stats.runs_generated} runs, {stats.merge_levels} merge levels, "
      f"wide merge index peak {stats.max_index_occupancy:,} rows)")

_, hstats = hash_aggregate(users, None, cfg, output_estimate=uniq)
print(f"  hash aggregation spill (baseline): {hstats.total_spill_rows:,} rows")

# 2) SELECT country, hour, count(*), avg(latency) GROUP BY country, hour
#    — small output: early aggregation keeps it fully in memory (Fig 6)
key = pack_keys(jnp.asarray(country), jnp.asarray(hour), 5)
state, stats = group_by(np.asarray(key), latency, cfg, algorithm="insort",
                        output_estimate=50 * 24)
out = finalize(state, ("count", "avg"))
print(f"\n(country, hour) groups: {int(state.occupancy())}, "
      f"spill: {stats.total_spill_rows} rows (in-memory, like TPC-H Q1)")
k0 = int(np.asarray(state.keys)[0])
print(f"  first group country={k0 >> 5} hour={k0 & 31} "
      f"count={int(out['count'][0])} avg_latency={float(out['avg'][0,0]):.1f}ms")

# 3) the output is sorted — a GROUP BY + ORDER BY needs no extra sort
ks = np.asarray(state.keys); ks = ks[ks != EMPTY]
assert np.all(np.diff(ks.astype(np.int64)) > 0)
print("\noutput arrives sorted: GROUP BY + ORDER BY in one operator ✓")

# 4) the traditional baseline the paper retires
_, tstats = sort_then_stream_aggregate(users[:200_000], None, cfg)
print(f"\ntraditional sort-then-aggregate on 200k rows spills "
      f"{tstats.total_spill_rows:,} rows — vs in-sort "
      f"{insort_aggregate(users[:200_000], None, cfg, output_estimate=n_users)[1].total_spill_rows:,}")

# 5) the schema front door: the same query declaratively — a composite
#    (user, country, hour) key with the full 32-bit user-id space needs
#    43 bits, so the engine widens to uint64 under the hood (no manual
#    bit shifting, no 32-bit ceiling)
import repro

spec = repro.KeySpec.of(user=32, country=6, hour=5)
res = repro.aggregate(
    {"user": users, "country": country, "hour": hour},
    by=spec,
    values=latency,
    aggs=repro.AggSpec("count", "avg"),
    order_by=("user",),          # any key prefix is free — it's one sort
    cfg=cfg,
    output_estimate=n_users,
)
rel = res.relation()
print(f"\nfront door: {res.occupancy():,} (user, country, hour) groups "
      f"[key dtype {res.state.keys.dtype}], spill "
      f"{res.stats.total_spill_rows:,} rows")
print(f"  first group user={rel['user'][0]} country={rel['country'][0]} "
      f"hour={rel['hour'][0]} count={rel['count'][0]} "
      f"avg={float(rel['avg'][0, 0]):.1f}ms")
print(f"  plan: {res.plan['predicted_spill_insort']:,.0f} predicted in-sort "
      f"spill vs {res.plan['predicted_spill_hash']:,.0f} hash")

# 6) streamed ingest: the same query over an ITERATOR of column batches
#    — the log never needs to be resident at once.  Each super-batch is
#    device_put while the device aggregates the previous one (double
#    buffering); the result is identical to the resident run above.
from repro.data.pipeline import iter_column_batches

log = {"user": users, "country": country, "hour": hour, "latency": latency}
batches = iter_column_batches(log, rows=max(1, N // 8))  # e.g. log shards
res_s = repro.aggregate(
    batches,
    by=spec,
    values="latency",            # a column carried in each batch
    aggs=repro.AggSpec("count", "avg"),
    cfg=cfg,
    output_estimate=n_users,
)
rel_s = res_s.relation()
assert np.array_equal(rel_s["user"], rel["user"])
assert np.array_equal(rel_s["count"], rel["count"])
print(f"\nstreamed ingest ({8} batches): {res_s.occupancy():,} groups — "
      f"identical relation, device footprint bounded by the batch size ✓")
