"""Sort-based MoE dispatch (the paper's technique inside the model).

Compares the dense one-hot dispatch against the paper's sorted grouping
on a qwen3-style MoE block, on CPU with real arrays: identical outputs,
and the sorted path's dispatch tensor is E×C×D (capacity-bounded) versus
dense's E×T×D.

Run:  PYTHONPATH=src python examples/moe_sorted_dispatch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models import moe as MOE

cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
key = jax.random.PRNGKey(0)
params, _ = M.init(cfg, key)
moe_p = jax.tree.map(lambda a: a[0], params["layers"])["moe"]

B, S = 8, 256
x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)

dense = jax.jit(lambda p, x: MOE.moe_block(p, cfg, x, dispatch="dense")[0])
sorted_ = jax.jit(lambda p, x: MOE.moe_block(p, cfg, x, dispatch="sorted")[0])

y_dense = dense(moe_p, x)
y_sorted = sorted_(moe_p, x)
err = float(jnp.abs(y_dense - y_sorted).max())
print(f"max |dense − sorted| = {err:.2e}")

for name, fn in [("dense", dense), ("sorted", sorted_)]:
    fn(moe_p, x).block_until_ready()
    t0 = time.time()
    for _ in range(10):
        fn(moe_p, x).block_until_ready()
    print(f"{name:7s}: {(time.time()-t0)/10*1e3:7.2f} ms  "
          f"(E={cfg.moe.num_experts}, T={B*S}, top-{cfg.moe.top_k})")

e, t, d = cfg.moe.num_experts, B * S, cfg.d_model
cap = int(cfg.moe.capacity_factor * t * cfg.moe.top_k / e + 7) // 8 * 8
print(f"dispatch tensor rows: dense E×T = {e*t:,} vs sorted E×C = {e*cap:,} "
      f"({e*t/(e*cap):.0f}× smaller)")
