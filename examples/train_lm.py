"""End-to-end LM training: ~100M-parameter dense model, a few hundred
steps on CPU, with checkpoint/restart exercised mid-run.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses as dc
import sys

sys.path.insert(0, "src")
from repro.launch.train import train
from repro.configs import get_config
from repro.models.config import ModelConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: llama-family, 12L × d512 (embed dominates w/ 128k vocab)
    import repro.configs.llama3_8b as L

    cfg100m = dc.replace(
        get_config("llama3-8b", smoke=True),
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, d_head=64,
        d_ff=1408, vocab=65536, attn_chunk_q=256, attn_chunk_k=256,
    )
    # register as a one-off config
    import repro.configs as C

    orig = C.get_config

    def patched(name, smoke=False):
        if name == "lm-100m":
            return cfg100m
        return orig(name, smoke=smoke)

    C.get_config = patched
    import repro.launch.train as T

    T.get_config = patched

    print("training ~100M-param LM; first segment …")
    train("lm-100m", smoke=True, steps=args.steps // 2, batch=8, seq=256,
          ckpt_dir=args.ckpt, lr=1e-3, log_every=20, save_every=50)
    print("simulated restart: resuming from checkpoint …")
    losses = train("lm-100m", smoke=True, steps=args.steps, batch=8, seq=256,
                   ckpt_dir=args.ckpt, resume=True, lr=1e-3, log_every=20,
                   save_every=100)
    assert losses[-1] < losses[0], "loss should decrease"
    print("done: loss fell from", losses[0], "to", losses[-1])


if __name__ == "__main__":
    main()
