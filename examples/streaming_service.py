"""Aggregation as a service: sessionization over an unbounded stream.

A clickstream arrives minute by minute and never ends, so there is no
"after the last row" at which to run a one-shot GROUP BY.  This demo
keeps ONE long-lived device-resident session open instead:

* micro-batches flow through the zero-readback staged ingest path;
* dashboards query the live aggregate mid-stream with **merge-on-read
  snapshots** — sorted relations computed into a fresh buffer while the
  engine keeps ingesting (nothing is consumed);
* a **watermark TTL** retires minutes older than the session gap from
  the run store, so state tracks the active window, not the stream's
  whole history — and every retired row stays accounted in
  ``stats.rows_retired``.

Run:  PYTHONPATH=src python examples/streaming_service.py
      (SERVICE_MINUTES=... scales the stream; CI smoke uses a short one)
"""
import os

import numpy as np

import repro
from repro.core import ExecConfig

rng = np.random.default_rng(0)
MINUTES = int(os.environ.get("SERVICE_MINUTES", 64))
ROWS_PER_MIN = int(os.environ.get("SERVICE_ROWS", 4096))
SNAP_EVERY = max(2, MINUTES // 8)   # dashboard refresh cadence
TTL = 3 * SNAP_EVERY                # session gap: minutes kept live

print(f"== clickstream: {MINUTES} minutes x {ROWS_PER_MIN:,} events, "
      f"snapshot every {SNAP_EVERY} min, TTL {TTL} min ==")

# the watermark column (minute) is the MAJOR key column, so TTL expiry
# is one contiguous packed-key range — a sorted prefix cut on device
# a memory budget well under the stream size: the session spills runs
# and the TTL retirement is a real run-store cut, not a no-op
sess = repro.serve_aggregate(
    by=repro.KeySpec.of(minute=12, user=14),
    values="ms", aggs=("count", "sum", "avg"), watermark="minute",
    cfg=ExecConfig(memory_rows=4096, page_rows=256, fanin=8,
                   batch_rows=512),
    output_estimate=MINUTES * ROWS_PER_MIN,
)

total = 0
for minute in range(MINUTES):
    n = ROWS_PER_MIN
    sess.ingest({
        "minute": np.full(n, minute, np.uint32),
        "user": (rng.zipf(1.4, n) % (1 << 14)).astype(np.uint32),
        "ms": rng.gamma(2.0, 30.0, n).astype(np.float32),
    })
    total += n

    if (minute + 1) % SNAP_EVERY == 0:
        # TTL first: drop minutes that fell out of the session window
        sess.expire_below(minute=max(0, minute + 1 - TTL))
        res = sess.snapshot()          # merge-on-read: ingest continues
        rel = res.relation()
        live_min = int(rel["minute"].min()) if len(rel["count"]) else -1
        print(f"minute {minute + 1:4d}: {len(rel['count']):7,} live "
              f"(minute,user) groups from minute {live_min:3d}, "
              f"{res.stats.rows_retired:7,} rows retired "
              f"[{sess.metrics.snapshot_latencies_s[-1] * 1e3:6.1f} ms]")
        assert live_min >= max(0, minute + 1 - TTL)

m = sess.metrics
print(f"\nmid-stream queries: {m.snapshots_taken} snapshots, "
      f"p50 {m.snapshot_latency_s(0.5) * 1e3:.1f} ms, "
      f"p99 {m.snapshot_latency_s(0.99) * 1e3:.1f} ms")
print(f"duplicate rate {m.duplicate_rate:.3f} "
      f"(zipf users collapsing into live groups)")

final = sess.close()
rel = final.relation()
# TTL accounting: retirement happens at snapshot boundaries, so the
# surviving events are EXACTLY the minutes at or above the last cutoff
last_cut = max(0, (MINUTES // SNAP_EVERY) * SNAP_EVERY - TTL)
survived = int(rel["count"].sum())
print(f"\nfinal drain: {len(rel['count']):,} groups, "
      f"{final.stats.rows_retired:,} store rows retired over the session")
print(f"accounting: surviving events {survived:,} == "
      f"{MINUTES - last_cut} live minutes x {ROWS_PER_MIN:,} "
      f"({total:,} ingested in all) ✓")
assert survived == ROWS_PER_MIN * (MINUTES - last_cut), (survived, last_cut)
assert final.stats.rows_retired > 0
print("sessionized service OK")
