#!/usr/bin/env bash
# Tier-1 test entry point: the one command CI and contributors run.
#
#   scripts/test.sh               full tier-1 suite
#   scripts/test.sh --pipeline    fast selector: device-pipeline parity +
#                                 transfer-guard tests, then the smoke-mode
#                                 benches (so benchmark code cannot rot)
#   scripts/test.sh --shard       mesh-sharded selector: sharded parity /
#                                 edge / transfer-guard tests (forced fake
#                                 host devices in subprocesses — including
#                                 the world=32 parity + Zipf-skew grids
#                                 and the exchange quota/retry tests)
#                                 plus the shard benchmark in smoke mode
#                                 (which runs the Zipf skew sweep)
#   scripts/test.sh --stream      streamed-pipeline selector: streamed vs
#                                 resident parity + single-readback tests,
#                                 then the streaming bench in smoke mode
#   scripts/test.sh --service     aggregation-service selector: snapshot
#                                 parity / non-destructiveness / TTL
#                                 eviction tests, then the service bench
#                                 in smoke mode
#   scripts/test.sh --join        order-preserving join selector: merge
#                                 join oracle parity / jaxpr no-sort
#                                 checks / composed pipeline parity,
#                                 then the join bench in smoke mode
#   scripts/test.sh --adaptive    adaptive-policy selector: governor
#                                 decision paths, oracle parity on
#                                 Zipf/phase-change streams, readback
#                                 accounting, constants-schema check,
#                                 then the calibration code path and the
#                                 adaptive bench in smoke mode
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--pipeline" ]]; then
  shift
  python -m pytest -x -q tests/test_pipeline.py "$@"
  make bench
  exit 0
fi

if [[ "${1:-}" == "--stream" ]]; then
  shift
  python -m pytest -x -q tests/test_stream.py "$@"
  python benchmarks/bench_stream.py --smoke
  exit 0
fi

if [[ "${1:-}" == "--service" ]]; then
  shift
  python -m pytest -x -q tests/test_service.py "$@"
  python benchmarks/bench_service.py --smoke
  exit 0
fi

if [[ "${1:-}" == "--join" ]]; then
  shift
  python -m pytest -x -q tests/test_join.py "$@"
  python benchmarks/bench_join.py --smoke
  exit 0
fi

if [[ "${1:-}" == "--adaptive" ]]; then
  shift
  python -m pytest -x -q tests/test_adaptive.py "$@"
  python benchmarks/calibrate.py --check
  python benchmarks/calibrate.py --smoke
  python benchmarks/bench_adaptive.py --smoke
  exit 0
fi

if [[ "${1:-}" == "--shard" ]]; then
  shift
  python -m pytest -x -q tests/test_shard.py \
    tests/test_distributed.py::test_distributed_groupby_matches_oracle \
    tests/test_distributed.py::test_distributed_groupby_overflow_fails_loudly \
    "$@"
  python benchmarks/bench_shard.py --smoke
  exit 0
fi

exec python -m pytest -x -q "$@"
