#!/usr/bin/env bash
# Tier-1 test entry point: the one command CI and contributors run.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
