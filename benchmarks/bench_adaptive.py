"""Adaptive vs fixed-policy streaming on adversarial phase-change keys.

The stream that defeats any up-front policy choice: keys drawn from a
huge domain (duplicate rate ≈ 0) that switch to a tiny domain
(duplicate rate ≈ 1) halfway through — and the reverse.  A fixed policy
is tuned for one phase and eats the other; ``policy="adaptive"`` reads
the engine's device-side observation block every k-th chunk and lets
the calibrated governor re-decide, so the wrong guess costs one
observation window.

Acceptance (ISSUE 8, checked here and recorded in BENCH_adaptive.json):
  * adaptive is within 10% of the BEST fixed policy on each phase;
  * adaptive is >= 1.5x faster than the WORST fixed policy end-to-end;
  * exact keys/counts parity with the one-shot oracle.

A second adaptive run starts from a deliberately wrong arm
(``start="rs"``) to demonstrate mid-flight recovery — its switch events
land in the report.
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

import _harness as H

sys.path.insert(0, str(H.REPO_ROOT / "src"))

from repro.core.adaptive import GovernorConfig  # noqa: E402
from repro.core.pipeline import ADAPTIVE_ARMS, StreamingAggregator  # noqa: E402
from repro.core.types import ExecConfig, empty_key  # noqa: E402


def make_phases(cfg: ExecConfig, chunks_per_phase: int, order: str,
                seed: int = 3):
    """Two lists of (keys, payload) chunks: a unique-heavy phase and a
    duplicate-heavy phase, in the requested order."""
    rng = np.random.default_rng(seed)
    M = cfg.memory_rows
    n = chunks_per_phase * M

    def chunked(keys):
        vals = rng.random((n, 1)).astype(np.float32)
        return [(keys[i:i + M], vals[i:i + M]) for i in range(0, n, M)]

    uniq = chunked(rng.integers(1, 2**31, size=n).astype(np.uint32))
    dup = chunked(rng.integers(1, max(2, M // 64), size=n).astype(np.uint32))
    phases = {"uniq": uniq, "dup": dup}
    names = order.split("->")
    return [(nm, phases[nm]) for nm in names]


def run_stream(cfg, phases, *, policy, backend, output_estimate,
               governor=None):
    """One full streamed aggregation; returns per-phase wall seconds,
    finalize seconds, and the result."""
    agg = StreamingAggregator(
        cfg, policy=policy, key_dtype=np.uint32, width=1, backend=backend,
        output_estimate=output_estimate, governor=governor,
    )
    phase_s = []
    for _name, chunks in phases:
        t0 = time.perf_counter()
        for k, p in chunks:
            agg.absorb(k, p)
        agg.wait()
        phase_s.append(time.perf_counter() - t0)
    t0 = time.perf_counter()
    state, stats = agg.finalize()
    jax.block_until_ready(state.keys)
    fin_s = time.perf_counter() - t0
    return phase_s, fin_s, state, stats, agg


def oracle(phases):
    keys = np.concatenate([k for _n, chunks in phases for k, _p in chunks])
    uk, counts = np.unique(keys, return_counts=True)
    return uk, counts


def check_parity(state, phases) -> bool:
    uk, counts = oracle(phases)
    got_k = np.asarray(state.keys)
    live = got_k != empty_key(got_k.dtype)
    ok = (int(live.sum()) == len(uk)
          and bool(np.array_equal(np.sort(got_k[live]), uk)))
    if ok:
        got_c = np.asarray(state.count)[live]
        order = np.argsort(got_k[live])
        ok = bool(np.array_equal(got_c[order], counts))
    return ok


def bench_scenario(order: str, cfg, chunks_per_phase, backend, smoke,
                   iters: int = 3):
    phases = make_phases(cfg, chunks_per_phase, order)
    n_rows = 2 * chunks_per_phase * cfg.memory_rows
    uk, _ = oracle(phases)
    out_est = int(2 ** int(np.ceil(np.log2(len(uk) + 1))))
    print(f"\n== scenario {order}: {n_rows} rows, {len(uk)} groups ==")

    results = {}
    contenders = [(p, p, None) for p in ADAPTIVE_ARMS]
    contenders.append(("adaptive", "adaptive", None))
    contenders.append(
        ("adaptive_wrong_start", "adaptive",
         lambda: GovernorConfig(start="rs", interval_chunks=4)))
    for label, policy, gov_fn in contenders:
        run_stream(cfg, phases, policy=policy, backend=backend,
                   output_estimate=out_est,
                   governor=gov_fn() if gov_fn else None)  # warmup: compile
        # min over repeats, per phase: at ~0.1s per phase a single sample
        # carries allocator/scheduler noise comparable to the 10% bar, so
        # every contender gets the same noise-robust estimator (a fresh
        # governor per repeat — adaptive re-fights its switches each time)
        reps = []
        for _ in range(max(1, iters)):
            reps.append(run_stream(
                cfg, phases, policy=policy, backend=backend,
                output_estimate=out_est,
                governor=gov_fn() if gov_fn else None))
        parity = all(check_parity(r[2], phases) for r in reps)
        phase_s = [min(r[0][i] for r in reps) for i in range(len(phases))]
        fin_s = min(r[1] for r in reps)
        _, _, state, stats, agg = reps[-1]
        d = stats.as_dict()
        results[label] = {
            "phase_s": [round(t, 4) for t in phase_s],
            "finalize_s": round(fin_s, 4),
            "end_to_end_s": round(sum(phase_s) + fin_s, 4),
            "iters": max(1, iters),
            "parity": parity,
            "policy_switches": d["policy_switches"],
            "readbacks_paid": d["readbacks_paid"],
            "duplicate_rate": round(d["duplicate_rate"], 4),
            "policy_events": agg.policy_events,
        }
        row = results[label]
        print(f"{label:22s} phases={row['phase_s']} fin={row['finalize_s']}"
              f" e2e={row['end_to_end_s']:.3f}s switches="
              f"{row['policy_switches']} readbacks={row['readbacks_paid']}"
              f" parity={'OK' if parity else 'MISMATCH'}")

    fixed = {p: results[p] for p in ADAPTIVE_ARMS}
    ad = results["adaptive"]
    checks = {}
    for i, (pname, _c) in enumerate(phases):
        best = min(r["phase_s"][i] for r in fixed.values())
        checks[f"phase_{i}_{pname}_within_10pct"] = (
            ad["phase_s"][i] <= 1.10 * best)
    worst_e2e = max(r["end_to_end_s"] for r in fixed.values())
    checks["beats_worst_fixed_1p5x"] = (
        worst_e2e >= 1.5 * ad["end_to_end_s"])
    checks["parity_all"] = all(r["parity"] for r in results.values())
    checks["readbacks_sublinear"] = (
        ad["readbacks_paid"] <= 2 * chunks_per_phase * 2 // 4 + 2)
    for name, ok in checks.items():
        tag = "PASS" if ok else ("WARN(smoke)" if smoke else "FAIL")
        print(f"  {tag}: {name}")
    return {"rows": n_rows, "groups": len(uk), "results": results,
            "checks": checks}


def main(argv=None):
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    H.add_common_args(p, iters=3)
    p.add_argument("--out", type=str, default=None)
    args = p.parse_args(argv)

    if args.smoke:
        cfg = ExecConfig(memory_rows=256, page_rows=32, fanin=4,
                         batch_rows=64)
        chunks_per_phase = 8
    else:
        cfg = ExecConfig(memory_rows=4096, page_rows=512, fanin=8,
                         batch_rows=1024)
        chunks_per_phase = 48
    report = {
        "bench": "adaptive",
        "cfg": {"memory_rows": cfg.memory_rows, "batch_rows": cfg.batch_rows,
                "fanin": cfg.fanin, "page_rows": cfg.page_rows},
        "chunks_per_phase": chunks_per_phase,
        "governor_interval": 4,
        "scenarios": {},
    }
    ok = True
    for order in ("uniq->dup", "dup->uniq"):
        res = bench_scenario(order, cfg, chunks_per_phase, args.backend,
                             args.smoke, iters=args.iters)
        report["scenarios"][order] = res
        ok &= all(res["checks"].values())
    H.write_json_report(report, out=args.out, smoke=args.smoke,
                        default_name="BENCH_adaptive.json")
    if not args.smoke and not ok:
        print("ACCEPTANCE FAILED")
        sys.exit(1)
    print("\nall scenarios done" + ("" if ok else " (smoke warnings)"))


if __name__ == "__main__":
    main()
