"""One benchmark per paper table/figure.

Where the paper counts rows (spill volume, run counts) we measure the
executable implementation's EXACT accounting at a scaled-down geometry
(CPU container) and validate the paper-parameter points with the analytic
cost model (validated against the paper's worked examples in
tests/test_cost_model.py).  Where the paper reports wall-clock, we time
the jitted implementations on CPU — relative ordering is the claim under
test, not TPU-microseconds.

Output format (benchmarks/run.py): ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    EMPTY, ExecConfig, cost_model as cm, count_and_count_distinct,
    f1_hash_aggregate, group_by_order_by, hash_aggregate, insort_aggregate,
    instream_aggregate, intersect_distinct, sort_then_stream_aggregate,
    sorted_groupby,
)

RNG = np.random.default_rng(0)

# scaled geometry: paper used I=6M, M=1M; we keep the same I/M/O ratios
SCALE_CFG = ExecConfig(memory_rows=20_000, page_rows=1_000, fanin=6,
                       batch_rows=5_000)
SCALE_I = 120_000  # I/M = 6, as in Fig 3


def _timeit(fn, *args, reps=3, **kw):
    fn(*args, **kw)
    t0 = time.time()
    for _ in range(reps):
        fn(*args, **kw)
    return (time.time() - t0) / reps * 1e6


def _rows(o):
    return RNG.integers(0, o, SCALE_I).astype(np.uint32)


def fig3_motivating_comparison(report):
    """Fig 3: duplicate removal, I=6·M, output sweep; three algorithms."""
    for o_frac in (0.02, 0.2, 1.0, 3.0):
        o = int(o_frac * SCALE_CFG.memory_rows)
        keys = _rows(o)
        t_sort = _timeit(sort_then_stream_aggregate, keys, None, SCALE_CFG)
        t_hash = _timeit(hash_aggregate, keys, None, SCALE_CFG,
                         output_estimate=o)
        t_insort = _timeit(insort_aggregate, keys, None, SCALE_CFG,
                           output_estimate=o)
        report(f"fig3_sort_stream_O{o}", t_sort, "")
        report(f"fig3_hash_O{o}", t_hash, "")
        report(f"fig3_insort_O{o}", t_insort,
               f"insort/hash={t_insort/t_hash:.2f}")


def fig7_12_spill_model_vs_measured(report):
    """Figs 7+12: predicted vs measured run-generation spill volume."""
    I, M = SCALE_I, SCALE_CFG.memory_rows
    for o_mult in (1.0, 1.5, 2.0, 4.0, 8.0):
        o = int(o_mult * M)
        keys = _rows(o)
        _, stats = insort_aggregate(keys, None, SCALE_CFG, output_estimate=o)
        model = cm.early_agg_run_gen(I, o, M)[0]
        report(f"fig7_spill_O{o_mult}M", 0,
               f"measured={stats.rows_spilled_run_generation};model={model:.0f}")


def fig11_inmemory_btree(report):
    """Fig 11: in-memory grouping cost vs output size (flat, like Fig 11)."""
    for o in (4, 300, 30_000):
        keys = _rows(max(o, 1))
        jk = jnp.asarray(keys)
        t = _timeit(lambda: sorted_groupby(jk).keys.block_until_ready())
        report(f"fig11_inmem_O{o}", t, "")


def fig13_merge_levels(report):
    """Fig 13 (Ex 3): wide merging caps depth at log_F(O/M) vs log_F(I/M)."""
    cfg = ExecConfig(memory_rows=1_000, page_rows=100, fanin=6,
                     batch_rows=500)
    keys = RNG.integers(0, 32_000, 180_000).astype(np.uint32)
    o = len(np.unique(keys))
    _, s_wide = insort_aggregate(keys, None, cfg, output_estimate=o)
    _, s_trad = insort_aggregate(keys, None, cfg, output_estimate=o,
                                 use_wide_merge=False)
    report("fig13_levels_wide", 0, f"levels={s_wide.merge_levels}")
    report("fig13_levels_traditional", 0, f"levels={s_trad.merge_levels}")


def fig14_wide_merge_spill(report):
    """Fig 14 (Ex 4): spill ≈ I with wide merging; > I traditionally."""
    cfg = ExecConfig(memory_rows=2_000, page_rows=200, fanin=8,
                     batch_rows=1_000)
    keys = RNG.integers(0, 40_000, 160_000).astype(np.uint32)
    o = len(np.unique(keys))
    _, s_wide = insort_aggregate(keys, None, cfg, output_estimate=o)
    _, s_trad = insort_aggregate(keys, None, cfg, output_estimate=o,
                                 use_wide_merge=False)
    report("fig14_spill_wide", 0,
           f"spill={s_wide.total_spill_rows};input={len(keys)}")
    report("fig14_spill_traditional", 0,
           f"spill={s_trad.total_spill_rows};input={len(keys)}")


def fig15_index_vs_hashtable(report):
    """Fig 15: ordered index vs hash table, in-memory (no spill)."""
    keys = _rows(5_000)
    jk = jnp.asarray(keys)
    t_tree = _timeit(lambda: sorted_groupby(jk).keys.block_until_ready())
    from repro.core.hash_agg import hash_u32

    t_hash = _timeit(
        lambda: sorted_groupby(hash_u32(jk)).keys.block_until_ready())
    report("fig15_btree", t_tree, "")
    report("fig15_hashtable", t_hash, f"ratio={t_tree/t_hash:.2f}")


def fig16_run_generation(report):
    """Fig 16: run generation via index vs priority-queue-style sort."""
    keys = _rows(200_000)  # virtually no duplicates: pure sorting work
    jk = jnp.asarray(keys)
    t_index = _timeit(lambda: sorted_groupby(jk).keys.block_until_ready())
    t_pq = _timeit(lambda: jnp.sort(jk).block_until_ready())
    report("fig16_rungen_index", t_index, "")
    report("fig16_rungen_sort", t_pq, f"overhead={t_index/t_pq:.2f}x")


def fig17_18_runs_and_spill(report):
    """Figs 17/18: runs + total spill, in-sort vs F1's pre-paper scheme."""
    for i_mult in (2, 4, 6):
        I = i_mult * SCALE_CFG.memory_rows
        keys = RNG.integers(0, 3 * SCALE_CFG.memory_rows, I).astype(np.uint32)
        o = len(np.unique(keys))
        _, s_new = insort_aggregate(keys, None, SCALE_CFG, output_estimate=o)
        _, s_f1 = f1_hash_aggregate(keys, None, SCALE_CFG)
        report(f"fig17_runs_I{i_mult}M", 0,
               f"insort={s_new.runs_generated};f1={s_f1.runs_generated}")
        report(f"fig18_spill_I{i_mult}M", 0,
               f"insort={s_new.total_spill_rows};f1={s_f1.total_spill_rows}")


def fig19_groupby_orderby(report):
    """Fig 19: matching GROUP BY + ORDER BY — in-sort needs no extra sort."""
    keys = _rows(40_000)
    _, _, extra_i = group_by_order_by(keys, None, SCALE_CFG,
                                      algorithm="insort",
                                      output_estimate=40_000)
    _, _, extra_h = group_by_order_by(keys, None, SCALE_CFG, algorithm="hash",
                                      output_estimate=40_000)
    report("fig19_extra_sort_insort", 0, f"rows={extra_i}")
    report("fig19_extra_sort_hash", 0, f"rows={extra_h}")


def fig20_count_distinct(report):
    """Fig 20: count + count-distinct — one sort vs two hash tables."""
    g = RNG.integers(0, 200, SCALE_I).astype(np.uint32)
    a = RNG.integers(0, 2_000, SCALE_I).astype(np.uint32)
    _, s_sort = count_and_count_distinct(g, a, lo_bits=12, cfg=SCALE_CFG,
                                         output_estimate=200 * 2_000)
    _, s_hash = count_and_count_distinct(g, a, lo_bits=12, cfg=SCALE_CFG,
                                         algorithm="hash",
                                         output_estimate=200 * 2_000)
    report("fig20_insort", 0, f"spill={s_sort.total_spill_rows}")
    report("fig20_hash", 0, f"spill={s_hash.total_spill_rows}")


def fig22_intersect(report):
    """Fig 22: INTERSECT DISTINCT — sorted plans spill each row once."""
    a = RNG.integers(0, 50_000, SCALE_I).astype(np.uint32)
    b = RNG.integers(25_000, 75_000, SCALE_I).astype(np.uint32)
    cfg = ExecConfig(memory_rows=40_000, page_rows=2_000, fanin=8,
                     batch_rows=10_000)
    _, s_s = intersect_distinct(a, b, cfg, algorithm="insort",
                                output_estimate=50_000)
    _, s_h = intersect_distinct(a, b, cfg, algorithm="hash",
                                output_estimate=50_000)
    report("fig22_insort", 0, f"spill={s_s.total_spill_rows}")
    report("fig22_hash", 0, f"spill={s_h.total_spill_rows}")


def fig24_revised_comparison(report):
    """Fig 23→24: the sort-vs-hash gap closes (analytic, paper params)."""
    red, early3, hash_, insort = cm.fig24_curves(points=7)
    for r, e, h, i in zip(red, early3, hash_, insort):
        report(f"fig24_red{r:.0f}", 0,
               f"sort83={e/1e6:.0f}MB;hash={h/1e6:.0f}MB;new={i/1e6:.0f}MB")


ALL = [
    fig3_motivating_comparison,
    fig7_12_spill_model_vs_measured,
    fig11_inmemory_btree,
    fig13_merge_levels,
    fig14_wide_merge_spill,
    fig15_index_vs_hashtable,
    fig16_run_generation,
    fig17_18_runs_and_spill,
    fig19_groupby_orderby,
    fig20_count_distinct,
    fig22_intersect,
    fig24_revised_comparison,
]


def fig4_join_by_grouping(report):
    """Fig 4 (§2.5): join inside the sort — spill ≤ |L|+|R|, one sort."""
    from repro.core.join import join_aggregate

    lk = RNG.integers(0, 20_000, 60_000).astype(np.uint32)
    rk = RNG.integers(10_000, 30_000, 40_000).astype(np.uint32)
    t0 = time.time()
    res, stats = join_aggregate(lk, rk, None, None, SCALE_CFG,
                                output_estimate=30_000)
    us = (time.time() - t0) * 1e6
    matched = int((np.asarray(res["join_count"]) > 0).sum())
    report("fig4_join_by_grouping", us,
           f"keys_matched={matched};spill={stats.total_spill_rows};"
           f"inputs={len(lk)+len(rk)}")


ALL.append(fig4_join_by_grouping)
