"""Microbenchmark: sort-absorb vs merge-absorb for the batched index insert.

The paper's ordered-index insert (§3.4) absorbs a sorted batch of B rows
into a sorted table of M rows.  The old engine did concat + full argsort
of all M+B rows — O((M+B)·log(M+B)) per batch; the new engine does a
linear merge (searchsorted-rank scatter on XLA, the merge-path kernel on
Pallas).  This benchmark sweeps the table/batch ratio M/B and reports
wall-clock per absorb for both strategies, plus the speedup.  The merge
engine should win clearly from M/B ≥ 4 — the regime every consumer
(early-agg run generation, wide-merge page absorb, replacement selection)
actually operates in.

Usage:  PYTHONPATH=src python benchmarks/bench_absorb.py [--m 32768]
            [--ratios 1,2,4,8,16,32] [--width 2] [--iters 30]
            [--backend xla] [--csv out.csv]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import _harness
from repro.core import sorted_ops
from repro.core.types import AggState, rows_to_state


def _sorted_state(rng, rows: int, width: int, domain: int) -> AggState:
    keys = rng.integers(0, domain, rows).astype(np.uint32)
    pay = None if width == 0 else rng.normal(size=(rows, width)).astype(np.float32)
    return sorted_ops.absorb(
        rows_to_state(jnp.asarray(keys), None if pay is None else jnp.asarray(pay))
    )


def sort_absorb(table: AggState, batch: AggState, *, backend: str = "xla") -> AggState:
    """The legacy strategy: concat + full argsort + combine."""
    cat = jax.tree.map(lambda x, y: jnp.concatenate([x, y], axis=0), table, batch)
    return sorted_ops.absorb(cat, backend=backend)


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--m", type=int, default=1 << 15, help="table rows M")
    p.add_argument("--ratios", type=str, default="1,2,4,8,16,32",
                   help="comma-separated M/B ratios to sweep")
    p.add_argument("--width", type=int, default=2, help="payload columns V")
    p.add_argument("--csv", type=str, default=None, help="also write CSV here")
    _harness.add_common_args(p, iters=30)
    args = p.parse_args()
    if args.smoke:
        args.m, args.iters, args.ratios = 1 << 10, 3, "1,4"

    rng = np.random.default_rng(0)
    ratios = [int(r) for r in args.ratios.split(",")]
    be = args.backend

    # merge-absorb in the configuration every index consumer uses: both
    # sides carry the OrderedIndex sorted/duplicate-free invariant, so the
    # absorb is a linear merge + pair-combine.  sort-absorb is the legacy
    # engine (concat + full argsort + segmented combine), which cannot
    # exploit the invariant it just destroyed.
    sort_jit = jax.jit(lambda t, b: sort_absorb(t, b, backend=be))
    merge_jit = jax.jit(
        lambda t, b: sorted_ops.merge_absorb(t, b, backend=be, assume_unique=True)
    )

    header = f"{'M':>8} {'B':>8} {'M/B':>5} {'sort-absorb':>13} {'merge-absorb':>13} {'speedup':>8}"
    print(f"backend={be}  width={args.width}  iters={args.iters}")
    print(header)
    print("-" * len(header))
    rows = []
    wins_at_4 = True
    for ratio in ratios:
        m = args.m
        b = max(1, m // ratio)
        table = _sorted_state(rng, m, args.width, domain=1 << 28)
        batch = _sorted_state(rng, b, args.width, domain=1 << 28)
        t_sort = _harness.time_fn(sort_jit, table, batch, iters=args.iters)
        t_merge = _harness.time_fn(merge_jit, table, batch, iters=args.iters)
        speedup = t_sort / t_merge
        rows.append((m, b, ratio, t_sort, t_merge, speedup))
        if ratio >= 4 and speedup <= 1.0:
            wins_at_4 = False
        print(f"{m:>8} {b:>8} {ratio:>5} {t_sort * 1e3:>11.3f}ms "
              f"{t_merge * 1e3:>11.3f}ms {speedup:>7.2f}x")

    _harness.write_csv(
        args.csv,
        ["m", "b", "ratio", "sort_absorb_s", "merge_absorb_s", "speedup"],
        rows,
    )

    if _harness.interpret_note(be):
        return 0
    if args.smoke:  # sanity run: sizes too small for a meaningful race
        print("smoke OK (perf win-check skipped at smoke sizes)")
        return 0
    if not wins_at_4:
        print("WARNING: merge-absorb did not beat sort-absorb at some M/B >= 4")
        return 1
    print("OK: merge-absorb beats sort-absorb at every M/B >= 4")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
