"""Streamed super-batch pipeline benchmark: double-buffered host→device
staging vs the fully device-resident one-shot program.

Three measurements back the streaming design's claims:

1. **Parity** — at an N that fits on device, the streamed pipeline
   (input cut into super-batches, each ``device_put`` while the previous
   one is being absorbed) should be within ~10% of the one-shot resident
   program: the chunked scan does the same work, and the double
   buffering hides the transfers.
2. **Beyond-resident scale** — inputs 4× / 8× the super-batch footprint
   stream through a generator (no full host materialization needed) with
   the device carrying only the engine state + ≤ 2 staged super-batches;
   the report records the input:super-batch byte ratio and the
   allocator's peak-memory stats where the platform exposes them.
3. **Overlap** — the same stream absorbed with staging serialized
   (block after every transfer and every absorb) vs double-buffered;
   the ratio is the measured dispatch/transfer overlap win.

Writes ``BENCH_stream.json`` (repo root) unless ``--smoke``.

Usage:  PYTHONPATH=src python benchmarks/bench_stream.py
            [--m 4096] [--sb-batches 8] [--ratios 4,8] [--dup 8]
            [--iters 3] [--backend xla] [--out FILE]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

import _harness
from repro.core import pipeline
from repro.core.types import ExecConfig


def _gen_chunks(rng_seed, n_chunks, sb, domain, width):
    """Producer-side stream: each super-batch is generated on demand —
    the full input never exists as one host array."""
    for i in range(n_chunks):
        rng = np.random.default_rng((rng_seed, i))
        keys = rng.integers(0, domain, sb).astype(np.uint32)
        pay = rng.normal(size=(sb, width)).astype(np.float32)
        yield keys, pay


def _stream(chunks, cfg, *, est, backend, overlapped=True):
    agg = pipeline.StreamingAggregator(
        cfg, policy="rs", key_dtype=np.uint32, width=1,
        backend=backend, output_estimate=est,
    )
    staged = None
    for keys, pay in chunks:
        nxt = agg.stage(keys, pay)
        if overlapped:
            if staged is not None:
                agg.absorb_staged(staged)
            staged = nxt
        else:  # serialize: wait out the transfer, then wait out the absorb
            jax.block_until_ready((nxt.bk, nxt.bp))
            agg.absorb_staged(nxt)
            jax.block_until_ready(agg._es)
    if overlapped:
        agg.absorb_staged(staged)
    return agg.finalize_device()


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--m", type=int, default=1 << 12, help="memory rows M")
    p.add_argument("--sb-batches", type=int, default=8,
                   help="super-batch size as a multiple of batch_rows")
    p.add_argument("--ratios", type=str, default="4,8",
                   help="input sizes as multiples of the super-batch")
    p.add_argument("--dup", type=int, default=8,
                   help="duplicate factor (mean rows per key)")
    p.add_argument("--out", type=str, default=None,
                   help="JSON output path (default: repo-root "
                        "BENCH_stream.json; suppressed under --smoke)")
    _harness.add_common_args(p, iters=3)
    args = p.parse_args()
    if args.smoke:
        args.m, args.iters, args.ratios = 1 << 8, 1, "4"

    M = args.m
    B = max(16, M // 8)
    sb = args.sb_batches * 8 * B  # super-batch rows (multiple of B and M)
    cfg = ExecConfig(memory_rows=M, page_rows=max(16, M // 16), fanin=4,
                     batch_rows=B)
    backend = args.backend
    rng = np.random.default_rng(0)

    # -- 1) parity: streamed vs resident at an N that fits ----------------
    n_fit = 4 * sb
    domain = max(1, n_fit // args.dup)
    keys = rng.integers(0, domain, n_fit).astype(np.uint32)
    pay = rng.normal(size=(n_fit, 1)).astype(np.float32)
    est = len(np.unique(keys))

    def resident():
        st, _ = pipeline.insort_aggregate_device(
            keys, pay, cfg, policy="rs", backend=backend,
            output_estimate=est,
        )
        return st.keys

    def streamed(overlapped=True):
        st, _ = _stream(
            ((keys[s:s + sb], pay[s:s + sb]) for s in range(0, n_fit, sb)),
            cfg, est=est, backend=backend, overlapped=overlapped,
        )
        return st.keys

    # min-of-iters: on a shared-core host (CPU "device") interference only
    # adds time, and the parity claim is about the pipeline, not the noise
    t_res = _harness.time_fn(resident, iters=args.iters, block_each=True,
                             reduce="min")
    t_str = _harness.time_fn(streamed, iters=args.iters, block_each=True,
                             reduce="min")
    t_str_ser = _harness.time_fn(lambda: streamed(False), iters=args.iters,
                                 block_each=True, reduce="min")
    best = min(t_str, t_str_ser)
    parity = {
        "n": n_fit, "super_batch_rows": sb, "n_super_batches": n_fit // sb,
        "resident_s": t_res, "streamed_s": t_str,
        "streamed_serialized_s": t_str_ser,
        "streamed_over_resident": best / t_res,
    }
    print(f"parity    N={n_fit:>9,}  resident {t_res * 1e3:8.1f} ms   "
          f"streamed {t_str * 1e3:8.1f} ms (serialized "
          f"{t_str_ser * 1e3:8.1f} ms)   ratio "
          f"{parity['streamed_over_resident']:.3f}")

    # -- 2) inputs ≥ 4x the super-batch footprint -------------------------
    row_bytes = 4 + 4  # uint32 key + one float32 payload column
    large = []
    for ratio in (int(r) for r in args.ratios.split(",")):
        n = ratio * sb
        dom = max(1, n // args.dup)

        def big():
            st, _ = _stream(
                _gen_chunks(1, ratio, sb, dom, 1), cfg, est=min(dom, n),
                backend=backend,
            )
            return st.keys

        t = _harness.time_fn(big, iters=args.iters, block_each=True)
        st, dstats = _stream(_gen_chunks(1, ratio, sb, dom, 1), cfg,
                             est=min(dom, n), backend=backend)
        stats = dstats.finalize()
        row = {
            "n": n, "super_batch_rows": sb,
            "input_over_super_batch": ratio,
            "input_bytes": n * row_bytes,
            "super_batch_bytes": sb * row_bytes,
            "wall_s": t, "rows_per_s": n / t,
            "groups": int(st.occupancy()),
            "spill_rows": stats.total_spill_rows,
            "runs": stats.runs_generated,
        }
        large.append(row)
        print(f"stream    N={n:>9,}  ({ratio}x super-batch)   "
              f"{t * 1e3:8.1f} ms   {row['rows_per_s'] / 1e3:8.1f} Krows/s   "
              f"{row['groups']:,} groups")

    # -- 3) overlap: double-buffered vs serialized staging ----------------
    n_ov = 4 * sb
    dom = max(1, n_ov // args.dup)

    def overlapped():
        st, _ = _stream(_gen_chunks(2, 4, sb, dom, 1), cfg,
                        est=min(dom, n_ov), backend=backend)
        return st.keys

    def serialized():
        st, _ = _stream(_gen_chunks(2, 4, sb, dom, 1), cfg,
                        est=min(dom, n_ov), backend=backend,
                        overlapped=False)
        return st.keys

    t_ov = _harness.time_fn(overlapped, iters=args.iters, block_each=True,
                            reduce="min")
    t_ser = _harness.time_fn(serialized, iters=args.iters, block_each=True,
                             reduce="min")
    overlap = {
        "n": n_ov, "overlapped_s": t_ov, "serialized_s": t_ser,
        "overlap_speedup": t_ser / t_ov,
    }
    if jax.default_backend() == "cpu":
        overlap["note"] = (
            "cpu backend: staging and 'device' compute share the same "
            "cores, so double buffering adds no parallelism here — the "
            "overlap win needs an accelerator with an async copy engine"
        )
    print(f"overlap   N={n_ov:>9,}  serialized {t_ser * 1e3:8.1f} ms   "
          f"double-buffered {t_ov * 1e3:8.1f} ms   "
          f"speedup {overlap['overlap_speedup']:.2f}x")

    report = {
        "bench": "stream_double_buffer",
        "backend": backend,
        "config": {"memory_rows": M, "batch_rows": B,
                   "page_rows": cfg.page_rows, "super_batch_rows": sb,
                   "dup": args.dup, "iters": args.iters},
        "parity": parity,
        "large_input": large,
        "overlap": overlap,
    }
    _harness.write_json_report(report, out=args.out, smoke=args.smoke,
                               default_name="BENCH_stream.json")
    if parity["streamed_over_resident"] <= 1.10:
        print("streamed is within 10% of the resident pipeline")
    if all(r["input_over_super_batch"] >= 4 for r in large):
        print("aggregated inputs >= 4x the resident super-batch footprint")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
