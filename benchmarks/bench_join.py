"""Order-consuming merge join vs the re-sort baseline.

The paper's "interesting orderings" payoff: aggregation output arrives
key-sorted, so a downstream join can consume that order directly — a
rank-alignment probe + compaction gather, no sort anywhere.  An engine
that cannot carry the order property must (re)sort both inputs before it
can merge-join them; that is the baseline raced here.  Both contenders
run the IDENTICAL probe+gather join — the baseline just pays the two
argsort+gathers the order-preserving pipeline proves it can skip — so
the gap is exactly the cost of re-establishing an order the upstream
operator already paid for.

The JSON report additionally embeds the calibrated cost-model surface
for the composed plan (what ``AggResult.merge_join`` records in
``plan["cost_model"]``): the order-consuming side shows a ZERO sort
term, the baseline a sort over every input row.

Usage:  PYTHONPATH=src python benchmarks/bench_join.py [--sizes 4096,16384,65536]
            [--iters 20] [--backend xla] [--out BENCH_join.json] [--smoke]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import _harness
from repro.core import cost_model
from repro.core import merge_join as mj
from repro.core.types import AggState, empty_key


def _sorted_state(rng, capacity: int, occupancy: float, domain: int) -> AggState:
    n = int(capacity * occupancy)
    uniq = np.sort(rng.choice(domain, n, replace=False)).astype(np.uint32)
    keys = np.full(capacity, int(empty_key(np.dtype(np.uint32))), np.uint32)
    keys[:n] = uniq
    count = np.zeros(capacity, np.int32)
    count[:n] = rng.integers(1, 100, n)
    s = np.zeros((capacity, 2), np.float32)
    s[:n] = rng.normal(size=(n, 2))
    inf = np.float32(np.inf)
    mn = np.full((capacity, 2), inf, np.float32)
    mx = np.full((capacity, 2), -inf, np.float32)
    mn[:n] = s[:n] - 1.0
    mx[:n] = s[:n] + 1.0
    return AggState(keys=jnp.asarray(keys), count=jnp.asarray(count),
                    sum=jnp.asarray(s), min=jnp.asarray(mn),
                    max=jnp.asarray(mx))


def _resort(st: AggState) -> AggState:
    """What an order-oblivious engine must do before it can merge-join:
    (re)sort the relation by key.  One argsort + full-state gather."""
    order = jnp.argsort(st.keys)
    return AggState(
        keys=jnp.take(st.keys, order),
        count=jnp.take(st.count, order),
        sum=jnp.take(st.sum, order, axis=0),
        min=jnp.take(st.min, order, axis=0),
        max=jnp.take(st.max, order, axis=0),
    )


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--sizes", type=str, default="4096,16384,65536",
                   help="comma-separated per-side group counts (capacities)")
    p.add_argument("--out", type=str, default=None,
                   help="JSON report path (default: repo-root BENCH_join.json)")
    _harness.add_common_args(p, iters=20)
    args = p.parse_args()
    if args.smoke:
        args.sizes, args.iters = "1024", 3

    rng = np.random.default_rng(0)
    be = args.backend
    sizes = [int(s) for s in args.sizes.split(",")]

    ordered_jit = jax.jit(
        lambda a, b: mj.merge_join(a, b, how="inner", backend=be))
    resort_jit = jax.jit(
        lambda a, b: mj.merge_join(_resort(a), _resort(b), how="inner",
                                   backend=be))

    header = (f"{'groups/side':>12} {'matched':>8} {'order-consuming':>16} "
              f"{'re-sort join':>13} {'speedup':>8}")
    print(f"backend={be}  iters={args.iters}")
    print(header)
    print("-" * len(header))
    rows, wins = [], True
    for m in sizes:
        # ~75% occupancy, ~50% key overlap between the two sides
        a = _sorted_state(rng, m, 0.75, domain=2 * m)
        b = _sorted_state(rng, m, 0.75, domain=2 * m)
        matched = int(np.intersect1d(np.asarray(a.keys),
                                     np.asarray(b.keys)).size) - 1
        t_ord = _harness.time_fn(ordered_jit, a, b, iters=args.iters)
        t_re = _harness.time_fn(resort_jit, a, b, iters=args.iters)
        speedup = t_re / t_ord
        wins &= speedup > 1.0
        rows.append({"groups_per_side": m, "matched_keys": matched,
                     "order_consuming_s": t_ord, "resort_join_s": t_re,
                     "speedup": speedup})
        print(f"{m:>12} {matched:>8} {t_ord * 1e3:>14.3f}ms "
              f"{t_re * 1e3:>11.3f}ms {speedup:>7.2f}x")

    # the composed plan's calibrated surface: zero sort term on the join
    # side (exactly what AggResult.merge_join records in plan["cost_model"])
    m = sizes[-1]
    surface = cost_model.join_cost_surface(m, m, inputs_sorted=True)
    baseline = cost_model.join_cost_surface(m, m, inputs_sorted=False)
    assert surface["sort_rows"] == 0.0
    print(f"cost model @ {m}/side: join sort_rows={surface['sort_rows']:.0f} "
          f"(re-sort baseline {baseline['sort_rows']:.0f}), "
          f"sort_ns_avoided={surface['sort_ns_avoided']:.0f}")

    _harness.write_json_report(
        {
            "benchmark": "merge_join_order_consuming_vs_resort",
            "backend": be,
            "iters": args.iters,
            "rows": rows,
            "cost_model": {"join_side": surface, "resort_baseline": baseline},
        },
        out=args.out, smoke=args.smoke, default_name="BENCH_join.json",
    )

    if _harness.interpret_note(be):
        return 0
    if args.smoke:
        print("smoke OK (perf win-check skipped at smoke sizes)")
        return 0
    if not wins:
        print("WARNING: order-consuming join did not beat the re-sort "
              "baseline at some size")
        return 1
    print("OK: order-consuming merge join beats the re-sort baseline at "
          "every size")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
