"""Aggregation-service benchmark: sustained ingest under periodic
merge-on-read snapshot queries.

Three measurements back the service layer's claims:

1. **Sustained ingest** — rows/sec through the double-buffered
   ``ingest`` path of one long-lived session, measured over the whole
   serving loop (snapshot time excluded), overlap on vs off.
2. **Snapshot latency** — p50/p99 of the blocking merge-on-read query
   against the live engine at a steady snapshot cadence (compile
   buckets pre-warmed by a twin session, so this is the latency a
   serving deployment sees, not jit compile time).
3. **Snapshot cost on ingest** — the same ingest with and without
   interleaved snapshots; the ratio is what answering queries
   mid-flight costs the ingest path.

Writes ``BENCH_service.json`` (repo root) unless ``--smoke``.

Usage:  PYTHONPATH=src python benchmarks/bench_service.py
            [--chunks 120] [--chunk-rows 8192] [--snapshot-every 20]
            [--policy rs] [--iters 3] [--backend auto] [--out FILE]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import _harness
from repro.launch import serve_agg


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--chunks", type=int, default=120)
    p.add_argument("--chunk-rows", type=int, default=8192)
    p.add_argument("--snapshot-every", type=int, default=20)
    p.add_argument("--policy", default="rs",
                   choices=("traditional", "inrun_dedup", "early_agg", "rs"))
    p.add_argument("--memory-rows", type=int, default=1 << 12)
    p.add_argument("--ttl", type=int, default=2)
    p.add_argument("--out", type=str, default=None,
                   help="JSON output path (default: repo-root "
                        "BENCH_service.json; suppressed under --smoke)")
    _harness.add_common_args(p, iters=3, backend="auto")
    args = p.parse_args()
    if args.smoke:
        args.chunks, args.chunk_rows, args.snapshot_every = 12, 512, 4
        args.memory_rows, args.iters = 1 << 8, 1

    kw = dict(chunks=args.chunks, chunk_rows=args.chunk_rows,
              policy=args.policy, backend=args.backend,
              memory_rows=args.memory_rows,
              batch_rows=max(64, args.memory_rows // 8), quiet=True)

    def run(*, snapshot_every, overlap=True, ttl=0, warmup=True):
        return serve_agg.serve(snapshot_every=snapshot_every,
                               overlap=overlap, ttl=ttl, warmup=warmup, **kw)

    # run 1 warms every compile bucket; later runs reuse the jit caches
    runs = [run(snapshot_every=args.snapshot_every)
            for _ in range(max(1, args.iters))]
    best = max(runs, key=lambda r: r["ingest_rows_per_s"])
    service = {
        "rows_ingested": best["rows_ingested"],
        "ingest_rows_per_s": best["ingest_rows_per_s"],
        "snapshots": best["snapshots"],
        "snapshot_p50_ms": float(np.median([r["snapshot_p50_ms"]
                                            for r in runs])),
        "snapshot_p99_ms": float(max(r["snapshot_p99_ms"] for r in runs)),
        "final_groups": best["final_groups"],
        "duplicate_rate": best["duplicate_rate"],
    }
    print(f"service   {service['rows_ingested']:>9,} rows   "
          f"{service['ingest_rows_per_s'] / 1e6:6.2f} M rows/s   "
          f"snapshot p50 {service['snapshot_p50_ms']:7.1f} ms  "
          f"p99 {service['snapshot_p99_ms']:7.1f} ms")

    # -- snapshot cost on ingest: same load, queries off ------------------
    t0 = time.perf_counter()
    quiet_run = run(snapshot_every=0, warmup=False)  # caches already warm
    no_query_wall = time.perf_counter() - t0
    no_query = {
        "ingest_rows_per_s": quiet_run["ingest_rows_per_s"],
        "wall_s": no_query_wall,
        "ingest_slowdown_with_snapshots":
            quiet_run["ingest_rows_per_s"]
            / max(service["ingest_rows_per_s"], 1e-9),
    }
    print(f"no-query  ingest {quiet_run['ingest_rows_per_s'] / 1e6:6.2f} "
          f"M rows/s   slowdown with snapshots "
          f"{no_query['ingest_slowdown_with_snapshots']:.3f}x")

    # -- overlap on/off ---------------------------------------------------
    ser_run = run(snapshot_every=args.snapshot_every, overlap=False,
                  warmup=False)
    overlap = {
        "overlapped_rows_per_s": service["ingest_rows_per_s"],
        "serialized_rows_per_s": ser_run["ingest_rows_per_s"],
        "overlap_speedup": service["ingest_rows_per_s"]
        / max(ser_run["ingest_rows_per_s"], 1e-9),
    }
    print(f"overlap   double-buffered "
          f"{overlap['overlapped_rows_per_s'] / 1e6:6.2f} M rows/s   "
          f"serialized {overlap['serialized_rows_per_s'] / 1e6:6.2f}   "
          f"speedup {overlap['overlap_speedup']:.2f}x")

    # -- TTL / sessionization --------------------------------------------
    ttl_run = run(snapshot_every=args.snapshot_every, ttl=args.ttl)
    ttl = {
        "ttl_periods": args.ttl,
        "rows_retired": ttl_run["rows_retired"],
        "final_groups": ttl_run["final_groups"],
        "snapshot_p50_ms": ttl_run["snapshot_p50_ms"],
        "snapshot_p99_ms": ttl_run["snapshot_p99_ms"],
    }
    print(f"ttl       retired {ttl['rows_retired']:,} rows   "
          f"groups {ttl['final_groups']:,}   snapshot p50 "
          f"{ttl['snapshot_p50_ms']:.1f} ms")

    report = {
        "bench": "aggregation_service",
        "backend": args.backend,
        "config": {"chunks": args.chunks, "chunk_rows": args.chunk_rows,
                   "snapshot_every": args.snapshot_every,
                   "policy": args.policy, "memory_rows": args.memory_rows,
                   "iters": args.iters},
        "service": service,
        "no_query": no_query,
        "overlap": overlap,
        "ttl": ttl,
    }
    _harness.write_json_report(report, out=args.out, smoke=args.smoke,
                               default_name="BENCH_service.json")
    assert service["snapshots"] > 0 and service["final_groups"] > 0
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
