"""Benchmark harness: one function per paper table/figure plus framework
benches (kernels, MoE dispatch, data-pipeline dedup).

Prints ``name,us_per_call,derived`` CSV (one line per measurement).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,...]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def report_factory(rows):
    def report(name, us, derived=""):
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}")

    return report


def framework_kernels(report):
    """Kernel microbenches (interpret mode: correctness-path timing only;
    the derived column carries the structural numbers that transfer)."""
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.grouped_matmul import grouped_matmul

    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.integers(0, 1 << 30, 4096).astype(np.uint32))
    t0 = time.time()
    ops.sort_u32(k).block_until_ready()
    report("kernel_bitonic_sort_4096", (time.time() - t0) * 1e6,
           "interpret-mode; NlogN^2 compare-exchange via lane rolls")
    e, c, d, f = 8, 128, 256, 256
    x = jnp.asarray(rng.normal(size=(e * c, d)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32))
    t0 = time.time()
    grouped_matmul(x, w, capacity=c).block_until_ready()
    flops = 2 * e * c * d * f
    report("kernel_grouped_matmul", (time.time() - t0) * 1e6,
           f"flops={flops};mxu_tiles=128x128")


def framework_moe_dispatch(report):
    """Sorted vs dense dispatch on a smoke MoE block (CPU wall time)."""
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import model as M, moe as MOE

    cfg = get_config("qwen3-moe-30b-a3b", smoke=True)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    moe_p = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 256, cfg.d_model),
                          jnp.float32)
    for mode in ("dense", "sorted"):
        fn = jax.jit(lambda p, xx, m=mode: MOE.moe_block(p, cfg, xx,
                                                         dispatch=m)[0])
        fn(moe_p, x).block_until_ready()
        t0 = time.time()
        for _ in range(10):
            fn(moe_p, x).block_until_ready()
        report(f"moe_dispatch_{mode}", (time.time() - t0) / 10 * 1e6,
               f"E={cfg.moe.num_experts};T={8*256};k={cfg.moe.top_k}")


def framework_data_dedup(report):
    """Data-pipeline dedup (the paper's web-log workload, corpus form)."""
    from repro.data import SyntheticCorpus, dedup_examples
    from repro.core import ExecConfig

    corpus = SyntheticCorpus(vocab=1000, n_docs=2000, dup_rate=0.4)
    docs = corpus.documents()
    t0 = time.time()
    uniq, stats = dedup_examples(docs, ExecConfig(memory_rows=512,
                                                  page_rows=64, fanin=8,
                                                  batch_rows=256))
    report("data_dedup_2000docs", (time.time() - t0) * 1e6,
           f"unique={len(uniq)};spill={stats.total_spill_rows}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import paper_figures

    rows = []
    report = report_factory(rows)
    benches = list(paper_figures.ALL) + [
        framework_kernels, framework_moe_dispatch, framework_data_dedup,
    ]
    only = args.only.split(",") if args.only else None
    print("name,us_per_call,derived")
    for bench in benches:
        if only and not any(o in bench.__name__ for o in only):
            continue
        try:
            bench(report)
        except Exception as e:  # pragma: no cover
            print(f"{bench.__name__},ERROR,{type(e).__name__}: {e}",
                  file=sys.stderr)
            raise
    print(f"# {len(rows)} measurements", file=sys.stderr)


if __name__ == "__main__":
    main()
