"""Shared benchmark harness: timing loop, block-until-ready discipline,
JSON/CSV report writing, and ``--smoke`` plumbing.

Every benchmark in this directory follows the same protocol — warm up
(compile + caches), time a loop, print a table, optionally persist a
machine-readable report, and degrade to a tiny CI sanity run under
``--smoke``.  That boilerplate used to be copy-pasted per script; it
lives here now so a fix (e.g. to the block-until-ready discipline)
lands everywhere at once.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def add_common_args(p: argparse.ArgumentParser, *, iters: int,
                    backend: str = "xla") -> argparse.ArgumentParser:
    """The flags every benchmark shares: --iters, --backend, --smoke."""
    p.add_argument("--iters", type=int, default=iters)
    p.add_argument("--backend", type=str, default=backend,
                   choices=("xla", "pallas", "auto"))
    p.add_argument("--smoke", action="store_true",
                   help="tiny sizes / few iters — CI sanity run, not a "
                        "measurement; JSON reports are suppressed unless "
                        "an explicit output path is given")
    return p


def time_fn(fn, *args, iters: int, block_each: bool = False,
            reduce: str = "mean") -> float:
    """Seconds per call of ``fn(*args)`` over ``iters`` timed calls,
    after one untimed warmup call (compile + caches).

    ``block_each=True`` blocks on every call's result (end-to-end latency
    per call — use when the loop body's dispatch overlap would hide host
    orchestration costs being measured); the default blocks once after
    the loop (amortized device throughput).

    ``reduce`` picks the estimator: ``"mean"`` over the timed calls, or
    ``"min"`` (fastest call — robust when other processes contend for
    the cores, since interference only ever ADDS time).
    """
    out = fn(*args)  # warmup: compile + caches
    jax.block_until_ready(out)
    if reduce == "min" and block_each:
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            times.append(time.perf_counter() - t0)
        return min(times)
    if reduce != "mean":
        raise ValueError("reduce='min' requires block_each=True")
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        if block_each:
            jax.block_until_ready(out)
    if not block_each:
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def device_memory_stats() -> dict:
    """Peak / in-use device memory for report footprint tracking.

    Backed by ``jax.local_devices()[0].memory_stats()`` where the runtime
    exposes it (GPU/TPU); platforms without allocator stats (CPU) report
    ``{"available": False, "note": "n/a"}`` so BENCH_*.json trajectories
    always carry the field."""
    try:
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        stats = None
    if not stats:
        return {"available": False, "note": "n/a"}
    out = {"available": True}
    for k in ("bytes_in_use", "peak_bytes_in_use", "bytes_limit",
              "largest_alloc_size"):
        if k in stats:
            out[k] = int(stats[k])
    return out


def write_json_report(report: dict, *, out: str | None, smoke: bool,
                      default_name: str) -> str | None:
    """Persist ``report`` as JSON.  Default path is the repo root (the
    committed ``BENCH_*.json`` convention); ``--smoke`` runs write
    nothing unless the caller passed an explicit path.  Every report
    carries the device kind and its peak-memory stats (footprint
    trajectories, not just wall-clock)."""
    if out is None and not smoke:
        out = str(REPO_ROOT / default_name)
    if out:
        report = dict(report, jax_device=jax.default_backend(),
                      device_memory=device_memory_stats())
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {out}")
    return out


def write_csv(path: str | None, header: list[str], rows: list[tuple]) -> None:
    if not path:
        return
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")


def interpret_note(backend: str) -> bool:
    """Print the standard caveat when Pallas ran in interpret mode (the
    timings are emulator overhead, not kernel performance).  Returns
    whether the caveat applies — perf win-checks should be skipped."""
    from repro.core import dispatch

    if backend == "pallas" and dispatch.should_interpret():
        print("note: pallas ran in interpret mode (no TPU) — timings are "
              "emulator overhead, not kernel performance")
        return True
    return False
