"""Mesh-sharded pipeline benchmark: per-world wall time and shuffle volume.

Runs the fused external-aggregation program (run generation → §4.3
pre-merge → wide merge → key-range all_to_all → per-owner merge) over
meshes of increasing world size and reports, per world:

* wall-clock per aggregate (the whole mesh runs ONE compiled program);
* **rows_shuffled vs rows_input** — valid rows that crossed the
  all_to_all.  Each shard aggregates its slice *before* the exchange
  (the paper's "aggregate early and locally"), so on duplicate-heavy
  workloads the wire carries only unique-per-shard rows: the shuffle
  reduction the distributed-aggregation studies in PAPERS.md measure;
* the capacity-bounded exchange accounting — the derived per-peer
  ``quota``, the fullest observed send segment (``max_fill``), their
  ratio ``fill_frac``, and the analytic per-shard exchange footprint.

A Zipf skew sweep (``--zipf-sweep``, default s ∈ {0, 0.8, 1.2}) then
stresses the sampled cuts at the LARGEST world: heavier skew
concentrates keys, so ``fill_frac`` rises toward the headroom bound and
``exchange_retries`` counts how often the quota ladder had to step.

Off-TPU this forces fake host devices (the test-suite trick), so wall
times are thread-level parallelism at best — the shuffle accounting is
the portable signal.  Writes ``BENCH_shard.json`` unless ``--smoke``.

Usage:  PYTHONPATH=src python benchmarks/bench_shard.py
            [--n 262144] [--m 4096] [--dup 16] [--worlds 1,2,8]
            [--policy rs] [--iters 3] [--backend xla] [--out FILE]
            [--zipf-sweep 0,0.8,1.2]
"""
from __future__ import annotations

import argparse
import os


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--n", type=int, default=1 << 18, help="total input rows")
    p.add_argument("--m", type=int, default=1 << 12, help="memory rows M")
    p.add_argument("--dup", type=int, default=16,
                   help="duplicate factor (mean rows per key)")
    p.add_argument("--worlds", type=str, default="1,2,8",
                   help="comma-separated mesh sizes to sweep")
    p.add_argument("--policy", type=str, default="rs")
    p.add_argument("--width", type=int, default=1, help="payload columns V")
    p.add_argument("--out", type=str, default=None,
                   help="JSON output path (default: repo-root "
                        "BENCH_shard.json; suppressed under --smoke)")
    # can't use _harness.add_common_args before the env setup below —
    # importing the harness imports jax; keep the same flags by hand
    p.add_argument("--iters", type=int, default=3)
    p.add_argument("--backend", type=str, default="xla",
                   choices=("xla", "pallas", "auto"))
    p.add_argument("--zipf-sweep", type=str, default="0,0.8,1.2",
                   help="comma-separated Zipf skew exponents swept at the "
                        "largest world (empty string disables)")
    p.add_argument("--smoke", action="store_true",
                   help="tiny sizes / few iters — CI sanity run, not a "
                        "measurement; writes no JSON unless --out is given")
    args = p.parse_args()
    if args.smoke:
        args.n, args.m, args.iters, args.worlds = 1 << 12, 1 << 8, 1, "1,2"
    worlds = [int(w) for w in args.worlds.split(",")]
    zipf_ss = [float(s) for s in args.zipf_sweep.split(",") if s]

    # Fake host devices MUST be configured before jax initializes — hence
    # no module-level jax/_harness import in this one benchmark.  A
    # pre-existing smaller device-count flag is raised to what the sweep
    # needs (larger counts are kept).
    import re

    need = max(worlds)
    flags = os.environ.get("XLA_FLAGS", "")
    m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
    if m is None:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={need}".strip()
        )
    elif int(m.group(1)) < need:
        os.environ["XLA_FLAGS"] = flags.replace(
            m.group(0), f"--xla_force_host_platform_device_count={need}"
        )

    import jax
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    import _harness
    from repro.core import pipeline
    from repro.core.types import ExecConfig
    from repro.distributed import groupby as gb

    if len(jax.devices()) < need:
        # unreachable unless jax was initialized before main(); a skip,
        # not a failure — CI selectors run under `set -e`
        print(f"SKIP: need {need} devices, have {len(jax.devices())} "
              "(jax initialized before the device-count flag was set)")
        return 0

    n, M = args.n, args.m
    cfg = ExecConfig(memory_rows=M, page_rows=max(16, M // 16), fanin=4,
                     batch_rows=max(16, M // 8))
    rng = np.random.default_rng(0)
    domain = max(1, n // args.dup)
    keys = rng.integers(0, domain, n).astype(np.uint32)
    pay = (rng.normal(size=(n, args.width)).astype(np.float32)
           if args.width else None)
    est = len(np.unique(keys))

    header = (f"{'world':>6} {'per-call':>11} {'rows_in':>9} "
              f"{'rows_shuffled':>14} {'shuffle/in':>11} {'spill':>9}")
    print(f"backend={args.backend}  policy={args.policy}  N={n}  M={M}  "
          f"dup={args.dup}  iters={args.iters}{'  [smoke]' if args.smoke else ''}")
    print(header)
    print("-" * len(header))

    results = []
    for world in worlds:
        mesh = jax.make_mesh((world,), ("shard",))
        dk = jax.device_put(keys, NamedSharding(mesh, P("shard")))
        dp = (None if pay is None else
              jax.device_put(pay, NamedSharding(mesh, P("shard", None))))

        def run():
            st, dstats = pipeline.aggregate_device(
                dk, dp, cfg, policy=args.policy, backend=args.backend,
                output_estimate=est, mesh=mesh,
            )
            return st.keys, dstats

        t = _harness.time_fn(run, iters=args.iters, block_each=True)
        _, dstats = run()
        stats = dstats.finalize()
        ratio = stats.rows_exchanged / n
        quota = stats.exchange_quota
        results.append({
            "world": world, "seconds": t, "rows_input": n,
            "rows_shuffled": stats.rows_exchanged, "shuffle_ratio": ratio,
            "total_spill_rows": stats.total_spill_rows,
            "runs_generated": stats.runs_generated,
            "exchange_quota": quota,
            "exchange_max_fill": stats.exchange_max_fill,
            "fill_frac": round(stats.exchange_max_fill / quota, 4)
            if quota else 0.0,
            "exchange_footprint_rows": gb.exchange_footprint_rows(world, quota)
            if quota else 0,
        })
        print(f"{world:>6} {t * 1e3:>9.1f}ms {n:>9} "
              f"{stats.rows_exchanged:>14} {ratio:>10.3f} "
              f"{stats.total_spill_rows:>9}")

    # ---- Zipf skew sweep at the largest world: how close the sampled
    # cuts drive each send segment to the capacity-derived quota ----
    zipf_sweep = []
    if zipf_ss and max(worlds) > 1:
        world = max(worlds)
        mesh = jax.make_mesh((world,), ("shard",))
        ranks = np.arange(1, domain + 1, dtype=np.float64)
        hdr = (f"{'zipf s':>7} {'per-call':>11} {'rows_shuffled':>14} "
               f"{'quota':>7} {'max_fill':>9} {'fill':>6} {'retries':>8}")
        print(f"\nZipf skew sweep at world={world}")
        print(hdr)
        print("-" * len(hdr))
        for s in zipf_ss:
            prob = ranks ** -s
            zkeys = rng.choice(domain, size=n, p=prob / prob.sum()) \
                .astype(np.uint32)
            zpay = (rng.normal(size=(n, args.width)).astype(np.float32)
                    if args.width else None)
            zest = len(np.unique(zkeys))
            dk = jax.device_put(zkeys, NamedSharding(mesh, P("shard")))
            dp = (None if zpay is None else
                  jax.device_put(zpay, NamedSharding(mesh, P("shard", None))))

            # timing on the device-only program; stats (including the
            # retry ladder, which needs the host readback) via the
            # insort entry point
            def zrun():
                st, dstats = pipeline.aggregate_device(
                    dk, dp, cfg, policy=args.policy, backend=args.backend,
                    output_estimate=zest, mesh=mesh)
                return st.keys, dstats

            t = _harness.time_fn(zrun, iters=args.iters, block_each=True)
            _, stats = pipeline.insort_aggregate_device(
                dk, dp, cfg, policy=args.policy, backend=args.backend,
                output_estimate=zest, mesh=mesh)
            quota = stats.exchange_quota
            fill = stats.exchange_max_fill
            zipf_sweep.append({
                "zipf_s": s, "world": world, "seconds": t,
                "rows_input": n, "rows_shuffled": stats.rows_exchanged,
                "shuffle_ratio": stats.rows_exchanged / n,
                "exchange_quota": quota, "exchange_max_fill": fill,
                "fill_frac": round(fill / quota, 4) if quota else 0.0,
                "exchange_retries": stats.exchange_retries,
                "exchange_footprint_rows":
                    gb.exchange_footprint_rows(world, quota) if quota else 0,
            })
            print(f"{s:>7.2f} {t * 1e3:>9.1f}ms {stats.rows_exchanged:>14} "
                  f"{quota:>7} {fill:>9} "
                  f"{(fill / quota if quota else 0):>6.2f} "
                  f"{stats.exchange_retries:>8}")

    report = {
        "bench": "shard_scaling",
        "backend": args.backend,
        "config": {"n": n, "memory_rows": M, "dup": args.dup,
                   "policy": args.policy, "iters": args.iters,
                   "payload_width": args.width,
                   "note": "fake host devices off-TPU: wall time is "
                           "thread-level parallelism; shuffle accounting "
                           "is the portable signal"},
        "results": results,
        "zipf_sweep": zipf_sweep,
    }
    _harness.write_json_report(report, out=args.out, smoke=args.smoke,
                               default_name="BENCH_shard.json")
    if args.dup > 1 and all(r["rows_shuffled"] < r["rows_input"]
                            for r in results):
        print("local early aggregation kept shuffle volume below input "
              "rows at every world size")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
