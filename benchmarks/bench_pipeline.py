"""End-to-end pipeline benchmark: host-orchestrated loop vs the fused
device-resident program (run generation + wide merge in one compile).

The host reference (:func:`repro.core.insort.insort_aggregate`,
``pipeline="host"``) dispatches one jitted step per input batch and then
**blocks on an occupancy readback** before deciding whether to flush a
run — O(N/B) round trips.  The device pipeline
(:func:`repro.core.pipeline.insort_aggregate_device`) runs the same
policy as a single ``lax.scan`` fused with the wide merge — O(1) host
syncs — so the gap between the two is pure orchestration overhead, and
it widens with the batch count N/B.

Sweeps N/M and the duplicate factor (mean rows per key) for the two
production policies.  Writes ``BENCH_pipeline.json`` (repo root) unless
``--smoke`` (CI sanity run: tiny sizes, no JSON unless --out is given).

Usage:  PYTHONPATH=src python benchmarks/bench_pipeline.py
            [--m 4096] [--ratios 2,8,32] [--dups 1,16] [--iters 3]
            [--policies early_agg,rs] [--backend xla] [--out FILE]
"""
from __future__ import annotations

import argparse

import numpy as np

import _harness
from repro.core import pipeline
from repro.core.insort import insort_aggregate
from repro.core.types import ExecConfig

_RUN_POLICY = {"early_agg": "batch", "rs": "rs"}  # host-loop spelling


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--m", type=int, default=1 << 12, help="memory rows M")
    p.add_argument("--ratios", type=str, default="2,8,32",
                   help="comma-separated N/M ratios to sweep")
    p.add_argument("--dups", type=str, default="1,16",
                   help="duplicate factors (mean rows per key)")
    p.add_argument("--policies", type=str, default="early_agg,rs")
    p.add_argument("--width", type=int, default=1, help="payload columns V")
    p.add_argument("--out", type=str, default=None,
                   help="JSON output path (default: repo-root "
                        "BENCH_pipeline.json; suppressed under --smoke)")
    _harness.add_common_args(p, iters=3)
    args = p.parse_args()
    if args.smoke:
        args.m, args.iters = 1 << 8, 1
        args.ratios, args.dups, args.policies = "2,16", "4", "rs"

    M = args.m
    B = max(16, M // 8)  # N/B = 8 * (N/M)
    cfg = ExecConfig(memory_rows=M, page_rows=max(16, M // 16), fanin=4,
                     batch_rows=B)
    rng = np.random.default_rng(0)
    results = []
    for policy in args.policies.split(","):
        for ratio in (int(r) for r in args.ratios.split(",")):
            for dup in (int(d) for d in args.dups.split(",")):
                n = ratio * M
                domain = max(1, n // dup)
                keys = rng.integers(0, domain, n).astype(np.uint32)
                pay = (rng.normal(size=(n, args.width)).astype(np.float32)
                       if args.width else None)
                # the optimizer estimate both paths plan their §4.3 merge
                # depth from — exact here, so neither path under-merges
                est = len(np.unique(keys))

                def host():
                    st, _ = insort_aggregate(
                        keys, pay, cfg, run_policy=_RUN_POLICY[policy],
                        backend=args.backend, pipeline="host",
                        output_estimate=est,
                    )
                    return st.keys

                def device():
                    st, _ = pipeline.insort_aggregate_device(
                        keys, pay, cfg, policy=policy, backend=args.backend,
                        output_estimate=est,
                    )
                    return st.keys

                # block_each: the host loop's per-batch readbacks ARE the
                # measured quantity — per-call end-to-end latency
                t_host = _harness.time_fn(host, iters=args.iters,
                                          block_each=True)
                t_dev = _harness.time_fn(device, iters=args.iters,
                                         block_each=True)
                row = {
                    "policy": policy, "n": n, "m": M, "b": B,
                    "n_over_m": ratio, "n_over_b": n // B, "dup": dup,
                    "host_s": t_host, "device_s": t_dev,
                    "speedup": t_host / t_dev,
                }
                results.append(row)
                print(f"{policy:10s} N/M={ratio:<3d} N/B={n // B:<4d} "
                      f"dup={dup:<3d} host {t_host * 1e3:8.1f} ms   "
                      f"device {t_dev * 1e3:8.1f} ms   "
                      f"speedup {row['speedup']:.2f}x")

    report = {
        "bench": "pipeline_host_vs_device",
        "backend": args.backend,
        "config": {"memory_rows": M, "batch_rows": B,
                   "page_rows": cfg.page_rows, "iters": args.iters,
                   "payload_width": args.width},
        "results": results,
    }
    _harness.write_json_report(report, out=args.out, smoke=args.smoke,
                               default_name="BENCH_pipeline.json")
    wins = [r for r in results if r["n_over_b"] >= 16]
    if wins and all(r["speedup"] > 1.0 for r in wins):
        print("device pipeline wins at every N/B >= 16")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
