"""Microbenchmark: uint32 vs uint64 keys through the ordered-index engine.

PR 2 widened the engine to a parameterized key dtype so composite keys
(KeySpec) stop competing for 32 bits.  This benchmark measures what that
width costs on the two hot primitives:

* **absorb**   — canonicalize an unsorted batch (argsort + combine);
* **merge**    — merge-absorb a sorted batch into a sorted table (the
  linear merge every engine consumer runs per input batch).

For each key width it reports wall-clock and effective row throughput;
the u64/u32 ratio is the price of the wider key (on XLA: wider compares
plus x64 mode; on Pallas: a second uint32 lane through every kernel).

Usage:  PYTHONPATH=src python benchmarks/bench_keywidth.py [--m 32768]
            [--ratio 8] [--width 2] [--iters 20] [--backend xla]
            [--smoke] [--csv out.csv]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

import _harness
from repro.core import sorted_ops
from repro.core.types import AggState, key_dtype_context, rows_to_state


def _keys(rng, rows: int, dtype) -> np.ndarray:
    if np.dtype(dtype) == np.uint64:
        # spread over > 32 bits so 64-bit comparisons do real work
        hi = rng.integers(0, 1 << 20, rows).astype(np.uint64)
        lo = rng.integers(0, 1 << 20, rows).astype(np.uint64)
        return (hi << np.uint64(24)) | lo
    return rng.integers(0, 1 << 28, rows).astype(np.uint32)


def _sorted_state(rng, rows: int, width: int, dtype) -> AggState:
    pay = None if width == 0 else rng.normal(size=(rows, width)).astype(np.float32)
    return sorted_ops.absorb(rows_to_state(_keys(rng, rows, dtype), pay))


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--m", type=int, default=1 << 15, help="table rows M")
    p.add_argument("--ratio", type=int, default=8, help="table/batch ratio M/B")
    p.add_argument("--width", type=int, default=2, help="payload columns V")
    p.add_argument("--csv", type=str, default=None, help="also write CSV here")
    _harness.add_common_args(p, iters=20)
    args = p.parse_args()
    if args.smoke:
        args.m, args.iters = 1 << 10, 3

    rng = np.random.default_rng(0)
    m, b = args.m, max(1, args.m // args.ratio)
    be = args.backend

    header = (f"{'dtype':>7} {'op':>7} {'rows':>9} {'per-call':>11} "
              f"{'Mrows/s':>9}")
    print(f"backend={be}  M={m}  B={b}  width={args.width}  iters={args.iters}"
          f"{'  [smoke]' if args.smoke else ''}")
    print(header)
    print("-" * len(header))

    rows_out = []
    per_dtype: dict[str, dict[str, float]] = {}
    for dtype in (np.uint32, np.uint64):
        name = np.dtype(dtype).name
        with key_dtype_context(dtype):
            table = _sorted_state(rng, m, args.width, dtype)
            batch = _sorted_state(rng, b, args.width, dtype)
            raw = rows_to_state(
                _keys(rng, m, dtype),
                None if args.width == 0 else
                rng.normal(size=(m, args.width)).astype(np.float32),
            )
            absorb_jit = jax.jit(lambda s: sorted_ops.absorb(s, backend=be))
            merge_jit = jax.jit(lambda t, x: sorted_ops.merge_absorb(
                t, x, backend=be, assume_unique=True))
            t_absorb = _harness.time_fn(absorb_jit, raw, iters=args.iters)
            t_merge = _harness.time_fn(merge_jit, table, batch, iters=args.iters)
        per_dtype[name] = {"absorb": t_absorb, "merge": t_merge}
        for op, t, n in (("absorb", t_absorb, m), ("merge", t_merge, m + b)):
            print(f"{name:>7} {op:>7} {n:>9} {t * 1e3:>9.3f}ms {n / t / 1e6:>9.2f}")
            rows_out.append((name, op, n, t))

    r_a = per_dtype["uint64"]["absorb"] / per_dtype["uint32"]["absorb"]
    r_m = per_dtype["uint64"]["merge"] / per_dtype["uint32"]["merge"]
    print(f"\nu64/u32 cost ratio: absorb {r_a:.2f}x, merge {r_m:.2f}x")

    _harness.write_csv(args.csv, ["dtype", "op", "rows", "seconds"], rows_out)
    _harness.interpret_note(be)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
